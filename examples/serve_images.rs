//! End-to-end serving driver (deliverable (b)/(e) of DESIGN.md):
//! the coordinator serves batched classification requests from concurrent
//! clients through the PJRT runtime, while the FPGA simulator produces the
//! modeled on-device timing/energy ledger for the same workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_images -- \
//!     --requests 256 --clients 8
//! ```
//! Falls back to the simulator backend when artifacts are missing
//! (`--backend sim`).

use fastcaps::config::SystemConfig;
use fastcaps::coordinator::server::{Backend, PjrtBackend, Server, SimBackend};
use fastcaps::data::{generate, Task};
use fastcaps::fpga::{power::PowerModel, resources, DeployedModel};
use fastcaps::util::cli::Args;
use std::path::PathBuf;
use std::time::Duration;

fn main() -> fastcaps::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.get_usize("requests", 128);
    let n_clients = args.get_usize("clients", 4).max(1);
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let use_pjrt =
        args.get_or("backend", "pjrt") == "pjrt" && dir.join("manifest.json").exists();
    let max_wait = Duration::from_millis(args.get_u64("max-wait-ms", 5));

    let server = if use_pjrt {
        let dir2 = dir.clone();
        Server::start(
            move || {
                let rt = fastcaps::runtime::Runtime::open(&dir2)?;
                let weights = dir2.join("weights-mnist.fcw");
                let mut engines = Vec::new();
                for b in rt.batch_buckets("capsnet-mnist-pruned") {
                    engines.push(rt.engine("capsnet-mnist-pruned", b, &weights)?);
                }
                Ok(Box::new(PjrtBackend::new(engines)?) as Box<dyn Backend>)
            },
            max_wait,
        )
    } else {
        println!("(artifacts missing or --backend sim: using simulator backend)");
        Server::start(
            move || {
                Ok(Box::new(SimBackend {
                    model: DeployedModel::synthetic(&SystemConfig::proposed("mnist"), 7),
                }) as Box<dyn Backend>)
            },
            max_wait,
        )
    };

    println!(
        "end-to-end: {n_requests} requests, {n_clients} clients, backend={}",
        if use_pjrt { "pjrt" } else { "sim" }
    );
    let t0 = std::time::Instant::now();
    let mut agreement = 0usize;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let server = &server;
            handles.push(scope.spawn(move || {
                let data = generate(Task::Digits, n_requests / n_clients, 100 + c as u64);
                let mut hits = 0usize;
                for (img, &label) in data.images.into_iter().zip(&data.labels) {
                    if let Ok(resp) = server.classify(img) {
                        if resp.predicted == label {
                            hits += 1;
                        }
                    }
                }
                hits
            }));
        }
        for h in handles {
            agreement += h.join().unwrap();
        }
    });
    let wall = t0.elapsed();
    let m = server.shutdown();

    println!("\n=== serving metrics (host) ===");
    println!("{}", m.summary());
    println!(
        "wall {:.2}s  → {:.1} req/s end-to-end",
        wall.as_secs_f64(),
        m.requests as f64 / wall.as_secs_f64()
    );
    println!(
        "label-agreement {}/{} (random weights — chance ≈ 10%)",
        agreement, m.requests
    );

    // Modeled on-device ledger for the identical workload.
    let cfg = SystemConfig::proposed("mnist");
    let model = DeployedModel::synthetic(&cfg, 7);
    let t = model.estimate_frame();
    let u = resources::estimate(&cfg);
    let pm = PowerModel::default();
    println!("\n=== modeled PYNQ-Z1 ledger (same workload) ===");
    println!(
        "per-frame {:.3} ms  → {:.0} FPS, {:.1} FPJ; {} frames = {:.2} s, {:.1} J",
        t.latency_s() * 1e3,
        t.fps(),
        pm.fpj(t.fps(), &u, false),
        m.requests,
        m.requests as f64 * t.latency_s(),
        m.requests as f64 * t.latency_s() * pm.watts(&u, false),
    );
    Ok(())
}
