//! Loopback integration tests for the network serving front-end: the
//! wire protocol end to end over real TCP connections — happy path
//! (bit-identical to in-process serving), every framing fault getting a
//! typed error frame without poisoning the connection or the server,
//! pipelined ordering under concurrency, graceful drain, and the
//! dead-pool path surfacing as a typed error instead of a hang.
//!
//! All connections here use [`Connection::v1_compat`]: these tests pin
//! the v1 in-order semantics that pre-v2 clients rely on. The v2
//! out-of-order path is covered by `net_hostile.rs`.

use fastcaps::backend::{BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use fastcaps::coordinator::net::{Connection, NetServer};
use fastcaps::coordinator::server::Server;
use fastcaps::coordinator::wire::{self, ErrorCode, ServerFrame, MAGIC, MAX_PAYLOAD, VERSION};
use fastcaps::tensor::Tensor;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

const RECV_TIMEOUT: Duration = Duration::from_secs(10);

fn toy_spec(buckets: Vec<usize>) -> BackendSpec {
    BackendSpec {
        kind: "toy".into(),
        model: "toy".into(),
        input_shape: (1, 4, 4),
        batch_buckets: buckets,
        reports_timing: false,
        max_replicas: None,
        compression: None,
        fingerprint: 0,
        routing: String::new(),
        workers: 1,
        coupling_fingerprint: None,
    }
}

/// Deterministic backend: the lengths one-hot-encode the image mean, so
/// wire and in-process answers are comparable bit for bit.
struct ToyBackend {
    spec: BackendSpec,
    delay: Duration,
}

impl InferenceBackend for ToyBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }
    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(InferOutput::untimed(
            req.images
                .iter()
                .map(|img| {
                    let m = img.sum() / img.len() as f32;
                    let mut l = vec![0.1f32; 10];
                    l[(m * 10.0) as usize % 10] = 0.9;
                    l
                })
                .collect(),
        ))
    }
}

/// A toy server listening on an OS-assigned loopback port.
fn toy_net(delay: Duration, max_wait: Duration, max_queue: usize) -> NetServer {
    let server = Server::builder(move || {
        Ok(Box::new(ToyBackend {
            spec: toy_spec(vec![1, 4]),
            delay,
        }) as Box<dyn InferenceBackend>)
    })
    .max_wait(max_wait)
    .max_queue_depth(max_queue)
    .start();
    NetServer::bind("127.0.0.1:0", server).expect("bind loopback")
}

fn connect(net: &NetServer) -> Connection {
    let c = Connection::v1_compat(net.local_addr()).expect("connect");
    c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    c
}

/// Image whose toy prediction is `k % 10` (mean = k/10 + 0.05).
fn image_for(k: usize) -> Tensor {
    Tensor::full(&[1, 4, 4], (k % 10) as f32 / 10.0 + 0.05)
}

#[test]
fn net_clients_match_in_process_classify_bitwise() {
    let net = toy_net(Duration::ZERO, Duration::from_millis(1), 1024);
    std::thread::scope(|scope| {
        for c in 0..3usize {
            let net = &net;
            scope.spawn(move || {
                let mut client = connect(net);
                for k in 0..8 {
                    let img = image_for(c * 8 + k);
                    let direct = net.server().classify(img.clone()).unwrap();
                    let wired = client.classify(&img).unwrap();
                    // Bit-identical lengths: the wire must not perturb
                    // the classification result.
                    assert_eq!(wired.lengths.len(), direct.lengths.len());
                    for (a, b) in wired.lengths.iter().zip(&direct.lengths) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                    assert_eq!(wired.predicted as usize, direct.predicted);
                    assert_eq!(wired.predicted as usize, (c * 8 + k) % 10);
                }
            });
        }
    });
    let m = net.shutdown();
    // 24 wire + 24 in-process requests; per-connection counters folded.
    assert_eq!(m.requests, 48);
    assert_eq!(m.wire_requests, 24);
    assert_eq!(m.wire_errors, 0);
    assert_eq!(m.connections_opened, 3);
    assert_eq!(m.connections_closed, 3);
}

/// Raw-socket helper: read one server frame with a timeout.
fn read_frame(stream: &TcpStream) -> Result<ServerFrame, wire::Fault> {
    stream.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    let mut r = BufReader::new(stream);
    wire::read_server_frame(&mut r)
}

#[test]
fn malformed_magic_gets_typed_error_and_server_survives() {
    let net = toy_net(Duration::ZERO, Duration::from_millis(1), 1024);
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.write_all(b"XXXXgarbage-not-a-frame").unwrap();
    raw.flush().unwrap();
    match read_frame(&raw).unwrap() {
        ServerFrame::Error { code, message } => {
            assert_eq!(code, ErrorCode::Malformed);
            assert!(message.contains("magic"), "{message}");
        }
        other => panic!("expected error frame, got {other:?}"),
    }
    // The stream cannot be resynchronized: the server closes it.
    assert!(matches!(read_frame(&raw), Err(wire::Fault::Closed)));
    // But the *server* is not poisoned: a fresh connection serves.
    let mut client = connect(&net);
    assert_eq!(client.classify(&image_for(3)).unwrap().predicted, 3);
    let m = net.shutdown();
    assert_eq!(m.wire_errors, 1);
}

#[test]
fn truncated_frame_does_not_poison_server() {
    let net = toy_net(Duration::ZERO, Duration::from_millis(1), 1024);
    {
        let mut raw = TcpStream::connect(net.local_addr()).unwrap();
        // Valid header promising a 64-byte image, then die mid-payload.
        let mut h = Vec::new();
        h.extend_from_slice(&MAGIC);
        h.push(VERSION);
        h.push(0x01); // Classify
        h.extend_from_slice(&64u32.to_le_bytes());
        h.extend_from_slice(&[0u8; 10]);
        raw.write_all(&h).unwrap();
        raw.flush().unwrap();
        // Drop: the server sees a truncated stream and just closes.
    }
    let mut client = connect(&net);
    assert_eq!(client.classify(&image_for(7)).unwrap().predicted, 7);
    let m = net.shutdown();
    assert_eq!(m.requests, 1);
}

#[test]
fn oversized_length_prefix_gets_typed_error() {
    let net = toy_net(Duration::ZERO, Duration::from_millis(1), 1024);
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    let mut h = Vec::new();
    h.extend_from_slice(&MAGIC);
    h.push(VERSION);
    h.push(0x01); // Classify
    h.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
    raw.write_all(&h).unwrap();
    raw.flush().unwrap();
    match read_frame(&raw).unwrap() {
        ServerFrame::Error { code, message } => {
            assert_eq!(code, ErrorCode::Oversized);
            assert!(message.contains("exceeds"), "{message}");
        }
        other => panic!("expected error frame, got {other:?}"),
    }
    assert!(matches!(read_frame(&raw), Err(wire::Fault::Closed)));
    let mut client = connect(&net);
    assert_eq!(client.classify(&image_for(1)).unwrap().predicted, 1);
    net.shutdown();
}

#[test]
fn wrong_input_shape_typed_error_connection_survives() {
    let net = toy_net(Duration::ZERO, Duration::from_millis(1), 1024);
    let mut client = connect(&net);
    // 2×2 image against a (1,4,4) spec: 16 bytes instead of 64.
    match client.classify(&Tensor::full(&[1, 2, 2], 0.5)) {
        Err(e) => {
            assert_eq!(e.code, ErrorCode::InvalidRequest);
            let message = &e.message;
            assert!(message.contains("64"), "should name expected bytes: {message}");
            assert!(message.contains("(1, 4, 4)"), "should name the spec shape: {message}");
        }
        Ok(resp) => panic!("expected InvalidRequest rejection, got {resp:?}"),
    }
    // Same connection still serves a well-formed request afterwards.
    assert_eq!(client.classify(&image_for(5)).unwrap().predicted, 5);
    let m = net.shutdown();
    assert_eq!(m.wire_errors, 1);
    assert_eq!(m.requests, 1); // the malformed one never hit the pool
}

#[test]
fn concurrent_pipelined_clients_get_responses_in_request_order() {
    let net = toy_net(Duration::ZERO, Duration::from_millis(2), 1024);
    std::thread::scope(|scope| {
        for c in 0..4usize {
            let net = &net;
            scope.spawn(move || {
                let mut client = connect(net);
                let n = 16;
                let mut tags = Vec::with_capacity(n);
                for k in 0..n {
                    tags.push(client.submit(&image_for(c + 2 * k)).unwrap());
                }
                for k in 0..n {
                    let (tag, resp) = client.recv().unwrap();
                    // v1 compat: responses arrive strictly in request
                    // order, so the synthesized tags match FIFO order.
                    assert_eq!(tag, tags[k], "client {c} got response {k} out of order");
                    assert_eq!(
                        resp.predicted as usize,
                        (c + 2 * k) % 10,
                        "client {c} got response {k} out of order"
                    );
                }
            });
        }
    });
    let m = net.shutdown();
    assert_eq!(m.requests, 64);
    assert_eq!(m.wire_requests, 64);
}

#[test]
fn graceful_drain_finishes_in_flight_requests() {
    let net = toy_net(Duration::from_millis(5), Duration::from_millis(1), 1024);
    let mut client = connect(&net);
    let n = 6;
    for k in 0..n {
        client.submit(&image_for(k)).unwrap();
    }
    // Let the IO shard pull everything off the socket so the requests
    // count as in-flight when the drain cuts the read side.
    std::thread::sleep(Duration::from_millis(100));
    let collector = std::thread::spawn(move || {
        let mut got = 0usize;
        for k in 0..n {
            let (_, resp) = client.recv().expect("in-flight response lost in drain");
            assert_eq!(resp.predicted as usize, k % 10);
            got += 1;
        }
        got
    });
    let m = net.shutdown();
    assert_eq!(collector.join().unwrap(), n);
    assert_eq!(m.requests, n as u64);
    assert_eq!(m.connections_closed, m.connections_opened);
}

#[test]
fn wire_shutdown_frame_triggers_graceful_drain() {
    let net = toy_net(Duration::ZERO, Duration::from_millis(1), 1024);
    assert!(!net.shutdown_requested());
    let mut client = connect(&net);
    assert_eq!(client.classify(&image_for(4)).unwrap().predicted, 4);
    client.shutdown_server().expect("shutdown ack");
    net.wait_shutdown_requested(); // must return, not block
    assert!(net.shutdown_requested());
    let m = net.shutdown();
    assert_eq!(m.requests, 1);
}

#[test]
fn queue_full_surfaces_as_typed_error_over_wire() {
    // One slow replica, queue depth 1: a pipelined burst must overflow
    // admission, and the overflow must come back as typed QueueFull
    // frames — the connection (and server) keep working.
    let net = toy_net(Duration::from_millis(30), Duration::from_micros(100), 1);
    let mut client = connect(&net);
    let n = 12;
    for k in 0..n {
        client.submit(&image_for(k)).unwrap();
    }
    let mut ok = 0;
    let mut rejected = 0;
    for _ in 0..n {
        match client.recv() {
            Ok(_) => ok += 1,
            Err(e) if e.code == ErrorCode::QueueFull => rejected += 1,
            Err(other) => panic!("unexpected transport error: {other}"),
        }
    }
    assert_eq!(ok + rejected, n);
    assert!(rejected >= 1, "burst of {n} never overflowed depth-1 queue");
    assert!(ok >= 1, "everything was rejected");
    // Connection survives the rejections: an eventual retry succeeds.
    let mut served = false;
    for _ in 0..100 {
        match client.classify(&image_for(2)) {
            Ok(resp) => {
                assert_eq!(resp.predicted, 2);
                served = true;
                break;
            }
            Err(e) if e.code == ErrorCode::QueueFull => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(other) => panic!("unexpected error: {other}"),
        }
    }
    assert!(served, "connection never recovered after QueueFull");
    let m = net.shutdown();
    // The retry loop may add further rejections beyond the burst's.
    assert!(m.rejected as usize >= rejected, "{} < {rejected}", m.rejected);
}

#[test]
fn dead_pool_is_typed_error_over_wire_not_a_hang() {
    struct PanicBackend(BackendSpec);
    impl InferenceBackend for PanicBackend {
        fn spec(&self) -> &BackendSpec {
            &self.0
        }
        fn infer(&mut self, _req: &InferRequest) -> Result<InferOutput, BackendError> {
            panic!("backend bug");
        }
    }
    let server = Server::builder(|| {
        Ok(Box::new(PanicBackend(toy_spec(vec![1]))) as Box<dyn InferenceBackend>)
    })
    .max_wait(Duration::from_millis(1))
    .start();
    let net = NetServer::bind("127.0.0.1:0", server).unwrap();
    let mut client = connect(&net);
    // First request rides the panicking replica: the dropped response
    // must come back as a typed Unavailable frame within the timeout.
    match client.classify(&image_for(0)) {
        Err(e) => assert_eq!(e.code, ErrorCode::Unavailable),
        Ok(resp) => panic!("expected Unavailable rejection, got {resp:?}"),
    }
    // Later requests are rejected at admission (dead pool), same type.
    match client.classify(&image_for(1)) {
        Err(e) => {
            assert_eq!(e.code, ErrorCode::Unavailable);
            assert!(e.message.contains("died"), "{}", e.message);
        }
        Ok(resp) => panic!("expected Unavailable rejection, got {resp:?}"),
    }
    let m = net.shutdown();
    assert_eq!(m.replicas_died, 1);
    assert_eq!(m.wire_errors, 2, "both rejections must be counted");
}

#[test]
fn listener_refuses_backend_that_never_started() {
    let server =
        Server::builder(|| Err(BackendError::Init("backend init failed".into()))).start();
    match NetServer::bind("127.0.0.1:0", server) {
        Err(BackendError::Unavailable(m)) => assert!(m.contains("never started"), "{m}"),
        other => panic!("expected Unavailable, got {other:?}"),
    }
}
