//! Golden pins for the sparse-compiled path: `CompiledCapsNet` logits
//! vs the masked-dense `CapsNet` on fixed seeds, for both dataset
//! shapes and at 100% mask density. Exact f32 equality, not tolerance —
//! the compiled path's contract is bit-exactness (the golden reference
//! is computed, not stored: platform libm differences in `exp` make
//! literal logit files non-portable, but the two paths must agree
//! bit-for-bit on any one platform).

use fastcaps::capsnet::{CapsNet, CompiledCapsNet};
use fastcaps::config::{CapsNetConfig, SparsityPlan};
use fastcaps::data::{generate, Task};
use fastcaps::pruning::NetworkMasks;
use fastcaps::util::rng::Rng;

/// Compiled logits == masked-dense logits at the paper's intra-channel
/// survivor counts, on the compacted MNIST / F-MNIST architectures.
#[test]
fn compiled_logits_pin_masked_dense_at_paper_counts() {
    let cases = [
        (
            CapsNetConfig::paper_pruned_mnist(),
            SparsityPlan::paper_mnist(),
            Task::Digits,
            101u64,
        ),
        (
            CapsNetConfig::paper_pruned_fmnist(),
            SparsityPlan::paper_fmnist(),
            Task::Garments,
            102u64,
        ),
    ];
    for (cfg, plan, task, seed) in cases {
        let mut rng = Rng::new(seed);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        // Intra-channel kernel sparsity of the deployed model: e.g. 423
        // of 3584 PrimaryCaps kernels on MNIST — what the Index Control
        // Module skips on-chip and the compiled path skips in software.
        let masks = NetworkMasks::from_plan(&net.weights, &cfg, &plan);
        assert_eq!(masks.pc.survived(), plan.pc_kernels, "{}", cfg.name);

        let dense = net.masked(&masks);
        let compiled = CompiledCapsNet::compile(&net, &masks).unwrap();
        let data = generate(task, 2, seed);
        let want = dense.forward_batch(&data.images).unwrap();
        let got = compiled.forward_batch(&data.images).unwrap();
        for (frame, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.class_lengths(),
                w.class_lengths(),
                "{} frame {frame}: compiled logits != masked-dense logits",
                cfg.name
            );
            assert_eq!(g.routing.v, w.routing.v, "{} frame {frame}", cfg.name);
            assert_eq!(g.primary_caps, w.primary_caps, "{} frame {frame}", cfg.name);
        }
    }
}

/// At 100% mask density the compiled model is the dense model: packing
/// every kernel must change nothing.
#[test]
fn compiled_at_full_density_equals_dense() {
    let cfg = CapsNetConfig::paper_pruned_mnist();
    let mut rng = Rng::new(103);
    let net = CapsNet::random(cfg.clone(), &mut rng);
    let masks = NetworkMasks::dense(&cfg);
    let compiled = CompiledCapsNet::compile(&net, &masks).unwrap();
    assert_eq!(
        compiled.stats().survived_kernels,
        compiled.stats().total_kernels
    );
    let img = generate(Task::Digits, 1, 104).images.remove(0);
    let want = net.forward(&img).unwrap();
    let got = compiled.forward(&img).unwrap();
    assert_eq!(got.class_lengths(), want.class_lengths());
    assert_eq!(got.routing.v, want.routing.v);
    assert_eq!(got.pc_conv.data, want.pc_conv.data);
}

/// The compiled model serves through the coordinator unchanged: an
/// `oracle-sparse` pool's responses equal direct compiled predictions.
#[test]
fn coordinator_serves_compiled_model() {
    use fastcaps::backend::{InferenceBackend, SparseOracleBackend};
    use fastcaps::coordinator::server::Server;

    let cfg = CapsNetConfig::tiny();
    let mut rng = Rng::new(105);
    let net = CapsNet::random(cfg.clone(), &mut rng);
    let masks = NetworkMasks::lakp(&net.weights, &cfg, 12, 100);
    let compiled = CompiledCapsNet::compile(&net, &masks).unwrap();
    let direct = compiled.clone();
    let server = Server::builder(move || {
        Ok(Box::new(SparseOracleBackend::new(compiled.clone())) as Box<dyn InferenceBackend>)
    })
    .replicas(2)
    .max_wait(std::time::Duration::from_millis(2))
    .start();
    let spec = server.spec().unwrap().clone();
    assert_eq!(spec.kind, "oracle-sparse");
    let compression = spec.compression.expect("sparse spec reports compression");
    assert_eq!(compression.survived_kernels, 112);

    let mut rng = Rng::new(106);
    for _ in 0..5 {
        let img = fastcaps::tensor::Tensor::randn(&[1, 20, 20], 0.4, &mut rng)
            .map(|x| x.abs().min(1.0));
        let want = direct.predict(&img).unwrap();
        let resp = server.classify(img).unwrap();
        assert_eq!(resp.predicted, want, "served vs direct compiled prediction");
    }
    let m = server.shutdown();
    assert_eq!(m.requests, 5);
}
