//! Steady-state allocation regression tests.
//!
//! The serving hot path is built around reusable buffers: the event
//! loop borrows classify payloads straight out of the connection read
//! buffer (no per-frame copy), `wire::decode_classify_into` reuses a
//! caller-owned f32 buffer, and the Q4.12 routing stage runs entirely
//! inside a long-lived [`RoutingScratch`]. This test binary installs a
//! counting global allocator and pins those properties: once warmed
//! up, the wire scan/decode path performs **zero** heap allocations per
//! frame, and a routing pass performs none beyond its two output
//! clones.
//!
//! The counting allocator lives here (and only here) as the
//! `#[global_allocator]` — the library never installs it.

use fastcaps::coordinator::wire;
use fastcaps::fixed::Q12;
use fastcaps::routing::fixed::{RoutingScratch, SoftmaxMode};
use fastcaps::testing::CountingAllocator;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator::new();

/// Allocation calls observed while running `f`.
fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = ALLOC.allocations();
    f();
    ALLOC.allocations() - before
}

#[test]
fn wire_scan_and_decode_are_allocation_free_at_steady_state() {
    // One 28×28 v2 classify frame, built before measurement.
    let image: Vec<f32> = (0..28 * 28).map(|i| (i % 7) as f32 / 7.0).collect();
    let frame = wire::encode_classify(wire::V2, 41, &image);

    let mut rbuf: Vec<u8> = Vec::with_capacity(frame.len() * 2);
    let mut words: Vec<f32> = Vec::new();

    // Warm-up: grows rbuf/words to their steady-state capacity.
    rbuf.extend_from_slice(&frame);
    let f = wire::scan_frame(&rbuf).unwrap().expect("whole frame");
    let (_tag, bytes) =
        wire::decode_classify_v2(&rbuf[wire::HEADER_LEN..f.total_len]).unwrap();
    wire::decode_classify_into(bytes, &mut words).unwrap();
    rbuf.drain(..f.total_len);
    assert_eq!(words.len(), image.len());

    // Steady state: scan → split → decode → drain must not touch the
    // heap at all.
    let frames = 100;
    let delta = allocs_during(|| {
        for _ in 0..frames {
            rbuf.extend_from_slice(&frame);
            let f = wire::scan_frame(&rbuf).unwrap().expect("whole frame");
            let (tag, bytes) =
                wire::decode_classify_v2(&rbuf[wire::HEADER_LEN..f.total_len]).unwrap();
            assert_eq!(tag, 41);
            wire::decode_classify_into(bytes, &mut words).unwrap();
            rbuf.drain(..f.total_len);
            assert_eq!(words.len(), image.len());
        }
    });
    assert_eq!(
        delta, 0,
        "wire scan/decode allocated {delta} times over {frames} steady-state frames"
    );
}

#[test]
fn routing_scratch_reuse_is_allocation_free_at_steady_state() {
    let (n_in, n_out, d_out) = (72, 10, 16);
    let mut scratch = RoutingScratch::new();

    // Warm-up sizes every internal buffer.
    scratch.prepare(n_in, n_out, d_out);
    fill_u_hat(&mut scratch, n_in * n_out * d_out);
    let _ = scratch.run(3, SoftmaxMode::Taylor);

    // Steady state: prepare + û refill + a full 3-iteration routing pass.
    // The only permitted allocations are the two output clones
    // (`RoutingOutputQ12 { v, coupling, .. }`) the caller receives.
    let frames = 50;
    let delta = allocs_during(|| {
        for _ in 0..frames {
            scratch.prepare(n_in, n_out, d_out);
            fill_u_hat(&mut scratch, n_in * n_out * d_out);
            let out = scratch.run(3, SoftmaxMode::Taylor);
            assert_eq!(out.v.len(), n_out * d_out);
        }
    });
    assert!(
        delta <= 2 * frames,
        "routing pass allocated {delta} times over {frames} frames \
         (budget: 2 output clones per frame)"
    );
}

fn fill_u_hat(scratch: &mut RoutingScratch, n: usize) {
    let u_hat = scratch.u_hat_mut();
    assert_eq!(u_hat.len(), n);
    for (i, u) in u_hat.iter_mut().enumerate() {
        *u = Q12::from_f32(((i % 31) as f32 - 15.0) / 16.0);
    }
}
