//! Cross-layer integration tests: fp32 reference ↔ fixed-point simulator ↔
//! PJRT runtime ↔ serving coordinator. PJRT cases skip gracefully when
//! `artifacts/` is absent (run `make artifacts` to enable them).

use fastcaps::capsnet::CapsNet;
use fastcaps::config::{CapsNetConfig, SparsityPlan, SystemConfig};
use fastcaps::data::{generate, Task};
use fastcaps::fpga::DeployedModel;
use fastcaps::pruning::KernelMask;
use fastcaps::util::rng::Rng;
use std::path::Path;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        None
    }
}

/// The quantized accelerator datapath must agree with the fp32 reference
/// model on most predictions (16-bit quantization, §IV-B: "did not lead
/// to a reduction in the accuracy").
#[test]
fn simulator_agrees_with_fp32_reference() {
    let cfg = CapsNetConfig::paper_pruned_mnist();
    let mut rng = Rng::new(33);
    let net = CapsNet::random(cfg.clone(), &mut rng);

    // Deploy the same weights densely (no pruning masks) on the simulator.
    let sys = SystemConfig {
        sparsity: SparsityPlan::dense(&cfg),
        model: cfg.clone(),
        budget: fastcaps::config::FpgaBudget::pynq_z1(),
        options: fastcaps::config::AcceleratorOptions::optimized(),
    };
    let conv1_mask = KernelMask::all_alive(cfg.conv1_ch, cfg.input.0);
    let pc_mask = KernelMask::all_alive(cfg.pc_channels(), cfg.conv1_ch);
    let deployed =
        DeployedModel::new(sys, &net.weights, &conv1_mask, &pc_mask).unwrap();

    // With random weights the class margins are ~1e-3 (untrained), so
    // argmax is noise; the correctness criterion is that the quantized
    // datapath reproduces the capsule *lengths*. (On trained weights the
    // margins are ~0.5 and predictions match — the paper's "no accuracy
    // drop"; see python/tests and the trained-weight flow.)
    let data = generate(Task::Digits, 8, 44);
    for img in &data.images {
        let fp32 = net.forward(img).unwrap().class_lengths();
        let (_, q12, _) = deployed.run_frame(img).unwrap();
        for (a, b) in fp32.iter().zip(&q12) {
            assert!(
                (a - b).abs() < 0.02,
                "16-bit datapath off: {a} vs {b} (full: {fp32:?} vs {q12:?})"
            );
        }
    }
}

/// PJRT engine (JAX-lowered HLO) vs the rust fp32 reference: same weights,
/// same image → same capsule lengths within fp tolerance. This pins all
/// three implementations of the model to each other.
#[test]
fn pjrt_matches_rust_reference() {
    let Some(dir) = artifacts() else { return };
    let rt = match fastcaps::runtime::Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: {e:#}"); // built without the pjrt feature
            return;
        }
    };
    let weights_path = dir.join("weights-mnist.fcw");
    let engine = rt.engine("capsnet-mnist-pruned", 1, &weights_path).unwrap();

    let cfg = CapsNetConfig::paper_pruned_mnist();
    let weights = fastcaps::capsnet::weights::Weights::load(&weights_path).unwrap();
    let net = CapsNet {
        config: cfg,
        weights,
    };

    let data = generate(Task::Digits, 3, 55);
    for img in &data.images {
        let pjrt = engine.run_batch(std::slice::from_ref(img)).unwrap();
        let rust = net.forward(img).unwrap().class_lengths();
        for (a, b) in pjrt[0].iter().zip(&rust) {
            assert!(
                (a - b).abs() < 5e-3,
                "pjrt {a} vs rust {b} (lengths {:?} vs {:?})",
                pjrt[0],
                rust
            );
        }
    }
}

/// Batch-8 engine must agree with batch-1 engine per image (padding path).
#[test]
fn pjrt_batch_buckets_consistent() {
    let Some(dir) = artifacts() else { return };
    let rt = match fastcaps::runtime::Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: {e:#}"); // built without the pjrt feature
            return;
        }
    };
    let weights = dir.join("weights-mnist.fcw");
    let e1 = rt.engine("capsnet-mnist-pruned", 1, &weights).unwrap();
    let e8 = rt.engine("capsnet-mnist-pruned", 8, &weights).unwrap();

    let data = generate(Task::Digits, 8, 66);
    let batched = e8.run_batch(&data.images).unwrap();
    for (i, img) in data.images.iter().enumerate() {
        let single = e1.run_batch(std::slice::from_ref(img)).unwrap();
        for (a, b) in batched[i].iter().zip(&single[0]) {
            assert!((a - b).abs() < 1e-4, "batch vs single mismatch at {i}");
        }
    }
}

/// Serving through the coordinator with the simulator backend (built via
/// the registry): results identical to calling the simulator directly.
#[test]
fn coordinator_serves_simulator_backend() {
    use fastcaps::backend::{BackendConfig, BackendRegistry};
    use fastcaps::coordinator::server::Server;
    use std::sync::Arc;

    let cfg = SystemConfig::proposed("mnist");
    let direct = DeployedModel::synthetic(&cfg, 7);
    let registry = Arc::new(BackendRegistry::with_defaults());
    let bcfg = BackendConfig::default(); // sim: proposed mnist, seed 7
    let server = Server::builder(move || registry.build("sim", &bcfg))
        .max_wait(std::time::Duration::from_millis(2))
        .start();
    assert_eq!(server.spec().unwrap().kind, "sim");
    let data = generate(Task::Digits, 6, 77);
    for img in &data.images {
        let (want, _, _) = direct.run_frame(img).unwrap();
        let resp = server.classify(img.clone()).unwrap();
        assert_eq!(resp.predicted, want, "served vs direct prediction");
    }
    let m = server.shutdown();
    assert_eq!(m.requests, 6);
}

/// Serving through the coordinator with the fp32 oracle backend — the
/// reference model is servable through the same unified API.
#[test]
fn coordinator_serves_oracle_backend() {
    use fastcaps::backend::OracleBackend;
    use fastcaps::capsnet::CapsNet;
    use fastcaps::coordinator::server::Server;

    let cfg = CapsNetConfig::tiny();
    let mut rng = Rng::new(3);
    let direct = CapsNet::random(cfg.clone(), &mut rng);
    let net = direct.clone();
    let server = Server::builder(move || {
        Ok(Box::new(OracleBackend::new(net.clone()))
            as Box<dyn fastcaps::backend::InferenceBackend>)
    })
        .replicas(2)
        .max_wait(std::time::Duration::from_millis(2))
        .start();
    assert_eq!(server.spec().unwrap().kind, "oracle");

    let mut rng = Rng::new(4);
    for _ in 0..5 {
        let img = fastcaps::tensor::Tensor::randn(&[1, 20, 20], 0.4, &mut rng)
            .map(|x| x.abs().min(1.0));
        let want = direct.forward(&img).unwrap().predicted_class();
        let resp = server.classify(img).unwrap();
        assert_eq!(resp.predicted, want, "served vs direct oracle prediction");
        assert_eq!(resp.lengths.len(), 10);
    }
    let m = server.shutdown();
    assert_eq!(m.requests, 5);
}

/// End-to-end through PJRT behind the coordinator, concurrent clients.
/// Skips when artifacts are missing or the `pjrt` feature is not built.
#[test]
fn coordinator_serves_pjrt_backend() {
    use fastcaps::backend::{BackendConfig, BackendError, BackendRegistry};
    use fastcaps::coordinator::server::Server;
    use std::sync::Arc;

    let Some(dir) = artifacts() else { return };
    let registry = Arc::new(BackendRegistry::with_defaults());
    let bcfg = BackendConfig {
        artifacts: dir.to_path_buf(),
        ..BackendConfig::default()
    };
    let server = Server::builder(move || registry.build("pjrt", &bcfg))
        .replicas(4) // must be clamped to the backend's max_replicas = 1
        .max_wait(std::time::Duration::from_millis(4))
        .start();
    match server.init_error() {
        Some(BackendError::Unsupported(m)) => {
            eprintln!("skipping: {m}");
            return;
        }
        Some(other) => panic!("pjrt backend failed: {other}"),
        None => {}
    }
    assert_eq!(server.spec().unwrap().max_replicas, Some(1));
    assert_eq!(server.live_replicas(), 1, "pjrt must stay single-replica");
    std::thread::scope(|scope| {
        for c in 0..3 {
            let server = &server;
            scope.spawn(move || {
                let data = generate(Task::Digits, 8, 200 + c);
                for img in data.images {
                    let resp = server.classify(img).unwrap();
                    assert_eq!(resp.lengths.len(), 10);
                }
            });
        }
    });
    let m = server.shutdown();
    assert_eq!(m.requests, 24);
    assert!(m.batches <= 24);
}

/// `.fcw` interchange: weights written by Python load into the rust model
/// and validate against the pruned architecture.
#[test]
fn python_weights_load_and_validate() {
    let Some(dir) = artifacts() else { return };
    let w = fastcaps::capsnet::weights::Weights::load(&dir.join("weights-mnist.fcw")).unwrap();
    w.validate(&CapsNetConfig::paper_pruned_mnist()).unwrap();
    let wf =
        fastcaps::capsnet::weights::Weights::load(&dir.join("weights-fmnist.fcw")).unwrap();
    wf.validate(&CapsNetConfig::paper_pruned_fmnist()).unwrap();
    // Quantization to 16-bit stays within format resolution.
    let (_, worst) = w.quantize16::<12>();
    assert!(worst <= 0.5 / 4096.0 + 1e-6);
}

/// Pruning → deployment flow: prune random full-size weights with LAKP,
/// compact nothing (keep masks), deploy, and check the simulator skips
/// the pruned work.
#[test]
fn lakp_prune_then_deploy_cuts_cycles() {
    use fastcaps::pruning::{lakp, AdjacencyNorms};

    let cfg = CapsNetConfig::paper_full("capsnet-mnist");
    let mut rng = Rng::new(91);
    let weights = fastcaps::capsnet::weights::Weights::random(&cfg, &mut rng);
    let adj = AdjacencyNorms {
        prev: AdjacencyNorms::prev_from_conv(&weights.conv1_w),
        next: AdjacencyNorms::next_from_digitcaps(&weights.w_ij, cfg.pc_types, cfg.pc_dim),
    };
    let pruned = lakp::prune_layer(&weights.pc_w, &adj, 0.95);
    let conv1_mask = KernelMask::all_alive(cfg.conv1_ch, cfg.input.0);
    let dense_pc = KernelMask::all_alive(cfg.pc_channels(), cfg.conv1_ch);

    let mk = |pc_mask: &KernelMask| {
        let sys = SystemConfig {
            sparsity: SparsityPlan {
                conv1_kernels: cfg.conv1_ch,
                pc_kernels: pc_mask.survived(),
                conv1_channels: cfg.conv1_ch,
                pc_types: fastcaps::pruning::surviving_capsule_types(pc_mask, cfg.pc_dim),
            },
            model: cfg.clone(),
            budget: fastcaps::config::FpgaBudget::pynq_z1(),
            options: fastcaps::config::AcceleratorOptions::optimized(),
        };
        DeployedModel::new(sys, &weights, &conv1_mask, pc_mask).unwrap()
    };
    let dense_cycles = mk(&dense_pc).estimate_frame().total_cycles();
    let pruned_cycles = mk(&pruned.mask).estimate_frame().total_cycles();
    assert!(
        (pruned_cycles as f64) < 0.4 * dense_cycles as f64,
        "pruning 95% of PC kernels should cut frame cycles: {pruned_cycles} vs {dense_cycles}"
    );
}
