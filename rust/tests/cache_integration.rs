//! Redeploy isolation for the content-addressed inference cache.
//!
//! The prune→compile→serve story: a model is pruned, compiled, served;
//! then re-pruned at different survivor counts and served again —
//! *reusing the same cache store* (the allocation survives the
//! redeploy, `Server::cache_store` → `ServerBuilder::cache_store`).
//! Because every cache key digests the deployment fingerprint (backend
//! kind + model name + deployed weight/mask bits), the second
//! deployment must never see the first one's responses: zero stale
//! hits, by construction rather than by invalidation.

use fastcaps::backend::{InferenceBackend, SparseOracleBackend};
use fastcaps::cache::{CacheConfig, CacheStore};
use fastcaps::capsnet::compiled::CompiledCapsNet;
use fastcaps::capsnet::CapsNet;
use fastcaps::config::CapsNetConfig;
use fastcaps::coordinator::server::Server;
use fastcaps::pruning::NetworkMasks;
use fastcaps::tensor::Tensor;
use fastcaps::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Serve the tiny architecture pruned at the given survivor counts.
fn deploy(keep_conv1: usize, keep_pc: usize, store: Arc<CacheStore>) -> Server {
    let cfg = CapsNetConfig::tiny();
    let mut rng = Rng::new(11);
    let net = CapsNet::random(cfg.clone(), &mut rng);
    let masks = NetworkMasks::lakp(&net.weights, &cfg, keep_conv1, keep_pc);
    let compiled = CompiledCapsNet::compile(&net, &masks).expect("compile");
    Server::builder(move || {
        Ok(Box::new(SparseOracleBackend::new(compiled.clone())) as Box<dyn InferenceBackend>)
    })
    .max_wait(Duration::from_millis(1))
    .cache_store(store)
    .start()
}

/// Serve the same compiled deployment as [`deploy(12, 128, …)`], but in
/// accumulated-coefficients routing mode (coupling baked from a small
/// calibration set through the compiled model's own numerics).
fn deploy_accumulated(store: Arc<CacheStore>) -> Server {
    let cfg = CapsNetConfig::tiny();
    let mut rng = Rng::new(11);
    let net = CapsNet::random(cfg.clone(), &mut rng);
    let masks = NetworkMasks::lakp(&net.weights, &cfg, 12, 128);
    let mut compiled = CompiledCapsNet::compile(&net, &masks).expect("compile");
    let calib: Vec<Tensor> = (0..4).map(|i| image(&cfg, 500 + i)).collect();
    let coupling = compiled.accumulate_coupling(&calib).expect("accumulate");
    compiled.bake_accumulated(coupling).expect("bake coupling");
    Server::builder(move || {
        Ok(Box::new(SparseOracleBackend::new(compiled.clone())) as Box<dyn InferenceBackend>)
    })
    .max_wait(Duration::from_millis(1))
    .cache_store(store)
    .start()
}

fn image(cfg: &CapsNetConfig, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let (c, h, w) = cfg.input;
    let mut t = Tensor::zeros(&[c, h, w]);
    for x in t.data.iter_mut() {
        *x = rng.f32();
    }
    t
}

#[test]
fn redeploy_with_changed_masks_never_serves_stale_hits() {
    let cfg = CapsNetConfig::tiny();
    let store = Arc::new(CacheStore::new(
        CacheConfig::default().entries,
        CacheConfig::default().shards,
    ));
    let frames: Vec<Tensor> = (0..4).map(|i| image(&cfg, 100 + i)).collect();

    // Deployment v1: fill the cache, then prove it hits.
    let v1 = deploy(12, 128, store.clone());
    let fp1 = v1.spec().expect("v1 init").fingerprint;
    let first: Vec<_> = frames
        .iter()
        .map(|f| v1.classify(f.clone()).expect("v1 classify"))
        .collect();
    for (f, want) in frames.iter().zip(&first) {
        let again = v1.classify(f.clone()).expect("v1 re-classify");
        assert_eq!(
            again.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "v1 cache hit must be bit-identical"
        );
    }
    let m1 = v1.shutdown();
    assert_eq!(m1.cache_hits, 4, "second pass must be all hits");
    assert_eq!(m1.cache_misses, 4);
    assert_eq!(m1.cache_stale, 0);
    assert!(!store.is_empty(), "v1 left its responses in the store");

    // Deployment v2: same weights, different survivor masks, SAME
    // store. Different masks ⇒ different fingerprint ⇒ different keys:
    // every request misses and runs v2's own (different) model.
    let v2 = deploy(10, 100, store.clone());
    let fp2 = v2.spec().expect("v2 init").fingerprint;
    assert_ne!(fp1, fp2, "changed masks must change the fingerprint");
    let second: Vec<_> = frames
        .iter()
        .map(|f| v2.classify(f.clone()).expect("v2 classify"))
        .collect();
    assert!(
        first
            .iter()
            .zip(&second)
            .any(|(a, b)| a.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                != b.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>()),
        "different survivor masks should change at least one response \
         (otherwise a stale hit would be unobservable)"
    );
    let m2 = v2.shutdown();
    assert_eq!(
        m2.cache_hits, 0,
        "v2 served a response cached by v1 — stale hit across a redeploy"
    );
    assert_eq!(m2.cache_misses, 4);
    assert_eq!(m2.cache_stale, 0, "fingerprint is in the key; stale is impossible");

    // Deployment v3 = v1's masks again: identical weights + masks
    // rebuild the identical fingerprint, so v1's entries (still in the
    // shared store) hit again — and bit-identically.
    let v3 = deploy(12, 128, store.clone());
    assert_eq!(v3.spec().expect("v3 init").fingerprint, fp1);
    for (f, want) in frames.iter().zip(&first) {
        let got = v3.classify(f.clone()).expect("v3 classify");
        assert_eq!(
            got.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "identical redeploy must reuse v1's cached responses"
        );
    }
    let m3 = v3.shutdown();
    assert_eq!(m3.cache_hits, 4, "identical redeploy must hit v1's entries");
    assert_eq!(m3.cache_misses, 0);
    assert_eq!(m3.cache_stale, 0);
}

#[test]
fn routing_mode_switch_never_serves_cross_mode_hits() {
    // ISSUE 7 satellite pin: iterative and accumulated deployments of
    // the SAME weights + masks share a cache store but never a cache
    // key — the routing mode (and baked coefficients) are part of the
    // deployment fingerprint, so a mode switch can't replay the other
    // mode's responses.
    let cfg = CapsNetConfig::tiny();
    let store = Arc::new(CacheStore::new(
        CacheConfig::default().entries,
        CacheConfig::default().shards,
    ));
    let frames: Vec<Tensor> = (0..4).map(|i| image(&cfg, 200 + i)).collect();

    // Iterative deployment fills the store.
    let iter = deploy(12, 128, store.clone());
    let fp_iter = iter.spec().expect("iter init").fingerprint;
    let iter_resp: Vec<_> = frames
        .iter()
        .map(|f| iter.classify(f.clone()).expect("iterative classify"))
        .collect();
    let m_iter = iter.shutdown();
    assert_eq!(m_iter.cache_misses, 4);
    assert!(!store.is_empty());

    // Accumulated deployment of the same model, same store: every
    // request must miss and run the zero-iteration path.
    let acc = deploy_accumulated(store.clone());
    let fp_acc = acc.spec().expect("acc init").fingerprint;
    assert_ne!(
        fp_iter, fp_acc,
        "routing mode must re-key the deployment fingerprint"
    );
    let acc_resp: Vec<_> = frames
        .iter()
        .map(|f| acc.classify(f.clone()).expect("accumulated classify"))
        .collect();
    assert!(
        iter_resp
            .iter()
            .zip(&acc_resp)
            .any(|(a, b)| a.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                != b.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>()),
        "the two modes should differ on at least one frame \
         (otherwise a cross-mode hit would be unobservable)"
    );
    let m_acc = acc.shutdown();
    assert_eq!(
        m_acc.cache_hits, 0,
        "accumulated deployment served an iterative deployment's response"
    );
    assert_eq!(m_acc.cache_misses, 4);
    assert_eq!(m_acc.cache_stale, 0);

    // Back to iterative: the original entries are still keyed correctly.
    let again = deploy(12, 128, store.clone());
    assert_eq!(again.spec().expect("again init").fingerprint, fp_iter);
    for (f, want) in frames.iter().zip(&iter_resp) {
        let got = again.classify(f.clone()).expect("re-iterative classify");
        assert_eq!(
            got.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "returning to iterative mode must reuse its own cached responses"
        );
    }
    let m_again = again.shutdown();
    assert_eq!(m_again.cache_hits, 4);
    assert_eq!(m_again.cache_misses, 0);
    assert_eq!(m_again.cache_stale, 0);
}
