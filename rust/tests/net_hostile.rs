//! Hostile-traffic and v2 out-of-order tests for the sharded network
//! front-end: clients that trickle, stall, overflow, half-open, or mix
//! protocol dialects must never wedge the event loop or the executor
//! pool — and the v2 tagged path must complete out of order around a
//! stalled head-of-line request, bit-identical to in-process serving.

use fastcaps::backend::{BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use fastcaps::coordinator::net::{Connection, NetConfig, NetServer};
use fastcaps::coordinator::server::Server;
use fastcaps::coordinator::wire::{self, ErrorCode, ServerFrame, V2, VERSION};
use fastcaps::tensor::Tensor;
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

const RECV_TIMEOUT: Duration = Duration::from_secs(10);

fn toy_spec() -> BackendSpec {
    BackendSpec {
        kind: "toy".into(),
        model: "toy".into(),
        input_shape: (1, 4, 4),
        batch_buckets: vec![1],
        reports_timing: false,
        max_replicas: None,
        compression: None,
        fingerprint: 0,
        routing: String::new(),
        workers: 1,
        coupling_fingerprint: None,
    }
}

/// Marker pixel value: images whose first element is `STALL` make the
/// backend sleep, pinning one replica — the head-of-line stall.
const STALL: f32 = 9.0;

/// Deterministic backend: lengths one-hot-encode the image mean (so
/// wire and in-process answers compare bit for bit); `STALL`-marked
/// images additionally sleep before answering.
struct ToyBackend {
    spec: BackendSpec,
    stall: Duration,
    lengths: usize,
}

impl InferenceBackend for ToyBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }
    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        if req.images.iter().any(|img| img.data[0] == STALL) {
            std::thread::sleep(self.stall);
        }
        Ok(InferOutput::untimed(
            req.images
                .iter()
                .map(|img| {
                    let m = img.sum() / img.len() as f32;
                    let mut l = vec![0.1f32; self.lengths];
                    l[(m * 10.0) as usize % self.lengths] = 0.9;
                    l
                })
                .collect(),
        ))
    }
}

fn toy_server(replicas: usize, stall: Duration, lengths: usize) -> Server {
    Server::builder(move || {
        Ok(Box::new(ToyBackend {
            spec: toy_spec(),
            stall,
            lengths,
        }) as Box<dyn InferenceBackend>)
    })
    .replicas(replicas)
    .max_wait(Duration::from_micros(100))
    .max_queue_depth(1024)
    .start()
}

fn toy_net(cfg: NetConfig) -> NetServer {
    NetServer::bind_with("127.0.0.1:0", toy_server(2, Duration::ZERO, 10), cfg)
        .expect("bind loopback")
}

/// Image whose toy prediction is `k % 10` (mean = k/10 + 0.05).
fn image_for(k: usize) -> Tensor {
    Tensor::full(&[1, 4, 4], (k % 10) as f32 / 10.0 + 0.05)
}

/// Image carrying the stall marker in pixel 0.
fn stall_image() -> Tensor {
    let mut data = vec![0.0f32; 16];
    data[0] = STALL;
    Tensor::from_vec(&[1, 4, 4], data).unwrap()
}

fn read_frame(stream: &TcpStream) -> Result<ServerFrame, wire::Fault> {
    stream.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    let mut r = BufReader::new(stream);
    wire::read_server_frame(&mut r)
}

/// A slowloris trickling one byte every millisecond must not stall the
/// shard: a well-behaved client on the SAME shard keeps being served
/// concurrently, and the slow request itself completes once assembled.
#[test]
fn slowloris_does_not_stall_the_shard() {
    let net = toy_net(NetConfig {
        io_shards: 1,
        ..NetConfig::default()
    });
    let addr = net.local_addr();
    let frame = wire::encode_classify(VERSION, 0, &image_for(3).data);
    let slow = std::thread::spawn(move || {
        let mut raw = TcpStream::connect(addr).unwrap();
        for b in &frame {
            raw.write_all(std::slice::from_ref(b)).unwrap();
            raw.flush().unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
        match read_frame(&raw).unwrap() {
            ServerFrame::Response(r) => assert_eq!(r.predicted, 3),
            other => panic!("slow client expected a response, got {other:?}"),
        }
    });
    // While the trickle is in progress, fast traffic flows normally.
    let mut client = Connection::v1_compat(addr).expect("connect");
    client.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    let t0 = Instant::now();
    for k in 0..20 {
        assert_eq!(client.classify(&image_for(k)).unwrap().predicted as usize, k % 10);
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "fast client was starved behind a slowloris: {:?}",
        t0.elapsed()
    );
    slow.join().unwrap();
    net.shutdown();
}

/// Half-open peers (connected but silent, or write-shutdown mid-frame)
/// must not block a graceful drain.
#[test]
fn half_open_connections_do_not_block_drain() {
    let net = toy_net(NetConfig::default());
    let addr = net.local_addr();
    // Silent connection: never sends a byte.
    let _silent = TcpStream::connect(addr).unwrap();
    // Mid-frame half-open: partial header, then write side shut down.
    let mut partial = TcpStream::connect(addr).unwrap();
    partial.write_all(b"FCAP").unwrap();
    partial.shutdown(std::net::Shutdown::Write).unwrap();
    // A real request proves the server noticed all three connections.
    let mut client = Connection::v1_compat(addr).expect("connect");
    client.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    assert_eq!(client.classify(&image_for(1)).unwrap().predicted, 1);
    let t0 = Instant::now();
    let m = net.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "drain blocked on half-open connections: {:?}",
        t0.elapsed()
    );
    assert_eq!(m.connections_closed, m.connections_opened);
}

/// The whole point of the readiness loop: connections are state, not
/// threads. A pile of idle connections must not grow the thread count.
#[cfg(target_os = "linux")]
#[test]
fn idle_connections_do_not_spawn_threads() {
    fn threads() -> usize {
        let status = std::fs::read_to_string("/proc/self/status").unwrap();
        status
            .lines()
            .find_map(|l| l.strip_prefix("Threads:"))
            .and_then(|v| v.trim().parse().ok())
            .expect("Threads: line in /proc/self/status")
    }
    let net = toy_net(NetConfig {
        io_shards: 2,
        ..NetConfig::default()
    });
    let baseline = threads();
    let n = 256usize;
    let idle: Vec<TcpStream> = (0..n)
        .map(|_| TcpStream::connect(net.local_addr()).unwrap())
        .collect();
    // Wait until every connection has been accepted and handed to a
    // shard (accept is async to connect returning).
    let t0 = Instant::now();
    while net.server().metrics().connections_opened < n as u64 {
        assert!(t0.elapsed() < RECV_TIMEOUT, "server never accepted {n} connections");
        std::thread::sleep(Duration::from_millis(10));
    }
    let with_idle = threads();
    assert!(
        with_idle <= baseline + 2,
        "{n} idle connections grew the thread count {baseline} -> {with_idle}"
    );
    // They are still live connections, not dropped on the floor.
    drop(idle);
    let m = net.shutdown();
    assert!(m.connections_opened >= n as u64);
}

/// v2 out-of-order completion: a stalled head-of-line request must not
/// hold back later submissions — they complete first, tagged, and the
/// results are bit-identical to in-process classification.
#[test]
fn v2_stalled_head_completes_out_of_order_bit_identical() {
    let server = toy_server(2, Duration::from_millis(400), 10);
    let net = NetServer::bind_with("127.0.0.1:0", server, NetConfig::default()).unwrap();
    let mut client = Connection::connect(net.local_addr()).expect("connect");
    client.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    assert_eq!(client.protocol_version(), V2);

    let stall_tag = client.submit(&stall_image()).unwrap();
    let fast: Vec<(u64, Tensor)> = (0..4)
        .map(|k| {
            let img = image_for(k);
            (client.submit(&img).unwrap(), img)
        })
        .collect();

    let mut order = Vec::new();
    let mut responses = Vec::new();
    for _ in 0..5 {
        let (tag, resp) = client.recv().unwrap();
        order.push(tag);
        responses.push((tag, resp));
    }
    // The stalled request pins one replica for 400ms; the fast four run
    // on the other replica and answer while it sleeps.
    assert_ne!(order[0], stall_tag, "stalled head blocked later requests");
    assert_eq!(
        order.last().copied(),
        Some(stall_tag),
        "stalled request should complete last, got order {order:?}"
    );
    // Bit-identical to in-process serving, matched up by tag.
    for (tag, img) in &fast {
        let direct = net.server().classify(img.clone()).unwrap();
        let wired = &responses.iter().find(|(t, _)| t == tag).unwrap().1;
        assert_eq!(wired.lengths.len(), direct.lengths.len());
        for (a, b) in wired.lengths.iter().zip(&direct.lengths) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    let m = net.shutdown();
    assert_eq!(m.wire_requests, 5);
    assert_eq!(m.wire_errors, 0);
}

/// A client that pipelines requests but never reads responses must be
/// disconnected when its write buffer overflows — counted in
/// `net_slow_client_drops` — while the server keeps serving others.
#[test]
fn slow_reader_is_dropped_not_buffered_forever() {
    // ~120KB per response (30k lengths) against the minimum 4KiB write
    // buffer: a handful of unread responses overflow it no matter how
    // much the kernel socket buffers absorb.
    let server = toy_server(2, Duration::ZERO, 30_000);
    let net = NetServer::bind_with(
        "127.0.0.1:0",
        server,
        NetConfig {
            io_shards: 1,
            max_write_buffer: 4096,
        },
    )
    .unwrap();
    let mut hog = Connection::connect(net.local_addr()).expect("connect");
    for k in 0..100 {
        // The server may cut the connection (the point of this test)
        // while submissions are still in flight — that's not a failure.
        if hog.submit(&image_for(k)).is_err() {
            break;
        }
    }
    // Never read: the server must cut the connection, not buffer 12MB.
    let t0 = Instant::now();
    while net.server().metrics().slow_client_drops == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "server buffered a non-reading client forever"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    // The server survives and serves well-behaved clients.
    let mut client = Connection::connect(net.local_addr()).expect("connect");
    client.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    assert_eq!(client.classify(&image_for(2)).unwrap().predicted, 2);
    drop(hog);
    let m = net.shutdown();
    assert!(m.slow_client_drops >= 1);
    assert!(m.summary().contains("slow_client_drops="), "{}", m.summary());
}

/// Mixing wire dialects on one connection is a desync: the server
/// answers what it accepted, reports `Malformed`, and closes.
#[test]
fn mixed_version_frames_are_malformed_desync() {
    let net = toy_net(NetConfig::default());
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.write_all(&wire::encode_classify(VERSION, 0, &image_for(4).data))
        .unwrap();
    raw.write_all(&wire::encode_classify(V2, 7, &image_for(5).data))
        .unwrap();
    raw.flush().unwrap();
    // The accepted v1 request is still answered, in order...
    match read_frame(&raw).unwrap() {
        ServerFrame::Response(r) => assert_eq!(r.predicted, 4),
        other => panic!("expected the v1 response first, got {other:?}"),
    }
    // ...then the dialect mix surfaces as a typed desync error...
    match read_frame(&raw).unwrap() {
        ServerFrame::Error { code, message } => {
            assert_eq!(code, ErrorCode::Malformed);
            assert!(message.contains("mixed"), "{message}");
        }
        other => panic!("expected a Malformed error frame, got {other:?}"),
    }
    // ...and the stream closes (it cannot be resynchronized).
    assert!(matches!(read_frame(&raw), Err(wire::Fault::Closed)));
    let m = net.shutdown();
    assert_eq!(m.wire_errors, 1);
}

/// Raw-text probe round-trip on the serving port: the sidecar answers
/// HEALTH/READY/METRICS without speaking the binary protocol.
#[test]
fn plaintext_probes_roundtrip_on_the_serving_port() {
    fn ask(addr: std::net::SocketAddr, req: &[u8]) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
        s.write_all(req).unwrap();
        s.flush().unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }
    let net = toy_net(NetConfig::default());
    let addr = net.local_addr();
    // Serve one request so the counters are nonzero in the exposition.
    let mut client = Connection::connect(addr).expect("connect");
    client.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    assert_eq!(client.classify(&image_for(6)).unwrap().predicted, 6);

    assert_eq!(ask(addr, b"HEALTH\n"), "OK\n");
    assert_eq!(ask(addr, b"READY\n"), "READY\n");
    let metrics = ask(addr, b"METRICS\n");
    assert!(metrics.contains("fastcaps_requests_total 1"), "{metrics}");
    assert!(metrics.contains("fastcaps_shard_connections_total"), "{metrics}");

    // The same endpoints speak enough HTTP for curl/probes.
    let health = ask(addr, b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n");
    assert!(health.starts_with("HTTP/1.0 200 OK\r\n"), "{health}");
    assert!(health.ends_with("ok\n"), "{health}");
    let ready = ask(addr, b"GET /readyz HTTP/1.1\r\nHost: x\r\n\r\n");
    assert!(ready.starts_with("HTTP/1.0 200 OK\r\n"), "{ready}");
    let http_metrics = ask(addr, b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
    assert!(http_metrics.contains("fastcaps_wire_requests_total"), "{http_metrics}");
    let missing = ask(addr, b"GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
    assert!(missing.starts_with("HTTP/1.0 404"), "{missing}");
    net.shutdown();
}

/// After a wire-initiated drain begins, READY flips to NOT_READY while
/// HEALTH stays OK — the probe split load balancers rely on.
#[test]
fn readiness_flips_during_drain_health_does_not() {
    let net = toy_net(NetConfig::default());
    let addr = net.local_addr();
    let client = Connection::connect(addr).expect("connect");
    client.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    client.shutdown_server().expect("shutdown ack");
    net.wait_shutdown_requested();
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    s.write_all(b"READY\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    assert_eq!(out, "NOT_READY\n");
    net.shutdown();
}
