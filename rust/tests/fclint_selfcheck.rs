//! fclint self-check: the analyzer must run clean on this repository's
//! own source tree (the same invariant the blocking CI job enforces),
//! and the committed fixtures must keep violating it — otherwise the
//! positive-case coverage has silently rotted.

use fastcaps::analysis::{self, LintConfig};
use std::path::Path;

#[test]
fn repo_tree_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = analysis::analyze_tree(&src, &LintConfig::repo_default()).expect("scan src");
    assert!(
        report.findings.is_empty(),
        "fclint findings on the repo tree: {:#?}",
        report.findings
    );
    assert!(report.files_scanned > 10, "walker found too few files");
}

#[test]
fn repo_tree_is_clean_from_relative_root() {
    // CI runs `fclint -- src` with the crate directory as cwd; the
    // upward searches for the repo-root DESIGN.md and the bench file
    // must work from a relative root too (a relative path has only the
    // empty-path ancestor, so the walk needs canonicalization first).
    // Cargo sets the test cwd to the manifest dir, mirroring CI.
    assert!(
        Path::new("src/analysis").is_dir(),
        "test cwd is not the crate root; relative-root check is void"
    );
    let report =
        analysis::analyze_tree(Path::new("src"), &LintConfig::repo_default()).expect("scan src");
    assert!(
        report.findings.is_empty(),
        "fclint findings from a relative root: {:#?}",
        report.findings
    );
}

#[test]
fn fixture_tree_still_violates() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/analysis/fixtures");
    let cfg = LintConfig::repo_default();
    let report = analysis::analyze_tree(&root, &cfg).expect("scan fixtures");
    assert!(report.denies() > 0, "fixtures must keep violating fclint");
    assert!(report.suppressed > 0, "fixture pragmas must keep suppressing");
}
