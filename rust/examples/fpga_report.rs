//! Full accelerator report: cycles per stage, resources, FPS and FPJ for
//! all six paper configurations (Fig. 1 / Table II / Table III in one
//! view), paper values alongside.
//!
//! ```sh
//! cargo run --release --example fpga_report
//! ```

use fastcaps::config::SystemConfig;
use fastcaps::fpga::{power::PowerModel, resources, DeployedModel};

fn main() {
    let pm = PowerModel::default();
    for (name, cfg, paper_fps) in [
        ("original-mnist", SystemConfig::original("mnist"), 5.0),
        ("pruned-mnist", SystemConfig::pruned("mnist"), 82.0),
        ("proposed-mnist", SystemConfig::proposed("mnist"), 1351.0),
        ("original-fmnist", SystemConfig::original("fmnist"), 5.0),
        ("pruned-fmnist", SystemConfig::pruned("fmnist"), 48.0),
        ("proposed-fmnist", SystemConfig::proposed("fmnist"), 934.0),
    ] {
        let d = DeployedModel::synthetic(&cfg, 7);
        let t = d.estimate_frame();
        let u = resources::estimate(&cfg);
        let w = pm.watts(&u, !cfg.is_pruned());
        println!(
            "{name:18} fps={:8.1} (paper {paper_fps:6.1})  cycles={:>11}  lat={:.5}s  \
             P={w:.2}W fpj={:.1}",
            t.fps(),
            fastcaps::util::fmt_thousands(t.total_cycles()),
            t.latency_s(),
            t.fps() / w,
        );
        println!(
            "    resources: LUT={} LUTRAM={} BRAM={} DSP={}",
            u.luts, u.lutram, u.bram36, u.dsp48e
        );
        for s in &t.stages {
            println!("    {:24} {:>11} cycles", s.name, fastcaps::util::fmt_thousands(s.cycles));
        }
        if t.ddr_cycles > 0 {
            println!(
                "    {:24} {:>11} cycles (overlapped)",
                "ddr weight streaming",
                fastcaps::util::fmt_thousands(t.ddr_cycles)
            );
        }
        println!();
    }
    println!("Routing-op detail (Fig. 8): `fastcaps report fig8`");
}
