//! End-to-end serving driver (deliverable (b)/(e) of DESIGN.md):
//! the coordinator serves batched classification requests from concurrent
//! clients through any registered [`fastcaps::backend`] — PJRT runtime,
//! FPGA simulator, or the fp32 oracle — while the simulator produces the
//! modeled on-device timing/energy ledger for the same workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_images -- \
//!     --requests 256 --clients 8 --backend pjrt
//! cargo run --release --example serve_images -- \
//!     --backend sim --replicas 4        # executor pool across cores
//! ```
//! Falls back to the simulator backend when PJRT artifacts are missing.

use fastcaps::backend::{BackendConfig, BackendRegistry};
use fastcaps::config::SystemConfig;
use fastcaps::coordinator::server::Server;
use fastcaps::data::{generate, Task};
use fastcaps::fpga::{power::PowerModel, resources, DeployedModel};
use fastcaps::util::cli::Args;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn main() -> fastcaps::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.get_usize("requests", 128);
    let n_clients = args.get_usize("clients", 4).max(1);
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let max_wait = Duration::from_millis(args.get_u64("max-wait-ms", 5));
    let replicas = args.get_usize("replicas", 1);
    let max_queue = args.get_usize("max-queue", 1024);

    let mut backend_kind = args.get_or("backend", "pjrt").to_string();
    if backend_kind == "pjrt" {
        if !cfg!(feature = "pjrt") {
            println!("(built without the pjrt feature: using the simulator backend)");
            backend_kind = "sim".to_string();
        } else if !dir.join("manifest.json").exists() {
            println!("(artifacts missing: falling back to the simulator backend)");
            backend_kind = "sim".to_string();
        }
    }

    let registry = Arc::new(BackendRegistry::with_defaults());
    let bcfg = BackendConfig {
        artifacts: dir,
        ..BackendConfig::default()
    };
    let kind = backend_kind.clone();
    let server = Server::builder(move || registry.build(&kind, &bcfg))
        .replicas(replicas)
        .max_wait(max_wait)
        .max_queue_depth(max_queue)
        .start();
    if let Some(e) = server.init_error() {
        anyhow::bail!("starting backend '{backend_kind}': {e}");
    }

    println!(
        "end-to-end: {n_requests} requests, {n_clients} clients, \
         backend={backend_kind}, replicas={}",
        server.pool_size()
    );
    let t0 = std::time::Instant::now();
    let mut agreement = 0usize;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let server = &server;
            let share = n_requests / n_clients + usize::from(c < n_requests % n_clients);
            handles.push(scope.spawn(move || {
                let data = generate(Task::Digits, share, 100 + c as u64);
                let mut hits = 0usize;
                for (img, &label) in data.images.into_iter().zip(&data.labels) {
                    if let Ok(resp) = server.classify(img) {
                        if resp.predicted == label {
                            hits += 1;
                        }
                    }
                }
                hits
            }));
        }
        for h in handles {
            agreement += h.join().unwrap();
        }
    });
    let wall = t0.elapsed();
    let m = server.shutdown();

    println!("\n=== serving metrics (host) ===");
    println!("{}", m.summary());
    println!(
        "wall {:.2}s  → {:.1} req/s end-to-end",
        wall.as_secs_f64(),
        m.requests as f64 / wall.as_secs_f64()
    );
    println!(
        "label-agreement {}/{} (random weights — chance ≈ 10%)",
        agreement, m.requests
    );

    // Modeled on-device ledger for the identical workload.
    let cfg = SystemConfig::proposed("mnist");
    let model = DeployedModel::synthetic(&cfg, 7);
    let t = model.estimate_frame();
    let u = resources::estimate(&cfg);
    let pm = PowerModel::default();
    println!("\n=== modeled PYNQ-Z1 ledger (same workload) ===");
    println!(
        "per-frame {:.3} ms  → {:.0} FPS, {:.1} FPJ; {} frames = {:.2} s, {:.1} J",
        t.latency_s() * 1e3,
        t.fps(),
        pm.fpj(t.fps(), &u, false),
        m.requests,
        m.requests as f64 * t.latency_s(),
        m.requests as f64 * t.latency_s() * pm.watts(&u, false),
    );
    Ok(())
}
