//! Quickstart: load the AOT artifacts, classify one synthetic digit via
//! the PJRT runtime, and show the same frame on the FPGA simulator.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use fastcaps::config::SystemConfig;
use fastcaps::data::{generate, Task};
use fastcaps::fpga::DeployedModel;
use std::path::Path;

fn main() -> fastcaps::Result<()> {
    // A synthetic MNIST-like digit (class 3).
    let data = generate(Task::Digits, 4, 42);
    let img = &data.images[3];
    println!("input: 28x28 digit, label {}", data.labels[3]);

    // --- Functional path: the JAX-lowered HLO on the PJRT CPU client.
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        match fastcaps::runtime::Runtime::open(dir) {
            Ok(rt) => {
                let engine =
                    rt.engine("capsnet-mnist-pruned", 1, &dir.join("weights-mnist.fcw"))?;
                let lengths = engine.run_batch(std::slice::from_ref(img))?;
                let pred = fastcaps::util::argmax(&lengths[0]);
                println!("PJRT  : predicted {pred} (capsule lengths {:?})", &lengths[0]);
                println!(
                    "        (weights are random-init; train with `make table1` for meaning)"
                );
            }
            // Built without the `pjrt` feature: keep the simulator demo.
            Err(e) => println!("PJRT  : skipped — {e}"),
        }
    } else {
        println!("PJRT  : skipped — run `make artifacts` first");
    }

    // --- Timing path: the same frame on the cycle-level accelerator.
    let model = DeployedModel::synthetic(&SystemConfig::proposed("mnist"), 7);
    let (pred, _, t) = model.run_frame(img)?;
    println!(
        "FPGA  : predicted {pred}, {} cycles = {:.2} ms @100MHz ({:.0} FPS)",
        fastcaps::util::fmt_thousands(t.total_cycles()),
        t.latency_s() * 1e3,
        t.fps()
    );
    for s in &t.stages {
        println!("        {:<18} {:>9} cycles", s.name, s.cycles);
    }
    Ok(())
}
