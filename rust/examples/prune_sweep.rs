//! Pruning sweep on the full-size CapsNet: LAKP vs KP vs unstructured vs
//! capsule pruning across sparsities — compression rate, surviving
//! capsule count, index-memory cost, and the resulting simulated FPS.
//!
//! ```sh
//! cargo run --release --example prune_sweep [-- --weights artifacts/weights-mnist.fcw]
//! ```

use fastcaps::capsnet::weights::Weights;
use fastcaps::config::{CapsNetConfig, FpgaBudget, SparsityPlan, SystemConfig};
use fastcaps::fpga::DeployedModel;
use fastcaps::pruning::{capsule, kp, lakp, magnitude, surviving_capsule_types, AdjacencyNorms};
use fastcaps::util::cli::Args;
use fastcaps::util::rng::Rng;
use std::path::Path;

fn main() -> fastcaps::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    // The sweep runs on the *full* (unpruned) architecture, like §III-A.
    let cfg = CapsNetConfig::paper_full("capsnet-mnist");
    let weights = match args.get("weights") {
        Some(p) => Weights::load(Path::new(p))?,
        None => Weights::random(&cfg, &mut Rng::new(11)),
    };

    println!(
        "CapsNet {}: {} prunable conv kernels ({} params)\n",
        cfg.name,
        cfg.conv1_ch + cfg.pc_channels() * cfg.conv1_ch,
        SparsityPlan::dense(&cfg).survived_conv_params(&cfg),
    );
    println!(
        "{:>9} | {:>22} {:>8} {:>10} | {:>10} {:>8} | {:>12}",
        "sparsity", "method", "kernels", "capsules", "compress%", "idx B", "sim FPS"
    );
    println!("{}", "-".repeat(95));

    for sparsity in [0.5, 0.9, 0.97, 0.99, 0.995] {
        // LAKP with real adjacency (Eq. 1).
        let adj = AdjacencyNorms {
            prev: AdjacencyNorms::prev_from_conv(&weights.conv1_w),
            next: AdjacencyNorms::next_from_digitcaps(&weights.w_ij, cfg.pc_types, cfg.pc_dim),
        };
        let r_lakp = lakp::prune_layer(&weights.pc_w, &adj, sparsity);
        let r_kp = kp::prune_layer(&weights.pc_w, sparsity);
        let m_caps = capsule::prune_types(&weights.pc_w, cfg.pc_dim, sparsity);
        let m_unstr = magnitude::prune_layer(&weights.pc_w, sparsity);

        for (name, survived, types, idx_bytes) in [
            (
                "LAKP (proposed)",
                r_lakp.mask.survived(),
                surviving_capsule_types(&r_lakp.mask, cfg.pc_dim),
                r_lakp.mask.index_bytes(),
            ),
            (
                "KP (magnitude)",
                r_kp.mask.survived(),
                surviving_capsule_types(&r_kp.mask, cfg.pc_dim),
                r_kp.mask.index_bytes(),
            ),
            (
                "capsule pruning",
                m_caps.survived(),
                surviving_capsule_types(&m_caps, cfg.pc_dim),
                m_caps.index_bytes(),
            ),
            (
                "unstructured",
                m_unstr.survived() / (cfg.pc_k * cfg.pc_k), // kernel-equivalents
                cfg.pc_types,
                m_unstr.index_bytes(),
            ),
        ] {
            let (h2, w2) = cfg.pc_out();
            let caps = types * h2 * w2;
            let plan = SparsityPlan {
                conv1_kernels: cfg.conv1_ch,
                pc_kernels: survived,
                conv1_channels: cfg.conv1_ch,
                pc_types: types,
            };
            let compression = plan.compression_rate(&cfg, &cfg);
            // Simulated throughput of this deployment.
            let sys = SystemConfig {
                model: cfg.clone(),
                sparsity: plan,
                budget: FpgaBudget::pynq_z1(),
                options: fastcaps::config::AcceleratorOptions::optimized(),
            };
            let fps = DeployedModel::synthetic(&sys, 5).estimate_frame().fps();
            println!(
                "{:>8.1}% | {:>22} {:>8} {:>10} | {:>9.2}% {:>8} | {:>11.1}",
                sparsity * 100.0,
                name,
                survived,
                caps,
                compression,
                idx_bytes,
                fps
            );
        }
        println!("{}", "-".repeat(95));
    }
    println!(
        "\nNote: capsule pruning saturates at whole-type granularity and unstructured\n\
         pruning needs per-weight indices ({}x more index memory at equal sparsity) —\n\
         the §III-C argument for kernel-structured LAKP.",
        (cfg.pc_k * cfg.pc_k)
    );
    Ok(())
}
