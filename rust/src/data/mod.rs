//! Synthetic dataset generators.
//!
//! The build environment has no network access, so the paper's datasets
//! (MNIST, Fashion-MNIST, CIFAR-10, GTSRB) are replaced by procedural
//! generators that produce 10-class image tasks at the same input shapes
//! (see DESIGN.md §4 for why this preserves the experiments' shape):
//!
//! * [`digits`] — MNIST-like 28×28 grayscale stroke-rendered digits with
//!   random affine jitter.
//! * [`garments`] — F-MNIST-like 28×28 grayscale texture/silhouette
//!   classes (harder than digits, mirroring F-MNIST vs MNIST).
//!
//! The same procedural definitions are mirrored in
//! `python/compile/data.py` for the training-side experiments.

pub mod digits;
pub mod garments;

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A labeled dataset of CHW images.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<Tensor>,
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Split into (train, test) at `train_frac`.
    pub fn split(mut self, train_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.images.len();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_train = ((n as f64) * train_frac).round() as usize;
        let mut train = Dataset {
            images: Vec::with_capacity(n_train),
            labels: Vec::with_capacity(n_train),
            num_classes: self.num_classes,
        };
        let mut test = Dataset {
            images: Vec::with_capacity(n - n_train),
            labels: Vec::with_capacity(n - n_train),
            num_classes: self.num_classes,
        };
        // Drain in index order to avoid cloning tensors.
        let mut taken: Vec<Option<Tensor>> =
            self.images.drain(..).map(Some).collect();
        for (rank, &i) in idx.iter().enumerate() {
            let img = taken[i].take().unwrap();
            let lab = self.labels[i];
            if rank < n_train {
                train.images.push(img);
                train.labels.push(lab);
            } else {
                test.images.push(img);
                test.labels.push(lab);
            }
        }
        (train, test)
    }
}

/// Which synthetic task to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// MNIST-like stroke digits.
    Digits,
    /// F-MNIST-like garment silhouettes.
    Garments,
}

impl Task {
    pub fn parse(name: &str) -> Option<Task> {
        match name {
            "mnist" | "digits" => Some(Task::Digits),
            "fmnist" | "garments" => Some(Task::Garments),
            _ => None,
        }
    }
}

/// Generate `n` samples of the task, classes balanced round-robin.
pub fn generate(task: Task, n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut images = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        let img = match task {
            Task::Digits => digits::render(class, &mut rng),
            Task::Garments => garments::render(class, &mut rng),
        };
        images.push(img);
        labels.push(class);
    }
    Dataset {
        images,
        labels,
        num_classes: 10,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_balanced_classes() {
        let d = generate(Task::Digits, 100, 1);
        assert_eq!(d.len(), 100);
        for c in 0..10 {
            assert_eq!(d.labels.iter().filter(|&&l| l == c).count(), 10);
        }
    }

    #[test]
    fn images_are_28x28_normalized() {
        for task in [Task::Digits, Task::Garments] {
            let d = generate(task, 20, 2);
            for img in &d.images {
                assert_eq!(img.shape, vec![1, 28, 28]);
                for &v in &img.data {
                    assert!((0.0..=1.0).contains(&v), "pixel {v} out of range");
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(Task::Digits, 10, 7);
        let b = generate(Task::Digits, 10, 7);
        for (x, y) in a.images.iter().zip(&b.images) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn different_classes_look_different() {
        let mut rng = Rng::new(3);
        let a = digits::render(1, &mut rng);
        let mut rng2 = Rng::new(3);
        let b = digits::render(8, &mut rng2);
        let diff: f32 = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .sum();
        assert!(diff > 5.0, "classes 1 and 8 nearly identical (diff {diff})");
    }

    #[test]
    fn split_partitions() {
        let d = generate(Task::Garments, 50, 4);
        let mut rng = Rng::new(5);
        let (tr, te) = d.split(0.8, &mut rng);
        assert_eq!(tr.len(), 40);
        assert_eq!(te.len(), 10);
    }

    #[test]
    fn task_parsing() {
        assert_eq!(Task::parse("mnist"), Some(Task::Digits));
        assert_eq!(Task::parse("fmnist"), Some(Task::Garments));
        assert_eq!(Task::parse("imagenet"), None);
    }
}
