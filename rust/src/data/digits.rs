//! MNIST-like procedural digit renderer.
//!
//! Each digit class is defined as a set of strokes (line segments and arcs)
//! on a 28×28 canvas; rendering applies a random affine jitter (translate,
//! scale, rotate), draws the strokes with a soft round brush, and adds a
//! touch of pixel noise. This preserves what MNIST gives the pruning study:
//! smooth, centered, stroke-structured shapes whose classes differ in
//! global topology — the regime where CapsNet's pose-aware capsules work.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

const SIZE: usize = 28;

/// A stroke in normalized [0,1]² canvas coordinates.
enum Stroke {
    /// Line from a to b.
    Line([f32; 2], [f32; 2]),
    /// Circular arc: center, radius, start/end angle (radians, CCW).
    Arc([f32; 2], f32, f32, f32),
}

fn digit_strokes(class: usize) -> Vec<Stroke> {
    use Stroke::*;
    let pi = std::f32::consts::PI;
    match class {
        0 => vec![Arc([0.5, 0.5], 0.32, 0.0, 2.0 * pi)],
        1 => vec![
            Line([0.5, 0.15], [0.5, 0.85]),
            Line([0.38, 0.28], [0.5, 0.15]),
        ],
        2 => vec![
            Arc([0.5, 0.32], 0.2, pi, 2.6 * pi),
            Line([0.66, 0.45], [0.3, 0.85]),
            Line([0.3, 0.85], [0.72, 0.85]),
        ],
        3 => vec![
            Arc([0.48, 0.32], 0.18, 1.1 * pi, 2.5 * pi),
            Arc([0.48, 0.67], 0.18, 1.5 * pi, 2.9 * pi),
        ],
        4 => vec![
            Line([0.62, 0.15], [0.62, 0.85]),
            Line([0.62, 0.15], [0.3, 0.6]),
            Line([0.3, 0.6], [0.75, 0.6]),
        ],
        5 => vec![
            Line([0.68, 0.15], [0.35, 0.15]),
            Line([0.35, 0.15], [0.33, 0.45]),
            Arc([0.5, 0.63], 0.2, 1.2 * pi, 2.7 * pi),
        ],
        6 => vec![
            Arc([0.48, 0.62], 0.2, 0.0, 2.0 * pi),
            Arc([0.56, 0.42], 0.32, 0.9 * pi, 1.5 * pi),
        ],
        7 => vec![
            Line([0.3, 0.15], [0.72, 0.15]),
            Line([0.72, 0.15], [0.42, 0.85]),
        ],
        8 => vec![
            Arc([0.5, 0.32], 0.16, 0.0, 2.0 * pi),
            Arc([0.5, 0.66], 0.19, 0.0, 2.0 * pi),
        ],
        _ => vec![
            Arc([0.52, 0.38], 0.2, 0.0, 2.0 * pi),
            Arc([0.44, 0.58], 0.32, 1.5 * pi, 2.1 * pi),
        ],
    }
}

/// Render one digit of `class` with randomized pose.
pub fn render(class: usize, rng: &mut Rng) -> Tensor {
    let strokes = digit_strokes(class % 10);
    // Random affine jitter: the pose variation CapsNet is built to model.
    let angle = rng.range_f32(-0.25, 0.25);
    let scale = rng.range_f32(0.85, 1.1);
    let dx = rng.range_f32(-0.06, 0.06);
    let dy = rng.range_f32(-0.06, 0.06);
    let brush = rng.range_f32(0.045, 0.065);
    let (sin, cos) = angle.sin_cos();

    let tf = |p: [f32; 2]| -> [f32; 2] {
        // Rotate/scale about canvas center, then translate.
        let (x, y) = (p[0] - 0.5, p[1] - 0.5);
        [
            0.5 + scale * (cos * x - sin * y) + dx,
            0.5 + scale * (sin * x + cos * y) + dy,
        ]
    };

    // Collect polyline points for every stroke.
    let mut points: Vec<[f32; 2]> = Vec::new();
    for s in &strokes {
        match *s {
            Stroke::Line(a, b) => {
                let steps = 24;
                for i in 0..=steps {
                    let t = i as f32 / steps as f32;
                    points.push(tf([
                        a[0] + t * (b[0] - a[0]),
                        a[1] + t * (b[1] - a[1]),
                    ]));
                }
            }
            Stroke::Arc(c, r, a0, a1) => {
                let steps = 48;
                for i in 0..=steps {
                    let t = a0 + (a1 - a0) * i as f32 / steps as f32;
                    points.push(tf([c[0] + r * t.cos(), c[1] + r * t.sin()]));
                }
            }
        }
    }

    let mut img = Tensor::zeros(&[1, SIZE, SIZE]);
    // Soft round brush: intensity = exp(-d²/2σ²) accumulated with max().
    let sigma = brush;
    for py in 0..SIZE {
        for px in 0..SIZE {
            let cx = (px as f32 + 0.5) / SIZE as f32;
            let cy = (py as f32 + 0.5) / SIZE as f32;
            let mut best = 0.0f32;
            for p in &points {
                let d2 = (p[0] - cx) * (p[0] - cx) + (p[1] - cy) * (p[1] - cy);
                if d2 < 9.0 * sigma * sigma {
                    let v = (-d2 / (2.0 * sigma * sigma)).exp();
                    if v > best {
                        best = v;
                    }
                }
            }
            // Light sensor noise.
            let noise = rng.range_f32(0.0, 0.04);
            img.data[py * SIZE + px] = (best + noise).clamp(0.0, 1.0);
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nonempty_strokes() {
        let mut rng = Rng::new(1);
        for class in 0..10 {
            let img = render(class, &mut rng);
            let ink: f32 = img.data.iter().sum();
            assert!(ink > 10.0, "class {class} too faint (ink {ink})");
            assert!(ink < 500.0, "class {class} saturated (ink {ink})");
        }
    }

    #[test]
    fn pose_jitter_varies_instances() {
        let mut rng = Rng::new(2);
        let a = render(3, &mut rng);
        let b = render(3, &mut rng);
        assert_ne!(a.data, b.data);
    }

    #[test]
    fn intra_class_closer_than_inter_class() {
        // Average L2 distance between same-class pairs should be smaller
        // than between class 0 (ring) and class 1 (stroke).
        let mut rng = Rng::new(3);
        let dist = |a: &Tensor, b: &Tensor| -> f32 {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
                .sqrt()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let n = 8;
        for _ in 0..n {
            let a0 = render(0, &mut rng);
            let b0 = render(0, &mut rng);
            let a1 = render(1, &mut rng);
            intra += dist(&a0, &b0);
            inter += dist(&a0, &a1);
        }
        assert!(
            intra < inter,
            "intra {intra} should be < inter {inter}"
        );
    }
}
