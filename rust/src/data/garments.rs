//! F-MNIST-like procedural garment renderer.
//!
//! Fashion-MNIST's classes are filled silhouettes with internal texture —
//! harder than MNIST because classes share large overlapping regions
//! (pullover vs coat vs shirt). We mirror that: each class is a filled
//! polygon silhouette with a class-specific texture frequency, so nearby
//! classes overlap heavily. The paper's F-MNIST numbers (lower accuracy,
//! lower pruning rate, lower FPS) all stem from this added difficulty and
//! the 432-capsule (vs 252) pruned model.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

const SIZE: usize = 28;

/// Silhouette as a polygon in normalized coordinates + texture parameters.
struct Garment {
    poly: Vec<[f32; 2]>,
    tex_freq: f32,
    tex_amp: f32,
}

fn garment(class: usize) -> Garment {
    // Rough silhouettes for the 10 F-MNIST classes: t-shirt, trouser,
    // pullover, dress, coat, sandal, shirt, sneaker, bag, ankle boot.
    let poly: Vec<[f32; 2]> = match class {
        0 => vec![
            // t-shirt: boxy torso + short sleeves
            [0.2, 0.25], [0.35, 0.2], [0.65, 0.2], [0.8, 0.25], [0.78, 0.4],
            [0.68, 0.38], [0.68, 0.8], [0.32, 0.8], [0.32, 0.38], [0.22, 0.4],
        ],
        1 => vec![
            // trouser: two legs
            [0.35, 0.15], [0.65, 0.15], [0.63, 0.85], [0.53, 0.85],
            [0.5, 0.45], [0.47, 0.85], [0.37, 0.85],
        ],
        2 => vec![
            // pullover: torso + long sleeves
            [0.15, 0.25], [0.35, 0.18], [0.65, 0.18], [0.85, 0.25],
            [0.82, 0.6], [0.7, 0.58], [0.7, 0.82], [0.3, 0.82], [0.3, 0.58],
            [0.18, 0.6],
        ],
        3 => vec![
            // dress: fitted top, flared bottom
            [0.38, 0.15], [0.62, 0.15], [0.58, 0.4], [0.75, 0.85],
            [0.25, 0.85], [0.42, 0.4],
        ],
        4 => vec![
            // coat: long torso + sleeves, open front
            [0.15, 0.22], [0.38, 0.15], [0.62, 0.15], [0.85, 0.22],
            [0.83, 0.62], [0.7, 0.6], [0.7, 0.88], [0.3, 0.88], [0.3, 0.6],
            [0.17, 0.62],
        ],
        5 => vec![
            // sandal: low wedge
            [0.15, 0.6], [0.8, 0.55], [0.85, 0.68], [0.7, 0.72],
            [0.45, 0.7], [0.18, 0.72],
        ],
        6 => vec![
            // shirt: like t-shirt but slimmer, longer sleeves
            [0.18, 0.25], [0.38, 0.18], [0.62, 0.18], [0.82, 0.25],
            [0.8, 0.52], [0.66, 0.48], [0.66, 0.85], [0.34, 0.85],
            [0.34, 0.48], [0.2, 0.52],
        ],
        7 => vec![
            // sneaker: chunky profile
            [0.15, 0.55], [0.55, 0.5], [0.8, 0.58], [0.85, 0.7],
            [0.75, 0.75], [0.2, 0.75],
        ],
        8 => vec![
            // bag: trapezoid + handle notch
            [0.22, 0.4], [0.78, 0.4], [0.82, 0.8], [0.18, 0.8],
        ],
        _ => vec![
            // ankle boot: heel + shaft
            [0.3, 0.3], [0.55, 0.3], [0.55, 0.55], [0.8, 0.6],
            [0.82, 0.75], [0.25, 0.75],
        ],
    };
    let tex_freq = 2.0 + (class % 5) as f32 * 2.5;
    let tex_amp = 0.15 + 0.05 * (class % 3) as f32;
    Garment {
        poly,
        tex_freq,
        tex_amp,
    }
}

/// Point-in-polygon (even-odd rule).
fn inside(poly: &[[f32; 2]], x: f32, y: f32) -> bool {
    let mut c = false;
    let n = poly.len();
    let mut j = n - 1;
    for i in 0..n {
        let (xi, yi) = (poly[i][0], poly[i][1]);
        let (xj, yj) = (poly[j][0], poly[j][1]);
        if ((yi > y) != (yj > y))
            && (x < (xj - xi) * (y - yi) / (yj - yi) + xi)
        {
            c = !c;
        }
        j = i;
    }
    c
}

/// Render one garment of `class` with randomized pose and texture phase.
pub fn render(class: usize, rng: &mut Rng) -> Tensor {
    let g = garment(class % 10);
    let angle = rng.range_f32(-0.12, 0.12);
    let scale = rng.range_f32(0.9, 1.08);
    let dx = rng.range_f32(-0.05, 0.05);
    let dy = rng.range_f32(-0.05, 0.05);
    let phase = rng.range_f32(0.0, std::f32::consts::TAU);
    let (sin, cos) = angle.sin_cos();

    // Transform the polygon once.
    let poly: Vec<[f32; 2]> = g
        .poly
        .iter()
        .map(|p| {
            let (x, y) = (p[0] - 0.5, p[1] - 0.5);
            [
                0.5 + scale * (cos * x - sin * y) + dx,
                0.5 + scale * (sin * x + cos * y) + dy,
            ]
        })
        .collect();

    let mut img = Tensor::zeros(&[1, SIZE, SIZE]);
    for py in 0..SIZE {
        for px in 0..SIZE {
            let cx = (px as f32 + 0.5) / SIZE as f32;
            let cy = (py as f32 + 0.5) / SIZE as f32;
            let mut v = 0.0f32;
            if inside(&poly, cx, cy) {
                // Filled body with woven texture.
                let tex = (g.tex_freq * std::f32::consts::TAU * cx + phase)
                    .sin()
                    * (g.tex_freq * std::f32::consts::TAU * cy + phase).cos();
                v = 0.75 + g.tex_amp * tex;
            }
            let noise = rng.range_f32(0.0, 0.05);
            img.data[py * SIZE + px] = (v + noise).clamp(0.0, 1.0);
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silhouettes_fill_reasonable_area() {
        let mut rng = Rng::new(1);
        for class in 0..10 {
            let img = render(class, &mut rng);
            let filled = img.data.iter().filter(|&&v| v > 0.3).count();
            assert!(
                filled > 40 && filled < 700,
                "class {class}: {filled} filled pixels"
            );
        }
    }

    #[test]
    fn point_in_polygon_square() {
        let sq = vec![[0.0, 0.0], [1.0, 0.0], [1.0, 1.0], [0.0, 1.0]];
        assert!(inside(&sq, 0.5, 0.5));
        assert!(!inside(&sq, 1.5, 0.5));
        assert!(!inside(&sq, -0.1, 0.99));
    }

    #[test]
    fn garments_harder_than_digits() {
        // Class-overlap proxy: pullover (2) vs coat (4) silhouettes share
        // more pixels than any two digit classes — F-MNIST difficulty.
        let mut rng = Rng::new(5);
        let a = render(2, &mut rng);
        let b = render(4, &mut rng);
        let overlap = a
            .data
            .iter()
            .zip(&b.data)
            .filter(|(&x, &y)| x > 0.3 && y > 0.3)
            .count();
        assert!(overlap > 100, "pullover/coat overlap only {overlap} px");
    }
}
