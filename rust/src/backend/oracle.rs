//! Batch adapter over the fp32 reference model ([`crate::capsnet`]) —
//! the oracle every other execution path is validated against, servable
//! through the same [`InferenceBackend`] API. Requests run through the
//! native [`CapsNet::forward_batch`] (shared weight traversal + one
//! routing scratch across the batch, bit-exact vs the per-image
//! forward). The bucket ladder stays small: padding still costs a full
//! forward here, unlike the AOT paths.

use super::{BackendConfig, BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use crate::capsnet::{weights::Weights, CapsNet};
use crate::config::CapsNetConfig;
use crate::routing::RoutingMode;
use crate::util::rng::Rng;

pub struct OracleBackend {
    net: CapsNet,
    routing: RoutingMode,
    coupling: Option<Vec<f32>>,
    workers: usize,
    spec: BackendSpec,
}

impl OracleBackend {
    /// Wrap an existing model on the config's iterative schedule.
    pub fn new(net: CapsNet) -> OracleBackend {
        let iters = net.config.routing_iters;
        OracleBackend::with_routing(net, RoutingMode::Iterative(iters), None, 1)
            .expect("iterative oracle construction cannot fail")
    }

    /// Wrap a model with an explicit routing schedule and worker count.
    /// `Accumulated` requires a coupling matrix of `n_caps × n_classes`
    /// mean coefficients (e.g. from [`CapsNet::accumulate_coupling`]).
    pub fn with_routing(
        net: CapsNet,
        routing: RoutingMode,
        coupling: Option<Vec<f32>>,
        workers: usize,
    ) -> Result<OracleBackend, BackendError> {
        if routing.is_accumulated() && coupling.is_none() {
            return Err(BackendError::Init(
                "accumulated routing requires coupling coefficients (run `fastcaps accumulate`)"
                    .into(),
            ));
        }
        if let Some(c) = &coupling {
            let want = net.config.num_primary_caps() * net.config.num_classes;
            if c.len() != want {
                return Err(BackendError::Init(format!(
                    "coupling has {} entries, geometry needs {want}",
                    c.len()
                )));
            }
        }
        // The routing mode (and any baked coefficients) changes what this
        // executor computes, so both join the weight bits in the content
        // hash; worker count does not — sharding is bit-identical by
        // construction.
        let mut h = crate::util::hash::Hash64::new(0x726f_7574); // "rout"
        h.absorb(net.weights.fingerprint());
        h.absorb(routing.fingerprint_tag());
        if let Some(c) = &coupling {
            h.absorb_f32s(c);
        }
        let content = h.finish();
        let workers = workers.max(1);
        let spec = BackendSpec {
            kind: "oracle".into(),
            model: net.config.name.clone(),
            input_shape: net.config.input,
            batch_buckets: BackendSpec::pow2_buckets(8),
            reports_timing: false,
            max_replicas: None,
            compression: None,
            fingerprint: BackendSpec::deployment_fingerprint("oracle", &net.config.name, content),
            routing: routing.to_string(),
            workers,
            coupling_fingerprint: coupling.as_deref().map(super::coupling_fingerprint),
        }
        .normalize();
        Ok(OracleBackend {
            net,
            routing,
            coupling,
            workers,
            spec,
        })
    }

    /// Registry factory: the pruned paper architecture for the dataset,
    /// with trained `.fcw` weights when present and seeded random
    /// weights otherwise (predictions are then noise, but the serving
    /// path is exercised end to end). In accumulated mode the factory
    /// takes coefficients from the `.fcw` sidecar when one matches the
    /// geometry, else self-calibrates on the deterministic calibration
    /// set through this model's own f32 numerics.
    pub fn from_config(cfg: &BackendConfig) -> Result<OracleBackend, BackendError> {
        let arch = if cfg.is_fmnist() {
            CapsNetConfig::paper_pruned_fmnist()
        } else {
            CapsNetConfig::paper_pruned_mnist()
        };
        let weights_path = cfg.weights_path();
        let weights = if weights_path.exists() {
            let w = Weights::load(&weights_path)
                .map_err(|e| BackendError::Init(format!("loading {weights_path:?}: {e:#}")))?;
            w.validate(&arch)
                .map_err(|e| BackendError::Init(format!("weights mismatch: {e:#}")))?;
            w
        } else {
            Weights::random(&arch, &mut Rng::new(cfg.seed))
        };
        let net = CapsNet {
            config: arch,
            weights,
        };
        let routing = cfg.routing_mode(&net.config);
        let coupling = if routing.is_accumulated() {
            let want = net.config.num_primary_caps() * net.config.num_classes;
            let sidecar = weights_path
                .exists()
                .then(|| crate::capsnet::weights::load_coupling(&weights_path).ok().flatten())
                .flatten()
                .filter(|t| t.data.len() == want)
                .map(|t| t.data);
            Some(match sidecar {
                Some(c) => c,
                None => net
                    .accumulate_coupling(&super::calibration_set(cfg, super::CALIBRATION_FRAMES))
                    .map_err(|e| BackendError::Init(format!("accumulation pass: {e:#}")))?,
            })
        } else {
            None
        };
        OracleBackend::with_routing(net, routing, coupling, cfg.worker_count())
    }
}

impl InferenceBackend for OracleBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        let acts = self
            .net
            .forward_batch_sharded(
                &req.images,
                self.routing,
                self.coupling.as_deref(),
                self.workers,
            )
            .map_err(|e| BackendError::Execution(format!("oracle forward: {e:#}")))?;
        Ok(InferOutput::untimed(
            acts.iter().map(|a| a.class_lengths()).collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tiny_oracle() -> OracleBackend {
        let mut rng = Rng::new(5);
        OracleBackend::new(CapsNet::random(CapsNetConfig::tiny(), &mut rng))
    }

    #[test]
    fn spec_mirrors_model() {
        let b = tiny_oracle();
        assert_eq!(b.spec().input_shape, (1, 20, 20));
        assert_eq!(b.spec().batch_buckets, vec![1, 2, 4, 8]);
        assert!(b.spec().max_replicas.is_none());
    }

    #[test]
    fn batched_infer_matches_per_image_forward() {
        let mut b = tiny_oracle();
        let mut rng = Rng::new(6);
        let images: Vec<Tensor> = (0..4)
            .map(|_| Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0)))
            .collect();
        let out = b.infer(&InferRequest::new(images.clone())).unwrap();
        for (img, got) in images.iter().zip(&out.lengths) {
            let want = b.net.forward(img).unwrap().class_lengths();
            assert_eq!(got, &want);
        }
    }

    #[test]
    fn accumulated_oracle_rekeys_and_matches_accumulated_forward() {
        let mut rng = Rng::new(5);
        let net = CapsNet::random(CapsNetConfig::tiny(), &mut rng);
        let images: Vec<Tensor> = (0..3)
            .map(|_| Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0)))
            .collect();
        let coupling = net.accumulate_coupling(&images).unwrap();
        let iter = OracleBackend::new(net.clone());
        let mut acc = OracleBackend::with_routing(
            net.clone(),
            RoutingMode::Accumulated,
            Some(coupling.clone()),
            4,
        )
        .unwrap();
        // Satellite pin: iterative and accumulated deployments of the
        // same weights can never share a cache key.
        assert_ne!(iter.spec().fingerprint, acc.spec().fingerprint);
        assert_eq!(iter.spec().routing, "iterative(3)");
        assert_eq!(acc.spec().routing, "accumulated");
        assert_eq!(acc.spec().workers, 4);
        assert!(acc.spec().coupling_fingerprint.is_some());
        assert!(iter.spec().coupling_fingerprint.is_none());
        // Sharded accumulated serving matches the direct per-image
        // accumulated forward bit for bit.
        let out = acc.infer(&InferRequest::new(images.clone())).unwrap();
        for (img, got) in images.iter().zip(&out.lengths) {
            let want = net
                .forward_mode(img, RoutingMode::Accumulated, Some(&coupling))
                .unwrap()
                .class_lengths();
            assert_eq!(got, &want);
        }
        // Accumulated mode without coefficients is a typed init error.
        assert!(matches!(
            OracleBackend::with_routing(net, RoutingMode::Accumulated, None, 1),
            Err(BackendError::Init(_))
        ));
    }
}
