//! Batch adapter over the fp32 reference model ([`crate::capsnet`]) —
//! the oracle every other execution path is validated against, servable
//! through the same [`InferenceBackend`] API. Requests run through the
//! native [`CapsNet::forward_batch`] (shared weight traversal + one
//! routing scratch across the batch, bit-exact vs the per-image
//! forward). The bucket ladder stays small: padding still costs a full
//! forward here, unlike the AOT paths.

use super::{BackendConfig, BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use crate::capsnet::{weights::Weights, CapsNet};
use crate::config::CapsNetConfig;
use crate::util::rng::Rng;

pub struct OracleBackend {
    net: CapsNet,
    spec: BackendSpec,
}

impl OracleBackend {
    /// Wrap an existing model.
    pub fn new(net: CapsNet) -> OracleBackend {
        let spec = BackendSpec {
            kind: "oracle".into(),
            model: net.config.name.clone(),
            input_shape: net.config.input,
            batch_buckets: BackendSpec::pow2_buckets(8),
            reports_timing: false,
            max_replicas: None,
            compression: None,
            fingerprint: BackendSpec::deployment_fingerprint(
                "oracle",
                &net.config.name,
                net.weights.fingerprint(),
            ),
        }
        .normalize();
        OracleBackend { net, spec }
    }

    /// Registry factory: the pruned paper architecture for the dataset,
    /// with trained `.fcw` weights when present and seeded random
    /// weights otherwise (predictions are then noise, but the serving
    /// path is exercised end to end).
    pub fn from_config(cfg: &BackendConfig) -> Result<OracleBackend, BackendError> {
        let arch = if cfg.is_fmnist() {
            CapsNetConfig::paper_pruned_fmnist()
        } else {
            CapsNetConfig::paper_pruned_mnist()
        };
        let weights_path = cfg.weights_path();
        let weights = if weights_path.exists() {
            let w = Weights::load(&weights_path)
                .map_err(|e| BackendError::Init(format!("loading {weights_path:?}: {e:#}")))?;
            w.validate(&arch)
                .map_err(|e| BackendError::Init(format!("weights mismatch: {e:#}")))?;
            w
        } else {
            Weights::random(&arch, &mut Rng::new(cfg.seed))
        };
        Ok(OracleBackend::new(CapsNet {
            config: arch,
            weights,
        }))
    }
}

impl InferenceBackend for OracleBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        let acts = self
            .net
            .forward_batch(&req.images)
            .map_err(|e| BackendError::Execution(format!("oracle forward: {e:#}")))?;
        Ok(InferOutput::untimed(
            acts.iter().map(|a| a.class_lengths()).collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tiny_oracle() -> OracleBackend {
        let mut rng = Rng::new(5);
        OracleBackend::new(CapsNet::random(CapsNetConfig::tiny(), &mut rng))
    }

    #[test]
    fn spec_mirrors_model() {
        let b = tiny_oracle();
        assert_eq!(b.spec().input_shape, (1, 20, 20));
        assert_eq!(b.spec().batch_buckets, vec![1, 2, 4, 8]);
        assert!(b.spec().max_replicas.is_none());
    }

    #[test]
    fn batched_infer_matches_per_image_forward() {
        let mut b = tiny_oracle();
        let mut rng = Rng::new(6);
        let images: Vec<Tensor> = (0..4)
            .map(|_| Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0)))
            .collect();
        let out = b.infer(&InferRequest::new(images.clone())).unwrap();
        for (img, got) in images.iter().zip(&out.lengths) {
            let want = b.net.forward(img).unwrap().class_lengths();
            assert_eq!(got, &want);
        }
    }
}
