//! The sparsity-aware FPGA simulator behind the unified API
//! (`"sim-sparse"`): the fixed-point counterpart of
//! [`super::SparseOracleBackend`].
//!
//! Where [`super::SimBackend`] serves the paper's *compacted* preset
//! architectures, this backend deploys the **full** paper architecture
//! LAKP-pruned at the deployment plan's survivor counts
//! ([`crate::config::SystemConfig::masked`]) onto the Q-format datapath:
//! the conv modules store and execute only the CSR-packed survivors
//! (bit-exact to masking the dense tensor — the fpga property tests pin
//! it), the ~80 KB of packed weights live on-chip instead of replaying
//! over DDR (the uncompacted 1152-capsule û still spills — the step the
//! compacted presets eliminate), and the cycle model prices only
//! surviving kernels. The spec
//! therefore reports *both* the pipelined timing
//! ([`BackendSpec::reports_timing`]) and the packing's
//! [`crate::capsnet::compiled::CompressionStats`]
//! ([`BackendSpec::compression`]) — the modeled-FPS-vs-compression story
//! the paper's Fig. 1 tells, servable behind the coordinator.

use super::{BackendConfig, BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use crate::capsnet::weights::Weights;
use crate::config::SystemConfig;
use crate::fpga::{BatchScratch, DeployedModel};
use crate::pruning::NetworkMasks;
use crate::util::rng::Rng;

pub struct SimSparseBackend {
    model: DeployedModel,
    workers: usize,
    spec: BackendSpec,
    scratch: BatchScratch,
}

impl SimSparseBackend {
    /// Wrap an already-deployed (CSR-packed, quantized) model. The spec
    /// reports whatever the modules actually pack, so this also serves
    /// hand-pruned deployments (the `fastcaps prune --serve --backend
    /// sim-sparse` path).
    pub fn new(model: DeployedModel) -> SimSparseBackend {
        SimSparseBackend::with_workers(model, 1)
    }

    /// Wrap a deployed model, sharding each batch over up to `workers`
    /// cores. Routing mode and baked coefficients live on the model and
    /// are already part of [`DeployedModel::fingerprint`].
    pub fn with_workers(model: DeployedModel, workers: usize) -> SimSparseBackend {
        let stats = model.compression();
        let workers = workers.max(1);
        let spec = BackendSpec {
            kind: "sim-sparse".into(),
            model: format!("{}-sparse", model.config.model.name),
            input_shape: model.config.model.input,
            // Same wide ladder as `sim`: marginal frames cost one
            // initiation interval in the pipelined cycle model.
            batch_buckets: BackendSpec::pow2_buckets(16),
            reports_timing: true,
            max_replicas: None,
            compression: Some(stats),
            fingerprint: BackendSpec::deployment_fingerprint(
                "sim-sparse",
                &model.config.model.name,
                model.fingerprint(),
            ),
            routing: model.routing.to_string(),
            workers,
            coupling_fingerprint: model.acc_coupling().map(|c| {
                super::coupling_fingerprint(
                    &c.iter().map(|q| q.to_f32()).collect::<Vec<_>>(),
                )
            }),
        }
        .normalize();
        SimSparseBackend {
            model,
            workers,
            spec,
            scratch: BatchScratch::new(),
        }
    }

    /// Registry factory: the full paper architecture for the dataset,
    /// LAKP-pruned at the paper plan's survivor counts and deployed on
    /// the fixed-point datapath. Weights resolve like `oracle-sparse`
    /// ([`BackendConfig::full_weights_path`]): explicit override →
    /// `weights-<dataset>-full.fcw` → seeded random (predictions are
    /// noise, but the prune→deploy→serve path runs end to end).
    pub fn from_config(cfg: &BackendConfig) -> Result<SimSparseBackend, BackendError> {
        let sys = SystemConfig::masked(if cfg.is_fmnist() { "fmnist" } else { "mnist" });
        let weights = match cfg.full_weights_path() {
            Some(path) => {
                let w = Weights::load(&path)
                    .map_err(|e| BackendError::Init(format!("loading {path:?}: {e:#}")))?;
                w.validate(&sys.model).map_err(|e| {
                    BackendError::Init(format!(
                        "sim-sparse deploys the full architecture; weights mismatch: {e:#}"
                    ))
                })?;
                w
            }
            None => Weights::random(&sys.model, &mut Rng::new(cfg.seed)),
        };
        let masks = NetworkMasks::from_plan(&weights, &sys.model, &sys.sparsity);
        let mut model = DeployedModel::new(sys, &weights, &masks.conv1, &masks.pc)
            .map_err(|e| BackendError::Init(format!("sparse deployment: {e:#}")))?;
        super::sim::bake_from_config(&mut model, cfg)?;
        Ok(SimSparseBackend::with_workers(model, cfg.worker_count()))
    }

    pub fn model(&self) -> &DeployedModel {
        &self.model
    }
}

impl InferenceBackend for SimSparseBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        let out = if self.workers > 1 && req.images.len() > 1 {
            self.model.run_batch_sharded(&req.images, self.workers)
        } else {
            self.model.run_batch(&req.images, &mut self.scratch)
        }
        .map_err(|e| BackendError::Execution(format!("sim-sparse batch: {e:#}")))?;
        Ok(InferOutput {
            lengths: out.lengths,
            frame_latency_s: Some(out.timing.frame.latency_s()),
            batch_latency_s: Some(out.timing.latency_s()),
            steady_state_fps: Some(out.timing.steady_state_fps()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Task};
    use std::path::PathBuf;

    fn no_artifacts() -> BackendConfig {
        BackendConfig {
            artifacts: PathBuf::from("/nonexistent/artifacts"),
            ..BackendConfig::default()
        }
    }

    #[test]
    fn spec_reports_compression_and_timing_at_plan_counts() {
        let b = SimSparseBackend::from_config(&no_artifacts()).unwrap();
        let spec = b.spec();
        assert_eq!(spec.kind, "sim-sparse");
        assert!(spec.reports_timing);
        assert_eq!(spec.input_shape, (1, 28, 28));
        let c = spec.compression.as_ref().unwrap();
        assert_eq!(c.survived_kernels, 64 + 423);
        assert_eq!(c.total_kernels, 256 + 65536);
        assert!(c.pruned_pct() > 99.0);
        // And the conv modules store only the survivors.
        assert_eq!(
            b.model().conv1.weights.len() + b.model().pc.weights.len(),
            (64 + 423) * 81
        );
    }

    #[test]
    fn served_lengths_match_direct_run_frame_and_report_pipelined_timing() {
        let mut b = SimSparseBackend::from_config(&no_artifacts()).unwrap();
        let direct = b.model().clone();
        let data = generate(Task::Digits, 2, 19);
        let out = b.infer(&InferRequest::new(data.images.clone())).unwrap();
        for (img, got) in data.images.iter().zip(&out.lengths) {
            let (_, want, _) = direct.run_frame(img).unwrap();
            assert_eq!(got, &want, "served vs direct sparse sim");
        }
        let frame = out.frame_latency_s.unwrap();
        let batch = out.batch_latency_s.unwrap();
        // The uncompacted û spill leaves the masked deployment DDR-bound,
        // so the serial û stream floors the initiation interval: the
        // 2-frame batch costs at most two full frames and steady-state
        // FPS sits at (or above) the 1/latency rate — never below it.
        assert!(batch > frame && batch <= 2.0 * frame, "{batch} vs {frame}");
        assert!(out.steady_state_fps.unwrap() >= 0.99 / frame);
    }

    #[test]
    fn steady_state_dominates_the_dense_sim() {
        // The serving-level view of the acceptance criterion: the
        // sparse sim's modeled steady-state FPS strictly beats the
        // dense (original) sim's on the same traffic.
        let sparse = SimSparseBackend::from_config(&no_artifacts()).unwrap();
        let dense_cfg = SystemConfig::original("mnist");
        let dense = DeployedModel::timing_stub(&dense_cfg, 7);
        assert!(
            sparse.model().estimate_batch(8).steady_state_fps()
                > dense.estimate_batch(8).steady_state_fps()
        );
    }

    #[test]
    fn accumulated_mode_rekeys_and_boosts_modeled_fps() {
        let iter = SimSparseBackend::from_config(&no_artifacts()).unwrap();
        let acc_cfg = BackendConfig {
            routing: Some(crate::routing::RoutingMode::Accumulated),
            ..no_artifacts()
        };
        let acc = SimSparseBackend::from_config(&acc_cfg).unwrap();
        // Satellite pin: iterative and accumulated deployments of the
        // same weights never share a cache key.
        assert_ne!(iter.spec().fingerprint, acc.spec().fingerprint);
        assert_eq!(iter.spec().routing, "iterative(3)");
        assert_eq!(acc.spec().routing, "accumulated");
        assert!(acc.spec().coupling_fingerprint.is_some());
        assert!(iter.spec().coupling_fingerprint.is_none());
        // Dropping the routing iterations shrinks both the routing stage
        // and the û DDR spill, so modeled sustained FPS strictly rises.
        assert!(
            acc.model().estimate_batch(16).steady_state_fps()
                > iter.model().estimate_batch(16).steady_state_fps()
        );
    }
}
