//! The PJRT runtime (AOT-lowered HLO) behind the unified API.
//!
//! PJRT executables wrap raw pointers and are single-owner by design in
//! this crate, so [`BackendSpec::max_replicas`] is pinned to 1 — the
//! coordinator keeps the one replica on its own thread and never clones
//! or shares engines. Each batch bucket is its own compiled executable;
//! the spec's buckets are exactly the engines loaded from the manifest.
//!
//! Built without the `pjrt` cargo feature, [`crate::runtime`] is a stub
//! whose `Runtime::open` fails, so [`PjrtBackend::from_config`] surfaces
//! a typed [`BackendError::Unsupported`]/[`BackendError::Init`] instead
//! of ever constructing a dead backend.

use super::{BackendConfig, BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use crate::runtime::{Engine, Runtime};

pub struct PjrtBackend {
    engines: Vec<Engine>,
    spec: BackendSpec,
}

impl PjrtBackend {
    /// Wrap loaded engines (one per batch bucket, same model).
    pub fn new(engines: Vec<Engine>) -> Result<PjrtBackend, BackendError> {
        if engines.is_empty() {
            return Err(BackendError::Init("need at least one engine".into()));
        }
        let entry = &engines[0].entry;
        if entry.input_shape.len() != 4 {
            return Err(BackendError::Init(format!(
                "expected NCHW input shape, got {:?}",
                entry.input_shape
            )));
        }
        let spec = BackendSpec {
            kind: "pjrt".into(),
            model: entry.model.clone(),
            input_shape: (
                entry.input_shape[1],
                entry.input_shape[2],
                entry.input_shape[3],
            ),
            batch_buckets: engines.iter().map(|e| e.batch_size()).collect(),
            reports_timing: false,
            max_replicas: Some(1),
            compression: None,
            // Weight bits live inside opaque AOT artifacts, so the
            // content hash is over the manifest identity (model, artifact
            // files, shapes, buckets) — weaker than the native backends'
            // bit-level fingerprints, but re-exported artifacts get new
            // manifest entries, which is the redeploy signal we have.
            fingerprint: BackendSpec::deployment_fingerprint("pjrt", &entry.model, {
                let mut h = crate::util::hash::Hash64::new(0x706a_7274); // "pjrt"
                for e in &engines {
                    h.absorb_str(&e.entry.name);
                    h.absorb_str(&e.entry.file);
                    h.absorb(e.entry.batch as u64);
                    h.absorb(e.entry.num_classes as u64);
                    h.absorb(e.entry.input_shape.len() as u64);
                    for &d in &e.entry.input_shape {
                        h.absorb(d as u64);
                    }
                }
                h.finish()
            }),
            // The routing schedule is frozen inside the AOT artifact at
            // export time; nothing here re-derives or overrides it.
            routing: "aot".into(),
            workers: 1,
            coupling_fingerprint: None,
        }
        .normalize();
        Ok(PjrtBackend { engines, spec })
    }

    /// Registry factory: open the artifact directory and load one engine
    /// per manifest bucket for the configured model.
    pub fn from_config(cfg: &BackendConfig) -> Result<PjrtBackend, BackendError> {
        let rt = Runtime::open(&cfg.artifacts).map_err(|e| {
            if cfg!(feature = "pjrt") {
                BackendError::Init(format!("{e:#}"))
            } else {
                // The stub runtime: PJRT support is not compiled in.
                BackendError::Unsupported(format!("{e:#}"))
            }
        })?;
        let weights = cfg.weights_path();
        let mut engines = Vec::new();
        for b in rt.batch_buckets(&cfg.model) {
            engines.push(rt.engine(&cfg.model, b, &weights).map_err(|e| {
                BackendError::Init(format!("loading {} (batch {b}): {e:#}", cfg.model))
            })?);
        }
        if engines.is_empty() {
            return Err(BackendError::Init(format!(
                "no artifacts for model '{}' in {}",
                cfg.model,
                cfg.artifacts.display()
            )));
        }
        PjrtBackend::new(engines)
    }
}

impl InferenceBackend for PjrtBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        let engine = self
            .engines
            .iter()
            .find(|e| e.batch_size() == req.batch())
            .ok_or_else(|| {
                BackendError::InvalidRequest(format!("no engine for bucket {}", req.batch()))
            })?;
        let lengths = engine
            .run_batch(&req.images)
            .map_err(|e| BackendError::Execution(format!("pjrt batch: {e:#}")))?;
        Ok(InferOutput::untimed(lengths))
    }
}
