//! The unified execution API (S9): every way of running the CapsNet —
//! the fp32 oracle, the fixed-point FPGA simulator, and the PJRT
//! runtime — is served through one batch-first [`InferenceBackend`]
//! trait, described by a [`BackendSpec`] and constructed uniformly from
//! a string-keyed [`BackendRegistry`].
//!
//! ```text
//!   BackendRegistry ("oracle" | "oracle-sparse" | "sim" | "sim-sparse" | "pjrt")
//!                     │ build(name, &BackendConfig)
//!                     ▼
//!              Box<dyn InferenceBackend>
//!        ┌───────────┬───────┼─────────────┬──────────────┐
//!        ▼           ▼       ▼             ▼              ▼
//!  OracleBackend SparseOracle SimBackend SimSparseBackend PjrtBackend
//!  (capsnet fp32) (compiled    (fpga      (fpga Q-path,   (runtime HLO)
//!                  sparse fp32) Q-path)    CSR survivors)
//! ```
//!
//! The coordinator ([`crate::coordinator::server`]) schedules batches
//! onto a pool of backend *replicas*; [`BackendSpec::max_replicas`]
//! tells it how many instances may run concurrently (PJRT executables
//! are single-owner here, so [`PjrtBackend`] pins it to 1).
//!
//! Errors at this boundary are the typed [`BackendError`] enum, not
//! `anyhow`, so callers can distinguish overload from malformed input
//! from engine failure.

pub mod oracle;
pub mod pjrt;
pub mod sim;
pub mod sim_sparse;
pub mod sparse;

pub use oracle::OracleBackend;
pub use pjrt::PjrtBackend;
pub use sim::SimBackend;
pub use sim_sparse::SimSparseBackend;
pub use sparse::SparseOracleBackend;

use crate::capsnet::compiled::CompressionStats;
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;

/// Typed error at the execution-API boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendError {
    /// Backend construction failed (missing artifacts, bad config, ...).
    Init(String),
    /// The request is malformed (wrong image shape, unknown bucket, ...).
    InvalidRequest(String),
    /// The engine failed while executing a well-formed request.
    Execution(String),
    /// The server rejected the request at admission (queue at capacity).
    QueueFull { depth: usize },
    /// The server is shut down (or never came up) and accepts no work.
    Unavailable(String),
    /// The capability is not compiled in or not supported by this build.
    Unsupported(String),
}

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendError::Init(m) => write!(f, "backend init failed: {m}"),
            BackendError::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            BackendError::Execution(m) => write!(f, "backend execution failed: {m}"),
            BackendError::QueueFull { depth } => {
                write!(f, "request rejected: queue full (max depth {depth})")
            }
            BackendError::Unavailable(m) => write!(f, "server unavailable: {m}"),
            BackendError::Unsupported(m) => write!(f, "unsupported: {m}"),
        }
    }
}

impl std::error::Error for BackendError {}

/// A batch of CHW images to classify. The batch size must be one of the
/// backend's [`BackendSpec::batch_buckets`]; schedulers pad short
/// batches up to a bucket before calling [`InferenceBackend::infer`].
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub images: Vec<Tensor>,
}

impl InferRequest {
    pub fn new(images: Vec<Tensor>) -> InferRequest {
        InferRequest { images }
    }

    pub fn batch(&self) -> usize {
        self.images.len()
    }
}

/// Batched inference result.
#[derive(Debug, Clone)]
pub struct InferOutput {
    /// DigitCaps lengths (class scores) per image: `[batch][num_classes]`.
    pub lengths: Vec<Vec<f32>>,
    /// Modeled on-device latency of one frame in isolation, when the
    /// backend reports timing ([`BackendSpec::reports_timing`]);
    /// `None` otherwise.
    pub frame_latency_s: Option<f64>,
    /// Modeled on-device time for the whole batch under the pipelined
    /// cycle model ([`crate::fpga::BatchTiming`]): the first frame's full
    /// latency plus one initiation interval per further frame — *not*
    /// `batch × frame_latency_s`.
    pub batch_latency_s: Option<f64>,
    /// Modeled steady-state throughput once the accelerator's stage
    /// pipeline is full (frames/s) — the sustained-serving number.
    pub steady_state_fps: Option<f64>,
}

impl InferOutput {
    /// An output with no modeled timing — for backends (oracle, PJRT,
    /// test fakes) whose [`BackendSpec::reports_timing`] is false.
    pub fn untimed(lengths: Vec<Vec<f32>>) -> InferOutput {
        InferOutput {
            lengths,
            frame_latency_s: None,
            batch_latency_s: None,
            steady_state_fps: None,
        }
    }

    /// Argmax class per image (NaN-safe total order).
    pub fn predicted(&self) -> Vec<usize> {
        self.lengths.iter().map(|l| crate::util::argmax(l)).collect()
    }
}

/// Static description of one backend instance's capabilities. The
/// coordinator derives its batch policy, padding shape, and replica
/// count from this — backends never see scheduling concerns.
#[derive(Debug, Clone)]
pub struct BackendSpec {
    /// Registry key this backend answers to (`"oracle"`, `"sim"`, ...).
    pub kind: String,
    /// Model the backend executes (e.g. `capsnet-mnist-pruned`).
    pub model: String,
    /// Input image shape (C, H, W); the scheduler pads blanks with it.
    pub input_shape: (usize, usize, usize),
    /// Batch sizes the backend accepts, ascending and deduplicated.
    pub batch_buckets: Vec<usize>,
    /// Whether [`InferOutput::frame_latency_s`] is populated.
    pub reports_timing: bool,
    /// Maximum concurrently running instances (`None` = unbounded).
    /// PJRT executables are single-owner, so that backend pins 1.
    pub max_replicas: Option<usize>,
    /// Kernel-compression metadata when the backend executes a
    /// sparse-compiled model (`oracle-sparse`): survivor counts and the
    /// §III-C index-memory cost. `None` for dense execution paths.
    pub compression: Option<CompressionStats>,
    /// Deployment fingerprint: a content hash over the backend kind,
    /// model/dataset name, and the deployed weight (and mask) bits.
    /// The inference cache ([`crate::cache`]) mixes it into every key,
    /// so two deployments that could answer the same input differently
    /// (different weights, different pruning masks, different backend)
    /// can never alias — a redeploy invalidates by construction. `0`
    /// means "no fingerprint" and disables cache reuse guarantees
    /// (test-only backends that don't care may leave it 0).
    pub fingerprint: u64,
    /// Active routing schedule in display form (`"iterative(3)"` /
    /// `"accumulated"`) for banners and metrics. The *content* of the
    /// mode (including baked coefficients) is folded into
    /// [`BackendSpec::fingerprint`] by the executor's own content hash.
    pub routing: String,
    /// Worker threads each replica shards a batch over. Display /
    /// scheduling metadata only — never part of the fingerprint, because
    /// sharding is bit-identical by construction
    /// ([`crate::util::parallel`]).
    pub workers: usize,
    /// Content hash of the baked accumulated-coupling matrix when the
    /// backend serves in accumulated mode (`None` for iterative): the
    /// banner surfaces it so operators can confirm which calibration
    /// artifact a replica is actually serving.
    pub coupling_fingerprint: Option<u64>,
}

impl BackendSpec {
    /// Digest a deployment identity into a [`BackendSpec::fingerprint`]:
    /// the backend kind and model name (two executors can answer the
    /// same weights differently — `oracle` in f32 vs `sim` in Q8.8),
    /// plus a content hash of the deployed weight/mask bits computed by
    /// the model type itself (`Weights::fingerprint`,
    /// `CompiledCapsNet::fingerprint`, `DeployedModel::fingerprint`).
    pub fn deployment_fingerprint(kind: &str, model: &str, content: u64) -> u64 {
        let mut h = crate::util::hash::Hash64::new(0x6465_706c_6f79); // "deploy"
        h.absorb_str(kind);
        h.absorb_str(model);
        h.absorb(content);
        h.finish()
    }

    /// Normalize buckets (sorted, deduplicated, non-empty is asserted by
    /// constructors).
    pub fn normalize(mut self) -> BackendSpec {
        self.batch_buckets.sort_unstable();
        self.batch_buckets.dedup();
        self
    }

    /// Elements in one input image (C·H·W).
    pub fn input_elems(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }

    /// Exact byte count of one image on the wire (f32-le words): the
    /// network front-end validates classify payloads against this, so
    /// shape checking at the socket boundary is spec-driven, not
    /// duplicated per call site.
    pub fn input_wire_bytes(&self) -> usize {
        self.input_elems() * std::mem::size_of::<f32>()
    }

    /// One-line routing/worker summary for the serve banner and metrics:
    /// `routing=accumulated workers=4 simd=avx2 coupling=0x…` (the
    /// coupling hash only appears in accumulated mode). `simd` is the
    /// active kernel dispatch — like `workers`, it is runtime metadata
    /// and deliberately not part of any deployment fingerprint (kernels
    /// are bit-identical across dispatch levels).
    pub fn routing_summary(&self) -> String {
        let mut s = format!(
            "routing={} workers={} simd={}",
            self.routing,
            self.workers,
            crate::kernels::active_name()
        );
        if let Some(fp) = self.coupling_fingerprint {
            s.push_str(&format!(" coupling={fp:#018x}"));
        }
        s
    }

    /// Canonical bucket ladder for host-synchronous backends: powers of
    /// two up to `max` (inclusive when `max` itself is a power of two).
    /// The single owner of bucket policy — `oracle` and `sim` size their
    /// ladders here instead of copy-pasting literals; PJRT derives its
    /// buckets from the compiled artifacts in the manifest.
    pub fn pow2_buckets(max: usize) -> Vec<usize> {
        let mut buckets = Vec::new();
        let mut b = 1usize;
        while b <= max.max(1) {
            buckets.push(b);
            b *= 2;
        }
        buckets
    }
}

/// The single execution API: run one padded batch, synchronously.
///
/// Implementations own their engine state (`&mut self`) — concurrency
/// comes from the coordinator running N independent replicas, not from
/// sharing one instance across threads.
pub trait InferenceBackend: Send {
    fn spec(&self) -> &BackendSpec;

    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError>;

    /// Validate a request against the spec (shared by implementations).
    fn validate(&self, req: &InferRequest) -> Result<(), BackendError> {
        let spec = self.spec();
        if !spec.batch_buckets.contains(&req.batch()) {
            return Err(BackendError::InvalidRequest(format!(
                "batch {} not in buckets {:?}",
                req.batch(),
                spec.batch_buckets
            )));
        }
        let (c, h, w) = spec.input_shape;
        for img in &req.images {
            if img.shape != [c, h, w] {
                return Err(BackendError::InvalidRequest(format!(
                    "image shape {:?} != backend input {:?}",
                    img.shape,
                    (c, h, w)
                )));
            }
        }
        Ok(())
    }
}

/// Everything a factory may need to construct a backend. One struct for
/// all kinds so `serve`, benches, and examples configure uniformly.
#[derive(Debug, Clone)]
pub struct BackendConfig {
    /// Dataset the traffic comes from (`"mnist"` or `"fmnist"`).
    pub dataset: String,
    /// Model name for artifact lookup (PJRT) and reporting.
    pub model: String,
    /// Accelerator config variant for the simulator
    /// (`"original" | "pruned" | "proposed"`).
    pub variant: String,
    /// Artifact directory (PJRT manifest + `.fcw` weights).
    pub artifacts: PathBuf,
    /// Optional explicit `.fcw` weights path; derived from `dataset`
    /// inside `artifacts` when `None`.
    pub weights: Option<PathBuf>,
    /// Seed for synthetic weights where no trained weights exist.
    pub seed: u64,
    /// Routing-schedule override for executors that route (`oracle`,
    /// `oracle-sparse`, `sim`, `sim-sparse`). `None` = the model
    /// config's iterative schedule (the pre-existing behavior);
    /// `Some(Accumulated)` makes the factory load sidecar coefficients
    /// or self-calibrate at construction.
    pub routing: Option<crate::routing::RoutingMode>,
    /// Worker threads each replica shards a batch over (≤ 1 = serial).
    pub workers: usize,
}

impl Default for BackendConfig {
    fn default() -> Self {
        BackendConfig {
            dataset: "mnist".into(),
            model: "capsnet-mnist-pruned".into(),
            variant: "proposed".into(),
            artifacts: PathBuf::from("artifacts"),
            weights: None,
            seed: 7,
            routing: None,
            workers: 1,
        }
    }
}

impl BackendConfig {
    /// Whether the dataset is the F-MNIST-like task (accepts both the
    /// `fmnist` name and its `garments` task alias).
    pub fn is_fmnist(&self) -> bool {
        self.dataset.contains("fmnist") || self.dataset.contains("garments")
    }

    /// The `.fcw` weights path: explicit override or the conventional
    /// per-dataset file in the artifact directory.
    pub fn weights_path(&self) -> PathBuf {
        match &self.weights {
            Some(p) => p.clone(),
            None => self.artifacts.join(if self.is_fmnist() {
                "weights-fmnist.fcw"
            } else {
                "weights-mnist.fcw"
            }),
        }
    }

    /// The *full-architecture* `.fcw` weights the prune-at-deploy
    /// backends (`oracle-sparse`, `sim-sparse`) consume: an explicit
    /// [`BackendConfig::weights`] override, else the conventional
    /// `weights-<dataset>-full.fcw` in the artifact directory when it
    /// exists. `None` means fall back to seeded random weights.
    pub fn full_weights_path(&self) -> Option<PathBuf> {
        match &self.weights {
            Some(p) => Some(p.clone()),
            None => {
                let conventional = self.artifacts.join(if self.is_fmnist() {
                    "weights-fmnist-full.fcw"
                } else {
                    "weights-mnist-full.fcw"
                });
                conventional.exists().then_some(conventional)
            }
        }
    }

    /// The effective routing mode for a model config: the explicit
    /// override, else the model's iterative schedule.
    pub fn routing_mode(&self, model: &crate::config::CapsNetConfig) -> crate::routing::RoutingMode {
        self.routing
            .unwrap_or(crate::routing::RoutingMode::Iterative(model.routing_iters))
    }

    /// Worker count clamped to at least one.
    pub fn worker_count(&self) -> usize {
        self.workers.max(1)
    }

    /// The simulator/oracle system config for this dataset + variant
    /// (dataset canonicalized so task aliases pick the right model).
    pub fn system_config(&self) -> crate::config::SystemConfig {
        use crate::config::SystemConfig;
        let dataset = if self.is_fmnist() { "fmnist" } else { "mnist" };
        match self.variant.as_str() {
            "original" => SystemConfig::original(dataset),
            "pruned" => SystemConfig::pruned(dataset),
            _ => SystemConfig::proposed(dataset),
        }
    }
}

/// Frames in the deterministic calibration set the factories use for
/// the offline accumulation pass when no `.fcw` sidecar provides
/// coefficients.
pub const CALIBRATION_FRAMES: usize = 32;

/// Deterministic calibration set for the offline accumulation pass:
/// `frames` synthetic frames from the dataset's generator at a fixed
/// seed, so every replica (and every rebuild) bakes bit-identical
/// coefficients — replicas of one deployment must share one
/// fingerprint.
pub fn calibration_set(cfg: &BackendConfig, frames: usize) -> Vec<Tensor> {
    let task = if cfg.is_fmnist() {
        crate::data::Task::Garments
    } else {
        crate::data::Task::Digits
    };
    crate::data::generate(task, frames, 0xacc0).images
}

/// Content hash of an f32 accumulated-coupling matrix, surfaced as
/// [`BackendSpec::coupling_fingerprint`]. (Executors separately fold
/// the same coefficients into their own content fingerprints — this one
/// exists for the banner, not the cache.)
pub fn coupling_fingerprint(coupling: &[f32]) -> u64 {
    let mut h = crate::util::hash::Hash64::new(0x6370_6c67); // "cplg"
    h.absorb_f32s(coupling);
    h.finish()
}

/// Factory signature: build one backend replica from a config.
pub type BackendFactory =
    Box<dyn Fn(&BackendConfig) -> Result<Box<dyn InferenceBackend>, BackendError> + Send + Sync>;

/// String-keyed registry of backend factories. `serve`, benches, and
/// examples all construct backends through here, so a new execution
/// path is one `register` call away from being servable.
pub struct BackendRegistry {
    factories: BTreeMap<String, BackendFactory>,
}

impl Default for BackendRegistry {
    fn default() -> Self {
        BackendRegistry::with_defaults()
    }
}

impl BackendRegistry {
    /// An empty registry (tests register their own fakes).
    pub fn new() -> BackendRegistry {
        BackendRegistry {
            factories: BTreeMap::new(),
        }
    }

    /// The built-in execution paths: `"oracle"`, `"oracle-sparse"`,
    /// `"sim"`, `"sim-sparse"`, `"pjrt"`.
    pub fn with_defaults() -> BackendRegistry {
        let mut r = BackendRegistry::new();
        r.register("oracle", |cfg| {
            Ok(Box::new(OracleBackend::from_config(cfg)?) as Box<dyn InferenceBackend>)
        });
        r.register("oracle-sparse", |cfg| {
            Ok(Box::new(SparseOracleBackend::from_config(cfg)?) as Box<dyn InferenceBackend>)
        });
        r.register("sim", |cfg| {
            Ok(Box::new(SimBackend::from_config(cfg)?) as Box<dyn InferenceBackend>)
        });
        r.register("sim-sparse", |cfg| {
            Ok(Box::new(SimSparseBackend::from_config(cfg)?) as Box<dyn InferenceBackend>)
        });
        r.register("pjrt", |cfg| {
            Ok(Box::new(PjrtBackend::from_config(cfg)?) as Box<dyn InferenceBackend>)
        });
        r
    }

    pub fn register<F>(&mut self, name: &str, factory: F)
    where
        F: Fn(&BackendConfig) -> Result<Box<dyn InferenceBackend>, BackendError>
            + Send
            + Sync
            + 'static,
    {
        self.factories.insert(name.to_string(), Box::new(factory));
    }

    /// Construct a backend by registry key.
    pub fn build(
        &self,
        name: &str,
        cfg: &BackendConfig,
    ) -> Result<Box<dyn InferenceBackend>, BackendError> {
        match self.factories.get(name) {
            Some(f) => f(cfg),
            None => Err(BackendError::Init(format!(
                "unknown backend '{name}' (available: {})",
                self.names().join(", ")
            ))),
        }
    }

    pub fn names(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_builtin_paths() {
        let r = BackendRegistry::with_defaults();
        assert_eq!(
            r.names(),
            vec!["oracle", "oracle-sparse", "pjrt", "sim", "sim-sparse"]
        );
    }

    #[test]
    fn input_wire_bytes_follow_spec_shape() {
        let r = BackendRegistry::with_defaults();
        let b = r.build("sim", &BackendConfig::default()).unwrap();
        let spec = b.spec();
        assert_eq!(spec.input_shape, (1, 28, 28));
        assert_eq!(spec.input_elems(), 784);
        assert_eq!(spec.input_wire_bytes(), 3136);
    }

    #[test]
    fn pow2_bucket_ladder() {
        assert_eq!(BackendSpec::pow2_buckets(8), vec![1, 2, 4, 8]);
        assert_eq!(BackendSpec::pow2_buckets(16), vec![1, 2, 4, 8, 16]);
        // Non-power-of-two caps truncate below the cap; zero still
        // yields a servable single-frame bucket.
        assert_eq!(BackendSpec::pow2_buckets(6), vec![1, 2, 4]);
        assert_eq!(BackendSpec::pow2_buckets(0), vec![1]);
    }

    #[test]
    fn sim_reports_batch_timing() {
        let r = BackendRegistry::with_defaults();
        let mut b = r.build("sim", &BackendConfig::default()).unwrap();
        let (c, h, w) = b.spec().input_shape;
        let bucket = *b.spec().batch_buckets.last().unwrap();
        let req = InferRequest::new(vec![Tensor::zeros(&[c, h, w]); bucket]);
        let out = b.infer(&req).unwrap();
        let frame = out.frame_latency_s.unwrap();
        let batch = out.batch_latency_s.unwrap();
        let steady = out.steady_state_fps.unwrap();
        // Pipelining: the batch costs more than one frame but less than
        // `bucket` serial frames, and sustained FPS beats 1/latency.
        assert!(batch > frame, "batch {batch} vs frame {frame}");
        assert!(batch < bucket as f64 * frame);
        assert!(steady > 1.0 / frame);
    }

    #[test]
    fn unknown_backend_is_typed_init_error() {
        let r = BackendRegistry::with_defaults();
        match r.build("tpu", &BackendConfig::default()) {
            Err(BackendError::Init(m)) => assert!(m.contains("tpu"), "{m}"),
            other => panic!("expected Init error, got {other:?}"),
        }
    }

    #[test]
    fn sim_and_oracle_build_and_infer_one_bucket() {
        let r = BackendRegistry::with_defaults();
        let cfg = BackendConfig {
            // Nonexistent artifact dir: the oracle paths fall back to
            // seeded random weights instead of depending on local files.
            artifacts: PathBuf::from("/nonexistent/artifacts"),
            ..BackendConfig::default()
        };
        for kind in ["sim", "oracle", "oracle-sparse", "sim-sparse"] {
            let mut b = r.build(kind, &cfg).unwrap();
            let spec = b.spec().clone();
            assert_eq!(spec.kind, kind);
            assert!(!spec.batch_buckets.is_empty());
            let (c, h, w) = spec.input_shape;
            let bucket = spec.batch_buckets[0];
            let req = InferRequest::new(vec![Tensor::zeros(&[c, h, w]); bucket]);
            let out = b.infer(&req).unwrap();
            assert_eq!(out.lengths.len(), bucket);
            assert!(out.lengths.iter().all(|l| l.len() == 10));
            assert_eq!(out.frame_latency_s.is_some(), spec.reports_timing);
        }
    }

    #[test]
    fn invalid_batch_rejected_with_typed_error() {
        let r = BackendRegistry::with_defaults();
        let mut b = r.build("sim", &BackendConfig::default()).unwrap();
        let (c, h, w) = b.spec().input_shape;
        let bogus = 1 + b.spec().batch_buckets.last().unwrap();
        let req = InferRequest::new(vec![Tensor::zeros(&[c, h, w]); bogus]);
        assert!(matches!(
            b.infer(&req),
            Err(BackendError::InvalidRequest(_))
        ));
    }

    #[test]
    fn wrong_shape_rejected() {
        let r = BackendRegistry::with_defaults();
        let mut b = r.build("sim", &BackendConfig::default()).unwrap();
        let req = InferRequest::new(vec![Tensor::zeros(&[1, 2, 2])]);
        assert!(matches!(
            b.infer(&req),
            Err(BackendError::InvalidRequest(_))
        ));
    }

    #[test]
    fn pjrt_without_artifacts_is_typed_error() {
        let cfg = BackendConfig {
            artifacts: PathBuf::from("/nonexistent/artifacts"),
            ..BackendConfig::default()
        };
        let r = BackendRegistry::with_defaults();
        let e = r.build("pjrt", &cfg).unwrap_err();
        assert!(
            matches!(e, BackendError::Init(_) | BackendError::Unsupported(_)),
            "{e:?}"
        );
    }

    #[test]
    fn error_display_is_informative() {
        let e = BackendError::QueueFull { depth: 64 };
        assert!(e.to_string().contains("64"));
        let e = BackendError::InvalidRequest("batch 3".into());
        assert!(e.to_string().contains("batch 3"));
    }
}
