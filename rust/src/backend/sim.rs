//! The fixed-point FPGA accelerator simulator behind the unified API.
//! Functional Q8.8/Q4.12 datapath per frame, plus the modeled on-device
//! frame latency ([`BackendSpec::reports_timing`] = true) so serving
//! metrics can be cross-checked against the cycle model.

use super::{BackendConfig, BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use crate::fpga::DeployedModel;

pub struct SimBackend {
    model: DeployedModel,
    spec: BackendSpec,
}

impl SimBackend {
    /// Wrap a deployed (quantized + masked) model.
    pub fn new(model: DeployedModel) -> SimBackend {
        let spec = BackendSpec {
            kind: "sim".into(),
            model: model.config.model.name.clone(),
            input_shape: model.config.model.input,
            batch_buckets: vec![1, 2, 4, 8],
            reports_timing: true,
            max_replicas: None,
        }
        .normalize();
        SimBackend { model, spec }
    }

    /// Registry factory: synthetic deployment of the configured variant
    /// (`original`/`pruned`/`proposed`) for the dataset.
    pub fn from_config(cfg: &BackendConfig) -> Result<SimBackend, BackendError> {
        let sys = cfg.system_config();
        Ok(SimBackend::new(DeployedModel::synthetic(&sys, cfg.seed)))
    }

    pub fn model(&self) -> &DeployedModel {
        &self.model
    }
}

impl InferenceBackend for SimBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        let mut lengths = Vec::with_capacity(req.batch());
        let mut latency = None;
        for img in &req.images {
            let (_, lens, timing) = self
                .model
                .run_frame(img)
                .map_err(|e| BackendError::Execution(format!("sim frame: {e:#}")))?;
            latency = Some(timing.latency_s());
            lengths.push(lens);
        }
        Ok(InferOutput {
            lengths,
            frame_latency_s: latency,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::data::{generate, Task};

    #[test]
    fn served_lengths_match_direct_run_frame() {
        let cfg = SystemConfig::proposed("mnist");
        let direct = DeployedModel::synthetic(&cfg, 9);
        let mut b = SimBackend::new(DeployedModel::synthetic(&cfg, 9));
        let data = generate(Task::Digits, 2, 77);
        let out = b.infer(&InferRequest::new(data.images.clone())).unwrap();
        for (img, got) in data.images.iter().zip(&out.lengths) {
            let (_, want, _) = direct.run_frame(img).unwrap();
            assert_eq!(got, &want);
        }
        assert!(out.frame_latency_s.unwrap() > 0.0);
    }

    #[test]
    fn spec_reports_timing_and_unbounded_replicas() {
        let b = SimBackend::from_config(&BackendConfig::default()).unwrap();
        assert!(b.spec().reports_timing);
        assert!(b.spec().max_replicas.is_none());
        assert_eq!(b.spec().input_shape, (1, 28, 28));
    }
}
