//! The fixed-point FPGA accelerator simulator behind the unified API.
//! Batch-native: requests run through [`DeployedModel::run_batch`] with
//! one [`BatchScratch`] owned for the backend's whole life, so
//! steady-state serving allocates nothing per frame, and the reported
//! timing is the pipelined [`crate::fpga::BatchTiming`] model
//! ([`BackendSpec::reports_timing`] = true) — per-frame latency, whole
//! batch latency, and steady-state FPS.

use super::{BackendConfig, BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use crate::fpga::{BatchScratch, DeployedModel};

pub struct SimBackend {
    model: DeployedModel,
    spec: BackendSpec,
    scratch: BatchScratch,
}

impl SimBackend {
    /// Wrap a deployed (quantized + masked) model.
    pub fn new(model: DeployedModel) -> SimBackend {
        let spec = BackendSpec {
            kind: "sim".into(),
            model: model.config.model.name.clone(),
            input_shape: model.config.model.input,
            // Wider ladder than the oracle's: the pipelined cycle model
            // prices marginal frames at one initiation interval, and the
            // batch path's scratch reuse keeps the host-side marginal
            // cost low too, so big buckets pay off.
            batch_buckets: BackendSpec::pow2_buckets(16),
            reports_timing: true,
            max_replicas: None,
            compression: None,
            fingerprint: BackendSpec::deployment_fingerprint(
                "sim",
                &model.config.model.name,
                model.fingerprint(),
            ),
        }
        .normalize();
        SimBackend {
            model,
            spec,
            scratch: BatchScratch::new(),
        }
    }

    /// Registry factory: synthetic deployment of the configured variant
    /// (`original`/`pruned`/`proposed`) for the dataset.
    pub fn from_config(cfg: &BackendConfig) -> Result<SimBackend, BackendError> {
        let sys = cfg.system_config();
        Ok(SimBackend::new(DeployedModel::synthetic(&sys, cfg.seed)))
    }

    pub fn model(&self) -> &DeployedModel {
        &self.model
    }
}

impl InferenceBackend for SimBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        let out = self
            .model
            .run_batch(&req.images, &mut self.scratch)
            .map_err(|e| BackendError::Execution(format!("sim batch: {e:#}")))?;
        // The per-frame loop this replaces overwrote `latency` every
        // iteration and reported only the *last* frame's number as the
        // batch's time; the batch figures now come from the pipelined
        // cycle model in one place.
        Ok(InferOutput {
            lengths: out.lengths,
            frame_latency_s: Some(out.timing.frame.latency_s()),
            batch_latency_s: Some(out.timing.latency_s()),
            steady_state_fps: Some(out.timing.steady_state_fps()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::data::{generate, Task};

    #[test]
    fn served_lengths_match_direct_run_frame() {
        let cfg = SystemConfig::proposed("mnist");
        let direct = DeployedModel::synthetic(&cfg, 9);
        let mut b = SimBackend::new(DeployedModel::synthetic(&cfg, 9));
        let data = generate(Task::Digits, 2, 77);
        let out = b.infer(&InferRequest::new(data.images.clone())).unwrap();
        for (img, got) in data.images.iter().zip(&out.lengths) {
            let (_, want, _) = direct.run_frame(img).unwrap();
            assert_eq!(got, &want);
        }
        assert!(out.frame_latency_s.unwrap() > 0.0);
    }

    #[test]
    fn spec_reports_timing_and_unbounded_replicas() {
        let b = SimBackend::from_config(&BackendConfig::default()).unwrap();
        assert!(b.spec().reports_timing);
        assert!(b.spec().max_replicas.is_none());
        assert_eq!(b.spec().input_shape, (1, 28, 28));
        // Widened ladder: the pipelined model makes big buckets cheap.
        assert_eq!(b.spec().batch_buckets, vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn batch_latency_is_pipelined_not_summed() {
        let cfg = SystemConfig::proposed("mnist");
        let mut b = SimBackend::new(DeployedModel::synthetic(&cfg, 9));
        let data = generate(Task::Digits, 4, 31);
        let out = b.infer(&InferRequest::new(data.images)).unwrap();
        let frame = out.frame_latency_s.unwrap();
        let batch = out.batch_latency_s.unwrap();
        assert!(batch > frame && batch < 4.0 * frame, "batch {batch} frame {frame}");
        assert!(out.steady_state_fps.unwrap() > 1.0 / frame);
    }
}
