//! The fixed-point FPGA accelerator simulator behind the unified API.
//! Batch-native: requests run through [`DeployedModel::run_batch`] with
//! one [`BatchScratch`] owned for the backend's whole life, so
//! steady-state serving allocates nothing per frame, and the reported
//! timing is the pipelined [`crate::fpga::BatchTiming`] model
//! ([`BackendSpec::reports_timing`] = true) — per-frame latency, whole
//! batch latency, and steady-state FPS.

use super::{BackendConfig, BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use crate::fpga::{BatchScratch, DeployedModel};

pub struct SimBackend {
    model: DeployedModel,
    workers: usize,
    spec: BackendSpec,
    scratch: BatchScratch,
}

impl SimBackend {
    /// Wrap a deployed (quantized + masked) model (serial batches).
    pub fn new(model: DeployedModel) -> SimBackend {
        SimBackend::with_workers(model, 1)
    }

    /// Wrap a deployed model, sharding each batch over up to `workers`
    /// cores. The deployment carries its own routing mode and baked
    /// coefficients — [`DeployedModel::fingerprint`] folds both in.
    pub fn with_workers(model: DeployedModel, workers: usize) -> SimBackend {
        let workers = workers.max(1);
        let spec = BackendSpec {
            kind: "sim".into(),
            model: model.config.model.name.clone(),
            input_shape: model.config.model.input,
            // Wider ladder than the oracle's: the pipelined cycle model
            // prices marginal frames at one initiation interval, and the
            // batch path's scratch reuse keeps the host-side marginal
            // cost low too, so big buckets pay off.
            batch_buckets: BackendSpec::pow2_buckets(16),
            reports_timing: true,
            max_replicas: None,
            compression: None,
            fingerprint: BackendSpec::deployment_fingerprint(
                "sim",
                &model.config.model.name,
                model.fingerprint(),
            ),
            routing: model.routing.to_string(),
            workers,
            coupling_fingerprint: model
                .acc_coupling()
                .map(|c| super::coupling_fingerprint(&c.iter().map(|q| q.to_f32()).collect::<Vec<_>>())),
        }
        .normalize();
        SimBackend {
            model,
            workers,
            spec,
            scratch: BatchScratch::new(),
        }
    }

    /// Registry factory: synthetic deployment of the configured variant
    /// (`original`/`pruned`/`proposed`) for the dataset. In accumulated
    /// mode the factory self-calibrates on the deterministic calibration
    /// set through the quantized iterative pipeline and bakes the mean
    /// coefficients (synthetic deployments have no `.fcw` sidecar).
    pub fn from_config(cfg: &BackendConfig) -> Result<SimBackend, BackendError> {
        let sys = cfg.system_config();
        let mut model = DeployedModel::synthetic(&sys, cfg.seed);
        bake_from_config(&mut model, cfg)?;
        Ok(SimBackend::with_workers(model, cfg.worker_count()))
    }

    pub fn model(&self) -> &DeployedModel {
        &self.model
    }
}

/// Shared accumulated-mode setup for the simulator factories: honor the
/// config's routing override on an already-deployed model, taking
/// coefficients from a `.fcw` sidecar when one matches the geometry and
/// self-calibrating on the deterministic calibration set otherwise.
pub(super) fn bake_from_config(
    model: &mut DeployedModel,
    cfg: &BackendConfig,
) -> Result<(), BackendError> {
    let mode = cfg.routing_mode(&model.config.model);
    if mode.is_accumulated() {
        let m = &model.config.model;
        let want = model.config.sparsity.num_primary_caps(m) * m.num_classes;
        let sidecar = cfg
            .full_weights_path()
            .and_then(|p| crate::capsnet::weights::load_coupling(&p).ok().flatten())
            .filter(|t| t.data.len() == want)
            .map(|t| t.data);
        let coupling = match sidecar {
            Some(c) => c,
            None => model
                .accumulate_coupling(&super::calibration_set(cfg, super::CALIBRATION_FRAMES))
                .map_err(|e| BackendError::Init(format!("accumulation pass: {e:#}")))?,
        };
        model
            .bake_accumulated(&coupling)
            .map_err(|e| BackendError::Init(format!("baking coupling: {e:#}")))?;
    } else {
        model
            .set_routing_mode(mode)
            .map_err(|e| BackendError::Init(format!("routing mode: {e:#}")))?;
    }
    Ok(())
}

impl InferenceBackend for SimBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        let out = if self.workers > 1 && req.images.len() > 1 {
            self.model.run_batch_sharded(&req.images, self.workers)
        } else {
            self.model.run_batch(&req.images, &mut self.scratch)
        }
        .map_err(|e| BackendError::Execution(format!("sim batch: {e:#}")))?;
        // The per-frame loop this replaces overwrote `latency` every
        // iteration and reported only the *last* frame's number as the
        // batch's time; the batch figures now come from the pipelined
        // cycle model in one place.
        Ok(InferOutput {
            lengths: out.lengths,
            frame_latency_s: Some(out.timing.frame.latency_s()),
            batch_latency_s: Some(out.timing.latency_s()),
            steady_state_fps: Some(out.timing.steady_state_fps()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::data::{generate, Task};

    #[test]
    fn served_lengths_match_direct_run_frame() {
        let cfg = SystemConfig::proposed("mnist");
        let direct = DeployedModel::synthetic(&cfg, 9);
        let mut b = SimBackend::new(DeployedModel::synthetic(&cfg, 9));
        let data = generate(Task::Digits, 2, 77);
        let out = b.infer(&InferRequest::new(data.images.clone())).unwrap();
        for (img, got) in data.images.iter().zip(&out.lengths) {
            let (_, want, _) = direct.run_frame(img).unwrap();
            assert_eq!(got, &want);
        }
        assert!(out.frame_latency_s.unwrap() > 0.0);
    }

    #[test]
    fn spec_reports_timing_and_unbounded_replicas() {
        let b = SimBackend::from_config(&BackendConfig::default()).unwrap();
        assert!(b.spec().reports_timing);
        assert!(b.spec().max_replicas.is_none());
        assert_eq!(b.spec().input_shape, (1, 28, 28));
        // Widened ladder: the pipelined model makes big buckets cheap.
        assert_eq!(b.spec().batch_buckets, vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn batch_latency_is_pipelined_not_summed() {
        let cfg = SystemConfig::proposed("mnist");
        let mut b = SimBackend::new(DeployedModel::synthetic(&cfg, 9));
        let data = generate(Task::Digits, 4, 31);
        let out = b.infer(&InferRequest::new(data.images)).unwrap();
        let frame = out.frame_latency_s.unwrap();
        let batch = out.batch_latency_s.unwrap();
        assert!(batch > frame && batch < 4.0 * frame, "batch {batch} frame {frame}");
        assert!(out.steady_state_fps.unwrap() > 1.0 / frame);
    }

    #[test]
    fn accumulated_workers_serve_bit_identical_to_serial_iterative_baseline() {
        // One config, two factories: accumulated + 4 workers must agree
        // with its own serial run bit for bit, and must re-key vs the
        // iterative deployment of the same seed.
        let base = BackendConfig::default();
        let acc_cfg = BackendConfig {
            routing: Some(crate::routing::RoutingMode::Accumulated),
            workers: 4,
            ..base.clone()
        };
        let iter = SimBackend::from_config(&base).unwrap();
        let mut acc = SimBackend::from_config(&acc_cfg).unwrap();
        assert_ne!(iter.spec().fingerprint, acc.spec().fingerprint);
        assert_eq!(acc.spec().routing, "accumulated");
        assert_eq!(acc.spec().workers, 4);
        let data = generate(Task::Digits, 4, 53);
        let out = acc.infer(&InferRequest::new(data.images.clone())).unwrap();
        let direct = acc.model().clone();
        let mut scratch = BatchScratch::new();
        let serial = direct.run_batch(&data.images, &mut scratch).unwrap();
        assert_eq!(out.lengths, serial.lengths);
    }
}
