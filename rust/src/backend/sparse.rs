//! The sparse-compiled fp32 oracle behind the unified API
//! (`"oracle-sparse"`): the prune→compile→serve path.
//!
//! Where [`super::OracleBackend`] serves the hand-compacted pruned
//! architecture densely, this backend runs the *full* paper architecture
//! through LAKP at the deployment plan's survivor counts
//! ([`crate::config::SparsityPlan::paper_mnist`]: 64 + 423 kernels →
//! 99.26% compression), compiles the survivors into the CSR packing
//! shared with the FPGA Index Control Module, and executes only alive
//! kernels — values stay bit-exact to the masked-dense reference while
//! the dense ~1%-alive multiply cost disappears
//! (`benches/pruning_bench.rs` asserts the ≥5× win). The spec reports
//! the packing's [`CompressionStats`] so the coordinator and CLI can
//! surface what the replica actually executes.

use super::{BackendConfig, BackendError, BackendSpec, InferOutput, InferRequest, InferenceBackend};
use crate::capsnet::compiled::CompiledCapsNet;
use crate::capsnet::{weights::Weights, CapsNet};
use crate::config::{CapsNetConfig, SparsityPlan};
use crate::pruning::NetworkMasks;
use crate::util::rng::Rng;

pub struct SparseOracleBackend {
    net: CompiledCapsNet,
    workers: usize,
    spec: BackendSpec,
}

impl SparseOracleBackend {
    /// Wrap an already-compiled model (serial batches).
    pub fn new(net: CompiledCapsNet) -> SparseOracleBackend {
        SparseOracleBackend::with_workers(net, 1)
    }

    /// Wrap an already-compiled model, sharding each batch over up to
    /// `workers` cores. The compiled model carries its own routing mode
    /// (and any baked coefficients) — [`CompiledCapsNet::fingerprint`]
    /// already folds both in, so iterative and accumulated deployments
    /// of the same weights never share a cache key.
    pub fn with_workers(net: CompiledCapsNet, workers: usize) -> SparseOracleBackend {
        let stats = net.stats();
        let workers = workers.max(1);
        let spec = BackendSpec {
            kind: "oracle-sparse".into(),
            model: format!("{}-compiled", net.config.name),
            input_shape: net.config.input,
            batch_buckets: BackendSpec::pow2_buckets(8),
            reports_timing: false,
            max_replicas: None,
            compression: Some(stats),
            fingerprint: BackendSpec::deployment_fingerprint(
                "oracle-sparse",
                &net.config.name,
                net.fingerprint(),
            ),
            routing: net.routing.to_string(),
            workers,
            coupling_fingerprint: net.acc_coupling().map(super::coupling_fingerprint),
        }
        .normalize();
        SparseOracleBackend { net, workers, spec }
    }

    /// Registry factory: the full paper architecture for the dataset,
    /// LAKP-pruned at the paper's survivor counts and compiled.
    ///
    /// Like the other factories, this does its full setup (here: LAKP
    /// scoring + sparse compile, ~startup-only cost) once per replica —
    /// the executor pool builds each replica's backend on its own
    /// thread. When spinning many replicas around one model, compile
    /// once and clone into a `ServerBuilder` closure instead (the
    /// `fastcaps prune --compile --serve` path does exactly that).
    ///
    /// Weights resolve in order: an explicit [`BackendConfig::weights`]
    /// override (must match the *full* architecture — the conventional
    /// per-dataset `.fcw` files hold the compacted pruned architecture
    /// and would be rejected), then `weights-<dataset>-full.fcw` in the
    /// artifact directory, then seeded random weights (predictions are
    /// noise, but the prune→compile→serve path is exercised end to end).
    pub fn from_config(cfg: &BackendConfig) -> Result<SparseOracleBackend, BackendError> {
        let (arch, plan) = if cfg.is_fmnist() {
            (
                CapsNetConfig::paper_full("capsnet-fmnist"),
                SparsityPlan::paper_fmnist(),
            )
        } else {
            (
                CapsNetConfig::paper_full("capsnet-mnist"),
                SparsityPlan::paper_mnist(),
            )
        };
        let weights = match cfg.full_weights_path() {
            Some(path) => {
                let w = Weights::load(&path)
                    .map_err(|e| BackendError::Init(format!("loading {path:?}: {e:#}")))?;
                w.validate(&arch).map_err(|e| {
                    BackendError::Init(format!(
                        "oracle-sparse compiles the full architecture; weights mismatch: {e:#}"
                    ))
                })?;
                w
            }
            None => Weights::random(&arch, &mut Rng::new(cfg.seed)),
        };
        let net = CapsNet {
            config: arch,
            weights,
        };
        let masks = NetworkMasks::from_plan(&net.weights, &net.config, &plan);
        let mut compiled = CompiledCapsNet::compile(&net, &masks)
            .map_err(|e| BackendError::Init(format!("sparse compile: {e:#}")))?;
        let mode = cfg.routing_mode(&compiled.config);
        if mode.is_accumulated() {
            let want = compiled.config.num_primary_caps() * compiled.config.num_classes;
            let sidecar = cfg
                .full_weights_path()
                .and_then(|p| crate::capsnet::weights::load_coupling(&p).ok().flatten())
                .filter(|t| t.data.len() == want)
                .map(|t| t.data);
            let coupling = match sidecar {
                Some(c) => c,
                None => compiled
                    .accumulate_coupling(&super::calibration_set(cfg, super::CALIBRATION_FRAMES))
                    .map_err(|e| BackendError::Init(format!("accumulation pass: {e:#}")))?,
            };
            compiled
                .bake_accumulated(coupling)
                .map_err(|e| BackendError::Init(format!("baking coupling: {e:#}")))?;
        } else {
            // Explicit `iterative:N` overrides must land in the model
            // (and therefore its fingerprint), not just the config.
            compiled.routing = mode;
        }
        Ok(SparseOracleBackend::with_workers(compiled, cfg.worker_count()))
    }

    pub fn model(&self) -> &CompiledCapsNet {
        &self.net
    }
}

impl InferenceBackend for SparseOracleBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
        self.validate(req)?;
        let acts = self
            .net
            .forward_batch_sharded(&req.images, self.workers)
            .map_err(|e| BackendError::Execution(format!("sparse oracle forward: {e:#}")))?;
        Ok(InferOutput::untimed(
            acts.iter().map(|a| a.class_lengths()).collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tiny_sparse() -> (CapsNet, NetworkMasks, SparseOracleBackend) {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(15);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let masks = NetworkMasks::lakp(&net.weights, &cfg, 12, 128);
        let b = SparseOracleBackend::new(CompiledCapsNet::compile(&net, &masks).unwrap());
        (net, masks, b)
    }

    #[test]
    fn spec_reports_compression() {
        let (_, masks, b) = tiny_sparse();
        assert_eq!(b.spec().kind, "oracle-sparse");
        let c = b.spec().compression.as_ref().unwrap();
        assert_eq!(c.survived_kernels, masks.survived());
        assert_eq!(c.total_kernels, masks.total());
        assert!(c.pruned_pct() > 50.0);
    }

    #[test]
    fn served_lengths_match_masked_dense_oracle() {
        let (net, masks, mut b) = tiny_sparse();
        let dense = net.masked(&masks);
        let mut rng = Rng::new(16);
        let images: Vec<Tensor> = (0..4)
            .map(|_| Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0)))
            .collect();
        let out = b.infer(&InferRequest::new(images.clone())).unwrap();
        for (img, got) in images.iter().zip(&out.lengths) {
            let want = dense.forward(img).unwrap().class_lengths();
            assert_eq!(got, &want, "bit-exactness through the serving API");
        }
        assert!(out.frame_latency_s.is_none());
    }

    #[test]
    fn from_config_compiles_paper_plan() {
        // Random weights (no artifacts on disk in tests): still compiles
        // the full architecture at the paper's survivor counts.
        let cfg = BackendConfig {
            artifacts: std::path::PathBuf::from("/nonexistent/artifacts"),
            ..BackendConfig::default()
        };
        let b = SparseOracleBackend::from_config(&cfg).unwrap();
        let c = b.spec().compression.as_ref().unwrap();
        assert_eq!(c.survived_kernels, 64 + 423);
        assert_eq!(c.total_kernels, 256 + 65536);
        assert!(c.pruned_pct() > 99.0);
        assert_eq!(b.spec().input_shape, (1, 28, 28));
        assert_eq!(b.spec().routing, "iterative(3)");
        assert_eq!(b.spec().workers, 1);
    }

    #[test]
    fn accumulated_from_config_bakes_and_rekeys() {
        let base = BackendConfig {
            artifacts: std::path::PathBuf::from("/nonexistent/artifacts"),
            ..BackendConfig::default()
        };
        let iter = SparseOracleBackend::from_config(&base).unwrap();
        let acc_cfg = BackendConfig {
            routing: Some(crate::routing::RoutingMode::Accumulated),
            workers: 2,
            ..base
        };
        let mut acc = SparseOracleBackend::from_config(&acc_cfg).unwrap();
        // Satellite pin: the two modes never share a cache key.
        assert_ne!(iter.spec().fingerprint, acc.spec().fingerprint);
        assert_eq!(acc.spec().routing, "accumulated");
        assert_eq!(acc.spec().workers, 2);
        assert!(acc.spec().coupling_fingerprint.is_some());
        // Served (sharded) accumulated lengths match the direct
        // accumulated forward of the same compiled model.
        let images = crate::backend::calibration_set(&acc_cfg, 2);
        let out = acc.infer(&InferRequest::new(images.clone())).unwrap();
        for (img, got) in images.iter().zip(&out.lengths) {
            let want = acc.model().forward(img).unwrap().class_lengths();
            assert_eq!(got, &want);
        }
    }
}
