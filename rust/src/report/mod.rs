//! Experiment report formatters: regenerate every table and figure of the
//! paper's evaluation as paper-vs-measured text tables (and JSON for
//! machine consumption). Invoked by `fastcaps report <exp>`.

use crate::config::SystemConfig;
use crate::fpga::power::PowerModel;
use crate::fpga::resources::{self, Utilization};
use crate::fpga::DeployedModel;
use crate::util::json::Json;
use crate::Result;
use std::path::Path;

fn hline(w: usize) -> String {
    "-".repeat(w)
}

/// Fig. 1: throughput and energy across original / pruned / proposed.
/// The `pipe FPS` column is the frame-pipelined steady-state throughput
/// (frames stream through the stage sequence at the slowest stage's
/// initiation interval); `FPS` stays the paper-anchored 1/latency
/// single-frame number.
pub fn fig1() -> String {
    let pm = PowerModel::default();
    let mut out = String::new();
    out.push_str("Fig. 1 — Throughput (FPS) and energy efficiency (FPJ)\n");
    out.push_str(&format!(
        "{:<22} {:>10} {:>12} {:>10} {:>8} {:>8}   {}\n",
        "config", "FPS", "paper FPS", "pipe FPS", "FPJ", "paper", "note"
    ));
    out.push_str(&hline(89));
    out.push('\n');
    let rows: [(&str, SystemConfig, f64, Option<f64>); 6] = [
        ("original-mnist", SystemConfig::original("mnist"), 5.0, Some(1.8)),
        ("pruned-mnist", SystemConfig::pruned("mnist"), 82.0, Some(41.8)),
        ("proposed-mnist", SystemConfig::proposed("mnist"), 1351.0, None),
        ("original-fmnist", SystemConfig::original("fmnist"), 5.0, Some(1.8)),
        ("pruned-fmnist", SystemConfig::pruned("fmnist"), 48.0, Some(24.5)),
        ("proposed-fmnist", SystemConfig::proposed("fmnist"), 934.0, None),
    ];
    for (name, cfg, paper_fps, paper_fpj) in rows {
        let model = DeployedModel::timing_stub(&cfg, 7);
        let t = model.estimate_frame();
        let pipe = model.estimate_batch(8).steady_state_fps();
        let u = resources::estimate(&cfg);
        let fpj = pm.fpj(t.fps(), &u, !cfg.is_pruned());
        out.push_str(&format!(
            "{:<22} {:>10.1} {:>12.1} {:>10.1} {:>8.1} {:>8}   {}\n",
            name,
            t.fps(),
            paper_fps,
            pipe,
            fpj,
            paper_fpj.map(|v| format!("{v:.1}")).unwrap_or_else(|| "—".into()),
            if cfg.is_pruned() { "on-chip" } else { "DDR-streaming" },
        ));
    }
    // Accumulated-coefficients fast path: the proposed deployments again
    // with zero routing iterations. Uniform coupling stands in for the
    // baked mean — the timing model reads only the iteration count, and
    // the fpga property tests pin Accumulated ≡ Iterative(0) exactly.
    for (name, cfg) in [
        ("proposed-mnist+acc", SystemConfig::proposed("mnist")),
        ("proposed-fmnist+acc", SystemConfig::proposed("fmnist")),
    ] {
        let mut model = DeployedModel::timing_stub(&cfg, 7);
        let n = cfg.sparsity.num_primary_caps(&cfg.model) * cfg.model.num_classes;
        model
            .bake_accumulated(&vec![1.0 / cfg.model.num_classes as f32; n])
            .expect("uniform coupling matches the geometry");
        let t = model.estimate_frame();
        let pipe = model.estimate_batch(8).steady_state_fps();
        let u = resources::estimate(&cfg);
        let fpj = pm.fpj(t.fps(), &u, !cfg.is_pruned());
        out.push_str(&format!(
            "{:<22} {:>10.1} {:>12} {:>10.1} {:>8.1} {:>8}   {}\n",
            name,
            t.fps(),
            "—",
            pipe,
            fpj,
            "—",
            "accumulated routing (0 iters)",
        ));
    }
    out
}

/// Sparse-datapath serving table: modeled dense-vs-pruned FPS, DDR
/// traffic, and BRAM for each dataset — the dense original (full replay
/// over DDR), the `sim-sparse` deployment (LAKP masks on the *full*
/// architecture, CSR survivors on-chip), and the paper's compacted
/// `proposed` design. Every figure comes from the survivor-aware models
/// (`DeployedModel::ddr_bytes`, `bram_plan`, the CSR cycle model).
pub fn sparse() -> String {
    let mut out = String::new();
    out.push_str("Sparse datapath — dense vs pruned modeled serving\n");
    out.push_str(&format!(
        "{:<20} {:>9} {:>10} {:>13} {:>8} {:>9}   {}\n",
        "config", "FPS", "steady", "DDR B/frame", "BRAM36", "pruned%", "note"
    ));
    out.push_str(&hline(92));
    out.push('\n');
    for ds in ["mnist", "fmnist"] {
        let rows = [
            (
                format!("original-{ds}"),
                SystemConfig::original(ds),
                "dense, DDR weight replay",
            ),
            (
                format!("sim-sparse-{ds}"),
                SystemConfig::masked(ds),
                "masked full arch, survivors on-chip",
            ),
            (
                format!("proposed-{ds}"),
                SystemConfig::proposed(ds),
                "compacted deployment (paper)",
            ),
        ];
        for (name, cfg, note) in rows {
            let model = DeployedModel::timing_stub(&cfg, 7);
            let t = model.estimate_frame();
            let steady = model.estimate_batch(8).steady_state_fps();
            let bram = resources::bram_plan(&cfg).total_blocks();
            let c = model.compression();
            out.push_str(&format!(
                "{:<20} {:>9.1} {:>10.1} {:>13} {:>8.1} {:>8.2}%   {}\n",
                name,
                t.fps(),
                steady,
                crate::util::fmt_thousands(model.ddr_bytes()),
                bram,
                c.pruned_pct(),
                note
            ));
        }
    }
    out.push_str(
        "\n(sim-sparse executes and cycle-prices only the CSR survivors of the\n \
         full architecture; its 1152-capsule û overflows the 140-block BRAM\n \
         budget and spills to DDR — the DDR B/frame column — leaving the\n \
         masked deployment û-stream-bound. The compacted `proposed` design\n \
         is the fix: 252/432 capsules fit on-chip, DDR column goes to 0)\n",
    );
    out
}

fn utilization_rows(name: &str, cfg: &SystemConfig, u: &Utilization, paper: Option<Utilization>) -> String {
    let pct = u.percent_of(&cfg.budget);
    let mut s = String::new();
    let paper_cell = |v: Option<f64>| -> String {
        v.map(|x| format!("{x:>10.1}")).unwrap_or_else(|| format!("{:>10}", "—"))
    };
    s.push_str(&format!(
        "{name}\n  {:<16} {:>10} {:>8} {:>10}\n",
        "resource", "model", "%", "paper"
    ));
    for (label, val, pc, pv) in [
        ("Slice LUTs", u.luts as f64, pct[0], paper.map(|p| p.luts as f64)),
        ("LUTs (memory)", u.lutram as f64, pct[1], paper.map(|p| p.lutram as f64)),
        ("BRAM36", u.bram36 as f64, pct[2], paper.map(|p| p.bram36 as f64)),
        ("DSP48E", u.dsp48e as f64, pct[3], paper.map(|p| p.dsp48e as f64)),
    ] {
        s.push_str(&format!(
            "  {:<16} {:>10.1} {:>7.1}% {}\n",
            label,
            val,
            pc,
            paper_cell(pv)
        ));
    }
    s
}

/// Table II: original vs proposed (MNIST) resources + latency.
pub fn table2() -> String {
    let mut out = String::new();
    out.push_str("Table II — Resource utilization + latency (MNIST)\n");
    out.push_str(&hline(56));
    out.push('\n');
    for (name, cfg, paper_key, paper_lat) in [
        ("Original CapsNet [4]", SystemConfig::original("mnist"), "original-mnist", 0.19),
        ("Proposed CapsNet", SystemConfig::proposed("mnist"), "proposed-mnist", 0.00074),
    ] {
        let u = resources::estimate(&cfg);
        out.push_str(&utilization_rows(name, &cfg, &u, resources::paper_reported(paper_key)));
        let t = DeployedModel::timing_stub(&cfg, 7).estimate_frame();
        out.push_str(&format!(
            "  {:<16} {:>10.5}s {:>8} {:>9.5}s\n\n",
            "Latency(1 sample)",
            t.latency_s(),
            "",
            paper_lat
        ));
    }
    out
}

/// Table III: proposed CapsNet on F-MNIST.
pub fn table3() -> String {
    let mut out = String::new();
    out.push_str("Table III — Proposed CapsNet (F-MNIST)\n");
    out.push_str(&hline(56));
    out.push('\n');
    let cfg = SystemConfig::proposed("fmnist");
    let u = resources::estimate(&cfg);
    out.push_str(&utilization_rows(
        "Proposed CapsNet (F-MNIST)",
        &cfg,
        &u,
        resources::paper_reported("proposed-fmnist"),
    ));
    let t = DeployedModel::timing_stub(&cfg, 7).estimate_frame();
    out.push_str(&format!(
        "  {:<16} {:>10.5}s {:>8} {:>9.5}s\n",
        "Latency(1 sample)",
        t.latency_s(),
        "",
        0.00107
    ));
    out
}

/// Fig. 8: per-operation routing cycles, non-optimized vs optimized.
pub fn fig8() -> String {
    use crate::fpga::pe::PeArray;
    use crate::fpga::routing_module::{routing_timing, RoutingGeometry, RoutingHardware};

    let cfg = SystemConfig::proposed("mnist");
    let pe = PeArray::new(&cfg.options);
    let g = RoutingGeometry::from_config(&cfg.model, cfg.sparsity.num_primary_caps(&cfg.model));
    let base = routing_timing(&g, &RoutingHardware::baseline(), &pe);
    let opt = routing_timing(&g, &RoutingHardware::optimized(), &pe);
    let mut out = String::new();
    out.push_str("Fig. 8 — Dynamic-routing op latency, pruned MNIST model (cycles)\n");
    out.push_str(&format!(
        "{:<26} {:>14} {:>12} {:>9}\n",
        "operation", "non-optimized", "optimized", "speedup"
    ));
    out.push_str(&hline(66));
    out.push('\n');
    for ((name, b), (_, o)) in base.stages().iter().zip(opt.stages().iter()) {
        let speedup = if *o == 0 { 0.0 } else { *b as f64 / *o as f64 };
        out.push_str(&format!(
            "{:<26} {:>14} {:>12} {:>8.1}x\n",
            name,
            crate::util::fmt_thousands(*b),
            crate::util::fmt_thousands(*o),
            speedup
        ));
    }
    out.push_str(&hline(66));
    out.push('\n');
    out.push_str(&format!(
        "{:<26} {:>14} {:>12} {:>8.1}x\n",
        "total",
        crate::util::fmt_thousands(base.total()),
        crate::util::fmt_thousands(opt.total()),
        base.total() as f64 / opt.total() as f64
    ));
    // Accumulated-coefficients mode skips every routing iteration (the
    // coefficients are baked offline), so the whole module degenerates
    // to the zero-iteration schedule — the fpga tests pin that
    // Accumulated and Iterative(0) price identically.
    let mut g0 = g;
    g0.iterations = 0;
    let acc = routing_timing(&g0, &RoutingHardware::optimized(), &pe);
    out.push_str(&format!(
        "{:<26} {:>14} {:>12}\n",
        "accumulated (0 iters)",
        "—",
        crate::util::fmt_thousands(acc.total()),
    ));
    out.push_str("\nUnit latencies (§III-B): exp 27→14 cycles, div 49→36 cycles\n");
    out
}

/// `fastcaps report routing`: iterative vs accumulated routing through
/// the fp32 oracle on both datasets. Coefficients come from an
/// accumulation pass over the deterministic calibration set (the same
/// seed the backend factories self-calibrate with); the eval set is
/// disjoint. With seeded random weights absolute accuracy is chance —
/// the load-bearing columns are the absolute accuracy delta and the
/// top-1 agreement between the two modes.
pub fn routing() -> String {
    use crate::capsnet::{weights::Weights, CapsNet};
    use crate::config::CapsNetConfig;
    use crate::data::{generate, Task};
    use crate::routing::RoutingMode;
    use crate::util::rng::Rng;

    const CALIB: usize = 32;
    const EVAL: usize = 64;
    let mut out = String::new();
    out.push_str("Routing modes — iterative vs accumulated (fp32 oracle, synthetic eval)\n");
    out.push_str(&format!(
        "{:<10} {:>10} {:>10} {:>8} {:>10} {:>12}\n",
        "dataset", "iter acc", "accum acc", "|Δacc|", "agreement", "mean |Δlen|"
    ));
    out.push_str(&hline(66));
    out.push('\n');
    for (ds, task, arch) in [
        ("mnist", Task::Digits, CapsNetConfig::paper_pruned_mnist()),
        ("fmnist", Task::Garments, CapsNetConfig::paper_pruned_fmnist()),
    ] {
        let weights = Weights::random(&arch, &mut Rng::new(7));
        let net = CapsNet {
            config: arch,
            weights,
        };
        let coupling = net
            .accumulate_coupling(&generate(task, CALIB, 0xacc0).images)
            .expect("accumulation over the calibration set");
        let eval = generate(task, EVAL, 0xe7a1);
        let (mut hit_i, mut hit_a, mut agree) = (0usize, 0usize, 0usize);
        let mut dlen = 0.0f64;
        for (img, &label) in eval.images.iter().zip(&eval.labels) {
            let it = net.forward(img).expect("iterative forward");
            let ac = net
                .forward_mode(img, RoutingMode::Accumulated, Some(&coupling))
                .expect("accumulated forward");
            let (ci, ca) = (it.predicted_class(), ac.predicted_class());
            hit_i += usize::from(ci == label);
            hit_a += usize::from(ca == label);
            agree += usize::from(ci == ca);
            let (li, la) = (it.class_lengths(), ac.class_lengths());
            dlen += li
                .iter()
                .zip(&la)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / li.len() as f64;
        }
        let pct = |n: usize| 100.0 * n as f64 / EVAL as f64;
        out.push_str(&format!(
            "{:<10} {:>9.1}% {:>9.1}% {:>7.1}% {:>9.1}% {:>12.4}\n",
            ds,
            pct(hit_i),
            pct(hit_a),
            (pct(hit_i) - pct(hit_a)).abs(),
            pct(agree),
            dlen / EVAL as f64,
        ));
    }
    out.push_str(
        "\n(seeded random weights: absolute accuracy is chance — the accumulated\n \
         column must *track* the iterative one, not beat the task.\n \
         benches/pruning_bench.rs gates the ≤1pp absolute delta.)\n",
    );
    out
}

/// Fig. 14: non-optimized vs optimized pruned CapsNet resources.
pub fn fig14() -> String {
    let base = SystemConfig::pruned("mnist");
    let opt = SystemConfig::proposed("mnist");
    let ub = resources::estimate(&base);
    let uo = resources::estimate(&opt);
    let mut out = String::new();
    out.push_str("Fig. 14 — Pruned CapsNet resources, non-optimized vs optimized (MNIST)\n");
    out.push_str(&format!(
        "{:<16} {:>14} {:>12}\n",
        "resource", "non-optimized", "optimized"
    ));
    out.push_str(&hline(46));
    out.push('\n');
    for (label, a, b) in [
        ("Slice LUTs", ub.luts as f64, uo.luts as f64),
        ("LUTs (memory)", ub.lutram as f64, uo.lutram as f64),
        ("BRAM36", ub.bram36 as f64, uo.bram36 as f64),
        ("DSP48E", ub.dsp48e as f64, uo.dsp48e as f64),
    ] {
        out.push_str(&format!("{label:<16} {a:>14.1} {b:>12.1}\n"));
    }
    out.push_str("\n(the optimization trades the LUT-hungry iterative divider\n for DSP-based Taylor units: LUT down, DSP up — Fig. 14's signature)\n");
    out
}

/// Table I from artifacts/table1.json (produced by `make table1`).
pub fn table1(artifacts: &Path) -> Result<String> {
    let path = artifacts.join("table1.json");
    let text = std::fs::read_to_string(&path).map_err(|_| {
        anyhow::anyhow!(
            "{} not found — run `make table1` (python -m compile.prune_study)",
            path.display()
        )
    })?;
    let j = Json::parse(&text)?;
    let rows = j
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("table1.json missing rows"))?;
    let mut out = String::new();
    out.push_str("Table I — Test error (%), KP vs proposed LAKP\n");
    out.push_str(&format!(
        "{:<10} {:<10} {:>8} {:>10} {:>8} {:>8} {:>9}\n",
        "model", "dataset", "base", "survived", "KP", "LAKP", "gain"
    ));
    out.push_str(&hline(70));
    out.push('\n');
    for r in rows {
        let f = |k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
        let s = |k: &str| r.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
        let kp = f("error_kp");
        let lakp = f("error_lakp");
        let gain = if kp > 0.0 { 100.0 * (kp - lakp) / kp } else { 0.0 };
        out.push_str(&format!(
            "{:<10} {:<10} {:>7.2}% {:>9.2}% {:>7.2}% {:>7.2}% {:>8.1}%\n",
            s("model"),
            s("dataset"),
            f("actual_error"),
            100.0 * f("survived_lakp"),
            kp,
            lakp,
            gain
        ));
    }
    out.push_str("\n('gain' = relative error reduction of LAKP vs KP;\n paper reports gains up to 96.4% at extreme sparsity)\n");
    Ok(out)
}

/// Fig. 5 from artifacts/fig5.json.
pub fn fig5(artifacts: &Path) -> Result<String> {
    let path = artifacts.join("fig5.json");
    let text = std::fs::read_to_string(&path).map_err(|_| {
        anyhow::anyhow!(
            "{} not found — run `make fig5` (python -m compile.prune_study --only fig5)",
            path.display()
        )
    })?;
    let j = Json::parse(&text)?;
    let pts = j
        .get("points")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("fig5.json missing points"))?;
    let base = j.get("baseline_error").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let mut out = String::new();
    out.push_str(&format!(
        "Fig. 5 — Pruning-method comparison on CapsNet (baseline err {base:.2}%)\n"
    ));
    out.push_str(&format!(
        "{:>10} {:>12} {:>10} {:>14}\n",
        "survived", "KP err", "LAKP err", "unstructured"
    ));
    out.push_str(&hline(50));
    out.push('\n');
    for p in pts {
        let f = |k: &str| p.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{:>9.2}% {:>11.2}% {:>9.2}% {:>13.2}%\n",
            100.0 * f("survived_lakp"),
            f("error_kp"),
            f("error_lakp"),
            f("error_unstructured"),
        ));
    }
    Ok(out)
}

/// All simulator-derived reports (no training artifacts needed).
pub fn all_simulated() -> String {
    format!(
        "{}\n{}\n{}\n{}\n{}\n{}",
        fig1(),
        sparse(),
        table2(),
        table3(),
        fig8(),
        fig14()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_reports_render() {
        let s = all_simulated();
        assert!(s.contains("Fig. 1"));
        assert!(s.contains("Table II"));
        assert!(s.contains("Table III"));
        assert!(s.contains("Fig. 8"));
        assert!(s.contains("Fig. 14"));
        // Spot-check figures contain paper anchors.
        assert!(s.contains("1351"));
        assert!(s.contains("27"));
        // The pipelined steady-state column rides along.
        assert!(s.contains("pipe FPS"));
        // The sparse-datapath dense-vs-pruned table renders.
        assert!(s.contains("sim-sparse-mnist"));
        assert!(s.contains("Sparse datapath"));
        // The accumulated-routing rows ride along in Fig. 1 and Fig. 8.
        assert!(s.contains("proposed-mnist+acc"));
        assert!(s.contains("accumulated (0 iters)"));
    }

    #[test]
    fn routing_report_renders_both_datasets() {
        let s = routing();
        assert!(s.contains("Routing modes"));
        assert!(s.contains("mnist"));
        assert!(s.contains("fmnist"));
        assert!(s.contains("agreement"));
        assert!(s.contains("1pp"));
    }

    #[test]
    fn table1_formatter_parses_sample() {
        let dir = std::env::temp_dir().join("fastcaps-report-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("table1.json"),
            r#"{"rows": [{"model": "capsnet", "dataset": "digits",
                "actual_error": 1.0, "sparsity": 0.9,
                "survived_kp": 0.1, "survived_lakp": 0.1,
                "error_kp": 5.0, "error_lakp": 3.0}]}"#,
        )
        .unwrap();
        let s = table1(&dir).unwrap();
        assert!(s.contains("capsnet"));
        assert!(s.contains("40.0%")); // gain = (5-3)/5
        std::fs::remove_file(dir.join("table1.json")).ok();
    }

    #[test]
    fn table1_missing_file_is_helpful() {
        let err = table1(Path::new("/nonexistent")).unwrap_err().to_string();
        assert!(err.contains("make table1"));
    }
}

/// Ablation: PE-array size and exp-lane count vs throughput — the design
/// choices §III-B motivates ("an array of 10 PEs ... improved the
/// throughput of the CapsNet model trained on MNIST by 615 FPS").
pub fn ablation() -> String {
    use crate::config::AcceleratorOptions;

    let mut out = String::new();
    out.push_str("Ablation — PE array size (proposed MNIST config)\n");
    out.push_str(&format!(
        "{:>8} {:>12} {:>14}\n",
        "PEs", "FPS", "Δ vs 1 PE"
    ));
    out.push_str(&"-".repeat(38));
    out.push('\n');
    let mut base_fps = 0.0;
    for pes in [1usize, 2, 5, 10, 20] {
        let mut cfg = SystemConfig::proposed("mnist");
        cfg.options = AcceleratorOptions {
            num_pes: pes,
            ..AcceleratorOptions::optimized()
        };
        let fps = DeployedModel::timing_stub(&cfg, 7).estimate_frame().fps();
        if pes == 1 {
            base_fps = fps;
        }
        out.push_str(&format!(
            "{:>8} {:>12.1} {:>+13.1}\n",
            pes,
            fps,
            fps - base_fps
        ));
    }
    out.push_str(
        "\n(paper: the 10-PE exp array buys +615 FPS on MNIST; diminishing\n returns past 10 PEs as routing-state memory bandwidth saturates)\n",
    );
    out
}
