//! fclint fixture: fingerprint flow that absorbs a bit-neutral knob
//! (`workers`) and misses bit-affecting fields (coupling, row_ptr,
//! w_ij, weights).

pub struct Spec {
    pub workers: usize,
    pub routing_tag: u64,
}

impl Spec {
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        h ^= self.workers as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
        h ^= self.routing_tag;
        h
    }
}
