//! fclint fixture: panic sources in a hot path (positive case). The
//! `cache/` directory name puts it in the default hot-path scope.

use std::collections::HashMap;

pub fn lookup(map: &HashMap<u64, u64>, key: u64) -> u64 {
    *map.get(&key).unwrap()
}

pub fn admit(depth: usize, max: usize) {
    if depth > max {
        panic!("queue overflow");
    }
}

/// Named like a contractually index-free hot fn: indexing is denied.
pub fn submit(xs: &[u64]) -> u64 {
    xs[0]
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        super::admit(0, 1);
        assert_eq!(1u64, "1".parse::<u64>().unwrap());
    }
}
