//! fclint fixture: hot path with typed errors only (negative case).

use std::collections::HashMap;

pub fn lookup(map: &HashMap<u64, u64>, key: u64) -> Option<u64> {
    map.get(&key).copied()
}

pub fn admit(depth: usize, max: usize) -> Result<(), String> {
    if depth > max {
        return Err(format!("queue overflow: {depth} > {max}"));
    }
    Ok(())
}

pub fn submit(xs: &[u64]) -> Option<u64> {
    xs.first().copied()
}
