//! fclint fixture: a documented allow keeps a deliberate panic source.

pub fn checked_shift(x: u32) -> u32 {
    // fclint: allow(hot-path-no-panic) -- fixture: shift amount is constant
    x.checked_shl(2).unwrap()
}
