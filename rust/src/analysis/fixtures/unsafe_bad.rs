//! fclint fixture: `unsafe` without justification (positive case).
//! Not part of the crate's module tree — only read by the lint tests.

pub fn copy_heads(dst: &mut [i16], src: &[i16]) {
    let n = dst.len().min(src.len());
    unsafe {
        std::ptr::copy_nonoverlapping(src.as_ptr(), dst.as_mut_ptr(), n);
    }
}

pub unsafe fn first_unchecked(xs: &[i16]) -> i16 {
    *xs.get_unchecked(0)
}
