//! fclint fixture: fingerprint flow absorbing every bit-affecting
//! field and none of the bit-neutral knobs.

pub struct Model {
    pub routing_tag: u64,
    pub acc_coupling_q: i16,
    pub row_ptr: Vec<u32>,
    pub w_ij: Vec<i16>,
    pub conv_weights: Vec<i16>,
}

impl Model {
    pub fn fingerprint(&self) -> u64 {
        let mut h = self.routing_tag ^ self.acc_coupling_q as u64;
        for &r in &self.row_ptr {
            h = h.wrapping_mul(31).wrapping_add(r as u64);
        }
        for &w in self.w_ij.iter().chain(&self.conv_weights) {
            h = h.wrapping_mul(31).wrapping_add(w as u16 as u64);
        }
        h
    }
}
