//! fclint fixture: the dispatcher routes to an AVX2 kernel that has no
//! scalar twin and no bit-identity bench coverage.

pub mod avx2;
pub mod scalar;

pub fn frob_i16(x: &[i16]) -> i64 {
    if cfg!(target_feature = "avx2") {
        // SAFETY: fixture — dispatch checked the CPU feature.
        unsafe { avx2::frob_i16(x) }
    } else {
        scalar::noop_i16(x)
    }
}
