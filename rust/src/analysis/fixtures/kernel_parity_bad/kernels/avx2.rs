//! fclint fixture: the AVX2 half of the dispatched pair.

/// # Safety
/// The CPU must support AVX2.
pub unsafe fn frob_i16(x: &[i16]) -> i64 {
    x.iter().map(|&v| v as i64).sum()
}
