//! fclint fixture: the scalar twin of `frob_i16` is missing.

pub fn noop_i16(x: &[i16]) -> i64 {
    x.iter().map(|&v| v as i64).sum()
}
