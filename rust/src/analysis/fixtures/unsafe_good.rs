//! fclint fixture: `unsafe` with adjacent justification (negative case).

pub fn copy_heads(dst: &mut [i16], src: &[i16]) {
    let n = dst.len().min(src.len());
    // SAFETY: both pointers come from live slices and `n` is clamped to
    // the shorter length, so the copy stays in bounds.
    unsafe {
        std::ptr::copy_nonoverlapping(src.as_ptr(), dst.as_mut_ptr(), n);
    }
}

/// Reads the first element without a bounds check.
///
/// # Safety
/// `xs` must be non-empty.
pub unsafe fn first_unchecked(xs: &[i16]) -> i16 {
    // SAFETY: the caller promises `xs` is non-empty.
    unsafe { *xs.get_unchecked(0) }
}
