//! fclint fixture: the canonical wire constants.

pub const MAGIC: [u8; 4] = *b"FCAP";
pub const VERSION: u8 = 1;
pub const V2: u8 = 2;
pub const MAX_PAYLOAD: u32 = 4 << 20;
pub const HEADER_LEN: usize = 10;
