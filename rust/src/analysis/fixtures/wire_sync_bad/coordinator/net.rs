//! fclint fixture: a peer that drifts from wire.rs — it redefines a
//! version constant, hardcodes the payload cap, and re-spells the
//! frame magic instead of importing `wire::MAGIC`.

/// Drifted: wire.rs says 2.
pub const V2: u8 = 3;

pub fn frame_ok(len: u32) -> bool {
    (len as usize) < 4 << 20 && has_magic()
}

fn has_magic() -> bool {
    b"FCAP"[0] == 0x46
}
