//! fclint fixture: the suppression pragma silences the unsafe lint.

pub fn len_via_ffi(xs: &[i16]) -> usize {
    // fclint: allow(unsafe-needs-safety) -- fixture: pragma must silence this
    unsafe { ffi_len(xs.as_ptr(), xs.len()) }
}

extern "C" {
    fn ffi_len(ptr: *const i16, n: usize) -> usize;
}
