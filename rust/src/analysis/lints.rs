//! The `fclint` lint implementations.
//!
//! Each lint is a pure function from scanned sources (plus auxiliary
//! non-Rust texts: `kernel_bench.rs`, `DESIGN.md`) to findings. They
//! are registered in [`crate::analysis::registry`] and configured by
//! [`crate::analysis::LintConfig`]; suppression pragmas are applied
//! centrally by the engine, not here.

use super::scan::ScannedFile;
use super::{Finding, LintConfig};

/// Everything a lint may look at.
pub struct Ctx<'a> {
    /// Scanned in-tree `.rs` sources.
    pub files: &'a [ScannedFile],
    /// Auxiliary raw texts: `(path, text)` for `kernel_bench.rs`,
    /// `DESIGN.md`, … — consulted by repo-level lints only.
    pub aux: &'a [(String, String)],
    pub cfg: &'a LintConfig,
}

impl Ctx<'_> {
    fn file_ending_in(&self, suffix: &str) -> Option<&ScannedFile> {
        self.files.iter().find(|f| f.path.ends_with(suffix))
    }

    fn aux_ending_in(&self, suffix: &str) -> Option<&(String, String)> {
        self.aux.iter().find(|(p, _)| p.ends_with(suffix))
    }
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Whether `hay` contains `needle` as a word (identifier-bounded).
fn contains_word(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let at = from + pos;
        let before_ok = at == 0 || !hay[..at].chars().next_back().map(is_ident).unwrap_or(false);
        let after = hay[at + needle.len()..].chars().next();
        if before_ok && !after.map(is_ident).unwrap_or(false) {
            return true;
        }
        from = at + needle.len();
    }
    false
}

// ---------------------------------------------------------------------
// 1. unsafe-needs-safety

pub const UNSAFE_NEEDS_SAFETY: &str = "unsafe-needs-safety";

/// Every line with an `unsafe` token needs a justification: `SAFETY:`
/// in a trailing comment or in the contiguous comment/attribute block
/// directly above (a `/// # Safety` doc section also qualifies for
/// `unsafe fn` declarations). Test code is **not** exempt — the AVX2
/// bit-identity tests call `unsafe fn`s too.
pub fn unsafe_needs_safety(ctx: &Ctx) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in ctx.files {
        for (idx, line) in file.lines.iter().enumerate() {
            if !contains_word(&line.code, "unsafe") {
                continue;
            }
            if has_safety_note(file, idx) {
                continue;
            }
            let msg = "`unsafe` without an adjacent `// SAFETY:` comment".to_string();
            out.push(Finding::deny(UNSAFE_NEEDS_SAFETY, &file.path, idx + 1, msg));
        }
    }
    out
}

fn has_safety_note(file: &ScannedFile, idx: usize) -> bool {
    let marker = |c: &str| c.contains("SAFETY:") || c.contains("# Safety");
    if marker(&file.lines[idx].comment) {
        return true;
    }
    // Walk the contiguous comment/attribute block above.
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let line = &file.lines[i];
        let code = line.code.trim();
        let is_attr = code.starts_with("#[") || code.starts_with("#![");
        let is_comment_only = code.is_empty() && !line.comment.trim().is_empty();
        if !(is_attr || is_comment_only) {
            return false;
        }
        if marker(&line.comment) {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------
// 2. hot-path-no-panic

pub const HOT_PATH_NO_PANIC: &str = "hot-path-no-panic";

const PANIC_TOKENS: [&str; 6] = [
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

/// Panic sources are denied outside `#[cfg(test)]` in the configured
/// hot-path scopes. A scope is either a whole file (`fns` empty) or a
/// named-function subset of one. Additionally, functions listed in
/// `indexing_hot_fns` must stay free of slice-indexing expressions.
pub fn hot_path_no_panic(ctx: &Ctx) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in ctx.files {
        let scopes: Vec<_> = ctx
            .cfg
            .hot_paths
            .iter()
            .filter(|s| file.path.contains(&s.path))
            .collect();
        if scopes.is_empty() {
            continue;
        }
        let whole_file = scopes.iter().any(|s| s.fns.is_empty());
        let scope_fns: Vec<&str> = scopes
            .iter()
            .flat_map(|s| s.fns.iter().map(String::as_str))
            .collect();
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let lineno = idx + 1;
            let enclosing = file.enclosing_fn(lineno);
            if enclosing.map(|f| f.in_test).unwrap_or(false) {
                continue;
            }
            let in_scope = whole_file
                || enclosing
                    .map(|f| scope_fns.contains(&f.name.as_str()))
                    .unwrap_or(false);
            if in_scope {
                for tok in PANIC_TOKENS {
                    if line.code.contains(tok) {
                        out.push(Finding::deny(
                            HOT_PATH_NO_PANIC,
                            &file.path,
                            lineno,
                            format!("`{tok}` in hot path (typed errors only here)"),
                        ));
                    }
                }
            }
            let index_scoped = enclosing
                .map(|f| ctx.cfg.indexing_hot_fns.iter().any(|n| n == &f.name))
                .unwrap_or(false);
            if index_scoped && has_index_expr(&line.code) {
                let msg = "slice indexing in a contractually index-free hot fn".to_string();
                out.push(Finding::deny(HOT_PATH_NO_PANIC, &file.path, lineno, msg));
            }
        }
    }
    out
}

/// A `[` directly after an identifier, `)`, or `]` is an index (or
/// fixed-size-array type — close enough for a deny lint on functions
/// that are contractually index-free). Attribute lines are excluded.
fn has_index_expr(code: &str) -> bool {
    let t = code.trim();
    if t.starts_with("#[") || t.starts_with("#![") {
        return false;
    }
    let chars: Vec<char> = code.chars().collect();
    chars.windows(2).any(|w| w[1] == '[' && (is_ident(w[0]) || w[0] == ')' || w[0] == ']'))
}

// ---------------------------------------------------------------------
// 3. fingerprint-discipline

pub const FINGERPRINT_DISCIPLINE: &str = "fingerprint-discipline";

/// The deployment fingerprint keys the content-addressed cache, so its
/// input flow must absorb every bit-affecting knob (`required`: routing
/// mode, coupling, packed masks, weights) and must never absorb
/// bit-neutral ones (`forbidden`: worker count, SIMD level). Checked
/// over the union of all non-test fns named in `fingerprint_fns`.
pub fn fingerprint_discipline(ctx: &Ctx) -> Vec<Finding> {
    let mut spans: Vec<(&ScannedFile, usize, usize)> = Vec::new();
    for file in ctx.files {
        for f in &file.fns {
            if !f.in_test && ctx.cfg.fingerprint_fns.iter().any(|n| n == &f.name) {
                spans.push((file, f.start, f.end));
            }
        }
    }
    let Some(&(first_file, first_line, _)) = spans.first() else {
        // No fingerprint flow in this tree (e.g. a fixture subset):
        // nothing to check.
        return Vec::new();
    };
    let mut out = Vec::new();
    for req in &ctx.cfg.fingerprint_required {
        let found = spans.iter().any(|(file, start, end)| {
            file.lines[*start - 1..*end].iter().any(|l| ident_containing(&l.code, req))
        });
        if !found {
            let msg = format!("bit-affecting field `{req}` missing from the fingerprint flow");
            out.push(Finding::deny(FINGERPRINT_DISCIPLINE, &first_file.path, first_line, msg));
        }
    }
    for forb in &ctx.cfg.fingerprint_forbidden {
        for (file, start, end) in &spans {
            for (off, l) in file.lines[*start - 1..*end].iter().enumerate() {
                if ident_containing(&l.code, forb) {
                    let msg = format!("bit-neutral knob `{forb}` flows into the fingerprint");
                    out.push(Finding::deny(FINGERPRINT_DISCIPLINE, &file.path, start + off, msg));
                }
            }
        }
    }
    out
}

/// Case-insensitive substring search, so `coupling` matches
/// `acc_coupling_q`. Plain substring semantics are deliberate: the
/// manifest fragments are identifier-shaped and the `code` view has
/// comments removed and literal contents blanked, so a hit can only
/// come from identifier text.
fn ident_containing(code: &str, frag: &str) -> bool {
    code.to_ascii_lowercase().contains(&frag.to_ascii_lowercase())
}

// ---------------------------------------------------------------------
// 4. kernel-parity

pub const KERNEL_PARITY: &str = "kernel-parity";

/// Every kernel the dispatcher routes to AVX2 must have a scalar twin
/// (the bit-exactness reference), an AVX2 definition, and a mention in
/// `kernel_bench.rs` (where the bit-identity harness lives). Skipped
/// when the tree has no `kernels/mod.rs`.
pub fn kernel_parity(ctx: &Ctx) -> Vec<Finding> {
    let Some(mod_file) = ctx.file_ending_in("kernels/mod.rs") else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let dispatched = qualified_names(mod_file, "avx2::");
    let scalar_file = ctx.file_ending_in("kernels/scalar.rs");
    let avx2_file = ctx.file_ending_in("kernels/avx2.rs");
    let bench = ctx.aux_ending_in("kernel_bench.rs");
    for (name, lineno) in &dispatched {
        for (twin, file) in [("scalar", scalar_file), ("avx2", avx2_file)] {
            let defined = file.map(|f| defines_fn(f, name)).unwrap_or(false);
            if !defined {
                out.push(Finding::deny(
                    KERNEL_PARITY,
                    &mod_file.path,
                    *lineno,
                    format!("dispatched kernel `{name}` has no `{twin}` implementation"),
                ));
            }
        }
        match bench {
            None => out.push(Finding::deny(
                KERNEL_PARITY,
                &mod_file.path,
                *lineno,
                "kernel_bench.rs not found — bit-identity coverage unverifiable".to_string(),
            )),
            Some((bench_path, text)) => {
                if !contains_word(text, name) {
                    let msg = format!("`{name}` lacks bit-identity coverage in kernel_bench.rs");
                    out.push(Finding::deny(KERNEL_PARITY, bench_path, 1, msg));
                }
            }
        }
    }
    out
}

/// `(name, line)` pairs for identifiers qualified by `prefix` (e.g.
/// `avx2::`) in non-test code.
fn qualified_names(file: &ScannedFile, prefix: &str) -> Vec<(String, usize)> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let mut from = 0;
        while let Some(pos) = line.code[from..].find(prefix) {
            let at = from + pos + prefix.len();
            let name: String = line.code[at..].chars().take_while(|&c| is_ident(c)).collect();
            if !name.is_empty() && !out.iter().any(|(n, _)| n == &name) {
                out.push((name, idx + 1));
            }
            from = at;
        }
    }
    out
}

fn defines_fn(file: &ScannedFile, name: &str) -> bool {
    file.fns.iter().any(|f| f.name == name)
}

// ---------------------------------------------------------------------
// 5. wire-constant-sync

pub const WIRE_CONSTANT_SYNC: &str = "wire-constant-sync";

const WATCHED_CONSTS: [&str; 6] = [
    "MAGIC",
    "VERSION",
    "V2",
    "MAX_PAYLOAD",
    "HEADER_LEN",
    "CONN_TAG",
];

/// `wire.rs` is the single owner of the frame constants. Peers
/// (`net.rs`, `event_loop.rs`) must reference them qualified — any
/// local redefinition must be textually identical, and raw `FCAP`
/// magic or hardcoded payload-cap literals outside `wire.rs` are
/// denied. `DESIGN.md` must state the same magic and MiB cap.
pub fn wire_constant_sync(ctx: &Ctx) -> Vec<Finding> {
    let Some(wire) = ctx.file_ending_in("coordinator/wire.rs") else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let canon: Vec<(&str, String, usize)> = WATCHED_CONSTS
        .iter()
        .filter_map(|name| const_value(wire, name).map(|(v, l)| (*name, v, l)))
        .collect();
    let cap_entry = canon.iter().find(|(n, _, _)| *n == "MAX_PAYLOAD");
    let cap = cap_entry.and_then(|(_, v, _)| eval_u64(v));

    for peer_suffix in ["coordinator/net.rs", "coordinator/event_loop.rs"] {
        let Some(peer) = ctx.file_ending_in(peer_suffix) else {
            continue;
        };
        for (name, canon_value, _) in &canon {
            if let Some((peer_value, lineno)) = const_value(peer, name) {
                if normalize(&peer_value) != normalize(canon_value) {
                    let detail = format!("`{peer_value}` != wire.rs `{canon_value}`");
                    let msg = format!("local `{name}` is {detail}; import `wire::{name}`");
                    out.push(Finding::deny(WIRE_CONSTANT_SYNC, &peer.path, lineno, msg));
                }
            }
        }
        for must_ref in ["wire::VERSION", "wire::V2"] {
            if !peer.lines.iter().any(|l| l.code.contains(must_ref)) {
                out.push(Finding::deny(
                    WIRE_CONSTANT_SYNC,
                    &peer.path,
                    1,
                    format!("never references `{must_ref}` — wire version drift risk"),
                ));
            }
        }
        for (idx, line) in peer.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            if line.stripped.contains("FCAP") {
                out.push(Finding::deny(
                    WIRE_CONSTANT_SYNC,
                    &peer.path,
                    idx + 1,
                    "raw `FCAP` magic outside wire.rs — use `wire::MAGIC`".to_string(),
                ));
            }
            if cap.map(|c| mentions_cap_literal(&line.stripped, c)).unwrap_or(false) {
                let msg = "hardcoded payload cap — use `wire::MAX_PAYLOAD`".to_string();
                out.push(Finding::deny(WIRE_CONSTANT_SYNC, &peer.path, idx + 1, msg));
            }
        }
    }

    match ctx.aux_ending_in("DESIGN.md") {
        None => out.push(Finding::deny(
            WIRE_CONSTANT_SYNC,
            &wire.path,
            1,
            "DESIGN.md not found — wire constants undocumentable".to_string(),
        )),
        Some((design_path, text)) => {
            if !text.contains("FCAP") {
                out.push(Finding::deny(
                    WIRE_CONSTANT_SYNC,
                    design_path,
                    1,
                    "DESIGN.md never states the `FCAP` frame magic".to_string(),
                ));
            }
            if let Some(cap) = cap {
                let mib = format!("{} MiB", cap >> 20);
                if !text.contains(&mib) {
                    let msg = format!("DESIGN.md does not state the `{mib}` payload cap");
                    out.push(Finding::deny(WIRE_CONSTANT_SYNC, design_path, 1, msg));
                }
            }
            if !text.contains("v2") {
                out.push(Finding::deny(
                    WIRE_CONSTANT_SYNC,
                    design_path,
                    1,
                    "DESIGN.md never mentions the v2 wire dialect".to_string(),
                ));
            }
        }
    }
    out
}

/// `(value text, line)` of `const NAME: … = value;` in non-test code,
/// read from the comment-stripped (but literal-preserving) view.
fn const_value(file: &ScannedFile, name: &str) -> Option<(String, usize)> {
    let pat = format!("const {name}:");
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test || !line.stripped.contains(&pat) {
            continue;
        }
        let after_eq = line.stripped.split_once('=')?.1;
        let value = after_eq.split(';').next().unwrap_or(after_eq).trim();
        return Some((value.to_string(), idx + 1));
    }
    None
}

fn normalize(s: &str) -> String {
    s.chars().filter(|c| !c.is_whitespace()).collect()
}

/// Evaluate a const expression of the shapes used for the payload cap:
/// a decimal literal (with `_`), `A << B`, or `A * B * C…`.
fn eval_u64(expr: &str) -> Option<u64> {
    let s: String = expr
        .chars()
        .filter(|c| !c.is_whitespace() && *c != '_' && *c != '(' && *c != ')')
        .collect();
    if let Some((a, b)) = s.split_once("<<") {
        return Some(a.parse::<u64>().ok()? << b.parse::<u64>().ok()?);
    }
    if s.contains('*') {
        return s.split('*').try_fold(1u64, |acc, p| p.parse::<u64>().ok().map(|v| acc * v));
    }
    s.parse().ok()
}

/// Whether a code line hardcodes the payload cap (`4 << 20`, the raw
/// decimal, or `4 * 1024 * 1024`).
fn mentions_cap_literal(stripped: &str, cap: u64) -> bool {
    let mib = cap >> 20;
    let patterns = [
        format!("{mib} << 20"),
        format!("{mib}<<20"),
        cap.to_string(),
        format!("{mib} * 1024 * 1024"),
    ];
    patterns.iter().any(|p| {
        let mut from = 0;
        while let Some(pos) = stripped[from..].find(p.as_str()) {
            let at = from + pos;
            let before = stripped[..at].chars().next_back();
            let after = stripped[at + p.len()..].chars().next();
            let digit = |c: Option<char>| c.map(|c| c.is_ascii_digit()).unwrap_or(false);
            if !digit(before) && !digit(after) {
                return true;
            }
            from = at + p.len();
        }
        false
    })
}
