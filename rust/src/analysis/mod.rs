//! `fclint` — the repo-invariant static analyzer behind the `fclint`
//! binary (`src/bin/fclint.rs`) and the blocking CI gate.
//!
//! The codebase rests on invariants the compiler cannot see: scalar ↔
//! AVX2 bit-exactness, the fingerprint discipline that keeps the
//! content-addressed cache sound, `// SAFETY:` coverage on every
//! `unsafe` site, panic-free serving hot paths, and wire constants
//! that agree across modules and docs. This module scans the tree
//! (see [`scan`]) and checks those invariants as deny-level lints
//! (see [`lints`]); any finding fails CI.
//!
//! Suppression is per line: `// fclint: allow(<lint-name>) -- reason`
//! on the offending line or the line directly above. The reason is
//! free text but expected — suppressions without justification don't
//! survive review. See DESIGN.md §3i for the registry and the
//! fingerprint manifest.

pub mod lints;
pub mod scan;

use lints::Ctx;
use scan::ScannedFile;
use std::io;
use std::path::{Path, PathBuf};

/// Severity. Every current lint denies; `Warn` exists so a future lint
/// can report without gating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    Deny,
    Warn,
}

/// One lint hit, anchored to a file and 1-based line.
#[derive(Debug, Clone)]
pub struct Finding {
    pub lint: &'static str,
    pub level: Level,
    pub path: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn deny(lint: &'static str, path: &str, line: usize, message: String) -> Finding {
        Finding {
            lint,
            level: Level::Deny,
            path: path.to_string(),
            line,
            message,
        }
    }
}

/// A hot-path scope: a path substring, optionally narrowed to named fns
/// (empty `fns` = the whole file).
#[derive(Debug, Clone)]
pub struct HotPathScope {
    pub path: String,
    pub fns: Vec<String>,
}

impl HotPathScope {
    fn whole(path: &str) -> HotPathScope {
        HotPathScope {
            path: path.to_string(),
            fns: Vec::new(),
        }
    }

    fn fns(path: &str, fns: &[&str]) -> HotPathScope {
        HotPathScope {
            path: path.to_string(),
            fns: fns.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// Lint configuration. [`LintConfig::repo_default`] encodes this
/// repository's invariants; fixture tests construct their own.
#[derive(Debug, Clone)]
pub struct LintConfig {
    pub hot_paths: Vec<HotPathScope>,
    /// Fns that are contractually free of slice indexing.
    pub indexing_hot_fns: Vec<String>,
    /// Fn names whose union forms the fingerprint input flow.
    pub fingerprint_fns: Vec<String>,
    /// Ident fragments that must appear in that flow (bit-affecting).
    pub fingerprint_required: Vec<String>,
    /// Ident fragments that must not (bit-neutral).
    pub fingerprint_forbidden: Vec<String>,
    /// Run only these lints (empty = all).
    pub only: Vec<String>,
}

impl LintConfig {
    /// The checked manifest for this repository (see DESIGN.md §3i).
    pub fn repo_default() -> LintConfig {
        let strs = |xs: &[&str]| xs.iter().map(|s| s.to_string()).collect();
        LintConfig {
            hot_paths: vec![
                HotPathScope::whole("coordinator/event_loop.rs"),
                HotPathScope::fns(
                    "coordinator/server.rs",
                    &["submit", "submit_sink", "classify", "replica_loop", "run_and_reply"],
                ),
                HotPathScope::whole("cache/"),
                HotPathScope::whole("kernels/"),
                HotPathScope::whole("routing/"),
            ],
            indexing_hot_fns: strs(&["submit", "submit_sink", "classify"]),
            fingerprint_fns: strs(&[
                "fingerprint",
                "deployment_fingerprint",
                "absorb_fingerprint",
            ]),
            // Bit-affecting: routing mode + coupling quantization, the
            // packed survivor layout (row_ptr), the transformation
            // matrices (w_ij) and conv weights.
            fingerprint_required: strs(&["routing", "coupling", "row_ptr", "w_ij", "weights"]),
            // Bit-neutral: replica/worker counts and the SIMD dispatch
            // level change scheduling, never output bits.
            fingerprint_forbidden: strs(&["workers", "simd"]),
            only: Vec::new(),
        }
    }
}

/// A registered lint.
pub struct Lint {
    pub name: &'static str,
    pub description: &'static str,
    run: fn(&Ctx) -> Vec<Finding>,
}

/// The lint registry, in reporting order.
pub fn registry() -> Vec<Lint> {
    vec![
        Lint {
            name: lints::UNSAFE_NEEDS_SAFETY,
            description: "every `unsafe` needs an adjacent `// SAFETY:` justification",
            run: lints::unsafe_needs_safety,
        },
        Lint {
            name: lints::HOT_PATH_NO_PANIC,
            description: "no unwrap/expect/panic/unreachable (or indexing in \
                          contracted fns) in serving hot paths outside tests",
            run: lints::hot_path_no_panic,
        },
        Lint {
            name: lints::FINGERPRINT_DISCIPLINE,
            description: "bit-affecting knobs flow into the deployment \
                          fingerprint; bit-neutral knobs never do",
            run: lints::fingerprint_discipline,
        },
        Lint {
            name: lints::KERNEL_PARITY,
            description: "every dispatched kernel has scalar + avx2 twins and \
                          bit-identity bench coverage",
            run: lints::kernel_parity,
        },
        Lint {
            name: lints::WIRE_CONSTANT_SYNC,
            description: "wire magic/version/cap constants agree across \
                          wire.rs, net.rs, event_loop.rs and DESIGN.md",
            run: lints::wire_constant_sync,
        },
    ]
}

/// An unscanned source handed to [`analyze_sources`] — `path` is what
/// scoping and suppression reporting see.
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// The result of an analysis run.
#[derive(Debug)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Findings silenced by `// fclint: allow(...)` pragmas.
    pub suppressed: usize,
}

impl Report {
    /// Deny-level findings gate (exit nonzero / fail CI).
    pub fn denies(&self) -> usize {
        self.findings.iter().filter(|f| f.level == Level::Deny).count()
    }
}

/// Run the registry over in-memory sources. `aux` carries non-scanned
/// texts (`kernel_bench.rs`, `DESIGN.md`) for the repo-level lints.
pub fn analyze_sources(
    sources: &[SourceFile],
    aux: &[(String, String)],
    cfg: &LintConfig,
) -> Report {
    let scanned: Vec<ScannedFile> = sources
        .iter()
        .map(|src| scan::scan(&src.path, &src.text))
        .collect();
    let ctx = Ctx {
        files: &scanned,
        aux,
        cfg,
    };
    let mut findings = Vec::new();
    for lint in registry() {
        if !cfg.only.is_empty() && !cfg.only.iter().any(|n| n == lint.name) {
            continue;
        }
        findings.extend((lint.run)(&ctx));
    }
    findings.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    let before = findings.len();
    findings.retain(|f| !is_suppressed(&scanned, f));
    Report {
        suppressed: before - findings.len(),
        files_scanned: scanned.len(),
        findings,
    }
}

/// `// fclint: allow(<lint>)` on the finding's line or the line above.
fn is_suppressed(scanned: &[ScannedFile], f: &Finding) -> bool {
    let Some(file) = scanned.iter().find(|s| s.path == f.path) else {
        return false;
    };
    let allows = |idx: usize| {
        file.lines.get(idx).map(|l| pragma_allows(&l.comment, f.lint)).unwrap_or(false)
    };
    allows(f.line - 1) || (f.line >= 2 && allows(f.line - 2))
}

/// Whether comment text carries `fclint: allow(...)` naming `lint`.
fn pragma_allows(comment: &str, lint: &str) -> bool {
    let Some(pos) = comment.find("fclint: allow(") else {
        return false;
    };
    let inner = &comment[pos + "fclint: allow(".len()..];
    let Some(end) = inner.find(')') else {
        return false;
    };
    inner[..end].split(',').any(|n| n.trim() == lint)
}

/// Walk `root` for `.rs` sources (skipping `target/`, `vendor/`,
/// `fixtures/` and VCS dirs), locate the auxiliary texts, and run the
/// registry. Paths in findings are relative to `root`.
pub fn analyze_tree(root: &Path, cfg: &LintConfig) -> io::Result<Report> {
    // Canonicalize before the upward aux searches: a relative root like
    // `src` (how CI invokes the binary) has only the empty-path
    // ancestor, which would silently skip every parent directory.
    let canonical = root.canonicalize().unwrap_or_else(|_| root.to_path_buf());
    let root = canonical.as_path();
    let mut files = Vec::new();
    let mut aux: Vec<(String, String)> = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for path in entries {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
            if path.is_dir() {
                // `fixtures/` is only skipped when nested: pointing the
                // binary at a fixture tree directly must still lint it.
                if !matches!(name.as_str(), "target" | "target-native" | "vendor" | ".git")
                    && name != "fixtures"
                {
                    stack.push(path);
                }
                continue;
            }
            let rel = rel_path(root, &path);
            if name.ends_with(".rs") {
                files.push(SourceFile {
                    path: rel,
                    text: std::fs::read_to_string(&path)?,
                });
            } else if name == "DESIGN.md" {
                aux.push((rel, std::fs::read_to_string(&path)?));
            }
        }
    }
    // Aux texts that normally live outside the scan root: the crate's
    // bench file and the repo-root DESIGN.md.
    if !aux.iter().any(|(p, _)| p.ends_with("DESIGN.md")) {
        for up in root.ancestors().skip(1).take(4) {
            let candidate = up.join("DESIGN.md");
            if candidate.is_file() {
                aux.push(("DESIGN.md".into(), std::fs::read_to_string(candidate)?));
                break;
            }
        }
    }
    let bench_in_tree = files
        .iter()
        .find(|f| f.path.ends_with("kernel_bench.rs"))
        .map(|f| (f.path.clone(), f.text.clone()));
    match bench_in_tree {
        Some(pair) => aux.push(pair),
        None => {
            for up in root.ancestors().skip(1).take(2) {
                let candidate = up.join("benches/kernel_bench.rs");
                if candidate.is_file() {
                    aux.push((
                        "benches/kernel_bench.rs".into(),
                        std::fs::read_to_string(candidate)?,
                    ));
                    break;
                }
            }
        }
    }
    Ok(analyze_sources(&files, &aux, cfg))
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(files: &[(&str, &str)], aux: &[(&str, &str)], cfg: &LintConfig) -> Report {
        let mut srcs = Vec::new();
        for (p, t) in files {
            srcs.push(SourceFile { path: p.to_string(), text: t.to_string() });
        }
        let mut auxv = Vec::new();
        for (p, t) in aux {
            auxv.push((p.to_string(), t.to_string()));
        }
        analyze_sources(&srcs, &auxv, cfg)
    }

    fn only(lint: &str) -> LintConfig {
        LintConfig { only: vec![lint.to_string()], ..LintConfig::repo_default() }
    }

    #[test]
    fn registry_lists_five_lints() {
        assert_eq!(registry().len(), 5);
    }

    #[test]
    fn unsafe_without_note_is_denied() {
        let cfg = only(lints::UNSAFE_NEEDS_SAFETY);
        let r = run(&[("k.rs", include_str!("fixtures/unsafe_bad.rs"))], &[], &cfg);
        assert_eq!(r.denies(), 2, "{:?}", r.findings);
        assert_eq!(r.findings[0].line, 6);
        assert_eq!(r.findings[1].line, 11);
    }

    #[test]
    fn unsafe_with_note_is_clean() {
        let cfg = only(lints::UNSAFE_NEEDS_SAFETY);
        let r = run(&[("k.rs", include_str!("fixtures/unsafe_good.rs"))], &[], &cfg);
        assert_eq!(r.denies(), 0, "{:?}", r.findings);
    }

    #[test]
    fn unsafe_pragma_suppresses_and_is_counted() {
        let cfg = only(lints::UNSAFE_NEEDS_SAFETY);
        let r = run(&[("k.rs", include_str!("fixtures/unsafe_suppressed.rs"))], &[], &cfg);
        assert_eq!(r.denies(), 0, "{:?}", r.findings);
        assert_eq!(r.suppressed, 1);
    }

    #[test]
    fn hot_path_panics_are_denied_in_scope() {
        let cfg = only(lints::HOT_PATH_NO_PANIC);
        let text = include_str!("fixtures/cache/hot_path_bad.rs");
        let r = run(&[("cache/hot_path_bad.rs", text)], &[], &cfg);
        let lines: Vec<usize> = r.findings.iter().map(|f| f.line).collect();
        assert_eq!(lines, vec![7, 12, 18], "{:?}", r.findings);
        let out = run(&[("report/hot_path_bad.rs", text)], &[], &cfg);
        assert_eq!(out.denies(), 0, "out-of-scope file must not be linted");
    }

    #[test]
    fn hot_path_typed_errors_are_clean() {
        let cfg = only(lints::HOT_PATH_NO_PANIC);
        let text = include_str!("fixtures/cache/hot_path_good.rs");
        let r = run(&[("cache/hot_path_good.rs", text)], &[], &cfg);
        assert_eq!(r.denies(), 0, "{:?}", r.findings);
    }

    #[test]
    fn hot_path_pragma_suppresses() {
        let cfg = only(lints::HOT_PATH_NO_PANIC);
        let text = include_str!("fixtures/cache/hot_path_suppressed.rs");
        let r = run(&[("cache/hot_path_suppressed.rs", text)], &[], &cfg);
        assert_eq!(r.denies(), 0, "{:?}", r.findings);
        assert_eq!(r.suppressed, 1);
    }

    #[test]
    fn server_scope_is_limited_to_named_fns() {
        let cfg = only(lints::HOT_PATH_NO_PANIC);
        let text = "fn submit(x: Option<u32>) -> u32 {\n\
                        x.unwrap()\n\
                    }\n\
                    fn helper(x: Option<u32>) -> u32 {\n\
                        x.unwrap()\n\
                    }\n";
        let r = run(&[("coordinator/server.rs", text)], &[], &cfg);
        assert_eq!(r.denies(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].line, 2);
    }

    #[test]
    fn fingerprint_gaps_and_leaks_are_denied() {
        let cfg = only(lints::FINGERPRINT_DISCIPLINE);
        let text = include_str!("fixtures/fingerprint_bad.rs");
        let r = run(&[("model.rs", text)], &[], &cfg);
        assert_eq!(r.denies(), 5, "{:?}", r.findings);
        let msgs: Vec<&str> = r.findings.iter().map(|f| f.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("`coupling` missing")));
        assert!(msgs.iter().any(|m| m.contains("`workers` flows into")));
    }

    #[test]
    fn fingerprint_full_flow_is_clean() {
        let cfg = only(lints::FINGERPRINT_DISCIPLINE);
        let text = include_str!("fixtures/fingerprint_good.rs");
        let r = run(&[("model.rs", text)], &[], &cfg);
        assert_eq!(r.denies(), 0, "{:?}", r.findings);
    }

    #[test]
    fn fingerprint_lint_skips_trees_without_the_flow() {
        let cfg = only(lints::FINGERPRINT_DISCIPLINE);
        let r = run(&[("x.rs", "pub fn plain() {}\n")], &[], &cfg);
        assert_eq!(r.findings.len(), 0);
    }

    #[test]
    fn kernel_without_scalar_twin_or_bench_is_denied() {
        let cfg = only(lints::KERNEL_PARITY);
        let files = [
            ("kernels/mod.rs", include_str!("fixtures/kernel_parity_bad/kernels/mod.rs")),
            ("kernels/scalar.rs", include_str!("fixtures/kernel_parity_bad/kernels/scalar.rs")),
            ("kernels/avx2.rs", include_str!("fixtures/kernel_parity_bad/kernels/avx2.rs")),
        ];
        let r = run(&files, &[], &cfg);
        assert_eq!(r.denies(), 2, "{:?}", r.findings);
        let msgs: Vec<&str> = r.findings.iter().map(|f| f.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("no `scalar` implementation")));
        assert!(msgs.iter().any(|m| m.contains("kernel_bench.rs not found")));
    }

    #[test]
    fn kernel_with_twins_and_bench_is_clean() {
        let cfg = only(lints::KERNEL_PARITY);
        let scalar_ok = "pub fn frob_i16(x: &[i16]) -> i64 {\n    x.len() as i64\n}\n";
        let files = [
            ("kernels/mod.rs", include_str!("fixtures/kernel_parity_bad/kernels/mod.rs")),
            ("kernels/scalar.rs", scalar_ok),
            ("kernels/avx2.rs", include_str!("fixtures/kernel_parity_bad/kernels/avx2.rs")),
        ];
        let bench = [("benches/kernel_bench.rs", "frob_i16 bit-identity")];
        let r = run(&files, &bench, &cfg);
        assert_eq!(r.denies(), 0, "{:?}", r.findings);
    }

    #[test]
    fn wire_drift_is_denied() {
        let cfg = only(lints::WIRE_CONSTANT_SYNC);
        let design = "frames: FCAP magic, 4 MiB cap, v1 and v2 dialects\n";
        let files = [
            ("coordinator/wire.rs", include_str!("fixtures/wire_sync_bad/coordinator/wire.rs")),
            ("coordinator/net.rs", include_str!("fixtures/wire_sync_bad/coordinator/net.rs")),
        ];
        let r = run(&files, &[("DESIGN.md", design)], &cfg);
        assert_eq!(r.denies(), 5, "{:?}", r.findings);
        let msgs: Vec<&str> = r.findings.iter().map(|f| f.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("!= wire.rs")));
        assert!(msgs.iter().any(|m| m.contains("wire::MAGIC")));
        assert!(msgs.iter().any(|m| m.contains("wire::MAX_PAYLOAD")));
    }

    #[test]
    fn design_doc_drift_is_denied() {
        let cfg = only(lints::WIRE_CONSTANT_SYNC);
        let wire = "pub const MAGIC: [u8; 4] = *b\"FCAP\";\n\
                    pub const VERSION: u8 = 1;\n\
                    pub const V2: u8 = 2;\n\
                    pub const MAX_PAYLOAD: u32 = 8 << 20;\n\
                    pub const HEADER_LEN: usize = 10;\n";
        let net = "use super::wire;\n\
                   pub fn ok(v: u8) -> bool {\n\
                       v == wire::VERSION || v == wire::V2\n\
                   }\n";
        let design = "frames: FCAP magic, 4 MiB cap, v1 and v2 dialects\n";
        let files = [("coordinator/wire.rs", wire), ("coordinator/net.rs", net)];
        let r = run(&files, &[("DESIGN.md", design)], &cfg);
        assert_eq!(r.denies(), 1, "{:?}", r.findings);
        assert!(r.findings[0].message.contains("8 MiB"));
    }
}
