//! A lightweight, line-oriented Rust scanner for `fclint`.
//!
//! This is deliberately **not** a parser: the lints need only to tell
//! code from comments from string literals, to track brace depth, and
//! to attribute lines to `#[cfg(test)]` regions and named `fn` items.
//! Per line the scanner produces three views:
//!
//! * `code` — comments removed **and** string/char literal contents
//!   blanked, so lint tokens inside strings (including fclint's own
//!   message text) never self-trigger;
//! * `stripped` — comments removed but string literals intact, for
//!   checks that inspect literal values (wire magic, const values);
//! * `comment` — the comment text alone, for `// SAFETY:` adjacency
//!   and `// fclint: allow(...)` suppression pragmas.
//!
//! Block comments nest (as in Rust), raw strings (`r"…"`, `r#"…"#`)
//! are skipped to their terminator, and `'…'` is treated as a char
//! literal only when it closes like one — a bare `'ident` is a
//! lifetime and stays in `code`.

/// One scanned source line. Line numbers are implicit (index + 1).
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Comments removed, string/char contents blanked (delimiters kept).
    pub code: String,
    /// Comments removed, string literals intact.
    pub stripped: String,
    /// Comment text on this line (line + block comments, concatenated).
    pub comment: String,
    /// Inside a `#[cfg(test)]` / `#[test]` region (or opening one).
    pub in_test: bool,
}

/// A named `fn` item and the line span of its body.
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// 1-based line of the `fn` keyword.
    pub start: usize,
    /// 1-based line of the body's closing brace.
    pub end: usize,
    pub in_test: bool,
}

/// A scanned file: per-line views plus the extracted `fn` items.
#[derive(Debug)]
pub struct ScannedFile {
    /// Forward-slash path, as given by the caller (repo-relative when
    /// produced by the tree walker).
    pub path: String,
    pub lines: Vec<Line>,
    pub fns: Vec<FnItem>,
}

impl ScannedFile {
    /// The innermost named `fn` containing 1-based line `line`, if any.
    pub fn enclosing_fn(&self, line: usize) -> Option<&FnItem> {
        self.fns
            .iter()
            .filter(|f| f.start <= line && line <= f.end)
            .min_by_key(|f| f.end - f.start)
    }
}

/// Scan `text` into per-line code/comment views plus fn items.
pub fn scan(path: &str, text: &str) -> ScannedFile {
    let mut lines = lex(text);
    let fns = structure(&mut lines);
    ScannedFile {
        path: path.to_string(),
        lines,
        fns,
    }
}

// ---------------------------------------------------------------------
// pass 1: lexing (comments, strings, char literals)

#[derive(Clone, Copy, PartialEq)]
enum LexState {
    Code,
    /// Nesting depth of `/* … */`.
    Block(u32),
    Str,
    /// Raw string, closed by `"` followed by this many `#`s.
    RawStr(u32),
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn lex(text: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = LexState::Code;
    for raw in text.lines() {
        let mut line = Line::default();
        let chars: Vec<char> = raw.chars().collect();
        let mut i = 0;
        let mut prev_code: Option<char> = None;
        while i < chars.len() {
            let c = chars[i];
            let next = chars.get(i + 1).copied();
            match state {
                LexState::Block(depth) => {
                    if c == '/' && next == Some('*') {
                        state = LexState::Block(depth + 1);
                        i += 2;
                    } else if c == '*' && next == Some('/') {
                        state = if depth == 1 {
                            LexState::Code
                        } else {
                            LexState::Block(depth - 1)
                        };
                        i += 2;
                    } else {
                        line.comment.push(c);
                        i += 1;
                    }
                }
                LexState::Str => {
                    if c == '\\' {
                        // Keep the escape sequence in `stripped`:
                        // literal-comparing checks (wire-constant-sync)
                        // must see `"\n"` and `"\t"` as different.
                        line.stripped.push(c);
                        if let Some(n) = next {
                            line.stripped.push(n);
                        }
                        i += 2;
                    } else if c == '"' {
                        line.code.push('"');
                        line.stripped.push('"');
                        state = LexState::Code;
                        i += 1;
                    } else {
                        line.stripped.push(c);
                        i += 1;
                    }
                }
                LexState::RawStr(hashes) => {
                    if c == '"' && closes_raw(&chars, i + 1, hashes) {
                        line.code.push('"');
                        line.stripped.push('"');
                        state = LexState::Code;
                        i += 1 + hashes as usize;
                    } else {
                        line.stripped.push(c);
                        i += 1;
                    }
                }
                LexState::Code => {
                    if c == '/' && next == Some('/') {
                        // Line comment: the rest of the line (after the
                        // `//`) is comment text.
                        line.comment.push_str(&chars[i + 2..].iter().collect::<String>());
                        i = chars.len();
                    } else if c == '/' && next == Some('*') {
                        state = LexState::Block(1);
                        i += 2;
                    } else if c == '"' {
                        line.code.push('"');
                        line.stripped.push('"');
                        state = LexState::Str;
                        i += 1;
                    } else if c == 'r'
                        && !prev_code.map(is_ident).unwrap_or(false)
                        && raw_string_hashes(&chars, i + 1).is_some()
                    {
                        let hashes = raw_string_hashes(&chars, i + 1).unwrap_or(0);
                        line.code.push('"');
                        line.stripped.push('"');
                        state = LexState::RawStr(hashes);
                        i += 2 + hashes as usize;
                        prev_code = Some('"');
                        continue;
                    } else if c == '\'' {
                        // Char literal vs lifetime. `'\…'` and `'x'`
                        // are literals (blank them); `'ident` is a
                        // lifetime (keep the quote, move on).
                        if next == Some('\\') {
                            // Skip the escaped char, then find the close
                            // (handles `'\''` and `'\u{…}'`).
                            let from = (i + 3).min(chars.len());
                            let close = chars[from..].iter().position(|&c| c == '\'');
                            let skip = close.map(|p| from + p + 1).unwrap_or(chars.len());
                            line.code.push_str("''");
                            line.stripped.push_str("''");
                            i = skip;
                        } else if next.is_some() && chars.get(i + 2) == Some(&'\'') {
                            line.code.push_str("''");
                            line.stripped.push_str("''");
                            i += 3;
                        } else {
                            line.code.push('\'');
                            line.stripped.push('\'');
                            i += 1;
                        }
                        prev_code = Some('\'');
                        continue;
                    } else {
                        line.code.push(c);
                        line.stripped.push(c);
                        i += 1;
                        prev_code = Some(c);
                        continue;
                    }
                }
            }
            prev_code = None;
        }
        out.push(line);
    }
    out
}

/// After `r` at `chars[start..]`: `Some(n)` if `#^n "` begins a raw
/// string (n may be 0).
fn raw_string_hashes(chars: &[char], start: usize) -> Option<u32> {
    let mut n = 0;
    let mut i = start;
    while chars.get(i) == Some(&'#') {
        n += 1;
        i += 1;
    }
    (chars.get(i) == Some(&'"')).then_some(n)
}

/// Whether `"` at position `quote_end - 1` is followed by `hashes` `#`s.
fn closes_raw(chars: &[char], quote_end: usize, hashes: u32) -> bool {
    (0..hashes as usize).all(|k| chars.get(quote_end + k) == Some(&'#'))
}

// ---------------------------------------------------------------------
// pass 2: structure (brace depth, test regions, fn items)

/// A `fn` item seen but not yet attached to a body.
struct PendingFn {
    name: String,
    depth: usize,
    /// 1-based line of the `fn` keyword.
    start: usize,
    /// Char column of the `fn` keyword on that line. Punctuation
    /// *before* it is not the fn's own: a `;` there (`mod m; fn f…`)
    /// must not cancel it, and braces there (`impl X { fn g…`) adjust
    /// `depth` instead of attaching.
    col: usize,
}

impl PendingFn {
    fn owns(&self, depth: usize, lineno: usize, col: usize) -> bool {
        self.depth == depth && (self.start != lineno || col > self.col)
    }
}

/// Marks `in_test` on each line and extracts named `fn` spans.
///
/// A `#[cfg(test)]` or `#[test]` attribute arms a pending marker at the
/// current brace depth; the next `{` opening at that depth starts the
/// test region, which ends when the depth closes back. A `fn name`
/// token arms a pending fn the same way (cancelled by a `;` after it —
/// bodyless trait/extern declarations).
fn structure(lines: &mut [Line]) -> Vec<FnItem> {
    let mut fns = Vec::new();
    let mut depth = 0usize;
    let mut test_stack: Vec<usize> = Vec::new();
    let mut fn_stack: Vec<(String, usize, usize)> = Vec::new(); // (name, depth, start line)
    let mut pending_test: Option<usize> = None;
    let mut pending_fn: Option<PendingFn> = None;

    for (idx, line) in lines.iter_mut().enumerate() {
        let lineno = idx + 1;
        let at_start = !test_stack.is_empty();

        if line.code.contains("cfg(test)") || line.code.contains("#[test]") {
            pending_test = Some(depth);
        }
        if let Some((name, col)) = fn_name_on(&line.code) {
            pending_fn = Some(PendingFn {
                name,
                depth,
                start: lineno,
                col,
            });
        }

        for (col, c) in line.code.chars().enumerate() {
            match c {
                '{' => {
                    if pending_test == Some(depth) {
                        test_stack.push(depth);
                        pending_test = None;
                    }
                    if let Some(p) = pending_fn.as_mut() {
                        if p.start == lineno && col < p.col {
                            // A brace before the fn keyword on its own
                            // line (`impl X { fn g…`): the fn sits one
                            // level inside it, so its own `{`/`;` must
                            // be matched at the deeper depth.
                            p.depth += 1;
                        }
                    }
                    if let Some(p) = pending_fn.take() {
                        if p.owns(depth, lineno, col) {
                            fn_stack.push((p.name, depth, p.start));
                        } else {
                            pending_fn = Some(p);
                        }
                    }
                    depth += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if let Some(p) = pending_fn.as_mut() {
                        if p.start == lineno && col < p.col {
                            p.depth = p.depth.saturating_sub(1);
                        }
                    }
                    if pending_fn.as_ref().map(|p| depth < p.depth).unwrap_or(false) {
                        // The block the fn was declared in closed with
                        // no body attached — it can never attach now.
                        pending_fn = None;
                    }
                    if test_stack.last() == Some(&depth) {
                        test_stack.pop();
                    }
                    if fn_stack.last().map(|(_, d, _)| *d) == Some(depth) {
                        if let Some((name, _, start)) = fn_stack.pop() {
                            fns.push(FnItem {
                                name,
                                start,
                                end: lineno,
                                in_test: at_start || !test_stack.is_empty(),
                            });
                        }
                    }
                }
                ';' => {
                    if pending_fn.as_ref().map(|p| p.owns(depth, lineno, col)).unwrap_or(false) {
                        pending_fn = None;
                    }
                    if pending_test == Some(depth) && fn_stack.is_empty() {
                        // `#[cfg(test)] use …;` — attribute consumed by a
                        // braceless item. Only clear at item level.
                        pending_test = None;
                    }
                }
                _ => {}
            }
        }
        line.in_test = at_start || !test_stack.is_empty() || pending_test.is_some();
    }
    fns.sort_by_key(|f| f.start);
    fns
}

/// The identifier following a word-bounded `fn` keyword plus the
/// keyword's char column, if the line declares a named function
/// (`fn(` pointer types have no name).
fn fn_name_on(code: &str) -> Option<(String, usize)> {
    let bytes: Vec<char> = code.chars().collect();
    let mut i = 0;
    while i + 1 < bytes.len() {
        let word_start = i == 0 || !is_ident(bytes[i - 1]);
        if word_start && bytes[i] == 'f' && bytes[i + 1] == 'n' {
            let after = bytes.get(i + 2).copied();
            if after.map(|c| !is_ident(c)).unwrap_or(true) {
                let mut j = i + 2;
                while j < bytes.len() && bytes[j] == ' ' {
                    j += 1;
                }
                let name: String = bytes[j..].iter().take_while(|&&c| is_ident(c)).collect();
                if !name.is_empty() {
                    return Some((name, i));
                }
            }
        }
        i += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_separated() {
        let f = scan("t.rs", "let x = \"unsafe // not code\"; // SAFETY: note\n");
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(f.lines[0].stripped.contains("unsafe // not code"));
        assert!(f.lines[0].comment.contains("SAFETY:"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let f = scan("t.rs", "/* a /* b */ still comment */ code()\nmore();\n");
        assert!(f.lines[0].code.contains("code()"));
        assert!(!f.lines[0].code.contains("still"));
        assert!(f.lines[1].code.contains("more()"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let f = scan("t.rs", "let c = '{'; fn f<'a>(x: &'a str) {}\n");
        // The brace inside the char literal must not affect depth.
        assert_eq!(f.fns.len(), 1);
        assert!(f.lines[0].code.contains("'a>"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let f = scan("t.rs", "let s = r#\"unsafe { } \"#; call();\n");
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(f.lines[0].code.contains("call()"));
    }

    #[test]
    fn test_regions_cover_cfg_test_mods() {
        let text = "pub fn live() {}\n\
                    #[cfg(test)]\n\
                    mod tests {\n\
                        #[test]\n\
                        fn t() { x.unwrap(); }\n\
                    }\n\
                    pub fn live2() {}\n";
        let f = scan("t.rs", text);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test, "attribute line is test");
        assert!(f.lines[4].in_test, "test body is test");
        assert!(!f.lines[6].in_test, "code after the mod is live");
        let t = f.fns.iter().find(|x| x.name == "t").expect("fn t");
        assert!(t.in_test);
        let live = f.fns.iter().find(|x| x.name == "live").expect("fn live");
        assert!(!live.in_test);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let f = scan("t.rs", "#[cfg(not(test))]\npub fn live() { x(); }\n");
        assert!(!f.lines[1].in_test);
    }

    #[test]
    fn fn_spans_nest_and_enclose() {
        let text = "pub fn outer(a: u32) -> u32 {\n\
                        fn inner(b: u32) -> u32 {\n\
                            b + 1\n\
                        }\n\
                        inner(a)\n\
                    }\n";
        let f = scan("t.rs", text);
        assert_eq!(f.enclosing_fn(3).map(|x| x.name.as_str()), Some("inner"));
        assert_eq!(f.enclosing_fn(5).map(|x| x.name.as_str()), Some("outer"));
    }

    #[test]
    fn bodyless_fns_are_skipped() {
        let f = scan("t.rs", "extern \"C\" {\n    fn poll(n: u64) -> i32;\n}\n");
        assert!(f.fns.is_empty());
    }

    #[test]
    fn single_line_trait_decl_cancels_pending_fn() {
        // The fn's `;` sits one brace level deeper than the line start;
        // it must still cancel the declaration, not leak onto the next
        // top-level block.
        let text = "trait T { fn f(&self); }\n\
                    pub fn live() {\n\
                        body();\n\
                    }\n";
        let f = scan("t.rs", text);
        let names: Vec<&str> = f.fns.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, vec!["live"], "{:?}", f.fns);
        assert_eq!(f.enclosing_fn(3).map(|x| x.name.as_str()), Some("live"));
    }

    #[test]
    fn single_line_impl_fn_attaches_to_its_own_body() {
        let text = "impl X { fn g() { inner(); } }\n\
                    pub fn live() {}\n";
        let f = scan("t.rs", text);
        let g = f.fns.iter().find(|x| x.name == "g").expect("fn g");
        assert_eq!((g.start, g.end), (1, 1));
        let live = f.fns.iter().find(|x| x.name == "live").expect("fn live");
        assert_eq!((live.start, live.end), (2, 2));
    }

    #[test]
    fn string_escapes_survive_in_stripped() {
        let f = scan("t.rs", "let a = \"x\\n\";\nlet b = \"x\\t\";\n");
        assert!(f.lines[0].stripped.contains("\\n"));
        assert!(f.lines[1].stripped.contains("\\t"));
        assert_ne!(
            f.lines[0].stripped.replace("let a", ""),
            f.lines[1].stripped.replace("let b", "")
        );
    }
}
