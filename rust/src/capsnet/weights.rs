//! Weight container, binary interchange format and 16-bit quantization.
//!
//! The interchange format (`.fcw`) is written by `python/compile/train.py`
//! and read here, keeping Python strictly on the build path:
//!
//! ```text
//! magic   "FCW1"                       4 bytes
//! count   u32 LE                       number of named tensors
//! per tensor:
//!   name_len u32 LE, name utf-8
//!   rank     u32 LE, dims u32 LE × rank
//!   data     f32 LE × prod(dims)
//! ```

use crate::config::CapsNetConfig;
use crate::fixed::Fx;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::Result;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// All learned parameters of a CapsNet.
#[derive(Debug, Clone)]
pub struct Weights {
    /// Conv1 kernel `[conv1_ch, c_in, k, k]` and bias `[conv1_ch]`.
    pub conv1_w: Tensor,
    pub conv1_b: Tensor,
    /// PrimaryCaps kernel `[pc_channels, conv1_ch, k, k]` and bias.
    pub pc_w: Tensor,
    pub pc_b: Tensor,
    /// DigitCaps transform `[pc_types, n_classes, pc_dim, dc_dim]` —
    /// shared across spatial positions within a capsule type. This is the
    /// standard CapsNet-accelerator weight layout ([16], [17]): the
    /// per-position transform of Sabour et al. needs 645 KB at 16 bits for
    /// the pruned MNIST model alone, which cannot fit the PYNQ-Z1's 630 KB
    /// of BRAM; the paper's reported 131.5 BRAM only closes under sharing.
    /// See DESIGN.md §Hardware-Adaptation.
    pub w_ij: Tensor,
}

impl Weights {
    /// He-normal random initialisation matching the architecture.
    pub fn random(cfg: &CapsNetConfig, rng: &mut Rng) -> Weights {
        let (c_in, _, _) = cfg.input;
        let k1 = cfg.conv1_k;
        let std1 = (2.0 / (c_in * k1 * k1) as f32).sqrt();
        let conv1_w = Tensor::randn(&[cfg.conv1_ch, c_in, k1, k1], std1, rng);
        let conv1_b = Tensor::zeros(&[cfg.conv1_ch]);
        let k2 = cfg.pc_k;
        let std2 = (2.0 / (cfg.conv1_ch * k2 * k2) as f32).sqrt();
        let pc_w = Tensor::randn(&[cfg.pc_channels(), cfg.conv1_ch, k2, k2], std2, rng);
        let pc_b = Tensor::zeros(&[cfg.pc_channels()]);
        let std3 = (1.0 / cfg.pc_dim as f32).sqrt();
        let w_ij = Tensor::randn(
            &[cfg.pc_types, cfg.num_classes, cfg.pc_dim, cfg.dc_dim],
            std3,
            rng,
        );
        Weights {
            conv1_w,
            conv1_b,
            pc_w,
            pc_b,
            w_ij,
        }
    }

    /// Validate tensor shapes against an architecture config.
    pub fn validate(&self, cfg: &CapsNetConfig) -> Result<()> {
        let (c_in, _, _) = cfg.input;
        let want = vec![cfg.conv1_ch, c_in, cfg.conv1_k, cfg.conv1_k];
        anyhow::ensure!(
            self.conv1_w.shape == want,
            "conv1_w shape {:?} != {want:?}",
            self.conv1_w.shape
        );
        let want = vec![cfg.pc_channels(), cfg.conv1_ch, cfg.pc_k, cfg.pc_k];
        anyhow::ensure!(
            self.pc_w.shape == want,
            "pc_w shape {:?} != {want:?}",
            self.pc_w.shape
        );
        let want = vec![cfg.pc_types, cfg.num_classes, cfg.pc_dim, cfg.dc_dim];
        anyhow::ensure!(
            self.w_ij.shape == want,
            "w_ij shape {:?} != {want:?}",
            self.w_ij.shape
        );
        Ok(())
    }

    /// Round-trip all parameters through 16-bit fixed point (the paper's
    /// deployment quantization). Returns the quantized-then-dequantized
    /// weights plus the worst absolute error, so callers can assert the
    /// "no accuracy drop" claim.
    pub fn quantize16<const F: u32>(&self) -> (Weights, f32) {
        let mut worst = 0.0f32;
        let q = |t: &Tensor, worst: &mut f32| -> Tensor {
            let data: Vec<f32> = t
                .data
                .iter()
                .map(|&x| {
                    let r = Fx::<F>::from_f32(x).to_f32();
                    *worst = worst.max((r - x).abs());
                    r
                })
                .collect();
            Tensor {
                shape: t.shape.clone(),
                data,
            }
        };
        let w = Weights {
            conv1_w: q(&self.conv1_w, &mut worst),
            conv1_b: q(&self.conv1_b, &mut worst),
            pc_w: q(&self.pc_w, &mut worst),
            pc_b: q(&self.pc_b, &mut worst),
            w_ij: q(&self.w_ij, &mut worst),
        };
        (w, worst)
    }

    /// Content fingerprint over shapes and exact f32 bit patterns of
    /// all five tensors (in `.fcw` save order). Feeds
    /// [`crate::backend::BackendSpec::fingerprint`], so any weight
    /// change — retrain, re-quantize, even a single flipped mantissa
    /// bit — re-keys the inference cache.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::hash::Hash64::new(0x6663_7721); // "fcw!"
        for t in [
            &self.conv1_w,
            &self.conv1_b,
            &self.pc_w,
            &self.pc_b,
            &self.w_ij,
        ] {
            h.absorb(t.shape.len() as u64);
            for &d in &t.shape {
                h.absorb(d as u64);
            }
            h.absorb_f32s(&t.data);
        }
        h.finish()
    }

    /// Serialize to the `.fcw` interchange format.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.save_with_coupling(path, None)
    }

    /// Serialize, optionally appending the offline-accumulated routing
    /// coupling (`fastcaps accumulate`) as an extra named tensor
    /// (`[n_caps, n_classes]`). Readers that predate the tensor ignore
    /// it — [`Weights::load`] takes only the five canonical tensors —
    /// so the sidecar is backward compatible by construction.
    pub fn save_with_coupling(&self, path: &Path, coupling: Option<&Tensor>) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"FCW1");
        let mut tensors: Vec<(&str, &Tensor)> = vec![
            ("conv1_w", &self.conv1_w),
            ("conv1_b", &self.conv1_b),
            ("pc_w", &self.pc_w),
            ("pc_b", &self.pc_b),
            ("w_ij", &self.w_ij),
        ];
        if let Some(c) = coupling {
            tensors.push((ACC_COUPLING, c));
        }
        buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, t) in tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &t.data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(&buf)?;
        Ok(())
    }

    /// Load from the `.fcw` interchange format.
    pub fn load(path: &Path) -> Result<Weights> {
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut buf)?;
        let mut map = parse_fcw(&buf)?;
        let mut take = |name: &str| -> Result<Tensor> {
            map.remove(name)
                .ok_or_else(|| anyhow::anyhow!("missing tensor '{name}'"))
        };
        Ok(Weights {
            conv1_w: take("conv1_w")?,
            conv1_b: take("conv1_b")?,
            pc_w: take("pc_w")?,
            pc_b: take("pc_b")?,
            w_ij: take("w_ij")?,
        })
    }
}

/// Name of the optional accumulated-coupling sidecar tensor in `.fcw`
/// files (`[n_caps, n_classes]`, written by `fastcaps accumulate`).
pub const ACC_COUPLING: &str = "acc_coupling";

/// Read the accumulated-coupling sidecar tensor from a `.fcw` file.
/// `Ok(None)` when the file has no sidecar (weights written before an
/// accumulation pass).
pub fn load_coupling(path: &Path) -> Result<Option<Tensor>> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut buf)?;
    let mut map = parse_fcw(&buf)?;
    Ok(map.remove(ACC_COUPLING))
}

/// Parse an `.fcw` byte buffer into named tensors.
pub fn parse_fcw(buf: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut pos;
    let rd_u32 = |buf: &[u8], pos: &mut usize| -> Result<u32> {
        if *pos + 4 > buf.len() {
            bail!("truncated .fcw at byte {pos:?}");
        }
        let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
        Ok(v)
    };
    if buf.len() < 8 || &buf[0..4] != b"FCW1" {
        bail!(".fcw magic mismatch");
    }
    pos = 4;
    let count = rd_u32(buf, &mut pos)?;
    let mut map = BTreeMap::new();
    for _ in 0..count {
        let name_len = rd_u32(buf, &mut pos)? as usize;
        if pos + name_len > buf.len() {
            bail!("truncated tensor name");
        }
        let name = std::str::from_utf8(&buf[pos..pos + name_len])
            .context("tensor name not utf-8")?
            .to_string();
        pos += name_len;
        let rank = rd_u32(buf, &mut pos)? as usize;
        if rank > 8 {
            bail!("implausible rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(rd_u32(buf, &mut pos)? as usize);
        }
        let n: usize = shape.iter().product();
        if pos + 4 * n > buf.len() {
            bail!("truncated tensor data for '{name}'");
        }
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            data.push(f32::from_le_bytes(
                buf[pos + 4 * i..pos + 4 * i + 4].try_into().unwrap(),
            ));
        }
        pos += 4 * n;
        map.insert(name, Tensor::from_vec(&shape, data)?);
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CapsNetConfig;

    #[test]
    fn fingerprint_tracks_weight_bits() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = crate::util::rng::Rng::new(3);
        let w = Weights::random(&cfg, &mut rng);
        assert_eq!(w.fingerprint(), w.clone().fingerprint(), "deterministic");
        assert_ne!(
            w.fingerprint(),
            Weights::random(&cfg, &mut rng).fingerprint(),
            "different draws must differ"
        );
        // A single flipped mantissa bit must re-key the deployment.
        let mut bitflip = w.clone();
        bitflip.pc_w.data[0] = f32::from_bits(bitflip.pc_w.data[0].to_bits() ^ 1);
        assert_ne!(w.fingerprint(), bitflip.fingerprint());
    }

    #[test]
    fn random_weights_validate() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(1);
        let w = Weights::random(&cfg, &mut rng);
        w.validate(&cfg).unwrap();
        // Wrong config fails.
        assert!(w.validate(&CapsNetConfig::paper_full("x")).is_err());
    }

    #[test]
    fn save_load_round_trip() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(2);
        let w = Weights::random(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("fastcaps-test-weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.fcw");
        w.save(&path).unwrap();
        let loaded = Weights::load(&path).unwrap();
        assert_eq!(loaded.conv1_w, w.conv1_w);
        assert_eq!(loaded.w_ij, w.w_ij);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn coupling_sidecar_round_trips_and_stays_backward_compatible() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(7);
        let w = Weights::random(&cfg, &mut rng);
        let coupling = Tensor::from_vec(
            &[cfg.num_primary_caps(), cfg.num_classes],
            vec![1.0 / cfg.num_classes as f32; cfg.num_primary_caps() * cfg.num_classes],
        )
        .unwrap();
        let dir = std::env::temp_dir().join("fastcaps-test-weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny-acc.fcw");
        w.save_with_coupling(&path, Some(&coupling)).unwrap();
        // The five canonical tensors still load (the sidecar is ignored).
        let loaded = Weights::load(&path).unwrap();
        assert_eq!(loaded.w_ij, w.w_ij);
        // The sidecar round-trips bit for bit.
        let side = load_coupling(&path).unwrap().unwrap();
        assert_eq!(side, coupling);
        // A file without the sidecar reads back as None.
        let plain = dir.join("tiny-plain.fcw");
        w.save(&plain).unwrap();
        assert!(load_coupling(&plain).unwrap().is_none());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&plain).ok();
    }

    #[test]
    fn load_rejects_corrupt() {
        assert!(parse_fcw(b"NOPE").is_err());
        assert!(parse_fcw(b"FCW1\x01\x00\x00\x00").is_err());
        // Valid magic+count but truncated body.
        let mut buf = b"FCW1".to_vec();
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.extend_from_slice(b"abc");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&100u32.to_le_bytes()); // claims 100 floats
        assert!(parse_fcw(&buf).is_err());
    }

    #[test]
    fn quantization_error_bounded() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(3);
        let w = Weights::random(&cfg, &mut rng);
        let (_, worst) = w.quantize16::<12>();
        // Q4.12 round-to-nearest: half a step unless saturated; He-init
        // weights are well inside ±8.
        assert!(worst <= 0.5 / 4096.0 + 1e-6, "worst {worst}");
    }
}
