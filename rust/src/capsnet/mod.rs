//! The CapsNet workload (Fig. 3): Conv1 → PrimaryCaps → DigitCaps with
//! dynamic routing — fp32 reference forward pass, weight container with a
//! binary interchange format (written by `python/compile/train.py`, read
//! here), and the 16-bit quantizer used before deployment.
//!
//! The fp32 forward in this module is the *oracle*: the PJRT runtime
//! (executing the JAX-lowered HLO) and the fixed-point FPGA simulator are
//! both tested against it.

pub mod compiled;
pub mod weights;

pub use compiled::CompiledCapsNet;

use crate::config::CapsNetConfig;
use crate::routing::{
    accumulated_routing_with, dynamic_routing_with, mean_coupling, Predictions, RoutingMode,
    RoutingOutput, RoutingScratch,
};
use crate::tensor::{conv2d, Tensor};
use crate::util::rng::Rng;
use crate::Result;
use weights::Weights;

/// A CapsNet model: architecture + weights.
#[derive(Debug, Clone)]
pub struct CapsNet {
    pub config: CapsNetConfig,
    pub weights: Weights,
}

/// Full forward-pass intermediates (useful for layer-wise verification).
#[derive(Debug, Clone)]
pub struct Activations {
    /// Conv1 output after ReLU: `[conv1_ch, h1, w1]`.
    pub conv1: Tensor,
    /// PrimaryCaps conv output: `[pc_channels, h2, w2]`.
    pub pc_conv: Tensor,
    /// Squashed primary capsules: `[n_caps][pc_dim]` flattened.
    pub primary_caps: Vec<f32>,
    /// Routing result over DigitCaps.
    pub routing: RoutingOutput,
}

impl Activations {
    /// Class scores = DigitCaps lengths.
    pub fn class_lengths(&self) -> Vec<f32> {
        self.routing.lengths()
    }

    pub fn predicted_class(&self) -> usize {
        // NaN-safe: a corrupt length must not panic callers (argmax
        // ignores NaN entries instead).
        crate::util::argmax(&self.class_lengths())
    }
}

impl CapsNet {
    /// Random-initialised model (He-style std per layer).
    pub fn random(config: CapsNetConfig, rng: &mut Rng) -> CapsNet {
        let weights = Weights::random(&config, rng);
        CapsNet { config, weights }
    }

    /// Stages up to (and including) the primary-capsule squash for one
    /// image — shared verbatim between [`CapsNet::forward`] and
    /// [`CapsNet::forward_batch`], so the two paths cannot drift.
    fn primary_stage(&self, image: &Tensor) -> Result<PrimaryStage> {
        let cfg = &self.config;
        anyhow::ensure!(
            image.shape == vec![cfg.input.0, cfg.input.1, cfg.input.2],
            "input shape {:?} != config {:?}",
            image.shape,
            cfg.input
        );

        // Conv1 + ReLU.
        let conv1 = conv2d(
            image,
            &self.weights.conv1_w,
            Some(&self.weights.conv1_b),
            cfg.conv1_stride,
        )?
        .relu();

        // PrimaryCaps conv (linear; the capsule non-linearity is squash).
        let pc_conv = conv2d(
            &conv1,
            &self.weights.pc_w,
            Some(&self.weights.pc_b),
            cfg.pc_stride,
        )?;

        let primary_caps = squash_primary(cfg, &pc_conv);
        Ok(PrimaryStage {
            conv1,
            pc_conv,
            primary_caps,
        })
    }

    /// Forward one `[c, h, w]` image through the full network
    /// (iterative routing at the config's iteration count).
    pub fn forward(&self, image: &Tensor) -> Result<Activations> {
        self.forward_mode(image, RoutingMode::Iterative(self.config.routing_iters), None)
    }

    /// [`CapsNet::forward`] under an explicit [`RoutingMode`].
    /// `Accumulated` requires the precomputed coupling matrix
    /// (`[n_caps][num_classes]` flat — see
    /// [`CapsNet::accumulate_coupling`]).
    pub fn forward_mode(
        &self,
        image: &Tensor,
        mode: RoutingMode,
        coupling: Option<&[f32]>,
    ) -> Result<Activations> {
        let stage = self.primary_stage(image)?;
        Ok(finish_forward(
            &self.config,
            &self.weights.w_ij,
            stage,
            mode,
            coupling,
        ))
    }

    /// Forward a batch of images, restructured around shared weight
    /// traversal: the DigitCaps transform block `W[t][j]` is loaded once
    /// and applied to every image's capsules of type `t` before moving to
    /// the next block (weight-stationary, the batch analogue of the PE
    /// array keeping one kernel resident), and one routing scratch is
    /// reused across all frames.
    ///
    /// Per-element accumulation order is identical to [`CapsNet::forward`]
    /// (each û element still sums over `kk` ascending), so the results are
    /// bit-exact equal to the per-image path — a property test pins this.
    pub fn forward_batch(&self, images: &[Tensor]) -> Result<Vec<Activations>> {
        self.forward_batch_mode(
            images,
            RoutingMode::Iterative(self.config.routing_iters),
            None,
        )
    }

    /// [`CapsNet::forward_batch`] under an explicit [`RoutingMode`].
    pub fn forward_batch_mode(
        &self,
        images: &[Tensor],
        mode: RoutingMode,
        coupling: Option<&[f32]>,
    ) -> Result<Vec<Activations>> {
        let stages: Vec<PrimaryStage> = images
            .iter()
            .map(|img| self.primary_stage(img))
            .collect::<Result<_>>()?;
        Ok(finish_forward_batch(
            &self.config,
            &self.weights.w_ij,
            stages,
            mode,
            coupling,
        ))
    }

    /// [`CapsNet::forward_batch_mode`] sharded across `workers` scoped
    /// threads (contiguous frame chunks). Frames are independent and
    /// each chunk runs the exact serial pipeline, so the result is
    /// bit-identical for every worker count — a property test pins it.
    pub fn forward_batch_sharded(
        &self,
        images: &[Tensor],
        mode: RoutingMode,
        coupling: Option<&[f32]>,
        workers: usize,
    ) -> Result<Vec<Activations>> {
        if workers <= 1 || images.len() <= 1 {
            return self.forward_batch_mode(images, mode, coupling);
        }
        let chunks = crate::util::parallel::shard_chunks(images, workers, |chunk| {
            self.forward_batch_mode(chunk, mode, coupling)
        });
        let mut out = Vec::with_capacity(images.len());
        for chunk in chunks {
            out.extend(chunk?);
        }
        Ok(out)
    }

    /// The offline accumulation pass (Zhao et al.): run *iterative*
    /// routing over a calibration set and average the final coupling
    /// coefficients into one `[n_caps][num_classes]` matrix. Serving
    /// with [`RoutingMode::Accumulated`] then replays this matrix with
    /// zero routing iterations.
    pub fn accumulate_coupling(&self, images: &[Tensor]) -> Result<Vec<f32>> {
        anyhow::ensure!(!images.is_empty(), "accumulation needs a calibration set");
        let acts = self.forward_batch(images)?;
        Ok(mean_coupling(
            acts.iter().map(|a| a.routing.coupling.as_slice()),
        ))
    }

    /// The masked-dense form of this model under `masks`: pruned kernels
    /// zeroed but every loop still executed densely. This is the
    /// bit-exactness reference for [`compiled::CompiledCapsNet`].
    pub fn masked(&self, masks: &crate::pruning::NetworkMasks) -> CapsNet {
        let mut net = self.clone();
        masks.apply(&mut net.weights);
        net
    }

    /// Classify one image (argmax of DigitCaps lengths) — a batch of one
    /// through the batch-native path.
    pub fn predict(&self, image: &Tensor) -> Result<usize> {
        let acts = self.forward_batch(std::slice::from_ref(image))?;
        Ok(acts[0].predicted_class())
    }

    /// Accuracy over a dataset, evaluated through the batched forward.
    pub fn accuracy(&self, data: &crate::data::Dataset) -> Result<f64> {
        const CHUNK: usize = 16;
        let mut correct = 0usize;
        for (imgs, labels) in data.images.chunks(CHUNK).zip(data.labels.chunks(CHUNK)) {
            for (acts, &label) in self.forward_batch(imgs)?.iter().zip(labels) {
                if acts.predicted_class() == label {
                    correct += 1;
                }
            }
        }
        Ok(correct as f64 / data.len().max(1) as f64)
    }
}

/// Per-image intermediates up to the primary-capsule squash (the part of
/// the forward pass with no cross-image structure to exploit). Also
/// produced by the sparse [`compiled`] path, so the routing tail below
/// is one shared implementation.
struct PrimaryStage {
    conv1: Tensor,
    pc_conv: Tensor,
    primary_caps: Vec<f32>,
}

/// The post-primary tail for one frame: û projection → dynamic routing →
/// [`Activations`]. Shared by [`CapsNet::forward`] and
/// [`compiled::CompiledCapsNet::forward`] — the bit-exactness contract
/// between the dense and sparse paths is that everything after the conv
/// stages is literally the same code.
fn finish_forward(
    cfg: &CapsNetConfig,
    w_ij: &Tensor,
    stage: PrimaryStage,
    mode: RoutingMode,
    coupling: Option<&[f32]>,
) -> Activations {
    let u_hat = project_u_hat(cfg, w_ij, &stage.primary_caps);
    let pred = Predictions::new(cfg.num_primary_caps(), cfg.num_classes, cfg.dc_dim, u_hat);
    let routing = route(&pred, mode, coupling, &mut RoutingScratch::new());
    Activations {
        conv1: stage.conv1,
        pc_conv: stage.pc_conv,
        primary_caps: stage.primary_caps,
        routing,
    }
}

/// Dispatch one frame's routing by mode — iterative loop or the
/// accumulated-coefficients fast path (which must have its matrix).
fn route(
    pred: &Predictions,
    mode: RoutingMode,
    coupling: Option<&[f32]>,
    scratch: &mut RoutingScratch,
) -> RoutingOutput {
    match mode {
        RoutingMode::Iterative(r) => dynamic_routing_with(pred, r, scratch),
        RoutingMode::Accumulated => accumulated_routing_with(
            pred,
            coupling.expect("accumulated routing requires a coupling matrix"),
            scratch,
        ),
    }
}

/// The batched tail: weight-stationary û projection, then routing per
/// frame with one scratch across the batch. Shared by
/// [`CapsNet::forward_batch`] and
/// [`compiled::CompiledCapsNet::forward_batch`].
fn finish_forward_batch(
    cfg: &CapsNetConfig,
    w_ij: &Tensor,
    stages: Vec<PrimaryStage>,
    mode: RoutingMode,
    coupling: Option<&[f32]>,
) -> Vec<Activations> {
    let caps: Vec<&[f32]> = stages.iter().map(|s| s.primary_caps.as_slice()).collect();
    let u_hats = project_u_hat_batch(cfg, w_ij, &caps);
    let mut scratch = RoutingScratch::new();
    stages
        .into_iter()
        .zip(u_hats)
        .map(|(stage, u_hat)| {
            let pred =
                Predictions::new(cfg.num_primary_caps(), cfg.num_classes, cfg.dc_dim, u_hat);
            let routing = route(&pred, mode, coupling, &mut scratch);
            Activations {
                conv1: stage.conv1,
                pc_conv: stage.pc_conv,
                primary_caps: stage.primary_caps,
                routing,
            }
        })
        .collect()
}

/// Regroup the PrimaryCaps conv output `[types*dim, h, w]` into capsules
/// `[type, y, x][dim]` and squash each. Shared verbatim by the dense
/// ([`CapsNet`]) and sparse-compiled ([`compiled::CompiledCapsNet`])
/// paths, so the post-conv stages cannot drift between them.
pub(crate) fn squash_primary(cfg: &CapsNetConfig, pc_conv: &Tensor) -> Vec<f32> {
    let (h2, w2) = cfg.pc_out();
    let n_caps = cfg.num_primary_caps();
    let d = cfg.pc_dim;
    let mut primary_caps = vec![0.0f32; n_caps * d];
    let mut s = vec![0.0f32; d];
    for t in 0..cfg.pc_types {
        for y in 0..h2 {
            for x in 0..w2 {
                let cap = (t * h2 + y) * w2 + x;
                for (k, sk) in s.iter_mut().enumerate() {
                    *sk = pc_conv.at(&[t * d + k, y, x]);
                }
                crate::routing::squash_into(
                    &s,
                    &mut primary_caps[cap * d..(cap + 1) * d],
                );
            }
        }
    }
    primary_caps
}

/// DigitCaps projections û_{j|i} = W_{t(i),j}^T u_i for one image
/// (transform shared across spatial positions within a type). Per-element
/// accumulation sums over `kk` ascending; [`project_u_hat_batch`] keeps
/// the identical order, so per-image and batched results are bit-exact
/// equal. `w_ij` layout: `[pc_types, n_out, pc_dim, dc_dim]`.
pub(crate) fn project_u_hat(
    cfg: &CapsNetConfig,
    w_ij: &Tensor,
    primary_caps: &[f32],
) -> Vec<f32> {
    let (h2, w2) = cfg.pc_out();
    let n_caps = cfg.num_primary_caps();
    let d = cfg.pc_dim;
    let n_out = cfg.num_classes;
    let d_out = cfg.dc_dim;
    let spatial = h2 * w2;
    let mut u_hat = vec![0.0f32; n_caps * n_out * d_out];
    for i in 0..n_caps {
        let t = i / spatial;
        let u = &primary_caps[i * d..(i + 1) * d];
        for j in 0..n_out {
            let base = ((t * n_out) + j) * d * d_out;
            let out = &mut u_hat[(i * n_out + j) * d_out..][..d_out];
            for (kk, &uk) in u.iter().enumerate() {
                if uk == 0.0 {
                    continue;
                }
                let wrow = &w_ij.data[base + kk * d_out..][..d_out];
                crate::kernels::axpy_f32(out, uk, wrow);
            }
        }
    }
    u_hat
}

/// Batched DigitCaps projection with shared weight traversal: each
/// transform block `W[t][j]` is loaded once and applied to every image's
/// capsules of type `t` (weight-stationary, the batch analogue of the PE
/// array keeping one kernel resident). Per-element accumulation order is
/// identical to [`project_u_hat`].
pub(crate) fn project_u_hat_batch(
    cfg: &CapsNetConfig,
    w_ij: &Tensor,
    primary_caps: &[&[f32]],
) -> Vec<Vec<f32>> {
    let (h2, w2) = cfg.pc_out();
    let n_caps = cfg.num_primary_caps();
    let d = cfg.pc_dim;
    let n_out = cfg.num_classes;
    let d_out = cfg.dc_dim;
    let spatial = h2 * w2;
    let mut u_hats = vec![vec![0.0f32; n_caps * n_out * d_out]; primary_caps.len()];
    for t in 0..cfg.pc_types {
        for j in 0..n_out {
            let base = ((t * n_out) + j) * d * d_out;
            let wblock = &w_ij.data[base..base + d * d_out];
            for (caps, u_hat) in primary_caps.iter().zip(u_hats.iter_mut()) {
                for p in 0..spatial {
                    let i = t * spatial + p;
                    let u = &caps[i * d..(i + 1) * d];
                    let out = &mut u_hat[(i * n_out + j) * d_out..][..d_out];
                    for (kk, &uk) in u.iter().enumerate() {
                        if uk == 0.0 {
                            continue;
                        }
                        let wrow = &wblock[kk * d_out..][..d_out];
                        crate::kernels::axpy_f32(out, uk, wrow);
                    }
                }
            }
        }
    }
    u_hats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CapsNetConfig;

    #[test]
    fn forward_shapes_tiny() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(1);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let img = Tensor::randn(&[1, 20, 20], 0.5, &mut rng).map(|x| x.abs().min(1.0));
        let acts = net.forward(&img).unwrap();
        let (h1, w1) = cfg.conv1_out();
        assert_eq!(acts.conv1.shape, vec![cfg.conv1_ch, h1, w1]);
        let (h2, w2) = cfg.pc_out();
        assert_eq!(acts.pc_conv.shape, vec![cfg.pc_channels(), h2, w2]);
        assert_eq!(
            acts.primary_caps.len(),
            cfg.num_primary_caps() * cfg.pc_dim
        );
        assert_eq!(acts.routing.v.len(), cfg.num_classes * cfg.dc_dim);
    }

    #[test]
    fn class_lengths_are_probability_like() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(2);
        let net = CapsNet::random(cfg, &mut rng);
        let img = crate::data::digits::render(3, &mut rng);
        // tiny config takes 20x20: crop center.
        let mut crop = Tensor::zeros(&[1, 20, 20]);
        for y in 0..20 {
            for x in 0..20 {
                crop.data[y * 20 + x] = img.at(&[0, y + 4, x + 4]);
            }
        }
        let acts = net.forward(&crop).unwrap();
        for l in acts.class_lengths() {
            assert!((0.0..1.0).contains(&l), "length {l}");
        }
        assert!(acts.predicted_class() < 10);
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let mut rng = Rng::new(3);
        let net = CapsNet::random(CapsNetConfig::tiny(), &mut rng);
        let img = Tensor::zeros(&[1, 28, 28]);
        assert!(net.forward(&img).is_err());
    }

    #[test]
    fn property_forward_batch_exactly_matches_per_image_forward() {
        // The batched weight-stationary traversal keeps each û element's
        // f32 accumulation order identical to the per-image path, so
        // equality is *exact*, not approximate.
        let mut rng = Rng::new(21);
        let net = CapsNet::random(CapsNetConfig::tiny(), &mut rng);
        crate::testing::check(
            "forward_batch == per-image forward (exact f32)",
            8,
            22,
            |r| {
                let n = 1 + r.below(5);
                (0..n)
                    .map(|_| {
                        Tensor::randn(&[1, 20, 20], 0.4, r).map(|x| x.abs().min(1.0))
                    })
                    .collect::<Vec<_>>()
            },
            |images| {
                let batched = net.forward_batch(images).unwrap();
                images.iter().zip(&batched).all(|(img, got)| {
                    let want = net.forward(img).unwrap();
                    got.routing.v == want.routing.v
                        && got.routing.coupling == want.routing.coupling
                        && got.primary_caps == want.primary_caps
                })
            },
        );
    }

    #[test]
    fn predict_and_accuracy_ride_the_batch_path() {
        let mut rng = Rng::new(23);
        let net = CapsNet::random(CapsNetConfig::tiny(), &mut rng);
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for i in 0..5 {
            let img = Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0));
            labels.push(net.forward(&img).unwrap().predicted_class());
            assert_eq!(net.predict(&img).unwrap(), labels[i]);
            images.push(img);
        }
        let data = crate::data::Dataset {
            images,
            labels,
            num_classes: 10,
        };
        // Labels are the model's own per-image predictions, so the batched
        // accuracy path must score 100% — any batch/per-image divergence
        // shows up as a miss.
        assert_eq!(net.accuracy(&data).unwrap(), 1.0);
    }

    #[test]
    fn property_sharded_forward_is_bit_identical_across_worker_counts() {
        // Contiguous frame sharding never changes any frame's
        // arithmetic, so 1/2/4 workers (and worker counts past the
        // batch size) agree bit for bit with the serial batch path.
        let mut rng = Rng::new(31);
        let net = CapsNet::random(CapsNetConfig::tiny(), &mut rng);
        let images: Vec<Tensor> = (0..6)
            .map(|_| Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0)))
            .collect();
        let coupling = net.accumulate_coupling(&images).unwrap();
        for (mode, c) in [
            (RoutingMode::Iterative(3), None),
            (RoutingMode::Accumulated, Some(coupling.as_slice())),
        ] {
            let serial = net.forward_batch_mode(&images, mode, c).unwrap();
            for workers in [1usize, 2, 4, 9] {
                let sharded = net
                    .forward_batch_sharded(&images, mode, c, workers)
                    .unwrap();
                assert_eq!(serial.len(), sharded.len());
                for (a, b) in serial.iter().zip(&sharded) {
                    assert_eq!(a.routing.v, b.routing.v, "{mode} workers={workers}");
                    assert_eq!(a.primary_caps, b.primary_caps);
                }
            }
        }
    }

    #[test]
    fn accumulated_mode_runs_iteration_free_and_deterministic() {
        let mut rng = Rng::new(32);
        let net = CapsNet::random(CapsNetConfig::tiny(), &mut rng);
        let cal: Vec<Tensor> = (0..4)
            .map(|_| Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0)))
            .collect();
        let coupling = net.accumulate_coupling(&cal).unwrap();
        let cfg = &net.config;
        assert_eq!(coupling.len(), cfg.num_primary_caps() * cfg.num_classes);
        // Every row of the accumulated matrix is a convex combination
        // of softmax rows — still ~normalized.
        for i in 0..cfg.num_primary_caps() {
            let row: f32 = coupling[i * cfg.num_classes..(i + 1) * cfg.num_classes]
                .iter()
                .sum();
            assert!((row - 1.0).abs() < 1e-3, "row {i} sums to {row}");
        }
        let img = &cal[0];
        let a = net
            .forward_mode(img, RoutingMode::Accumulated, Some(&coupling))
            .unwrap();
        let b = net
            .forward_mode(img, RoutingMode::Accumulated, Some(&coupling))
            .unwrap();
        assert_eq!(a.routing.v, b.routing.v);
        // The served coupling is exactly the accumulated constant.
        assert_eq!(a.routing.coupling, coupling);
        // Batch and per-image accumulated paths agree bit for bit.
        let batched = net
            .forward_batch_mode(
                std::slice::from_ref(img),
                RoutingMode::Accumulated,
                Some(&coupling),
            )
            .unwrap();
        assert_eq!(batched[0].routing.v, a.routing.v);
    }

    #[test]
    fn forward_deterministic() {
        let mut rng = Rng::new(4);
        let net = CapsNet::random(CapsNetConfig::tiny(), &mut rng);
        let img = Tensor::randn(&[1, 20, 20], 0.3, &mut rng);
        let a = net.forward(&img).unwrap();
        let b = net.forward(&img).unwrap();
        assert_eq!(a.routing.v, b.routing.v);
    }
}
