//! Sparse-compiled execution of a pruned CapsNet.
//!
//! LAKP leaves the network ~99% kernel-sparse (§III-A: 99.26% of MNIST
//! conv kernels removed), but [`crate::pruning::KernelMask::apply`] only
//! *zeroes* weights — a masked-dense forward still multiplies through
//! every dead kernel. [`CompiledCapsNet`] closes that prune→execute gap:
//! [`CompiledCapsNet::compile`] packs only the surviving kernels into a
//! CSR-style per-layer layout whose alive-kernel index lists are the
//! FPGA Index Control Module's own representation
//! ([`IndexControl::packed_rows`], §III-C), so the software and hardware
//! models share one sparsity encoding, and `forward`/`forward_batch`
//! skip dead kernels entirely.
//!
//! ## Bit-exactness contract
//!
//! `compile(net, masks).forward(x) ≡ net.masked(masks).forward(x)`
//! per element, for finite activations. This holds because
//!
//! * within each output channel the packed kernels keep ascending
//!   input-channel order (the dense loop order), so the surviving
//!   contributions are accumulated in exactly the dense sequence, and
//! * a dead kernel's dense contribution is `acc += x * 0.0`, which
//!   leaves a finite f32 accumulator unchanged — skipping it is exact;
//! * every post-conv stage (primary-capsule squash, DigitCaps û
//!   projection, dynamic routing) is the *same code* as the dense path
//!   ([`squash_primary`] and the shared `finish_forward` /
//!   `finish_forward_batch` routing tails), not a reimplementation.
//!
//! A property test pins the contract on random masks; the golden test
//! in `tests/compiled_golden.rs` pins it at the paper's MNIST/F-MNIST
//! compression points and at 100% density (compiled ≡ dense).

use super::{
    finish_forward, finish_forward_batch, squash_primary, Activations, CapsNet, PrimaryStage,
};
use crate::config::CapsNetConfig;
use crate::fpga::index_control::{IndexControl, PackedRows};
use crate::kernels;
use crate::pruning::{KernelMask, NetworkMasks};
use crate::routing::{mean_coupling, RoutingMode};
use crate::tensor::Tensor;
use crate::Result;

/// One conv layer packed to its surviving kernels.
#[derive(Debug, Clone)]
pub struct SparseConvLayer {
    pub out_ch: usize,
    pub in_ch: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    /// Alive-kernel index lists — the layout `IndexControl` keeps
    /// on-chip (§III-C), shared verbatim with the hardware model.
    pub index: PackedRows,
    /// Packed kernel weights: `kh*kw` values per surviving kernel, in
    /// `index` order (out channel major, in channel ascending).
    data: Vec<f32>,
    bias: Vec<f32>,
}

impl SparseConvLayer {
    /// Pack the surviving kernels of an OIHW tensor.
    pub fn pack(
        w: &Tensor,
        bias: &Tensor,
        stride: usize,
        mask: &KernelMask,
    ) -> Result<SparseConvLayer> {
        anyhow::ensure!(w.rank() == 4, "expected OIHW weights, got {:?}", w.shape);
        let (out_ch, in_ch, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
        anyhow::ensure!(
            mask.out_ch == out_ch && mask.in_ch == in_ch,
            "mask grid {}x{} != weight grid {}x{}",
            mask.out_ch,
            mask.in_ch,
            out_ch,
            in_ch
        );
        anyhow::ensure!(
            bias.len() == out_ch,
            "bias len {} != out_ch {}",
            bias.len(),
            out_ch
        );
        let index = IndexControl::from_mask(mask).packed_rows();
        let kk = kh * kw;
        let mut data = Vec::with_capacity(index.survived() * kk);
        for o in 0..out_ch {
            for &i in index.row(o) {
                let base = (o * in_ch + i as usize) * kk;
                data.extend_from_slice(&w.data[base..base + kk]);
            }
        }
        Ok(SparseConvLayer {
            out_ch,
            in_ch,
            kh,
            kw,
            stride,
            index,
            data,
            bias: bias.data.clone(),
        })
    }

    /// Sparse 2-D convolution over `[C_in, H, W]` input: the dense
    /// `conv2d` loop nest with the input-channel loop replaced by this
    /// output channel's alive-kernel list. Dead output channels (empty
    /// rows) still produce `bias` like the dense path.
    ///
    /// The loop nest is *weight-stationary* (CapsAcc-style reuse): each
    /// surviving kernel row (`kw` weights) is held resident while it
    /// sweeps every output position it touches, instead of re-fetching
    /// all survivor weights per output pixel. Per output element the
    /// contributions still arrive in (survivor ascending, ky, kx)
    /// order — the exact sequence of f32 adds the position-major nest
    /// performed — so results are bit-identical; the masked-dense
    /// property test pins this.
    pub fn forward(&self, input: &Tensor) -> Result<Tensor> {
        anyhow::ensure!(
            input.rank() == 3 && input.shape[0] == self.in_ch,
            "sparse conv wants [{}, H, W], got {:?}",
            self.in_ch,
            input.shape
        );
        let (h, w) = (input.shape[1], input.shape[2]);
        anyhow::ensure!(h >= self.kh && w >= self.kw, "kernel larger than input");
        let oh = (h - self.kh) / self.stride + 1;
        let ow = (w - self.kw) / self.stride + 1;
        let kk = self.kh * self.kw;
        let mut out = Tensor::zeros(&[self.out_ch, oh, ow]);
        for o in 0..self.out_ch {
            let row_start = self.index.row_ptr[o] as usize;
            let row = self.index.row(o);
            let plane = &mut out.data[o * oh * ow..][..oh * ow];
            // Bias seeds every accumulator first, exactly as the scalar
            // `acc = b` did.
            plane.fill(self.bias[o]);
            for (n, &i) in row.iter().enumerate() {
                let kernel = &self.data[(row_start + n) * kk..][..kk];
                let i = i as usize;
                for ky in 0..self.kh {
                    let w_row = &kernel[ky * self.kw..][..self.kw];
                    for oy in 0..oh {
                        let iy = oy * self.stride + ky;
                        let in_row = &input.data[(i * h + iy) * w..][..w];
                        let out_row = &mut plane[oy * ow..][..ow];
                        // Tap-outer: each weight tap is one strided f32
                        // axpy over the output row (SIMD-dispatched).
                        // Per output element the adds still arrive in
                        // (survivor, ky, kx) order — one rounded multiply
                        // + one rounded add each — so bits are unchanged.
                        for (kx, &wv) in w_row.iter().enumerate() {
                            kernels::axpy_strided_f32(out_row, wv, &in_row[kx..], self.stride);
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    pub fn survived(&self) -> usize {
        self.index.survived()
    }

    pub fn total(&self) -> usize {
        self.out_ch * self.in_ch
    }

    /// On-chip index memory this layer's survivor list costs (the
    /// packing owns the §III-C cost model).
    pub fn index_bytes(&self) -> usize {
        self.index.index_bytes()
    }

    /// Fold this layer's full deployed content — geometry, CSR survivor
    /// index, packed weight and bias bits — into a deployment
    /// fingerprint (see [`CompiledCapsNet::fingerprint`]).
    fn absorb_fingerprint(&self, h: &mut crate::util::hash::Hash64) {
        for d in [self.out_ch, self.in_ch, self.kh, self.kw, self.stride] {
            h.absorb(d as u64);
        }
        h.absorb_u32s(&self.index.row_ptr);
        h.absorb_u16s(&self.index.cols);
        h.absorb_f32s(&self.data);
        h.absorb_f32s(&self.bias);
    }
}

/// Packing summary of a compiled model — the compression metadata the
/// `oracle-sparse` backend reports through its `BackendSpec`.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressionStats {
    pub survived_kernels: usize,
    pub total_kernels: usize,
    /// §III-C index memory kept on-chip: the CSR sidecar (`u16` column
    /// per survivor + `u32` row pointer per output channel + 1), the
    /// same cost the BRAM/DDR models charge.
    pub index_bytes: usize,
}

impl CompressionStats {
    /// Fraction of conv kernels eliminated, percent (the paper's
    /// headline 99.26 / 98.84 numbers at the deployment masks).
    pub fn pruned_pct(&self) -> f64 {
        crate::pruning::pruned_pct(self.survived_kernels, self.total_kernels)
    }
}

/// A CapsNet compiled against its pruning masks: only surviving kernels
/// are stored and executed. See the module docs for the bit-exactness
/// contract vs the masked-dense [`CapsNet`].
#[derive(Debug, Clone)]
pub struct CompiledCapsNet {
    pub config: CapsNetConfig,
    pub conv1: SparseConvLayer,
    pub pc: SparseConvLayer,
    /// DigitCaps transform `[pc_types, n_classes, pc_dim, dc_dim]` —
    /// dense: it is tiny and its dead-capsule work is already skipped
    /// value-wise (`û += 0 · w` short-circuits in the projection).
    w_ij: Tensor,
    /// How the routing tail runs. `compile` defaults to the config's
    /// iterative count; [`CompiledCapsNet::bake_accumulated`] switches
    /// to the fast path.
    pub routing: RoutingMode,
    /// The baked accumulated coupling (`[n_caps][n_classes]` flat) —
    /// present exactly when `routing` is [`RoutingMode::Accumulated`].
    acc_coupling: Option<Vec<f32>>,
}

impl CompiledCapsNet {
    /// Pack `net`'s surviving kernels under `masks`.
    ///
    /// The weights are read *unmasked* and packing selects whole
    /// kernels, so `compile(net, m) == compile(net.masked(m), m)`; for
    /// unstructured ([`crate::pruning::WeightMask`]) pruning, apply the
    /// weight mask to the tensors first and compile with its
    /// [`crate::pruning::WeightMask::to_kernel_mask`] collapse — the
    /// packed kernels then carry their interior zeros.
    pub fn compile(net: &CapsNet, masks: &NetworkMasks) -> Result<CompiledCapsNet> {
        let cfg = &net.config;
        net.weights.validate(cfg)?;
        let conv1 = SparseConvLayer::pack(
            &net.weights.conv1_w,
            &net.weights.conv1_b,
            cfg.conv1_stride,
            &masks.conv1,
        )?;
        let pc = SparseConvLayer::pack(
            &net.weights.pc_w,
            &net.weights.pc_b,
            cfg.pc_stride,
            &masks.pc,
        )?;
        Ok(CompiledCapsNet {
            routing: RoutingMode::Iterative(cfg.routing_iters),
            config: cfg.clone(),
            conv1,
            pc,
            w_ij: net.weights.w_ij.clone(),
            acc_coupling: None,
        })
    }

    /// Bake an accumulated coupling matrix (from
    /// [`CompiledCapsNet::accumulate_coupling`] or a stored `.fcw`
    /// sidecar) and switch the routing tail to the iteration-free fast
    /// path. The baked bits join the deployment fingerprint, so a
    /// mode flip can never alias a cached iterative response.
    pub fn bake_accumulated(&mut self, coupling: Vec<f32>) -> Result<()> {
        let want = self.config.num_primary_caps() * self.config.num_classes;
        anyhow::ensure!(
            coupling.len() == want,
            "coupling len {} != n_caps × n_classes {}",
            coupling.len(),
            want
        );
        self.acc_coupling = Some(coupling);
        self.routing = RoutingMode::Accumulated;
        Ok(())
    }

    /// The baked coupling matrix, when the fast path is active.
    pub fn acc_coupling(&self) -> Option<&[f32]> {
        self.acc_coupling.as_deref()
    }

    /// The offline accumulation pass over this compiled model's own
    /// numerics: iterative routing over a calibration set, coupling
    /// averaged per (capsule, class). See
    /// [`CapsNet::accumulate_coupling`].
    pub fn accumulate_coupling(&self, images: &[Tensor]) -> Result<Vec<f32>> {
        anyhow::ensure!(!images.is_empty(), "accumulation needs a calibration set");
        let stages: Vec<PrimaryStage> = images
            .iter()
            .map(|img| self.primary_stage(img))
            .collect::<Result<_>>()?;
        let acts = finish_forward_batch(
            &self.config,
            &self.w_ij,
            stages,
            RoutingMode::Iterative(self.config.routing_iters),
            None,
        );
        Ok(mean_coupling(
            acts.iter().map(|a| a.routing.coupling.as_slice()),
        ))
    }

    pub fn stats(&self) -> CompressionStats {
        CompressionStats {
            survived_kernels: self.conv1.survived() + self.pc.survived(),
            total_kernels: self.conv1.total() + self.pc.total(),
            index_bytes: self.conv1.index_bytes() + self.pc.index_bytes(),
        }
    }

    /// Content fingerprint over everything this executor computes with:
    /// both packed layers (geometry, CSR survivor index, packed weight
    /// and bias bits) and the dense `w_ij` bits. Re-pruning with a
    /// different mask changes the survivor index and therefore the
    /// fingerprint even when the underlying weight tensor is unchanged
    /// — the property the cache's redeploy-invalidation rests on.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::hash::Hash64::new(0x6373_7221); // "csr!"
        for layer in [&self.conv1, &self.pc] {
            layer.absorb_fingerprint(&mut h);
        }
        h.absorb(self.w_ij.shape.len() as u64);
        for &d in &self.w_ij.shape {
            h.absorb(d as u64);
        }
        h.absorb_f32s(&self.w_ij.data);
        // Routing mode + baked coefficients re-key the deployment: an
        // accumulated deployment must never alias the iterative one in
        // the inference cache (PR 6 keys mix this fingerprint).
        h.absorb(self.routing.fingerprint_tag());
        if let Some(c) = &self.acc_coupling {
            h.absorb_f32s(c);
        }
        h.finish()
    }

    /// The sparse primary stage: Conv1 → ReLU → PrimaryCaps conv over
    /// surviving kernels only, then the shared squash regrouping — the
    /// same [`PrimaryStage`] the dense path produces, so the routing
    /// tail is literally shared code.
    fn primary_stage(&self, image: &Tensor) -> Result<PrimaryStage> {
        let cfg = &self.config;
        anyhow::ensure!(
            image.shape == vec![cfg.input.0, cfg.input.1, cfg.input.2],
            "input shape {:?} != config {:?}",
            image.shape,
            cfg.input
        );
        let conv1 = self.conv1.forward(image)?.relu();
        let pc_conv = self.pc.forward(&conv1)?;
        let primary_caps = squash_primary(cfg, &pc_conv);
        Ok(PrimaryStage {
            conv1,
            pc_conv,
            primary_caps,
        })
    }

    /// Forward one image — bit-exact to the masked-dense
    /// [`CapsNet::forward`]: the sparse primary stage, then the dense
    /// path's own routing tail ([`finish_forward`]).
    pub fn forward(&self, image: &Tensor) -> Result<Activations> {
        let stage = self.primary_stage(image)?;
        Ok(finish_forward(
            &self.config,
            &self.w_ij,
            stage,
            self.routing,
            self.acc_coupling.as_deref(),
        ))
    }

    /// Forward a batch — the sparse primary stage per frame, then the
    /// dense path's batched tail ([`finish_forward_batch`]:
    /// weight-stationary û traversal, one routing scratch). Bit-exact to
    /// both the per-image [`Self::forward`] and the masked-dense batch
    /// path.
    pub fn forward_batch(&self, images: &[Tensor]) -> Result<Vec<Activations>> {
        let stages: Vec<PrimaryStage> = images
            .iter()
            .map(|img| self.primary_stage(img))
            .collect::<Result<_>>()?;
        Ok(finish_forward_batch(
            &self.config,
            &self.w_ij,
            stages,
            self.routing,
            self.acc_coupling.as_deref(),
        ))
    }

    /// [`CompiledCapsNet::forward_batch`] sharded over `workers` scoped
    /// threads (contiguous frame chunks; bit-identical to serial for
    /// every worker count).
    pub fn forward_batch_sharded(
        &self,
        images: &[Tensor],
        workers: usize,
    ) -> Result<Vec<Activations>> {
        if workers <= 1 || images.len() <= 1 {
            return self.forward_batch(images);
        }
        let chunks = crate::util::parallel::shard_chunks(images, workers, |chunk| {
            self.forward_batch(chunk)
        });
        let mut out = Vec::with_capacity(images.len());
        for chunk in chunks {
            out.extend(chunk?);
        }
        Ok(out)
    }

    /// Classify one image through the batch path.
    pub fn predict(&self, image: &Tensor) -> Result<usize> {
        let acts = self.forward_batch(std::slice::from_ref(image))?;
        Ok(acts[0].predicted_class())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_masks(cfg: &CapsNetConfig, r: &mut Rng) -> NetworkMasks {
        let mut masks = NetworkMasks::dense(cfg);
        // Random density per layer, including occasionally fully dense.
        let p_dead = [0, 3, 6, 9][r.below(4)];
        for o in 0..masks.conv1.out_ch {
            for i in 0..masks.conv1.in_ch {
                if r.below(10) < p_dead {
                    masks.conv1.set(o, i, false);
                }
            }
        }
        for o in 0..masks.pc.out_ch {
            for i in 0..masks.pc.in_ch {
                if r.below(10) < p_dead {
                    masks.pc.set(o, i, false);
                }
            }
        }
        masks
    }

    #[test]
    fn fingerprint_changes_with_masks_not_with_recompiles() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(77);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let m1 = NetworkMasks::lakp(&net.weights, &cfg, 12, 128);
        let m2 = NetworkMasks::lakp(&net.weights, &cfg, 10, 100);
        let a = CompiledCapsNet::compile(&net, &m1).unwrap();
        let b = CompiledCapsNet::compile(&net, &m1).unwrap();
        let c = CompiledCapsNet::compile(&net, &m2).unwrap();
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "same net + same masks must fingerprint identically"
        );
        assert_ne!(
            a.fingerprint(),
            c.fingerprint(),
            "a re-prune (same weights, new masks) must re-key the deployment"
        );
    }

    #[test]
    fn property_compiled_is_bit_exact_to_masked_dense() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(41);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        crate::testing::check(
            "compile(mask(net)) ≡ mask(net), element-exact",
            8,
            42,
            |r| {
                let masks = random_masks(&cfg, r);
                let img = Tensor::randn(&[1, 20, 20], 0.4, r).map(|x| x.abs().min(1.0));
                (masks, img)
            },
            |(masks, img)| {
                let dense = net.masked(masks);
                let compiled = CompiledCapsNet::compile(&net, masks).unwrap();
                let want = dense.forward(img).unwrap();
                let got = compiled.forward(img).unwrap();
                got.conv1.data == want.conv1.data
                    && got.pc_conv.data == want.pc_conv.data
                    && got.primary_caps == want.primary_caps
                    && got.routing.v == want.routing.v
                    && got.routing.coupling == want.routing.coupling
            },
        );
    }

    #[test]
    fn property_compiled_batch_matches_per_image() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(43);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let masks = NetworkMasks::lakp(&net.weights, &cfg, 12, 96);
        let compiled = CompiledCapsNet::compile(&net, &masks).unwrap();
        crate::testing::check(
            "compiled forward_batch == per-image forward (exact f32)",
            6,
            44,
            |r| {
                let n = 1 + r.below(4);
                (0..n)
                    .map(|_| Tensor::randn(&[1, 20, 20], 0.4, r).map(|x| x.abs().min(1.0)))
                    .collect::<Vec<_>>()
            },
            |images| {
                let batched = compiled.forward_batch(images).unwrap();
                images.iter().zip(&batched).all(|(img, got)| {
                    let want = compiled.forward(img).unwrap();
                    got.routing.v == want.routing.v
                        && got.primary_caps == want.primary_caps
                })
            },
        );
    }

    #[test]
    fn baking_accumulated_coupling_rekeys_the_fingerprint() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(78);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let masks = NetworkMasks::lakp(&net.weights, &cfg, 12, 128);
        let iter = CompiledCapsNet::compile(&net, &masks).unwrap();
        let img = Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0));
        let coupling = iter.accumulate_coupling(std::slice::from_ref(&img)).unwrap();
        let mut acc = iter.clone();
        acc.bake_accumulated(coupling).unwrap();
        assert_ne!(
            iter.fingerprint(),
            acc.fingerprint(),
            "a mode flip must re-key the deployment (cache isolation)"
        );
        assert_eq!(acc.routing, RoutingMode::Accumulated);
        // Wrong-shaped coupling is rejected before it can be served.
        assert!(acc.clone().bake_accumulated(vec![0.1; 3]).is_err());
        // The accumulated forward serves the baked constant coupling.
        let out = acc.forward(&img).unwrap();
        assert_eq!(out.routing.coupling.as_slice(), acc.acc_coupling().unwrap());
    }

    #[test]
    fn sharded_compiled_batch_is_bit_identical() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(79);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let masks = NetworkMasks::lakp(&net.weights, &cfg, 12, 96);
        let compiled = CompiledCapsNet::compile(&net, &masks).unwrap();
        let images: Vec<Tensor> = (0..5)
            .map(|_| Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0)))
            .collect();
        let serial = compiled.forward_batch(&images).unwrap();
        for workers in [2usize, 4] {
            let sharded = compiled.forward_batch_sharded(&images, workers).unwrap();
            for (a, b) in serial.iter().zip(&sharded) {
                assert_eq!(a.routing.v, b.routing.v, "workers={workers}");
            }
        }
    }

    #[test]
    fn dense_masks_reproduce_the_dense_net() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(45);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let compiled = CompiledCapsNet::compile(&net, &NetworkMasks::dense(&cfg)).unwrap();
        assert_eq!(compiled.stats().survived_kernels, compiled.stats().total_kernels);
        let img = Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0));
        let want = net.forward(&img).unwrap();
        let got = compiled.forward(&img).unwrap();
        assert_eq!(got.routing.v, want.routing.v);
        assert_eq!(got.class_lengths(), want.class_lengths());
    }

    #[test]
    fn packing_stats_track_masks() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(46);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let masks = NetworkMasks::lakp(&net.weights, &cfg, 4, 50);
        let compiled = CompiledCapsNet::compile(&net, &masks).unwrap();
        let stats = compiled.stats();
        assert_eq!(stats.survived_kernels, 54);
        assert_eq!(stats.total_kernels, masks.total());
        // CSR sidecar per layer: u16 col per survivor + u32 row pointer
        // per output channel (+1). conv1: 4 of 16×1; pc: 50 of 32×16.
        assert_eq!(stats.index_bytes, (4 * 2 + 17 * 4) + (50 * 2 + 33 * 4));
        assert!(stats.pruned_pct() > 80.0);
        // The packed weights hold exactly kh*kw values per survivor.
        assert_eq!(compiled.conv1.survived(), 4);
        assert_eq!(compiled.pc.survived(), 50);
    }

    #[test]
    fn unstructured_weight_mask_flows_through_the_compiler() {
        // WeightMask path: mask weights first, collapse to kernel
        // granularity, compile — still bit-exact vs the weight-masked
        // dense model.
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(47);
        let mut net = CapsNet::random(cfg.clone(), &mut rng);
        let wm = crate::pruning::WeightMask {
            bits: (0..net.weights.pc_w.len()).map(|_| rng.below(4) != 0).collect(),
        };
        wm.apply(&mut net.weights.pc_w);
        let masks = NetworkMasks {
            conv1: KernelMask::all_alive(cfg.conv1_ch, cfg.input.0),
            pc: wm.to_kernel_mask(cfg.pc_channels(), cfg.conv1_ch),
        };
        let compiled = CompiledCapsNet::compile(&net, &masks).unwrap();
        assert!(compiled.pc.survived() <= compiled.pc.total());
        let img = Tensor::randn(&[1, 20, 20], 0.4, &mut rng).map(|x| x.abs().min(1.0));
        let want = net.forward(&img).unwrap();
        let got = compiled.forward(&img).unwrap();
        assert_eq!(got.routing.v, want.routing.v);
    }

    #[test]
    fn compile_rejects_mismatched_masks() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(48);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let bad = NetworkMasks {
            conv1: KernelMask::all_alive(3, 3),
            pc: KernelMask::all_alive(cfg.pc_channels(), cfg.conv1_ch),
        };
        assert!(CompiledCapsNet::compile(&net, &bad).is_err());
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let cfg = CapsNetConfig::tiny();
        let mut rng = Rng::new(49);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let compiled = CompiledCapsNet::compile(&net, &NetworkMasks::dense(&cfg)).unwrap();
        assert!(compiled.forward(&Tensor::zeros(&[1, 28, 28])).is_err());
    }
}
