//! PJRT runtime (S7): loads the AOT-lowered HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the *only* place the Python build output crosses into the rust
//! request path — as HLO **text** (not serialized protos; jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns them).
//!
//! One [`Engine`] holds one compiled executable (one model × batch-size
//! bucket) plus its resident weight literals; [`Runtime`] manages the
//! manifest and a bucket registry the coordinator picks from.
//!
//! The xla crate is not part of the offline vendored set, so the real
//! implementation is gated behind the `pjrt` cargo feature. Without it
//! this module keeps the same API surface and [`Runtime::open`] reports
//! that PJRT support is not compiled in — callers (the backend registry,
//! `fastcaps serve`, the integration tests) treat that exactly like
//! missing artifacts and fall back or skip.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub use real::{literal_from_tensor, Engine, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, Runtime};

#[cfg(feature = "pjrt")]
mod real {
    use super::manifest::{Manifest, ManifestEntry};
    use crate::tensor::Tensor;
    use crate::util::json::Json;
    use crate::Result;
    use anyhow::Context;
    use std::path::{Path, PathBuf};

    /// One compiled model executable with resident weights.
    ///
    /// Weights are transferred to device buffers once at load time
    /// (§Perf L3: the per-batch path only moves the input image batch, not
    /// the 1.2 MB of parameters).
    pub struct Engine {
        pub entry: ManifestEntry,
        exe: xla::PjRtLoadedExecutable,
        weights: Vec<xla::PjRtBuffer>,
        /// Host-side weight literals backing the device buffers. The CPU
        /// PJRT client may create zero-copy buffers that alias host memory,
        /// so the literals must live as long as the buffers.
        _weight_literals: Vec<xla::Literal>,
        client: xla::PjRtClient,
    }

    impl Engine {
        /// Compile an artifact on a PJRT client and load its weights from a
        /// `.fcw` file (ordered per the manifest's param list).
        pub fn load(
            client: &xla::PjRtClient,
            dir: &Path,
            entry: &ManifestEntry,
            weights_path: &Path,
        ) -> Result<Engine> {
            let hlo_path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", entry.name))?;

            let mut tensors = crate::capsnet::weights::parse_fcw(
                &std::fs::read(weights_path)
                    .with_context(|| format!("reading {}", weights_path.display()))?,
            )?;
            let mut weights = Vec::with_capacity(entry.params.len());
            for p in &entry.params {
                let t = tensors
                    .remove(&p.name)
                    .ok_or_else(|| anyhow::anyhow!("weights missing tensor '{}'", p.name))?;
                anyhow::ensure!(
                    t.shape == p.shape,
                    "tensor '{}' shape {:?} != manifest {:?}",
                    p.name,
                    t.shape,
                    p.shape
                );
                weights.push(literal_from_tensor(&t)?);
            }
            let buffers = weights
                .iter()
                .map(|lit| {
                    client
                        .buffer_from_host_literal(None, lit)
                        .map_err(|e| anyhow::anyhow!("uploading weights: {e}"))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(Engine {
                entry: entry.clone(),
                exe,
                weights: buffers,
                _weight_literals: weights,
                client: client.clone(),
            })
        }

        pub fn batch_size(&self) -> usize {
            self.entry.batch
        }

        /// Run one batch. `images` must contain exactly `batch` CHW tensors
        /// of the model's input shape. Returns per-image capsule lengths
        /// (`[num_classes]` each).
        pub fn run_batch(&self, images: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            let b = self.entry.batch;
            anyhow::ensure!(
                images.len() == b,
                "engine {} wants batch {b}, got {}",
                self.entry.name,
                images.len()
            );
            let per = self.entry.input_shape[1..].iter().product::<usize>();
            let mut flat = Vec::with_capacity(b * per);
            for img in images {
                anyhow::ensure!(img.len() == per, "image size {} != {per}", img.len());
                flat.extend_from_slice(&img.data);
            }
            let x = self
                .client
                .buffer_from_host_buffer(&flat, &self.entry.input_shape, None)
                .map_err(|e| anyhow::anyhow!("uploading input: {e}"))?;

            // Weights first, input last — the order aot.py lowered them in.
            let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
            args.push(&x);
            let result = self
                .exe
                .execute_b(&args)
                .map_err(|e| anyhow::anyhow!("executing {}: {e}", self.entry.name))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetching result: {e}"))?;
            // aot.py lowers with return_tuple=True: (lengths [B,J], v [B,J,D]).
            let parts = result
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("untupling result: {e}"))?;
            anyhow::ensure!(!parts.is_empty(), "empty result tuple");
            let lengths_flat: Vec<f32> = parts[0]
                .to_vec()
                .map_err(|e| anyhow::anyhow!("reading lengths: {e}"))?;
            let j = self.entry.num_classes;
            anyhow::ensure!(lengths_flat.len() == b * j, "lengths size mismatch");
            Ok(lengths_flat.chunks(j).map(|c| c.to_vec()).collect())
        }
    }

    /// Convert a dense f32 tensor into an XLA literal.
    pub fn literal_from_tensor(t: &Tensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&t.data)
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("literal reshape: {e}"))
    }

    /// The artifact registry: manifest + PJRT client; engines load on
    /// demand.
    pub struct Runtime {
        pub dir: PathBuf,
        pub manifest: Manifest,
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Open an artifact directory (expects `manifest.json`).
        pub fn open(dir: &Path) -> Result<Runtime> {
            let text = std::fs::read_to_string(dir.join("manifest.json"))
                .with_context(|| format!("reading manifest in {}", dir.display()))?;
            let manifest = Manifest::parse(&Json::parse(&text)?)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e}"))?;
            Ok(Runtime {
                dir: dir.to_path_buf(),
                manifest,
                client,
            })
        }

        /// Load the engine for a (model, batch) pair with the given weights.
        pub fn engine(&self, model: &str, batch: usize, weights: &Path) -> Result<Engine> {
            let entry = self
                .manifest
                .find(model, batch)
                .ok_or_else(|| anyhow::anyhow!("no artifact for {model} batch {batch}"))?;
            Engine::load(&self.client, &self.dir, entry, weights)
        }

        /// All batch sizes available for a model (the coordinator's
        /// buckets).
        pub fn batch_buckets(&self, model: &str) -> Vec<usize> {
            let mut v: Vec<usize> = self
                .manifest
                .entries
                .iter()
                .filter(|e| e.model == model)
                .map(|e| e.batch)
                .collect();
            v.sort_unstable();
            v
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::manifest::{Manifest, ManifestEntry};
    use crate::tensor::Tensor;
    use crate::Result;
    use std::path::{Path, PathBuf};

    /// Stub engine: same shape as the real one, but unconstructible —
    /// [`Runtime::open`] always fails without the `pjrt` feature.
    pub struct Engine {
        pub entry: ManifestEntry,
    }

    impl Engine {
        pub fn batch_size(&self) -> usize {
            self.entry.batch
        }

        pub fn run_batch(&self, _images: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!("fastcaps was built without the `pjrt` feature")
        }
    }

    /// Stub runtime: keeps call sites compiling; `open` reports the
    /// missing feature so callers fall back (serve) or skip (tests).
    pub struct Runtime {
        pub dir: PathBuf,
        pub manifest: Manifest,
    }

    impl Runtime {
        pub fn open(dir: &Path) -> Result<Runtime> {
            anyhow::bail!(
                "fastcaps was built without the `pjrt` feature; cannot open \
                 PJRT artifacts in {} (rebuild with --features pjrt and the \
                 xla crate available)",
                dir.display()
            )
        }

        pub fn engine(&self, model: &str, batch: usize, _weights: &Path) -> Result<Engine> {
            anyhow::bail!(
                "fastcaps was built without the `pjrt` feature; cannot load \
                 engine {model} (batch {batch})"
            )
        }

        pub fn batch_buckets(&self, model: &str) -> Vec<usize> {
            self.manifest
                .entries
                .iter()
                .filter(|e| e.model == model)
                .map(|e| e.batch)
                .collect()
        }
    }
}
