//! Artifact manifest: the JSON contract between `python/compile/aot.py`
//! and the rust runtime (model names, batch buckets, parameter order and
//! shapes).

use crate::util::json::Json;
use crate::Result;
use anyhow::Context;

/// One weight parameter of an artifact (ordered).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One lowered artifact (model × batch bucket).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub model: String,
    pub file: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub params: Vec<ParamSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

fn usize_arr(j: &Json, key: &str) -> Result<Vec<usize>> {
    Ok(j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("manifest entry missing '{key}'"))?
        .iter()
        .filter_map(|v| v.as_f64())
        .map(|v| v as usize)
        .collect())
}

fn str_field(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("manifest entry missing '{key}'"))?
        .to_string())
}

impl Manifest {
    pub fn parse(j: &Json) -> Result<Manifest> {
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing 'entries'")?;
        let mut out = Vec::with_capacity(entries.len());
        for e in entries {
            let params = e
                .get("params")
                .and_then(Json::as_arr)
                .context("entry missing 'params'")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: str_field(p, "name")?,
                        shape: usize_arr(p, "shape")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            out.push(ManifestEntry {
                name: str_field(e, "name")?,
                model: str_field(e, "model")?,
                file: str_field(e, "file")?,
                batch: e
                    .get("batch")
                    .and_then(Json::as_f64)
                    .context("entry missing 'batch'")? as usize,
                input_shape: usize_arr(e, "input_shape")?,
                num_classes: e
                    .get("num_classes")
                    .and_then(Json::as_f64)
                    .context("entry missing 'num_classes'")?
                    as usize,
                params,
            });
        }
        Ok(Manifest { entries: out })
    }

    pub fn find(&self, model: &str, batch: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.model == model && e.batch == batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "entries": [
        {
          "name": "capsnet-mnist-pruned.b1",
          "model": "capsnet-mnist-pruned",
          "file": "capsnet-mnist-pruned.b1.hlo.txt",
          "batch": 1,
          "input_shape": [1, 1, 28, 28],
          "num_classes": 10,
          "dc_dim": 16,
          "params": [
            {"name": "conv1_w", "shape": [64, 1, 9, 9]},
            {"name": "conv1_b", "shape": [64]}
          ],
          "outputs": ["lengths", "digit_caps"]
        }
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = &m.entries[0];
        assert_eq!(e.model, "capsnet-mnist-pruned");
        assert_eq!(e.batch, 1);
        assert_eq!(e.input_shape, vec![1, 1, 28, 28]);
        assert_eq!(e.params[0].shape, vec![64, 1, 9, 9]);
        assert!(m.find("capsnet-mnist-pruned", 1).is_some());
        assert!(m.find("capsnet-mnist-pruned", 8).is_none());
        assert!(m.find("nope", 1).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(&Json::parse("{}").unwrap()).is_err());
        let bad = r#"{"entries": [{"name": "x"}]}"#;
        assert!(Manifest::parse(&Json::parse(bad).unwrap()).is_err());
    }
}
