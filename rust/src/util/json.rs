//! Minimal JSON value model, parser and serializer.
//!
//! Used for the artifact interchange between the Python build path and the
//! rust deployment path (`artifacts/*.json`: weight manifests, the Table I
//! pruning-study results) and for machine-readable experiment reports.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (sufficient for our ASCII artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if self is not an object).
    pub fn set(&mut self, key: &str, v: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), v);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["results", "0", "error"])` walks objects and
    /// (numeric segments) arrays.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for seg in path {
            cur = match cur {
                Json::Obj(m) => m.get(*seg)?,
                Json::Arr(v) => v.get(seg.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Convenience constructors.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn round_trip_nested() {
        let text = r#"{"a":[1,2,{"b":null,"c":"x\ny"}],"d":-0.25,"e":[]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
        assert_eq!(v.at(&["a", "2", "c"]).unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.at(&["d"]).unwrap().as_f64(), Some(-0.25));
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "\"\\q\"", "[1] x"] {
            assert!(Json::parse(text).is_err(), "accepted {text:?}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("x", num(1.0)).set("y", arr([num(2.0), s("z")]));
        assert_eq!(o.to_string(), r#"{"x":1,"y":[2,"z"]}"#);
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}
