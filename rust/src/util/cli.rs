//! Tiny declarative CLI argument parser (`--flag`, `--key value`,
//! `--key=value`, positionals). Powers `fastcaps <subcommand> ...` and the
//! example binaries.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_options_flags() {
        let a = parse(&[
            "report", "table2", "--batch", "8", "--fast", "--seed=99",
        ]);
        assert_eq!(a.positional, vec!["report", "table2"]);
        assert_eq!(a.get("batch"), Some("8"));
        assert_eq!(a.get_usize("batch", 1), 8);
        assert_eq!(a.get_u64("seed", 0), 99);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse(&["--verbose", "--n", "3"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert_eq!(a.get_f64("rate", 1.5), 1.5);
    }
}
