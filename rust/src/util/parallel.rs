//! Intra-replica multi-core fan-out (std-only, `std::thread::scope`).
//!
//! One coordinator replica historically ran a whole batch on one core.
//! [`shard_chunks`] splits a batch into contiguous per-worker chunks and
//! runs one scoped thread per chunk, returning per-chunk results in
//! order. Frames are independent in every executor (each frame owns its
//! scratch state), so sharding by frame is bit-identical to the serial
//! path by construction — worker count can therefore never be part of a
//! deployment fingerprint.

/// Run `f` over contiguous chunks of `items` on up to `workers` scoped
/// threads, returning the per-chunk results in input order.
///
/// * `workers <= 1` (or a batch of one) runs inline on the caller's
///   thread — the serial path stays allocation- and thread-free.
/// * Chunks are `ceil(len / workers)` long, so worker `k` always sees
///   the same frames regardless of core count.
/// * A panicking worker propagates the panic to the caller.
pub fn shard_chunks<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&[T]) -> R + Sync,
{
    let workers = workers.max(1).min(items.len().max(1));
    if workers <= 1 {
        return vec![f(items)];
    }
    let chunk = items.len().div_ceil(workers);
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|c| scope.spawn(move || f(c)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunking_preserves_order_and_coverage() {
        let items: Vec<usize> = (0..17).collect();
        for workers in [1, 2, 3, 4, 8, 32] {
            let out: Vec<Vec<usize>> =
                shard_chunks(&items, workers, |c| c.iter().map(|&x| x * 2).collect());
            let flat: Vec<usize> = out.into_iter().flatten().collect();
            assert_eq!(
                flat,
                items.iter().map(|&x| x * 2).collect::<Vec<_>>(),
                "workers={workers}"
            );
        }
    }

    #[test]
    fn empty_and_single_inputs_run_inline() {
        let out: Vec<usize> = shard_chunks(&[] as &[usize], 4, |c| c.len());
        assert_eq!(out, vec![0]);
        let out: Vec<usize> = shard_chunks(&[42usize], 4, |c| c[0]);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn worker_results_are_deterministic_across_counts() {
        // The same frame always lands in a deterministic chunk, and the
        // flattened output never depends on the worker count.
        let items: Vec<f32> = (0..64).map(|i| i as f32 * 0.25).collect();
        let serial: Vec<f32> =
            shard_chunks(&items, 1, |c| c.iter().map(|x| x.sin()).collect::<Vec<_>>())
                .into_iter()
                .flatten()
                .collect();
        for workers in [2, 4, 7] {
            let sharded: Vec<f32> =
                shard_chunks(&items, workers, |c| {
                    c.iter().map(|x| x.sin()).collect::<Vec<_>>()
                })
                .into_iter()
                .flatten()
                .collect();
            assert_eq!(serial, sharded, "workers={workers}");
        }
    }
}
