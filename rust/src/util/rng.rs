//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding and xoshiro256++ for the stream — the standard
//! pairing used by `rand`'s small RNGs. Everything in the repo that needs
//! randomness (synthetic datasets, weight init, property tests, workload
//! generators) goes through [`Rng`] so runs are reproducible from a seed.

/// xoshiro256++ PRNG seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create an RNG from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-ish reduction; bias is negligible for
        // the n values used here (all << 2^32).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG (stable derivation, independent-looking stream).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::stddev(&xs);
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((s - 1.0).abs() < 0.05, "std {s}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
