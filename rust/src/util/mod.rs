//! Small self-contained utilities.
//!
//! The build environment resolves crates from a minimal vendored set (see
//! `Cargo.toml`), so the deterministic RNG, JSON codec, CLI parser and
//! bench harness that a crates.io project would pull in are implemented
//! here instead. Each is deliberately tiny and fully tested.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod parallel;
pub mod rng;

/// Format a f64 with engineering-style thousands separators (`1_234_567`).
pub fn fmt_thousands(v: u64) -> String {
    let s = v.to_string();
    let bytes = s.as_bytes();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

/// NaN-safe argmax over f32 scores (0 for an empty or all-NaN slice).
///
/// `partial_cmp().unwrap()` panics on NaN, and `total_cmp` alone would
/// rank +NaN above every real score; ignoring NaN entries instead means
/// a single corrupt length can neither panic an executor thread nor win
/// the argmax over real scores.
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thousands_formatting() {
        assert_eq!(fmt_thousands(0), "0");
        assert_eq!(fmt_thousands(999), "999");
        assert_eq!(fmt_thousands(1000), "1,000");
        assert_eq!(fmt_thousands(1234567), "1,234,567");
    }

    #[test]
    fn argmax_basics_and_nan_safety() {
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        // A NaN score must neither panic nor win against real scores.
        assert_eq!(argmax(&[0.1, f32::NAN, 0.3]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        // Ties resolve to the last max, matching max_by semantics.
        assert_eq!(argmax(&[0.5, 0.5]), 1);
    }

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!(stddev(&xs) > 0.0);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
