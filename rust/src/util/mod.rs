//! Small self-contained utilities.
//!
//! The build environment resolves crates from a minimal vendored set (see
//! `Cargo.toml`), so the deterministic RNG, JSON codec, CLI parser and
//! bench harness that a crates.io project would pull in are implemented
//! here instead. Each is deliberately tiny and fully tested.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;

/// Format a f64 with engineering-style thousands separators (`1_234_567`).
pub fn fmt_thousands(v: u64) -> String {
    let s = v.to_string();
    let bytes = s.as_bytes();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thousands_formatting() {
        assert_eq!(fmt_thousands(0), "0");
        assert_eq!(fmt_thousands(999), "999");
        assert_eq!(fmt_thousands(1000), "1,000");
        assert_eq!(fmt_thousands(1234567), "1,234,567");
    }

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!(stddev(&xs) > 0.0);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
