//! Streaming 64-bit content hash built on the SplitMix64 finalizer —
//! the same mixing already trusted by [`crate::util::rng`] for seeding.
//!
//! Not cryptographic. It keys the inference cache
//! ([`crate::cache`]), where the threat model is *accidental* collision
//! between distinct tensors / deployments, not an adversary; the cache
//! uses two independently-seeded lanes (128 bits total) so a collision
//! requires both lanes to collide at once.

/// SplitMix64 finalizer: a bijective avalanche over one word.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Order-sensitive streaming hash: absorb words, then [`Hash64::finish`].
#[derive(Debug, Clone, Copy)]
pub struct Hash64 {
    state: u64,
}

impl Hash64 {
    pub fn new(seed: u64) -> Hash64 {
        Hash64 {
            state: mix(seed ^ GOLDEN),
        }
    }

    /// Absorb one word. The golden-ratio increment makes the absorption
    /// position-dependent, so permuted streams hash differently.
    pub fn absorb(&mut self, word: u64) -> &mut Self {
        self.state = mix(self.state.wrapping_add(GOLDEN) ^ word);
        self
    }

    /// Absorb a byte string: length first (so `"ab" + "c"` and
    /// `"a" + "bc"` differ), then 8-byte little-endian words, the tail
    /// zero-padded.
    pub fn absorb_bytes(&mut self, bytes: &[u8]) -> &mut Self {
        self.absorb(bytes.len() as u64);
        for chunk in bytes.chunks(8) {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            self.absorb(u64::from_le_bytes(w));
        }
        self
    }

    pub fn absorb_str(&mut self, s: &str) -> &mut Self {
        self.absorb_bytes(s.as_bytes())
    }

    /// Absorb f32s by IEEE-754 bit pattern — bit-identical tensors hash
    /// equal, anything else (including -0.0 vs 0.0, NaN payloads) does
    /// not. Content addressing must match the "bit-identical response"
    /// contract, so no numeric tolerance is involved.
    pub fn absorb_f32s(&mut self, xs: &[f32]) -> &mut Self {
        self.absorb(xs.len() as u64);
        for x in xs {
            self.absorb(x.to_bits() as u64);
        }
        self
    }

    pub fn absorb_i16s(&mut self, xs: &[i16]) -> &mut Self {
        self.absorb(xs.len() as u64);
        for x in xs {
            self.absorb(*x as u16 as u64);
        }
        self
    }

    pub fn absorb_u32s(&mut self, xs: &[u32]) -> &mut Self {
        self.absorb(xs.len() as u64);
        for x in xs {
            self.absorb(*x as u64);
        }
        self
    }

    pub fn absorb_u16s(&mut self, xs: &[u16]) -> &mut Self {
        self.absorb(xs.len() as u64);
        for x in xs {
            self.absorb(*x as u64);
        }
        self
    }

    pub fn finish(&self) -> u64 {
        mix(self.state ^ GOLDEN)
    }
}

impl Default for Hash64 {
    fn default() -> Self {
        Hash64::new(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn of_words(seed: u64, words: &[u64]) -> u64 {
        let mut h = Hash64::new(seed);
        for &w in words {
            h.absorb(w);
        }
        h.finish()
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        assert_eq!(of_words(1, &[1, 2, 3]), of_words(1, &[1, 2, 3]));
        assert_ne!(of_words(1, &[1, 2, 3]), of_words(2, &[1, 2, 3]));
    }

    #[test]
    fn order_and_content_sensitive() {
        assert_ne!(of_words(0, &[1, 2]), of_words(0, &[2, 1]));
        assert_ne!(of_words(0, &[1, 2]), of_words(0, &[1, 3]));
        assert_ne!(of_words(0, &[0]), of_words(0, &[0, 0]));
    }

    #[test]
    fn byte_boundaries_do_not_alias() {
        // Same concatenated bytes, different message boundaries.
        let a = Hash64::new(7).absorb_bytes(b"ab").absorb_bytes(b"c").finish();
        let b = Hash64::new(7).absorb_bytes(b"a").absorb_bytes(b"bc").finish();
        assert_ne!(a, b);
        // Zero-padding of the tail chunk must not alias explicit zeros.
        let c = Hash64::new(7).absorb_bytes(&[1, 0]).finish();
        let d = Hash64::new(7).absorb_bytes(&[1]).finish();
        assert_ne!(c, d);
    }

    #[test]
    fn f32_bit_patterns_distinguished() {
        let a = Hash64::new(0).absorb_f32s(&[0.0]).finish();
        let b = Hash64::new(0).absorb_f32s(&[-0.0]).finish();
        assert_ne!(a, b, "content addressing is bit-level, not numeric");
    }

    #[test]
    fn avalanche_on_single_bit_flips_property() {
        // Flipping any single input bit should change the digest (for a
        // 64-bit hash a same-digest collision on a 1-bit flip would be
        // astronomically unlikely; hitting one here means the mixing is
        // broken, e.g. an xor placed after the last multiply).
        crate::testing::check(
            "single-bit flip changes Hash64::finish",
            200,
            23,
            |r| {
                let words: Vec<u64> = (0..1 + r.below(6)).map(|_| r.next_u64()).collect();
                let word_idx = r.below(words.len());
                let bit = r.below(64);
                (words, word_idx, bit)
            },
            |(words, word_idx, bit)| {
                let mut flipped = words.clone();
                flipped[*word_idx] ^= 1u64 << bit;
                of_words(11, words) != of_words(11, &flipped)
            },
        );
    }
}
