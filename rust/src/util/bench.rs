//! Micro-benchmark harness used by `cargo bench` (all bench targets are
//! `harness = false`). Criterion is not in the vendored crate set, so this
//! provides the same core loop: warm-up, timed iterations until a minimum
//! measurement window, then mean / stddev / p50 / p99 reporting.
//!
//! Benches print both the *host wall-time* of the simulator (regression
//! guard for the simulator itself) and, where relevant, the *modeled FPGA
//! cycles* the simulator reports (the paper-facing number).

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl Measurement {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner with fixed warm-up and measurement windows.
pub struct Bencher {
    pub warmup: Duration,
    pub window: Duration,
    pub max_samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Keep benches snappy: the suite covers every paper table/figure, so
        // per-case budget is modest. Override via FASTCAPS_BENCH_WINDOW_MS.
        let window_ms: u64 = std::env::var("FASTCAPS_BENCH_WINDOW_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(300);
        Bencher {
            warmup: Duration::from_millis(window_ms / 3),
            window: Duration::from_millis(window_ms),
            max_samples: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Benchmark `f`, which returns a value that is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warm-up.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.window && samples_ns.len() < self.max_samples {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        if samples_ns.is_empty() {
            samples_ns.push(0.0);
        }
        let m = Measurement {
            name: name.to_string(),
            iters: samples_ns.len() as u64,
            mean_ns: crate::util::mean(&samples_ns),
            stddev_ns: crate::util::stddev(&samples_ns),
            p50_ns: crate::util::percentile(&samples_ns, 50.0),
            p99_ns: crate::util::percentile(&samples_ns, 99.0),
        };
        println!(
            "{:<44} {:>12}/iter  (p50 {:>10}, p99 {:>10}, n={})",
            m.name,
            fmt_ns(m.mean_ns),
            fmt_ns(m.p50_ns),
            fmt_ns(m.p99_ns),
            m.iters
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Print a section header.
    pub fn section(&self, title: &str) {
        println!("\n== {title} ==");
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Report a modeled (simulated-hardware) quantity alongside host timings.
pub fn report_model(name: &str, value: f64, unit: &str) {
    println!("{name:<44} {value:>14.3} {unit}   [modeled]");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            window: Duration::from_millis(20),
            max_samples: 1000,
            results: Vec::new(),
        };
        let m = b.bench("noop-ish", || (0..100u64).sum::<u64>()).clone();
        assert!(m.iters > 0);
        assert!(m.mean_ns >= 0.0);
        assert!(m.p99_ns >= m.p50_ns * 0.5);
    }

    #[test]
    fn formatting_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2_000_000_000.0).contains(" s"));
    }
}
