//! Magnitude-based kernel pruning (KP) — the state-of-the-art baseline
//! the paper compares against (Mao et al. [14]). Kernel score is the L1
//! magnitude of its own parameters; no adjacency information.

use super::{KernelMask, LayerPruneResult};
use crate::tensor::Tensor;

/// Per-kernel magnitude scores for an OIHW tensor.
pub fn kernel_scores(w: &Tensor) -> Vec<f32> {
    let (o, i) = (w.shape[0], w.shape[1]);
    let kk = w.shape[2] * w.shape[3];
    let mut scores = Vec::with_capacity(o * i);
    for n in 0..o * i {
        let base = n * kk;
        scores.push(w.data[base..base + kk].iter().map(|x| x.abs()).sum());
    }
    scores
}

/// Build a mask pruning the lowest-scored `sparsity` fraction. Shared by
/// KP and LAKP (they differ only in the score).
pub fn mask_from_scores(
    scores: &[f32],
    out_ch: usize,
    in_ch: usize,
    sparsity: f64,
) -> KernelMask {
    let total = scores.len();
    let n_prune = ((total as f64) * sparsity.clamp(0.0, 1.0)).floor() as usize;
    mask_pruning_lowest(scores, out_ch, in_ch, n_prune)
}

/// Build a mask keeping exactly `keep` of the highest-scored kernels —
/// the form deployment planning wants (the paper reports survivor
/// *counts*: 64 + 423 kernels on MNIST), with no fraction→count
/// round-trip through floating point.
pub fn mask_keeping(
    scores: &[f32],
    out_ch: usize,
    in_ch: usize,
    keep: usize,
) -> KernelMask {
    mask_pruning_lowest(scores, out_ch, in_ch, scores.len().saturating_sub(keep))
}

fn mask_pruning_lowest(
    scores: &[f32],
    out_ch: usize,
    in_ch: usize,
    n_prune: usize,
) -> KernelMask {
    assert_eq!(scores.len(), out_ch * in_ch);
    let total = scores.len();
    let mut order: Vec<usize> = (0..total).collect();
    order.sort_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b)) // deterministic tie-break
    });
    let mut mask = KernelMask::all_alive(out_ch, in_ch);
    for &n in order.iter().take(n_prune.min(total)) {
        mask.set(n / in_ch, n % in_ch, false);
    }
    mask
}

/// Magnitude kernel pruning of one layer.
pub fn prune_layer(w: &Tensor, sparsity: f64) -> LayerPruneResult {
    let scores = kernel_scores(w);
    let mask = mask_from_scores(&scores, w.shape[0], w.shape[1], sparsity);
    LayerPruneResult { mask, scores }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::tests::tensor_with_kernel_sums;

    #[test]
    fn prunes_smallest_kernels() {
        let w = tensor_with_kernel_sums(&[&[1.0, 4.0], &[3.0, 2.0]], 3, 3);
        let res = prune_layer(&w, 0.5);
        assert!(!res.mask.get(0, 0)); // score 1 pruned
        assert!(!res.mask.get(1, 1)); // score 2 pruned
        assert!(res.mask.get(1, 0));
        assert!(res.mask.get(0, 1));
    }

    #[test]
    fn zero_sparsity_keeps_all() {
        let w = tensor_with_kernel_sums(&[&[1.0, 2.0]], 3, 3);
        assert_eq!(prune_layer(&w, 0.0).mask.survived(), 2);
    }

    #[test]
    fn full_sparsity_prunes_all() {
        let w = tensor_with_kernel_sums(&[&[1.0, 2.0]], 3, 3);
        assert_eq!(prune_layer(&w, 1.0).mask.survived(), 0);
    }

    #[test]
    fn mask_keeping_exact_counts() {
        let w = tensor_with_kernel_sums(&[&[1.0, 4.0], &[3.0, 2.0]], 3, 3);
        let scores = kernel_scores(&w);
        for keep in 0..=4 {
            let m = mask_keeping(&scores, 2, 2, keep);
            assert_eq!(m.survived(), keep, "keep={keep}");
        }
        // keep > total saturates instead of underflowing.
        assert_eq!(mask_keeping(&scores, 2, 2, 9).survived(), 4);
        // The survivors are the highest-scored kernels.
        let m = mask_keeping(&scores, 2, 2, 2);
        assert!(m.get(0, 1) && m.get(1, 0));
    }

    #[test]
    fn deterministic_tie_break() {
        let w = tensor_with_kernel_sums(&[&[2.0, 2.0], &[2.0, 2.0]], 3, 3);
        let a = prune_layer(&w, 0.5).mask;
        let b = prune_layer(&w, 0.5).mask;
        assert_eq!(a, b);
        assert_eq!(a.survived(), 2);
    }
}
