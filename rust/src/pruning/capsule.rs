//! Capsule pruning — the PrunedCaps [24] comparison point (§II-B): prune
//! whole PrimaryCaps *types* (all `pc_dim` output channels of the
//! PrimaryCaps conv at once), scored by the type's total weight magnitude.
//! Coarser than LAKP's kernel granularity, so compression saturates
//! earlier — which is exactly the comparison the paper draws (LAKP removes
//! >99.26% of FLOPs vs PrunedCaps' 95.36%).

use super::KernelMask;
use crate::tensor::Tensor;

/// Score each capsule type: L1 magnitude of all its channels' kernels.
pub fn type_scores(pc_w: &Tensor, pc_dim: usize) -> Vec<f32> {
    let o = pc_w.shape[0];
    assert_eq!(o % pc_dim, 0, "pc channels not divisible by capsule dim");
    let types = o / pc_dim;
    let per_ch = pc_w.len() / o;
    (0..types)
        .map(|t| {
            (0..pc_dim)
                .map(|k| {
                    let ch = t * pc_dim + k;
                    pc_w.data[ch * per_ch..(ch + 1) * per_ch]
                        .iter()
                        .map(|x| x.abs())
                        .sum::<f32>()
                })
                .sum()
        })
        .collect()
}

/// Prune the lowest-scored `sparsity` fraction of capsule types, returning
/// a kernel mask over the PrimaryCaps conv (whole channels zeroed).
pub fn prune_types(pc_w: &Tensor, pc_dim: usize, sparsity: f64) -> KernelMask {
    let (o, i) = (pc_w.shape[0], pc_w.shape[1]);
    let scores = type_scores(pc_w, pc_dim);
    let types = scores.len();
    let n_prune = ((types as f64) * sparsity.clamp(0.0, 1.0)).floor() as usize;
    let mut order: Vec<usize> = (0..types).collect();
    order.sort_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mask = KernelMask::all_alive(o, i);
    for &t in order.iter().take(n_prune) {
        for k in 0..pc_dim {
            for ic in 0..i {
                mask.set(t * pc_dim + k, ic, false);
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::surviving_capsule_types;
    use crate::util::rng::Rng;

    #[test]
    fn prunes_weakest_type() {
        // 3 types × 2 dims; make type 1 weakest.
        let mut rng = Rng::new(1);
        let mut w = Tensor::randn(&[6, 4, 3, 3], 1.0, &mut rng);
        let per_ch = w.len() / 6;
        for ch in [2, 3] {
            for v in &mut w.data[ch * per_ch..(ch + 1) * per_ch] {
                *v *= 0.01;
            }
        }
        let mask = prune_types(&w, 2, 0.34);
        assert_eq!(surviving_capsule_types(&mask, 2), 2);
        assert!(!mask.get(2, 0));
        assert!(!mask.get(3, 3));
        assert!(mask.get(0, 0));
    }

    #[test]
    fn granularity_coarser_than_kernel_pruning() {
        // At 50% sparsity, capsule pruning kills exactly half the types;
        // kernel pruning at the same parameter budget keeps every type
        // alive (spread sparsity) — LAKP's granularity advantage.
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[8, 4, 3, 3], 1.0, &mut rng);
        let cap_mask = prune_types(&w, 2, 0.5);
        assert_eq!(surviving_capsule_types(&cap_mask, 2), 2);
        let kp_mask = super::super::kp::prune_layer(&w, 0.5).mask;
        assert!(surviving_capsule_types(&kp_mask, 2) >= 3);
        // Identical survived parameter budget.
        assert_eq!(cap_mask.survived(), kp_mask.survived());
    }
}
