//! Network pruning engines (§III-A and baselines).
//!
//! * [`lakp`] — the paper's contribution: Look-Ahead Kernel Pruning
//!   (Algorithm 1). Kernel score = Σ of per-parameter look-ahead scores
//!   (Eq. 1), which factorizes to
//!   `abs_sum(kernel) · prev_norm(in_ch) · next_norm(out_ch)`.
//! * [`kp`] — magnitude-based kernel pruning (Mao et al. [14]), the
//!   state-of-the-art baseline: kernel score = `abs_sum(kernel)`.
//! * [`magnitude`] — unstructured magnitude pruning (Han et al. [21]),
//!   the red line of Fig. 5.
//! * [`capsule`] — PrunedCaps-style capsule pruning [24] (prunes whole
//!   PrimaryCaps types), the §II-B comparison point.
//!
//! All methods operate on OIHW conv tensors through [`KernelMask`] /
//! [`WeightMask`] so they compose with any model that has conv layers
//! (CapsNet here; VGG/ResNet rows of Table I run the mirrored Python
//! implementation — a golden-file test pins the two).

pub mod capsule;
pub mod kp;
pub mod lakp;
pub mod magnitude;
pub mod mask;

pub use mask::{KernelMask, WeightMask};

use crate::tensor::Tensor;

/// Per-channel coupling norms of the adjacent layers, used by Eq. 1.
///
/// `prev[j]` = magnitude of the layer-(i−1) weights *producing* input
/// channel `j`; `next[k]` = magnitude of the layer-(i+1) weights
/// *consuming* output channel `k`. Following the paper's worked example
/// (Fig. 7) these are L1 sums (Eq. 1 writes Frobenius for the FC case;
/// the kernel-pruning example uses `Sum(abs(…))` — we match the example).
#[derive(Debug, Clone)]
pub struct AdjacencyNorms {
    pub prev: Vec<f32>,
    pub next: Vec<f32>,
}

impl AdjacencyNorms {
    /// Neutral norms (all ones) — reduces LAKP to plain KP; used for
    /// boundary layers with no neighbour.
    pub fn neutral(in_ch: usize, out_ch: usize) -> AdjacencyNorms {
        AdjacencyNorms {
            prev: vec![1.0; in_ch],
            next: vec![1.0; out_ch],
        }
    }

    /// `prev` norms from the previous conv layer's OIHW tensor: producer
    /// of channel `j` is filter `j` (all its input kernels).
    pub fn prev_from_conv(prev_w: &Tensor) -> Vec<f32> {
        let o = prev_w.shape[0];
        let per = prev_w.len() / o;
        (0..o)
            .map(|j| {
                prev_w.data[j * per..(j + 1) * per]
                    .iter()
                    .map(|x| x.abs())
                    .sum()
            })
            .collect()
    }

    /// `next` norms from the following conv layer's OIHW tensor: consumers
    /// of channel `k` are all kernels with input index `k`.
    pub fn next_from_conv(next_w: &Tensor) -> Vec<f32> {
        let (o, i) = (next_w.shape[0], next_w.shape[1]);
        let kk = next_w.shape[2] * next_w.shape[3];
        let mut out = vec![0.0f32; i];
        for oc in 0..o {
            for ic in 0..i {
                let base = (oc * i + ic) * kk;
                let s: f32 = next_w.data[base..base + kk]
                    .iter()
                    .map(|x| x.abs())
                    .sum();
                out[ic] += s;
            }
        }
        out
    }

    /// `next` norms for the PrimaryCaps layer of a CapsNet: consumer of
    /// PrimaryCaps channel `k` is the DigitCaps transform slice
    /// `w_ij[k / pc_dim, :, k % pc_dim, :]` (shared-transform layout,
    /// every spatial position of a type reuses the same weights).
    pub fn next_from_digitcaps(w_ij: &Tensor, pc_types: usize, pc_dim: usize) -> Vec<f32> {
        // w_ij: [pc_types, n_classes, pc_dim, dc_dim].
        let n_classes = w_ij.shape[1];
        let d_in = w_ij.shape[2];
        let d_out = w_ij.shape[3];
        assert_eq!(w_ij.shape[0], pc_types);
        assert_eq!(d_in, pc_dim);
        let mut out = vec![0.0f32; pc_types * pc_dim];
        for t in 0..pc_types {
            for cls in 0..n_classes {
                for k in 0..pc_dim {
                    let base = ((t * n_classes + cls) * d_in + k) * d_out;
                    let s: f32 = w_ij.data[base..base + d_out]
                        .iter()
                        .map(|x| x.abs())
                        .sum();
                    out[t * pc_dim + k] += s;
                }
            }
        }
        out
    }
}

/// Result of pruning one layer.
#[derive(Debug, Clone)]
pub struct LayerPruneResult {
    pub mask: KernelMask,
    /// Kernel scores (for analysis / Fig. 5 style sweeps).
    pub scores: Vec<f32>,
}

/// Dead-channel analysis after kernel pruning: output channels of the
/// layer that retain no kernel — these channels (and any capsule types
/// whose channels are all dead) can be removed entirely (§III: "the
/// interconnections between neighboring layer kernels are studied to
/// eliminate any unnecessary kernels and capsules").
pub fn dead_output_channels(mask: &KernelMask) -> Vec<bool> {
    (0..mask.out_ch)
        .map(|o| (0..mask.in_ch).all(|i| !mask.get(o, i)))
        .collect()
}

/// Count of surviving PrimaryCaps capsule types given a pc-layer mask.
pub fn surviving_capsule_types(mask: &KernelMask, pc_dim: usize) -> usize {
    let dead = dead_output_channels(mask);
    let types = mask.out_ch / pc_dim;
    (0..types)
        .filter(|t| (0..pc_dim).any(|k| !dead[t * pc_dim + k]))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an OIHW tensor whose (o,i) kernel is constant `vals[o][i]/(kh*kw)`
    /// so that `abs_sum(kernel) == vals[o][i]`.
    pub fn tensor_with_kernel_sums(vals: &[&[f32]], kh: usize, kw: usize) -> Tensor {
        let o = vals.len();
        let i = vals[0].len();
        let mut t = Tensor::zeros(&[o, i, kh, kw]);
        for (oc, row) in vals.iter().enumerate() {
            for (ic, &v) in row.iter().enumerate() {
                let fill = v / (kh * kw) as f32;
                for y in 0..kh {
                    for x in 0..kw {
                        t.set(&[oc, ic, y, x], fill);
                    }
                }
            }
        }
        t
    }

    #[test]
    fn adjacency_prev_norms() {
        // prev layer: 2 output channels with known abs sums.
        let prev = tensor_with_kernel_sums(&[&[8.0, 9.0], &[10.0, 9.0]], 3, 3);
        let norms = AdjacencyNorms::prev_from_conv(&prev);
        assert!((norms[0] - 17.0).abs() < 1e-4);
        assert!((norms[1] - 19.0).abs() < 1e-4);
    }

    #[test]
    fn adjacency_next_norms() {
        let next = tensor_with_kernel_sums(&[&[6.0, 10.0], &[9.0, 10.0]], 3, 3);
        let norms = AdjacencyNorms::next_from_conv(&next);
        assert!((norms[0] - 15.0).abs() < 1e-4); // consumers of ch 0: 6+9
        assert!((norms[1] - 20.0).abs() < 1e-4); // consumers of ch 1: 10+10
    }

    #[test]
    fn dead_channel_detection() {
        let mut mask = KernelMask::all_alive(3, 2);
        mask.set(1, 0, false);
        mask.set(1, 1, false);
        let dead = dead_output_channels(&mask);
        assert_eq!(dead, vec![false, true, false]);
    }

    #[test]
    fn capsule_type_survival() {
        // 2 types × 2 dims = 4 output channels; kill both channels of
        // type 0 -> 1 surviving type.
        let mut mask = KernelMask::all_alive(4, 3);
        for i in 0..3 {
            mask.set(0, i, false);
            mask.set(1, i, false);
        }
        assert_eq!(surviving_capsule_types(&mask, 2), 1);
    }
}
