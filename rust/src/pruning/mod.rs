//! Network pruning engines (§III-A and baselines).
//!
//! * [`lakp`] — the paper's contribution: Look-Ahead Kernel Pruning
//!   (Algorithm 1). Kernel score = Σ of per-parameter look-ahead scores
//!   (Eq. 1), which factorizes to
//!   `abs_sum(kernel) · prev_norm(in_ch) · next_norm(out_ch)`.
//! * [`kp`] — magnitude-based kernel pruning (Mao et al. [14]), the
//!   state-of-the-art baseline: kernel score = `abs_sum(kernel)`.
//! * [`magnitude`] — unstructured magnitude pruning (Han et al. [21]),
//!   the red line of Fig. 5.
//! * [`capsule`] — PrunedCaps-style capsule pruning [24] (prunes whole
//!   PrimaryCaps types), the §II-B comparison point.
//!
//! All methods operate on OIHW conv tensors through [`KernelMask`] /
//! [`WeightMask`] so they compose with any model that has conv layers
//! (CapsNet here; VGG/ResNet rows of Table I run the mirrored Python
//! implementation — a golden-file test pins the two).

pub mod capsule;
pub mod kp;
pub mod lakp;
pub mod magnitude;
pub mod mask;

pub use mask::{KernelMask, WeightMask};

use crate::capsnet::weights::Weights;
use crate::config::{CapsNetConfig, SparsityPlan};
use crate::tensor::Tensor;

/// Per-channel coupling norms of the adjacent layers, used by Eq. 1.
///
/// `prev[j]` = magnitude of the layer-(i−1) weights *producing* input
/// channel `j`; `next[k]` = magnitude of the layer-(i+1) weights
/// *consuming* output channel `k`. Following the paper's worked example
/// (Fig. 7) these are L1 sums (Eq. 1 writes Frobenius for the FC case;
/// the kernel-pruning example uses `Sum(abs(…))` — we match the example).
#[derive(Debug, Clone)]
pub struct AdjacencyNorms {
    pub prev: Vec<f32>,
    pub next: Vec<f32>,
}

impl AdjacencyNorms {
    /// Neutral norms (all ones) — reduces LAKP to plain KP; used for
    /// boundary layers with no neighbour.
    pub fn neutral(in_ch: usize, out_ch: usize) -> AdjacencyNorms {
        AdjacencyNorms {
            prev: vec![1.0; in_ch],
            next: vec![1.0; out_ch],
        }
    }

    /// `prev` norms from the previous conv layer's OIHW tensor: producer
    /// of channel `j` is filter `j` (all its input kernels).
    pub fn prev_from_conv(prev_w: &Tensor) -> Vec<f32> {
        let o = prev_w.shape[0];
        let per = prev_w.len() / o;
        (0..o)
            .map(|j| {
                prev_w.data[j * per..(j + 1) * per]
                    .iter()
                    .map(|x| x.abs())
                    .sum()
            })
            .collect()
    }

    /// `next` norms from the following conv layer's OIHW tensor: consumers
    /// of channel `k` are all kernels with input index `k`.
    pub fn next_from_conv(next_w: &Tensor) -> Vec<f32> {
        let (o, i) = (next_w.shape[0], next_w.shape[1]);
        let kk = next_w.shape[2] * next_w.shape[3];
        let mut out = vec![0.0f32; i];
        for oc in 0..o {
            for ic in 0..i {
                let base = (oc * i + ic) * kk;
                let s: f32 = next_w.data[base..base + kk]
                    .iter()
                    .map(|x| x.abs())
                    .sum();
                out[ic] += s;
            }
        }
        out
    }

    /// `next` norms for the PrimaryCaps layer of a CapsNet: consumer of
    /// PrimaryCaps channel `k` is the DigitCaps transform slice
    /// `w_ij[k / pc_dim, :, k % pc_dim, :]` (shared-transform layout,
    /// every spatial position of a type reuses the same weights).
    pub fn next_from_digitcaps(w_ij: &Tensor, pc_types: usize, pc_dim: usize) -> Vec<f32> {
        // w_ij: [pc_types, n_classes, pc_dim, dc_dim].
        let n_classes = w_ij.shape[1];
        let d_in = w_ij.shape[2];
        let d_out = w_ij.shape[3];
        assert_eq!(w_ij.shape[0], pc_types);
        assert_eq!(d_in, pc_dim);
        let mut out = vec![0.0f32; pc_types * pc_dim];
        for t in 0..pc_types {
            for cls in 0..n_classes {
                for k in 0..pc_dim {
                    let base = ((t * n_classes + cls) * d_in + k) * d_out;
                    let s: f32 = w_ij.data[base..base + d_out]
                        .iter()
                        .map(|x| x.abs())
                        .sum();
                    out[t * pc_dim + k] += s;
                }
            }
        }
        out
    }
}

/// Result of pruning one layer.
#[derive(Debug, Clone)]
pub struct LayerPruneResult {
    pub mask: KernelMask,
    /// Kernel scores (for analysis / Fig. 5 style sweeps).
    pub scores: Vec<f32>,
}

/// Kernel masks for both conv layers of a CapsNet — the network-level
/// prune artifact the sparse compiler ([`crate::capsnet::compiled`])
/// consumes and [`crate::fpga::IndexControl`] mirrors on-chip.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetworkMasks {
    /// Conv1 grid: `conv1_ch × c_in`.
    pub conv1: KernelMask,
    /// PrimaryCaps grid: `pc_channels × conv1_ch`.
    pub pc: KernelMask,
}

impl NetworkMasks {
    /// Everything alive (compiling with this reproduces the dense net).
    pub fn dense(cfg: &CapsNetConfig) -> NetworkMasks {
        NetworkMasks {
            conv1: KernelMask::all_alive(cfg.conv1_ch, cfg.input.0),
            pc: KernelMask::all_alive(cfg.pc_channels(), cfg.conv1_ch),
        }
    }

    /// LAKP over the whole network at explicit survivor counts (the form
    /// the paper reports: 64 + 423 kernels on MNIST). Conv1 is pruned
    /// first; the PrimaryCaps scores then see the *masked* Conv1 as
    /// their `prev` norms, so kernels consuming dead channels score zero
    /// and are eliminated before any kernel on a live channel — the
    /// §III "interconnections between neighboring layer kernels" step.
    pub fn lakp(
        weights: &Weights,
        cfg: &CapsNetConfig,
        keep_conv1: usize,
        keep_pc: usize,
    ) -> NetworkMasks {
        let (c_in, _, _) = cfg.input;
        let adj1 = AdjacencyNorms {
            prev: vec![1.0; c_in], // no prunable producer before Conv1
            next: AdjacencyNorms::next_from_conv(&weights.pc_w),
        };
        let conv1 = kp::mask_keeping(
            &lakp::kernel_scores(&weights.conv1_w, &adj1),
            cfg.conv1_ch,
            c_in,
            keep_conv1,
        );
        let mut conv1_masked = weights.conv1_w.clone();
        conv1.apply(&mut conv1_masked);
        let adj_pc = AdjacencyNorms {
            prev: AdjacencyNorms::prev_from_conv(&conv1_masked),
            next: AdjacencyNorms::next_from_digitcaps(
                &weights.w_ij,
                cfg.pc_types,
                cfg.pc_dim,
            ),
        };
        let pc = kp::mask_keeping(
            &lakp::kernel_scores(&weights.pc_w, &adj_pc),
            cfg.pc_channels(),
            cfg.conv1_ch,
            keep_pc,
        );
        NetworkMasks { conv1, pc }
    }

    /// LAKP at a deployment plan's survivor counts (e.g.
    /// [`SparsityPlan::paper_mnist`]: 64 + 423 → 99.26% compression).
    pub fn from_plan(
        weights: &Weights,
        cfg: &CapsNetConfig,
        plan: &SparsityPlan,
    ) -> NetworkMasks {
        NetworkMasks::lakp(weights, cfg, plan.conv1_kernels, plan.pc_kernels)
    }

    /// Zero the pruned kernels of both conv layers in place — the
    /// masked-dense reference the sparse-compiled path is bit-exact to.
    pub fn apply(&self, weights: &mut Weights) {
        self.conv1.apply(&mut weights.conv1_w);
        self.pc.apply(&mut weights.pc_w);
    }

    pub fn survived(&self) -> usize {
        self.conv1.survived() + self.pc.survived()
    }

    pub fn total(&self) -> usize {
        self.conv1.total() + self.pc.total()
    }

    /// Fraction of conv kernels removed, in percent.
    pub fn pruned_pct(&self) -> f64 {
        pruned_pct(self.survived(), self.total())
    }
}

/// Fraction of kernels removed, in percent — the single owner of the
/// compression-rate arithmetic (shared with
/// [`crate::capsnet::compiled::CompressionStats`]).
pub fn pruned_pct(survived: usize, total: usize) -> f64 {
    100.0 * (1.0 - survived as f64 / total.max(1) as f64)
}

/// Dead-channel analysis after kernel pruning: output channels of the
/// layer that retain no kernel — these channels (and any capsule types
/// whose channels are all dead) can be removed entirely (§III: "the
/// interconnections between neighboring layer kernels are studied to
/// eliminate any unnecessary kernels and capsules").
pub fn dead_output_channels(mask: &KernelMask) -> Vec<bool> {
    (0..mask.out_ch)
        .map(|o| (0..mask.in_ch).all(|i| !mask.get(o, i)))
        .collect()
}

/// Count of surviving PrimaryCaps capsule types given a pc-layer mask.
pub fn surviving_capsule_types(mask: &KernelMask, pc_dim: usize) -> usize {
    let dead = dead_output_channels(mask);
    let types = mask.out_ch / pc_dim;
    (0..types)
        .filter(|t| (0..pc_dim).any(|k| !dead[t * pc_dim + k]))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an OIHW tensor whose (o,i) kernel is constant `vals[o][i]/(kh*kw)`
    /// so that `abs_sum(kernel) == vals[o][i]`.
    pub fn tensor_with_kernel_sums(vals: &[&[f32]], kh: usize, kw: usize) -> Tensor {
        let o = vals.len();
        let i = vals[0].len();
        let mut t = Tensor::zeros(&[o, i, kh, kw]);
        for (oc, row) in vals.iter().enumerate() {
            for (ic, &v) in row.iter().enumerate() {
                let fill = v / (kh * kw) as f32;
                for y in 0..kh {
                    for x in 0..kw {
                        t.set(&[oc, ic, y, x], fill);
                    }
                }
            }
        }
        t
    }

    #[test]
    fn adjacency_prev_norms() {
        // prev layer: 2 output channels with known abs sums.
        let prev = tensor_with_kernel_sums(&[&[8.0, 9.0], &[10.0, 9.0]], 3, 3);
        let norms = AdjacencyNorms::prev_from_conv(&prev);
        assert!((norms[0] - 17.0).abs() < 1e-4);
        assert!((norms[1] - 19.0).abs() < 1e-4);
    }

    #[test]
    fn adjacency_next_norms() {
        let next = tensor_with_kernel_sums(&[&[6.0, 10.0], &[9.0, 10.0]], 3, 3);
        let norms = AdjacencyNorms::next_from_conv(&next);
        assert!((norms[0] - 15.0).abs() < 1e-4); // consumers of ch 0: 6+9
        assert!((norms[1] - 20.0).abs() < 1e-4); // consumers of ch 1: 10+10
    }

    #[test]
    fn network_masks_keep_exact_survivor_counts() {
        let cfg = crate::config::CapsNetConfig::tiny();
        let mut rng = crate::util::rng::Rng::new(17);
        let w = Weights::random(&cfg, &mut rng);
        let masks = NetworkMasks::lakp(&w, &cfg, 10, 40);
        assert_eq!(masks.conv1.survived(), 10);
        assert_eq!(masks.pc.survived(), 40);
        assert_eq!(masks.survived(), 50);
        assert_eq!(
            masks.total(),
            cfg.conv1_ch * cfg.input.0 + cfg.pc_channels() * cfg.conv1_ch
        );
        assert!(masks.pruned_pct() > 80.0);
        // Dense masks change nothing.
        let dense = NetworkMasks::dense(&cfg);
        assert_eq!(dense.survived(), dense.total());
    }

    #[test]
    fn network_masks_eliminate_kernels_on_dead_channels_first() {
        // After Conv1 loses channels, every PrimaryCaps kernel consuming
        // a dead channel scores zero (prev norm 0) and must be pruned
        // before any kernel on a live channel.
        let cfg = crate::config::CapsNetConfig::tiny();
        let mut rng = crate::util::rng::Rng::new(18);
        let w = Weights::random(&cfg, &mut rng);
        let keep_conv1 = cfg.conv1_ch / 2;
        let masks = NetworkMasks::lakp(&w, &cfg, keep_conv1, 60);
        let dead = dead_output_channels(&masks.conv1);
        for o in 0..masks.pc.out_ch {
            for i in 0..masks.pc.in_ch {
                if masks.pc.get(o, i) {
                    assert!(
                        !dead[i],
                        "surviving pc kernel ({o},{i}) consumes dead conv1 channel"
                    );
                }
            }
        }
    }

    #[test]
    fn dead_channel_detection() {
        let mut mask = KernelMask::all_alive(3, 2);
        mask.set(1, 0, false);
        mask.set(1, 1, false);
        let dead = dead_output_channels(&mask);
        assert_eq!(dead, vec![false, true, false]);
    }

    #[test]
    fn capsule_type_survival() {
        // 2 types × 2 dims = 4 output channels; kill both channels of
        // type 0 -> 1 surviving type.
        let mut mask = KernelMask::all_alive(4, 3);
        for i in 0..3 {
            mask.set(0, i, false);
            mask.set(1, i, false);
        }
        assert_eq!(surviving_capsule_types(&mask, 2), 1);
    }
}
