//! Pruning masks: kernel-granular (structured) and weight-granular
//! (unstructured), plus the index encoding the accelerator stores
//! on-chip (§III-C).

use crate::tensor::Tensor;

/// Structured mask over the `out_ch × in_ch` kernel grid of an OIHW conv
/// tensor. `true` = kernel survives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelMask {
    pub out_ch: usize,
    pub in_ch: usize,
    bits: Vec<bool>,
}

impl KernelMask {
    pub fn all_alive(out_ch: usize, in_ch: usize) -> KernelMask {
        KernelMask {
            out_ch,
            in_ch,
            bits: vec![true; out_ch * in_ch],
        }
    }

    pub fn get(&self, o: usize, i: usize) -> bool {
        self.bits[o * self.in_ch + i]
    }

    pub fn set(&mut self, o: usize, i: usize, alive: bool) {
        self.bits[o * self.in_ch + i] = alive;
    }

    pub fn survived(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    pub fn total(&self) -> usize {
        self.bits.len()
    }

    pub fn survived_rate(&self) -> f64 {
        self.survived() as f64 / self.total().max(1) as f64
    }

    /// Zero the pruned kernels of an OIHW tensor in place.
    pub fn apply(&self, w: &mut Tensor) {
        assert_eq!(w.shape[0], self.out_ch);
        assert_eq!(w.shape[1], self.in_ch);
        let kk = w.shape[2] * w.shape[3];
        for o in 0..self.out_ch {
            for i in 0..self.in_ch {
                if !self.get(o, i) {
                    let base = (o * self.in_ch + i) * kk;
                    w.data[base..base + kk].fill(0.0);
                }
            }
        }
    }

    /// The kernel-index list the accelerator keeps on-chip: one (o, i)
    /// pair per surviving kernel. §III-C: with structured pruning this is
    /// tiny (vs one index per weight for unstructured pruning).
    pub fn survivor_indices(&self) -> Vec<(u16, u16)> {
        let mut out = Vec::with_capacity(self.survived());
        for o in 0..self.out_ch {
            for i in 0..self.in_ch {
                if self.get(o, i) {
                    out.push((o as u16, i as u16));
                }
            }
        }
        out
    }

    /// Bytes of on-chip index memory: 2 × u16 per surviving kernel.
    pub fn index_bytes(&self) -> usize {
        self.survived() * 4
    }
}

/// Unstructured per-weight mask (for the magnitude-pruning baseline).
#[derive(Debug, Clone)]
pub struct WeightMask {
    pub bits: Vec<bool>,
}

impl WeightMask {
    pub fn survived(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    pub fn survived_rate(&self) -> f64 {
        self.survived() as f64 / self.bits.len().max(1) as f64
    }

    pub fn apply(&self, w: &mut Tensor) {
        assert_eq!(w.len(), self.bits.len());
        for (v, &b) in w.data.iter_mut().zip(&self.bits) {
            if !b {
                *v = 0.0;
            }
        }
    }

    /// Unstructured pruning needs one index per surviving *weight*
    /// (u32 flat offset) — the §III-C comparison that motivates
    /// structured pruning on FPGA.
    pub fn index_bytes(&self) -> usize {
        self.survived() * 4
    }

    /// Collapse to kernel granularity over an `out_ch × in_ch` grid of
    /// `k×k` kernels: a kernel survives iff any of its weights does.
    /// This is how an unstructured mask enters the sparse-compiled path
    /// ([`crate::capsnet::compiled`]): apply the weight mask first (so
    /// partially-dead kernels carry their zeros), then compile with the
    /// collapsed kernel mask to skip the fully-dead ones.
    pub fn to_kernel_mask(&self, out_ch: usize, in_ch: usize) -> KernelMask {
        assert_eq!(self.bits.len() % (out_ch * in_ch), 0);
        let kk = self.bits.len() / (out_ch * in_ch);
        let mut mask = KernelMask::all_alive(out_ch, in_ch);
        for o in 0..out_ch {
            for i in 0..in_ch {
                let base = (o * in_ch + i) * kk;
                let alive = self.bits[base..base + kk].iter().any(|&b| b);
                mask.set(o, i, alive);
            }
        }
        mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_mask_apply_zeroes_kernels() {
        let mut w = Tensor::full(&[2, 2, 2, 2], 1.0);
        let mut m = KernelMask::all_alive(2, 2);
        m.set(0, 1, false);
        m.apply(&mut w);
        assert_eq!(w.at(&[0, 1, 0, 0]), 0.0);
        assert_eq!(w.at(&[0, 1, 1, 1]), 0.0);
        assert_eq!(w.at(&[0, 0, 0, 0]), 1.0);
        assert_eq!(w.at(&[1, 1, 1, 1]), 1.0);
        assert_eq!(m.survived(), 3);
        assert!((m.survived_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn survivor_indices_enumerate_alive() {
        let mut m = KernelMask::all_alive(2, 2);
        m.set(1, 0, false);
        assert_eq!(m.survivor_indices(), vec![(0, 0), (0, 1), (1, 1)]);
        assert_eq!(m.index_bytes(), 12);
    }

    #[test]
    fn structured_index_memory_beats_unstructured() {
        // Same survived parameter count; kernel indices are ~k² smaller.
        let (o, i, k) = (16, 16, 9);
        let mut km = KernelMask::all_alive(o, i);
        for oc in 0..o {
            for ic in 0..i {
                if (oc + ic) % 4 != 0 {
                    km.set(oc, ic, false);
                }
            }
        }
        let surviving_weights = km.survived() * k * k;
        let wm = WeightMask {
            bits: (0..o * i * k * k)
                .map(|n| n % (o * i * k * k / surviving_weights) == 0)
                .collect(),
        };
        assert!(km.index_bytes() * 20 < wm.index_bytes());
    }

    #[test]
    fn weight_mask_collapses_to_kernel_granularity() {
        // 2×2 grid of 2×2 kernels; kernel (0,1) fully dead, (1,0) has one
        // surviving weight → alive at kernel granularity.
        let mut bits = vec![true; 16];
        for b in bits.iter_mut().take(8).skip(4) {
            *b = false; // kernel (0,1): weights 4..8
        }
        for b in bits.iter_mut().take(11).skip(8) {
            *b = false; // kernel (1,0): 3 of 4 weights dead
        }
        let wm = WeightMask { bits };
        let km = wm.to_kernel_mask(2, 2);
        assert!(km.get(0, 0));
        assert!(!km.get(0, 1));
        assert!(km.get(1, 0));
        assert!(km.get(1, 1));
    }

    #[test]
    fn weight_mask_apply() {
        let mut w = Tensor::full(&[4], 2.0);
        let m = WeightMask {
            bits: vec![true, false, true, false],
        };
        m.apply(&mut w);
        assert_eq!(w.data, vec![2.0, 0.0, 2.0, 0.0]);
        assert_eq!(m.survived(), 2);
    }
}
