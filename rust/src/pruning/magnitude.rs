//! Unstructured magnitude pruning (Han et al. [21]) — the red line in
//! Fig. 5. Prunes individual weights by |w|, achieving high compression
//! but an irregular sparsity pattern that needs per-weight indices on
//! hardware (§III-C).

use super::WeightMask;
use crate::tensor::Tensor;

/// Prune the smallest-|w| `sparsity` fraction of individual weights.
pub fn prune_layer(w: &Tensor, sparsity: f64) -> WeightMask {
    let n = w.len();
    let n_prune = ((n as f64) * sparsity.clamp(0.0, 1.0)).floor() as usize;
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        w.data[a]
            .abs()
            .partial_cmp(&w.data[b].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut bits = vec![true; n];
    for &i in order.iter().take(n_prune) {
        bits[i] = false;
    }
    WeightMask { bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn prunes_smallest_weights() {
        let w = Tensor::from_vec(&[4], vec![0.1, -0.9, 0.5, -0.05]).unwrap();
        let m = prune_layer(&w, 0.5);
        assert_eq!(m.bits, vec![false, true, true, false]);
    }

    #[test]
    fn unstructured_keeps_more_signal_than_structured_at_same_rate() {
        // At equal survived-parameter budget, unstructured pruning retains
        // more total magnitude than kernel pruning — the Fig. 5 trade-off
        // (its weakness is the hardware index cost, not the signal).
        let mut rng = Rng::new(9);
        let w = Tensor::randn(&[8, 8, 3, 3], 1.0, &mut rng);
        let sparsity = 0.75;
        let um = prune_layer(&w, sparsity);
        let mut wu = w.clone();
        um.apply(&mut wu);
        let kp = super::super::kp::prune_layer(&w, sparsity);
        let mut wk = w.clone();
        kp.mask.apply(&mut wk);
        assert!(wu.abs_sum() > wk.abs_sum());
    }

    #[test]
    fn property_survived_rate_matches() {
        crate::testing::check_msg(
            "unstructured sparsity respected",
            20,
            13,
            |r| {
                let n = 32 + r.below(200);
                let w = Tensor::randn(&[n], 1.0, r);
                let s = r.f64() * 0.95;
                (w, s)
            },
            |(w, s)| {
                let m = prune_layer(w, *s);
                let want = w.len() - ((w.len() as f64) * s).floor() as usize;
                if m.survived() == want {
                    Ok(())
                } else {
                    Err(format!("survived {} want {want}", m.survived()))
                }
            },
        );
    }
}
