//! Look-Ahead Kernel Pruning — Algorithm 1, the paper's contribution.
//!
//! Per-parameter look-ahead score (Eq. 1):
//! `L_i(w) = |w| · ‖W_{i−1}[j,:]‖ · ‖W_{i+1}[:,k]‖`, where `w` sits in the
//! kernel connecting input channel `j` to output channel `k`. Because the
//! adjacency factors are constant over a kernel, the kernel score
//! factorizes:
//!
//! `LK(o,i) = abs_sum(W_i[o,i]) · prev[i] · next[o]`
//!
//! Layer-wise sparsity (`s_i`): the lowest-scored `s_i` fraction of
//! kernels is masked (the paper prunes layer-wise "due to the unequal
//! redundancy of network parameters in each layer" [25]).

use super::{AdjacencyNorms, LayerPruneResult};
use crate::tensor::Tensor;

/// Per-kernel look-ahead scores for an OIHW tensor.
pub fn kernel_scores(w: &Tensor, adj: &AdjacencyNorms) -> Vec<f32> {
    let (o, i) = (w.shape[0], w.shape[1]);
    assert_eq!(adj.prev.len(), i, "prev norms must cover input channels");
    assert_eq!(adj.next.len(), o, "next norms must cover output channels");
    let kk = w.shape[2] * w.shape[3];
    let mut scores = Vec::with_capacity(o * i);
    for oc in 0..o {
        for ic in 0..i {
            let base = (oc * i + ic) * kk;
            let s: f32 = w.data[base..base + kk].iter().map(|x| x.abs()).sum();
            scores.push(s * adj.prev[ic] * adj.next[oc]);
        }
    }
    scores
}

/// Prune the lowest-scored `sparsity` fraction of kernels (Algorithm 1
/// lines 5–10 for one layer).
pub fn prune_layer(w: &Tensor, adj: &AdjacencyNorms, sparsity: f64) -> LayerPruneResult {
    let scores = kernel_scores(w, adj);
    let mask = super::kp::mask_from_scores(&scores, w.shape[0], w.shape[1], sparsity);
    LayerPruneResult { mask, scores }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::tests::tensor_with_kernel_sums;

    /// The paper's Fig. 7 worked example: W_i, W_{i−1}, W_{i+1} all
    /// (2,2,3,3); kernel abs-sums as printed in the figure.
    ///
    /// Note: Fig. 7 prints the score of kernel (0,0) as 2295, but its own
    /// formula gives 8·(8+9)·(6+9) = 2040 — a typo in the paper. The
    /// remaining three scores (2280, 3060, 3800) and the resulting mask
    /// match exactly.
    #[test]
    fn fig7_worked_example() {
        let w_prev = tensor_with_kernel_sums(&[&[8.0, 9.0], &[10.0, 9.0]], 3, 3);
        let w_i = tensor_with_kernel_sums(&[&[8.0, 8.0], &[9.0, 10.0]], 3, 3);
        let w_next = tensor_with_kernel_sums(&[&[6.0, 10.0], &[9.0, 10.0]], 3, 3);

        let adj = AdjacencyNorms {
            prev: AdjacencyNorms::prev_from_conv(&w_prev),
            next: AdjacencyNorms::next_from_conv(&w_next),
        };
        let scores = kernel_scores(&w_i, &adj);
        // (o,i) order: (0,0), (0,1), (1,0), (1,1).
        assert!((scores[0] - 2040.0).abs() < 0.5, "got {}", scores[0]);
        assert!((scores[1] - 2280.0).abs() < 0.5, "got {}", scores[1]);
        assert!((scores[2] - 3060.0).abs() < 0.5, "got {}", scores[2]);
        assert!((scores[3] - 3800.0).abs() < 0.5, "got {}", scores[3]);

        // 50% sparsity → kernels (0,0) and (0,1) pruned: mask [[0,0],[1,1]].
        let res = prune_layer(&w_i, &adj, 0.5);
        assert!(!res.mask.get(0, 0));
        assert!(!res.mask.get(0, 1));
        assert!(res.mask.get(1, 0));
        assert!(res.mask.get(1, 1));
    }

    #[test]
    fn neutral_adjacency_reduces_to_kp() {
        let mut rng = crate::util::rng::Rng::new(1);
        let w = Tensor::randn(&[6, 4, 3, 3], 1.0, &mut rng);
        let adj = AdjacencyNorms::neutral(4, 6);
        let lakp = prune_layer(&w, &adj, 0.5);
        let kp = super::super::kp::prune_layer(&w, 0.5);
        assert_eq!(lakp.mask, kp.mask);
    }

    #[test]
    fn adjacency_changes_the_choice() {
        // Two kernels with equal magnitude; adjacency should break the tie
        // toward the one feeding the strong consumer.
        let w = tensor_with_kernel_sums(&[&[5.0], &[5.0]], 3, 3);
        let adj = AdjacencyNorms {
            prev: vec![1.0],
            next: vec![0.1, 10.0], // consumer of ch 1 is much stronger
        };
        let res = prune_layer(&w, &adj, 0.5);
        assert!(!res.mask.get(0, 0), "weakly-consumed kernel pruned");
        assert!(res.mask.get(1, 0), "strongly-consumed kernel kept");
    }

    #[test]
    fn property_sparsity_respected() {
        crate::testing::check_msg(
            "LAKP prunes exactly the requested fraction",
            20,
            11,
            |r| {
                let o = 2 + r.below(8);
                let i = 1 + r.below(8);
                let w = Tensor::randn(&[o, i, 3, 3], 1.0, r);
                let s = [0.0, 0.25, 0.5, 0.75, 0.9][r.below(5)];
                (w, s)
            },
            |(w, s)| {
                let adj = AdjacencyNorms::neutral(w.shape[1], w.shape[0]);
                let res = prune_layer(w, &adj, *s);
                let total = w.shape[0] * w.shape[1];
                let want_pruned = ((total as f64) * s).floor() as usize;
                let got = total - res.mask.survived();
                if got == want_pruned {
                    Ok(())
                } else {
                    Err(format!("pruned {got}, wanted {want_pruned}"))
                }
            },
        );
    }
}
