//! Single-flight coalescing: N concurrent identical misses run ONE
//! inference.
//!
//! The first miss becomes the *leader* and owns a [`FlightLead`]; it
//! rides the normal admission queue into the executor pool. Duplicates
//! arriving while the flight is open park a [`Waiter`] (their response
//! sender) on the flight entry instead of queueing. When the leader's
//! response arrives, [`FlightLead::complete`] publishes it to the store
//! and fans it out to every waiter. If the leader never completes — its
//! batch failed, it was rejected at admission, the pool died, or the
//! server shut down — the `FlightLead` is *dropped*, which removes the
//! entry and drops every parked sender: each waiter's `recv()`
//! disconnects immediately and surfaces as the same typed
//! `Unavailable` error an uncached dropped request gets. Waiters can
//! therefore observe exactly two outcomes: the leader's response, or a
//! typed error — never a hang.
//!
//! Lock order is table → entry-state (the leader only takes the state
//! lock after releasing the table lock), so joiners holding the table
//! lock can always park without deadlock.

use super::store::{CacheStore, CachedOutput};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::ReplySink;
use crate::coordinator::Response;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

/// A parked duplicate request, served (or drop-notified) when the
/// flight finishes. Latency is measured from the waiter's own arrival.
pub(crate) struct Waiter {
    pub id: u64,
    pub enqueued: Instant,
    pub sink: ReplySink,
}

struct FlightState {
    waiters: Vec<Waiter>,
    /// Set exactly once, after the entry has left the table — a join
    /// that somehow races the finish is refused instead of parking on
    /// a flight nobody will ever complete.
    done: bool,
}

/// One in-flight inference, shared between its leader and its waiters.
pub(crate) struct FlightEntry {
    state: Mutex<FlightState>,
}

impl FlightEntry {
    fn new() -> FlightEntry {
        FlightEntry {
            state: Mutex::new(FlightState {
                waiters: Vec::new(),
                done: false,
            }),
        }
    }

    /// Park a waiter; `Err` returns it if the flight already finished.
    fn join(&self, w: Waiter) -> Result<(), Waiter> {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        if st.done {
            return Err(w);
        }
        st.waiters.push(w);
        Ok(())
    }

    /// Mark done and take the waiters (idempotent: a second call — e.g.
    /// a completed lead's Drop — gets an empty vec).
    fn finish(&self) -> Vec<Waiter> {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.done = true;
        std::mem::take(&mut st.waiters)
    }
}

/// key → open flight. One entry per distinct in-flight request content.
#[derive(Default)]
pub(crate) struct FlightTable {
    flights: Mutex<HashMap<u128, Arc<FlightEntry>>>,
}

/// Outcome of [`FlightTable::join_or_lead`].
pub(crate) enum FlightRole {
    /// No open flight: the caller is now the leader and must either run
    /// inference to completion or drop the lead (which drop-notifies).
    /// The caller's waiter is handed back — its sink is the leader's own
    /// delivery path, not parked on the flight.
    Lead(FlightLead, Waiter),
    /// Parked on an existing flight; the caller's sink resolves when
    /// the flight finishes.
    Joined,
    /// The flight finished between lookup and join — the waiter is
    /// handed back so the caller can re-check the store and try again.
    Finished(Waiter),
}

impl FlightTable {
    /// Join the open flight for `key`, or open one and lead it.
    pub(crate) fn join_or_lead(
        self: &Arc<Self>,
        key: u128,
        fingerprint: u64,
        store: &Arc<CacheStore>,
        waiter: Waiter,
    ) -> FlightRole {
        let mut table = self.flights.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(entry) = table.get(&key) {
            return match entry.join(waiter) {
                Ok(()) => FlightRole::Joined,
                Err(w) => FlightRole::Finished(w),
            };
        }
        let entry = Arc::new(FlightEntry::new());
        table.insert(key, entry.clone());
        drop(table);
        FlightRole::Lead(
            FlightLead {
                key,
                fingerprint,
                entry,
                store: store.clone(),
                table: self.clone(),
                completed: false,
            },
            waiter,
        )
    }

    /// Remove `key` iff it still maps to this exact entry (a defensive
    /// identity check: a successor flight under the same key must not
    /// be torn down by a stale lead).
    fn remove(&self, key: u128, entry: &Arc<FlightEntry>) {
        let mut table = self.flights.lock().unwrap_or_else(PoisonError::into_inner);
        if table.get(&key).is_some_and(|e| Arc::ptr_eq(e, entry)) {
            table.remove(&key);
        }
    }

    /// Open flights right now (test observability).
    #[cfg(test)]
    pub(crate) fn len(&self) -> usize {
        self.flights
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }
}

/// Leadership of one flight. Either [`FlightLead::complete`] runs, or
/// Drop aborts the flight and drop-notifies every waiter.
pub(crate) struct FlightLead {
    key: u128,
    fingerprint: u64,
    entry: Arc<FlightEntry>,
    store: Arc<CacheStore>,
    table: Arc<FlightTable>,
    completed: bool,
}

impl FlightLead {
    /// Publish the leader's response: insert into the store *first*,
    /// then unlink the flight, then fan out to waiters — so any thread
    /// that misses the flight in the table is guaranteed to hit the
    /// store. Waiter latencies are recorded from each waiter's own
    /// arrival time.
    pub(crate) fn complete(&mut self, resp: &Response, m: &mut Metrics) {
        self.completed = true;
        let cached = Arc::new(CachedOutput {
            lengths: resp.lengths.clone(),
            predicted: resp.predicted,
            batch: resp.batch,
            fingerprint: self.fingerprint,
        });
        let evicted = self.store.insert(self.key, cached.clone());
        m.record_cache_evicted(evicted);
        self.table.remove(self.key, &self.entry);
        for w in self.entry.finish() {
            let r = cached.to_response(w.id, w.enqueued);
            m.record(r.latency_us);
            w.sink.send(r); // a vanished waiter is fine
        }
    }
}

impl Drop for FlightLead {
    fn drop(&mut self) {
        if !self.completed {
            // The leader died without a response (failed batch, admission
            // rejection, pool death, shutdown drain): unlink the flight
            // and drop the parked senders — every waiter's recv()
            // disconnects and maps to a typed Unavailable.
            self.table.remove(self.key, &self.entry);
            drop(self.entry.finish());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn waiter(id: u64) -> (Waiter, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Waiter {
                id,
                enqueued: Instant::now(),
                sink: ReplySink::Channel(tx),
            },
            rx,
        )
    }

    fn toy_response(id: u64) -> Response {
        Response {
            id,
            lengths: vec![0.25; 10],
            predicted: 4,
            latency_us: 17,
            batch: 2,
        }
    }

    #[test]
    fn leader_then_joiners_then_complete_fans_out() {
        let table = Arc::new(FlightTable::default());
        let store = Arc::new(CacheStore::new(8, 1));
        let (w0, _rx0) = waiter(1);
        let mut lead = match table.join_or_lead(5, 99, &store, w0) {
            FlightRole::Lead(l, _w) => l,
            _ => panic!("first caller must lead"),
        };
        let mut waiter_rxs = Vec::new();
        for id in 2..5 {
            let (w, rx) = waiter(id);
            match table.join_or_lead(5, 99, &store, w) {
                FlightRole::Joined => waiter_rxs.push((id, rx)),
                _ => panic!("duplicate must join the open flight"),
            }
        }
        assert_eq!(table.len(), 1);
        let mut m = Metrics::default();
        lead.complete(&toy_response(1), &mut m);
        for (id, rx) in waiter_rxs {
            let r = rx.recv().expect("waiter must be served");
            assert_eq!(r.id, id, "waiter keeps its own request id");
            assert_eq!(r.predicted, 4);
            assert_eq!(r.lengths, vec![0.25; 10]);
            assert_eq!(r.batch, 2);
        }
        assert_eq!(m.requests, 3, "one record per served waiter");
        assert_eq!(table.len(), 0, "completed flight must leave the table");
        let hit = store.get(5).expect("completed flight fills the store");
        assert_eq!(hit.fingerprint, 99);
    }

    #[test]
    fn dropped_lead_disconnects_waiters_instead_of_hanging() {
        let table = Arc::new(FlightTable::default());
        let store = Arc::new(CacheStore::new(8, 1));
        let (w0, rx0) = waiter(1);
        let lead = match table.join_or_lead(9, 1, &store, w0) {
            FlightRole::Lead(l, _w) => l,
            _ => panic!("first caller must lead"),
        };
        let (w1, rx1) = waiter(2);
        assert!(matches!(
            table.join_or_lead(9, 1, &store, w1),
            FlightRole::Joined
        ));
        drop(lead); // leader failed before completing
        assert!(
            matches!(rx1.recv(), Err(mpsc::RecvError)),
            "waiter must disconnect, not hang"
        );
        // The leader's own channel came from the caller and is simply
        // unused here; the flight is gone and the store untouched.
        drop(rx0);
        assert_eq!(table.len(), 0);
        assert!(store.get(9).is_none());
    }

    #[test]
    fn next_request_after_abort_leads_a_fresh_flight() {
        let table = Arc::new(FlightTable::default());
        let store = Arc::new(CacheStore::new(8, 1));
        let (w0, _rx0) = waiter(1);
        let lead = match table.join_or_lead(3, 1, &store, w0) {
            FlightRole::Lead(l, _w) => l,
            _ => panic!(),
        };
        drop(lead);
        let (w1, _rx1) = waiter(2);
        assert!(
            matches!(
                table.join_or_lead(3, 1, &store, w1),
                FlightRole::Lead(_, _)
            ),
            "an aborted flight must not block retries from leading"
        );
    }

    #[test]
    fn completed_lead_drop_is_inert() {
        let table = Arc::new(FlightTable::default());
        let store = Arc::new(CacheStore::new(8, 1));
        let (w0, _rx0) = waiter(1);
        let mut lead = match table.join_or_lead(7, 1, &store, w0) {
            FlightRole::Lead(l, _w) => l,
            _ => panic!(),
        };
        let mut m = Metrics::default();
        lead.complete(&toy_response(1), &mut m);
        // A new flight under the same key must survive the old lead's
        // Drop (identity check in FlightTable::remove).
        let (w1, _rx1) = waiter(2);
        let lead2 = match table.join_or_lead(7, 1, &store, w1) {
            FlightRole::Lead(l, _w) => l,
            _ => panic!("store hit is checked by the caller, not the table"),
        };
        drop(lead);
        assert_eq!(table.len(), 1, "successor flight was torn down");
        drop(lead2);
    }
}
