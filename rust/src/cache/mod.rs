//! Content-addressed inference cache with single-flight coalescing.
//!
//! Real serving traffic repeats (retries, duplicated sensors, hot
//! classes), and the paper identifies dynamic routing as the dominant
//! inference cost — FastCaps' 82→1351 FPS on PYNQ-Z1 came entirely
//! from attacking it. This layer sits between the network front-end
//! and the admission queue and turns a duplicate request into an
//! O(hash) lookup instead of another full conv+routing pass, for every
//! backend at once.
//!
//! **Key derivation.** A request's key is two independently-seeded
//! 64-bit lanes ([`crate::util::hash::Hash64`]) over the *deployment
//! fingerprint* followed by the input tensor's shape and exact f32 bit
//! patterns. The fingerprint ([`crate::backend::BackendSpec::fingerprint`])
//! digests the backend kind, model/dataset name, and the deployed
//! weight (and mask) bits — so a `prune --compile --serve` style
//! redeploy changes every key and a stale hit is structurally
//! impossible, rather than relying on explicit invalidation.
//!
//! **Single-flight.** A miss opens a flight in the [`flight`] table;
//! concurrent identical misses park on it instead of queueing, and the
//! one leader's response fans out to all of them (or a typed error
//! does, if the leader dies). See [`flight`] for the state machine.
//!
//! **Store.** Completed responses land in a bounded sharded clock-LRU
//! ([`store::CacheStore`]), shareable across server generations via
//! [`crate::coordinator::server::ServerBuilder::cache_store`] — which
//! is exactly what the redeploy integration test does to prove the
//! fingerprint isolation.

pub mod flight;
pub mod store;

pub use store::{CacheStore, CachedOutput};

use crate::tensor::Tensor;
use crate::util::hash::Hash64;
use flight::{FlightRole, FlightTable, Waiter};
use std::sync::Arc;

/// Cache sizing. `entries == 0` disables the layer entirely (the
/// server then never consults it).
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Total cached responses across all shards.
    pub entries: usize,
    /// Lock shards; more shards = less contention, slightly looser LRU.
    pub shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            entries: 4096,
            shards: 8,
        }
    }
}

impl CacheConfig {
    /// Default sharding with an explicit entry budget (0 = disabled).
    pub fn with_entries(entries: usize) -> CacheConfig {
        CacheConfig {
            entries,
            ..CacheConfig::default()
        }
    }

    pub fn enabled(&self) -> bool {
        self.entries > 0
    }
}

/// Outcome of a cache lookup, consumed by `Server::submit_sink`. `Hit`
/// and `Lead` hand the caller's waiter back — its sink is the delivery
/// path for the caller's own response and must not die with the lookup.
pub(crate) enum Lookup {
    /// Fingerprint-validated store hit: serve without touching the pool.
    Hit(Arc<CachedOutput>, Waiter),
    /// Parked on an in-flight identical request.
    Joined,
    /// Caller leads: run inference, then `lead.complete(...)`. `stale`
    /// reports that a wrong-fingerprint entry was found (and refused)
    /// under this key — with the fingerprint hashed into the key this
    /// is structurally impossible, and the counter it feeds stays 0.
    Lead {
        lead: flight::FlightLead,
        waiter: Waiter,
        stale: bool,
    },
}

/// One deployment's view of the cache: a store + flight table bound to
/// the serving backend's fingerprint.
pub struct InferenceCache {
    store: Arc<CacheStore>,
    flights: Arc<FlightTable>,
    fingerprint: u64,
}

impl InferenceCache {
    pub fn new(cfg: &CacheConfig, fingerprint: u64) -> InferenceCache {
        InferenceCache::with_store(
            Arc::new(CacheStore::new(cfg.entries, cfg.shards)),
            fingerprint,
        )
    }

    /// Bind to an existing store — entries written by other deployments
    /// (different fingerprints) are invisible, not shared; this is how
    /// a redeploy keeps the allocation without inheriting stale state.
    pub fn with_store(store: Arc<CacheStore>, fingerprint: u64) -> InferenceCache {
        InferenceCache {
            store,
            flights: Arc::new(FlightTable::default()),
            fingerprint,
        }
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn store(&self) -> &Arc<CacheStore> {
        &self.store
    }

    /// Content address of one input under this deployment: fingerprint
    /// first, then shape, then exact f32 bits. Two independently-seeded
    /// lanes make the effective key 128-bit, so accidental collision is
    /// out of reach for any realistic cache population.
    pub fn key_of(&self, image: &Tensor) -> u128 {
        let mut lo = Hash64::new(0x4641_5354_4341_5053); // "FASTCAPS"
        let mut hi = Hash64::new(0x6361_6368_656b_6579); // "cachekey"
        for h in [&mut lo, &mut hi] {
            h.absorb(self.fingerprint);
            h.absorb(image.shape.len() as u64);
            for &d in &image.shape {
                h.absorb(d as u64);
            }
            h.absorb_f32s(&image.data);
        }
        ((hi.finish() as u128) << 64) | lo.finish() as u128
    }

    /// Resolve one request against the cache. Never blocks beyond two
    /// short mutexes; the `Finished` race (a flight completing between
    /// the store probe and the join) retries, and each retry can only
    /// happen after another thread made real progress, so the loop
    /// terminates.
    pub(crate) fn lookup(&self, key: u128, mut waiter: Waiter) -> Lookup {
        let mut stale = false;
        loop {
            if let Some(out) = self.store.get(key) {
                if out.fingerprint == self.fingerprint {
                    return Lookup::Hit(out, waiter);
                }
                // Refuse to serve it; lead a fresh flight that will
                // overwrite the entry. (Unreachable by construction.)
                stale = true;
            }
            waiter = match self
                .flights
                .join_or_lead(key, self.fingerprint, &self.store, waiter)
            {
                FlightRole::Lead(lead, waiter) => {
                    return Lookup::Lead {
                        lead,
                        waiter,
                        stale,
                    }
                }
                FlightRole::Joined => return Lookup::Joined,
                FlightRole::Finished(w) => w,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn cache(entries: usize, fingerprint: u64) -> InferenceCache {
        InferenceCache::new(&CacheConfig::with_entries(entries.max(1)), fingerprint)
    }

    fn waiter(id: u64) -> (Waiter, mpsc::Receiver<crate::coordinator::Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Waiter {
                id,
                enqueued: Instant::now(),
                sink: crate::coordinator::server::ReplySink::Channel(tx),
            },
            rx,
        )
    }

    fn image(seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[1, 4, 4]);
        for (i, x) in t.data.iter_mut().enumerate() {
            *x = (seed as f32) * 0.01 + i as f32;
        }
        t
    }

    #[test]
    fn key_is_deterministic_and_content_sensitive() {
        let c = cache(16, 7);
        let a = image(1);
        assert_eq!(c.key_of(&a), c.key_of(&a.clone()));
        assert_ne!(c.key_of(&a), c.key_of(&image(2)));
        // One flipped mantissa bit must change the key.
        let mut b = a.clone();
        b.data[5] = f32::from_bits(b.data[5].to_bits() ^ 1);
        assert_ne!(c.key_of(&a), c.key_of(&b));
        // Same data, different shape must change the key.
        let mut s = a.clone();
        s.shape = vec![1, 2, 8];
        assert_ne!(c.key_of(&a), c.key_of(&s));
    }

    #[test]
    fn fingerprint_partitions_the_key_space() {
        let img = image(3);
        let v1 = cache(16, 100);
        let v2 = cache(16, 200);
        assert_ne!(
            v1.key_of(&img),
            v2.key_of(&img),
            "a redeploy (new fingerprint) must change every key"
        );
    }

    #[test]
    fn shared_store_with_new_fingerprint_never_hits_old_entries() {
        // The redeploy story in miniature: same store Arc, different
        // fingerprint ⇒ the old deployment's entries are unreachable.
        let v1 = cache(16, 100);
        let img = image(4);
        let (w, _rx) = waiter(1);
        let key1 = v1.key_of(&img);
        match v1.lookup(key1, w) {
            Lookup::Lead {
                mut lead, stale, ..
            } => {
                assert!(!stale);
                let resp = crate::coordinator::Response {
                    id: 1,
                    lengths: vec![0.5; 10],
                    predicted: 0,
                    latency_us: 1,
                    batch: 1,
                };
                let mut m = crate::coordinator::metrics::Metrics::default();
                lead.complete(&resp, &mut m);
            }
            _ => panic!("first lookup must lead"),
        }
        let (w, _rx) = waiter(2);
        assert!(
            matches!(v1.lookup(key1, w), Lookup::Hit(_, _)),
            "same deployment must hit"
        );
        let v2 = InferenceCache::with_store(v1.store().clone(), 200);
        let (w, _rx) = waiter(3);
        match v2.lookup(v2.key_of(&img), w) {
            Lookup::Lead { stale, .. } => {
                assert!(!stale, "different key, so not even a stale sighting")
            }
            _ => panic!("new fingerprint must miss the old entry"),
        }
    }

    #[test]
    fn duplicate_lookups_coalesce_until_leader_completes() {
        let c = cache(16, 9);
        let img = image(5);
        let key = c.key_of(&img);
        let (w, _rx) = waiter(1);
        let mut lead = match c.lookup(key, w) {
            Lookup::Lead { lead, .. } => lead,
            _ => panic!("miss must lead"),
        };
        let (w2, rx2) = waiter(2);
        assert!(matches!(c.lookup(key, w2), Lookup::Joined));
        let resp = crate::coordinator::Response {
            id: 1,
            lengths: vec![0.125; 10],
            predicted: 3,
            latency_us: 10,
            batch: 4,
        };
        let mut m = crate::coordinator::metrics::Metrics::default();
        lead.complete(&resp, &mut m);
        let got = rx2.recv().expect("waiter served on completion");
        assert_eq!(got.id, 2);
        assert_eq!(got.predicted, 3);
        assert_eq!(
            got.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            resp.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "coalesced response must be bit-identical to the leader's"
        );
        let (w3, _rx3) = waiter(3);
        assert!(matches!(c.lookup(key, w3), Lookup::Hit(_, _)));
    }
}
