//! Bounded sharded response store with clock (second-chance) eviction.
//!
//! std-only: each shard is a `Mutex<HashMap + slot ring>`; the shard
//! index comes from the key's high bits (the low bits pick the
//! `HashMap` bucket, so both levels see independent key material).
//! Clock eviction approximates LRU without an intrusive list: a hit
//! sets the slot's referenced bit, the insert hand clears bits until it
//! finds an unreferenced victim. All locks recover from poisoning with
//! [`std::sync::PoisonError::into_inner`] — the store holds plain data,
//! and a panicking client thread must not take the cache down with it.

use crate::coordinator::Response;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

/// The cached, backend-independent part of a response. `lengths` is
/// shared by `Arc`, so serving a hit never copies the score vector.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedOutput {
    /// DigitCaps lengths, bit-identical to the response that filled the
    /// entry.
    pub lengths: Vec<f32>,
    pub predicted: usize,
    /// Batch size the filling request was served in (reported so a hit
    /// is indistinguishable from the original response apart from
    /// latency).
    pub batch: usize,
    /// Deployment fingerprint the entry was computed under. The
    /// fingerprint is already part of the key, so a lookup can never
    /// return another deployment's entry; this copy exists for the
    /// belt-and-braces validation behind the `stale` counter.
    pub fingerprint: u64,
}

impl CachedOutput {
    /// Materialize a response for one request: cached content, the
    /// request's own id, latency measured from its own arrival. Apart
    /// from `latency_us` the result is bit-identical to the response
    /// that filled the entry.
    pub fn to_response(&self, id: u64, enqueued: Instant) -> Response {
        Response {
            id,
            lengths: self.lengths.clone(),
            predicted: self.predicted,
            latency_us: enqueued.elapsed().as_micros() as u64,
            batch: self.batch,
        }
    }
}

struct Slot {
    key: u128,
    value: Arc<CachedOutput>,
    referenced: bool,
}

struct Shard {
    /// key → index into `slots`.
    map: HashMap<u128, usize>,
    slots: Vec<Slot>,
    /// Clock hand for second-chance eviction.
    hand: usize,
    capacity: usize,
}

impl Shard {
    fn get(&mut self, key: u128) -> Option<Arc<CachedOutput>> {
        let &idx = self.map.get(&key)?;
        self.slots[idx].referenced = true;
        Some(self.slots[idx].value.clone())
    }

    /// Insert or replace; returns the number of entries evicted (0 or 1).
    fn insert(&mut self, key: u128, value: Arc<CachedOutput>) -> u64 {
        if let Some(&idx) = self.map.get(&key) {
            // Same key raced in twice (e.g. two leaders across a store
            // re-check window): keep the newer value, evict nothing.
            self.slots[idx].value = value;
            self.slots[idx].referenced = true;
            return 0;
        }
        if self.slots.len() < self.capacity {
            self.map.insert(key, self.slots.len());
            self.slots.push(Slot {
                key,
                value,
                referenced: false,
            });
            return 0;
        }
        // Full: advance the clock hand, granting one second chance per
        // referenced slot. Terminates within 2 laps (every bit cleared
        // after lap one).
        loop {
            let idx = self.hand;
            self.hand = (self.hand + 1) % self.slots.len();
            if self.slots[idx].referenced {
                self.slots[idx].referenced = false;
                continue;
            }
            self.map.remove(&self.slots[idx].key);
            self.map.insert(key, idx);
            self.slots[idx] = Slot {
                key,
                value,
                referenced: false,
            };
            return 1;
        }
    }
}

/// Sharded bounded store keyed by 128-bit content hashes.
#[derive(Debug)]
pub struct CacheStore {
    shards: Vec<Mutex<Shard>>,
    capacity: usize,
}

impl std::fmt::Debug for Shard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Shard")
            .field("len", &self.slots.len())
            .field("capacity", &self.capacity)
            .finish()
    }
}

impl CacheStore {
    /// `entries` total capacity spread over `shards` shards (both floored
    /// at 1; remainder entries go to the first shards).
    pub fn new(entries: usize, shards: usize) -> CacheStore {
        let entries = entries.max(1);
        let nshards = shards.clamp(1, entries);
        let shards = (0..nshards)
            .map(|i| {
                let capacity = entries / nshards + usize::from(i < entries % nshards);
                Mutex::new(Shard {
                    map: HashMap::new(),
                    slots: Vec::new(),
                    hand: 0,
                    capacity,
                })
            })
            .collect();
        CacheStore {
            shards,
            capacity: entries,
        }
    }

    fn shard(&self, key: u128) -> std::sync::MutexGuard<'_, Shard> {
        // High bits select the shard; HashMap consumes the full key, so
        // the two levels don't correlate.
        let idx = ((key >> 96) as usize) % self.shards.len();
        self.shards[idx]
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
    }

    pub fn get(&self, key: u128) -> Option<Arc<CachedOutput>> {
        self.shard(key).get(key)
    }

    /// Returns the number of entries evicted to make room (0 or 1).
    pub fn insert(&self, key: u128, value: Arc<CachedOutput>) -> u64 {
        self.shard(key).insert(key, value)
    }

    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(PoisonError::into_inner).slots.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn out(tag: usize) -> Arc<CachedOutput> {
        Arc::new(CachedOutput {
            lengths: vec![tag as f32; 10],
            predicted: tag % 10,
            batch: 1,
            fingerprint: 7,
        })
    }

    #[test]
    fn get_miss_then_insert_then_hit() {
        let store = CacheStore::new(8, 2);
        assert!(store.get(42).is_none());
        assert_eq!(store.insert(42, out(1)), 0);
        let hit = store.get(42).expect("hit after insert");
        assert_eq!(hit.predicted, 1);
        assert_eq!(store.len(), 1);
        assert!(!store.is_empty());
    }

    #[test]
    fn same_key_replaces_without_eviction() {
        let store = CacheStore::new(2, 1);
        store.insert(1, out(1));
        assert_eq!(store.insert(1, out(2)), 0);
        assert_eq!(store.get(1).unwrap().predicted, 2);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn capacity_bounds_len_and_counts_evictions() {
        let store = CacheStore::new(4, 2);
        let mut evicted = 0;
        for k in 0..32u128 {
            // Spread keys over both shards via the high bits.
            evicted += store.insert((k << 96) | k, out(k as usize));
        }
        assert!(store.len() <= store.capacity());
        assert_eq!(evicted as usize, 32 - store.len());
    }

    #[test]
    fn clock_eviction_spares_recently_hit_entries() {
        let store = CacheStore::new(2, 1);
        store.insert(1, out(1));
        store.insert(2, out(2));
        // Touch key 1: its referenced bit must grant a second chance.
        store.get(1).unwrap();
        store.insert(3, out(3));
        assert!(store.get(1).is_some(), "recently-hit entry was evicted");
        assert!(store.get(3).is_some(), "new entry missing");
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn zero_entries_floors_to_one() {
        let store = CacheStore::new(0, 8);
        assert_eq!(store.capacity(), 1);
        store.insert(1, out(1));
        assert_eq!(store.insert(2, out(2)), 1);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn concurrent_hammer_stays_bounded_and_consistent() {
        // 4 threads × 500 mixed get/insert ops on a 16-entry store: no
        // deadlock, len never exceeds capacity, and every value read
        // back under a key is a value some thread inserted under it.
        let store = Arc::new(CacheStore::new(16, 4));
        std::thread::scope(|scope| {
            for t in 0..4u128 {
                let store = store.clone();
                scope.spawn(move || {
                    for i in 0..500u128 {
                        let k = ((i % 24) << 96) | ((i % 24) ^ t);
                        if i % 3 == 0 {
                            store.insert(k, out((k & 0xff) as usize));
                        } else if let Some(v) = store.get(k) {
                            assert_eq!(v.predicted, ((k & 0xff) as usize) % 10);
                        }
                    }
                });
            }
        });
        assert!(store.len() <= store.capacity());
    }
}
