//! `fclint` — the FastCaps repo-invariant linter (see `src/analysis/`).
//!
//! ```text
//! fclint [PATH] [--format human|json] [--lint NAME]... [--list]
//! ```
//!
//! Scans `PATH` (default: `rust/src` or `src`, whichever exists) with
//! the repo's lint manifest and exits 1 on any deny-level finding,
//! 2 on usage/IO errors. CI runs this as a blocking job; see DESIGN.md
//! §3i for the lint registry and suppression pragma grammar.

use fastcaps::analysis::{self, Level, LintConfig, Report};
use fastcaps::util::json::{self, Json};
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    root: PathBuf,
    json_output: bool,
    only: Vec<String>,
    list: bool,
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("fclint: {e}");
            return ExitCode::from(2);
        }
    };
    if args.list {
        for lint in analysis::registry() {
            println!("{:24} {}", lint.name, lint.description);
        }
        return ExitCode::SUCCESS;
    }
    let mut cfg = LintConfig::repo_default();
    cfg.only = args.only;
    let report = match analysis::analyze_tree(&args.root, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fclint: scanning {}: {e}", args.root.display());
            return ExitCode::from(2);
        }
    };
    if args.json_output {
        println!("{}", to_json(&report).to_pretty());
    } else {
        print_human(&report);
    }
    if report.denies() > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn parse_args() -> Result<Args, String> {
    let mut root: Option<PathBuf> = None;
    let mut json_output = false;
    let mut only = Vec::new();
    let mut list = false;
    let mut argv = std::env::args().skip(1);
    while let Some(arg) = argv.next() {
        match arg.as_str() {
            "--format" => {
                let v = argv.next().ok_or("--format needs `human` or `json`")?;
                match v.as_str() {
                    "json" => json_output = true,
                    "human" => json_output = false,
                    other => return Err(format!("unknown format `{other}`")),
                }
            }
            "--lint" => only.push(argv.next().ok_or("--lint needs a lint name")?),
            "--list" => list = true,
            "--help" | "-h" => {
                return Err("usage: fclint [PATH] [--format json] [--lint NAME]... [--list]".into());
            }
            other if other.starts_with('-') => return Err(format!("unknown flag `{other}`")),
            other => root = Some(PathBuf::from(other)),
        }
    }
    let root = match root {
        Some(p) => resolve_root(p)?,
        None => default_root()?,
    };
    Ok(Args {
        root,
        json_output,
        only,
        list,
    })
}

/// Accept the path as given, or with the `rust/` prefix added/stripped
/// so `fclint rust/src` works from the repo root and from `rust/`.
fn resolve_root(p: PathBuf) -> Result<PathBuf, String> {
    if p.is_dir() {
        return Ok(p);
    }
    if let Ok(stripped) = p.strip_prefix("rust") {
        if stripped.is_dir() {
            return Ok(stripped.to_path_buf());
        }
    }
    let prefixed = PathBuf::from("rust").join(&p);
    if prefixed.is_dir() {
        return Ok(prefixed);
    }
    Err(format!("no such directory: {}", p.display()))
}

fn default_root() -> Result<PathBuf, String> {
    for candidate in ["rust/src", "src"] {
        let p = PathBuf::from(candidate);
        if p.is_dir() {
            return Ok(p);
        }
    }
    Err("no rust/src or src here; pass a path".to_string())
}

fn print_human(report: &Report) {
    for f in &report.findings {
        let level = match f.level {
            Level::Deny => "deny",
            Level::Warn => "warn",
        };
        println!("{}:{}: [{}/{}] {}", f.path, f.line, level, f.lint, f.message);
    }
    println!(
        "fclint: {} finding(s), {} suppressed, {} file(s) scanned",
        report.findings.len(),
        report.suppressed,
        report.files_scanned
    );
}

fn to_json(report: &Report) -> Json {
    let findings = report.findings.iter().map(|f| {
        let mut o = Json::obj();
        o.set("lint", json::s(f.lint));
        o.set("level", json::s(if f.level == Level::Deny { "deny" } else { "warn" }));
        o.set("path", json::s(&f.path));
        o.set("line", json::num(f.line as f64));
        o.set("message", json::s(&f.message));
        o
    });
    let mut out = Json::obj();
    out.set("findings", json::arr(findings));
    out.set("files_scanned", json::num(report.files_scanned as f64));
    out.set("suppressed", json::num(report.suppressed as f64));
    out.set("denies", json::num(report.denies() as f64));
    out
}
