//! `fastcaps` — CLI for the FastCaps reproduction.
//!
//! ```text
//! fastcaps report <table1|table2|table3|fig1|fig5|fig8|fig14|sparse|routing|all>
//! fastcaps simulate [--dataset mnist|fmnist] [--config original|pruned|proposed] [--frames N]
//! fastcaps accumulate [--dataset mnist|fmnist] [--arch pruned|full]
//!                   [--weights FILE.fcw] [--frames N] [--out FILE.fcw]
//!                     # offline accumulation pass: bake per-class mean
//!                     # coupling coefficients into the .fcw sidecar
//! fastcaps serve    [--backend oracle|oracle-sparse|sim|sim-sparse|pjrt]
//!                   [--model capsnet-mnist-pruned] [--dataset mnist|fmnist]
//!                   [--routing-mode iterative[:N]|accumulated] [--workers N]
//!                   [--replicas N] [--max-queue N]
//!                   [--requests N] [--clients K] [--artifacts DIR]
//!                   [--listen ADDR]   # TCP front-end; drains on a wire
//!                                     # Shutdown frame (bench-net --stop)
//!                   [--io-shards N]   # IO event-loop shards (default 2)
//!                   [--cache-entries N]  # content-addressed response cache
//!                                        # (default 4096 with --listen, else 0)
//! fastcaps bench-net --addr ADDR [--clients K] [--requests N]
//!                   [--window W] [--dataset mnist|fmnist] [--stop]
//!                   [--wire v1|v2]  # protocol dialect (default v2: tagged,
//!                                   # out-of-order completion)
//!                   [--dup-rate P] [--dup-pool N]  # P of traffic drawn from a
//!                                                  # shared N-frame hot pool
//!                   [--duration SECS]  # soak mode: sustained load for SECS,
//!                                      # asserts flat server RSS + stable p99
//! fastcaps prune    [--dataset mnist|fmnist] [--weights FILE.fcw] [--method lakp|kp]
//!                   [--sparsity S] [--compile] [--serve]
//!                   [--backend oracle-sparse|sim-sparse] [--replicas N]
//!                   [--routing-mode iterative[:N]|accumulated] [--workers N]
//!                   [--requests N] [--clients K] [--cache-entries N]
//! fastcaps selftest
//! ```

use fastcaps::backend::{BackendConfig, BackendRegistry};
use fastcaps::cache::CacheConfig;
use fastcaps::config::SystemConfig;
use fastcaps::coordinator::net::{NetConfig, NetServer};
use fastcaps::coordinator::server::Server;
use fastcaps::data::Task;
use fastcaps::fpga::{power::PowerModel, resources, DeployedModel};
use fastcaps::util::cli::Args;
use fastcaps::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "report" => cmd_report(&args),
        "simulate" => cmd_simulate(&args),
        "accumulate" => cmd_accumulate(&args),
        "serve" => cmd_serve(&args),
        "bench-net" => cmd_bench_net(&args),
        "prune" => cmd_prune(&args),
        "selftest" => cmd_selftest(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "fastcaps — FastCaps (LAKP + routing-optimized CapsNet accelerator) reproduction\n\n\
         subcommands:\n\
         \x20 report <exp>   regenerate a paper table/figure\n\
         \x20                exps: table1 table2 table3 fig1 fig5 fig8 fig14\n\
         \x20                sparse (dense-vs-pruned modeled FPS/DDR/BRAM)\n\
         \x20                routing (iterative-vs-accumulated accuracy delta) all\n\
         \x20 simulate       run frames through the cycle-level accelerator simulator\n\
         \x20 accumulate     offline accumulation pass: run the iterative router\n\
         \x20                over a deterministic calibration set and bake the\n\
         \x20                per-class mean coupling coefficients into the .fcw\n\
         \x20                sidecar (serve --routing-mode accumulated picks it up)\n\
         \x20 serve          start the serving coordinator and drive a workload\n\
         \x20                backends: oracle (fp32 reference), oracle-sparse\n\
         \x20                (sparse-compiled pruned fp32), sim (FPGA\n\
         \x20                simulator, default), sim-sparse (FPGA simulator\n\
         \x20                over CSR survivors: pipelined timing +\n\
         \x20                compression), pjrt (AOT artifacts);\n\
         \x20                --replicas N scales the executor pool;\n\
         \x20                --routing-mode iterative[:N]|accumulated picks the\n\
         \x20                routing schedule (accumulated = zero routing\n\
         \x20                iterations, baked mean coefficients);\n\
         \x20                --workers N shards each batch over N cores\n\
         \x20                per replica (bit-identical to serial);\n\
         \x20                --listen ADDR serves the wire protocol over TCP\n\
         \x20                instead of driving in-process traffic (drains\n\
         \x20                gracefully on a wire Shutdown frame); the same\n\
         \x20                listener answers HEALTH/READY/METRICS probes\n\
         \x20                (also HTTP GET /healthz /readyz /metrics);\n\
         \x20                --io-shards N sets the IO event-loop shard\n\
         \x20                count (default 2);\n\
         \x20                --cache-entries N bounds the content-addressed\n\
         \x20                response cache (default 4096 with --listen,\n\
         \x20                0 = off otherwise)\n\
         \x20 bench-net      drive a listening server over TCP:\n\
         \x20                --addr HOST:PORT [--clients K] [--requests N]\n\
         \x20                [--window W pipelined depth] [--stop: ask the\n\
         \x20                server to drain and exit after the run]\n\
         \x20                [--wire v1|v2: protocol dialect, default v2\n\
         \x20                (tagged requests, out-of-order completion)]\n\
         \x20                [--dup-rate P: fraction of requests drawn from\n\
         \x20                a shared hot pool of --dup-pool N frames —\n\
         \x20                exercises the server-side inference cache]\n\
         \x20                [--duration SECS: soak mode — sustained load\n\
         \x20                for SECS seconds, sampling the server's\n\
         \x20                fastcaps_rss_bytes gauge per window and\n\
         \x20                asserting flat RSS + stable client p99]\n\
         \x20 prune          LAKP/KP-prune weights, print compression;\n\
         \x20                --compile packs survivors into the sparse\n\
         \x20                execution path (CSR / Index-Control layout),\n\
         \x20                --serve then serves the compiled model\n\
         \x20                (--backend oracle-sparse|sim-sparse)\n\
         \x20 selftest       quick end-to-end sanity checks\n"
    );
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let dir = artifacts_dir(args);
    match which {
        "fig1" => print!("{}", fastcaps::report::fig1()),
        "table2" => print!("{}", fastcaps::report::table2()),
        "table3" => print!("{}", fastcaps::report::table3()),
        "fig8" => print!("{}", fastcaps::report::fig8()),
        "fig14" => print!("{}", fastcaps::report::fig14()),
        "routing" => print!("{}", fastcaps::report::routing()),
        "ablation" => print!("{}", fastcaps::report::ablation()),
        "sparse" => print!("{}", fastcaps::report::sparse()),
        "table1" => print!("{}", fastcaps::report::table1(&dir)?),
        "fig5" => print!("{}", fastcaps::report::fig5(&dir)?),
        "all" => {
            print!("{}", fastcaps::report::all_simulated());
            match fastcaps::report::table1(&dir) {
                Ok(s) => print!("\n{s}"),
                Err(e) => println!("\n[table1 skipped: {e}]"),
            }
            match fastcaps::report::fig5(&dir) {
                Ok(s) => print!("\n{s}"),
                Err(e) => println!("[fig5 skipped: {e}]"),
            }
        }
        other => anyhow::bail!("unknown report '{other}'"),
    }
    Ok(())
}

fn system_config(args: &Args) -> SystemConfig {
    let dataset = args.get_or("dataset", "mnist");
    match args.get_or("config", "proposed") {
        "original" => SystemConfig::original(dataset),
        "pruned" => SystemConfig::pruned(dataset),
        _ => SystemConfig::proposed(dataset),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = system_config(args);
    let frames = args.get_usize("frames", 4);
    let seed = args.get_u64("seed", 7);
    let task = fastcaps::data::Task::parse(args.get_or("dataset", "mnist"))
        .unwrap_or(fastcaps::data::Task::Digits);
    println!(
        "simulating {} frames on {} ({})",
        frames,
        cfg.model.name,
        if cfg.options.optimized_routing {
            "optimized routing"
        } else {
            "baseline routing"
        }
    );
    let model = DeployedModel::synthetic(&cfg, seed);
    let data = fastcaps::data::generate(task, frames, seed);
    let pm = PowerModel::default();
    let u = resources::estimate(&cfg);
    // The batch-native path: one scratch across all frames, cycle model
    // priced once; per-frame values are bitwise what run_frame computes.
    let mut scratch = fastcaps::fpga::BatchScratch::new();
    let out = model.run_batch(&data.images, &mut scratch)?;
    let t = &out.timing.frame;
    for i in 0..data.images.len() {
        println!(
            "frame {i}: label={} predicted={} top-length={:.3} cycles={} ({:.2} ms)",
            data.labels[i],
            out.classes[i],
            out.lengths[i].iter().cloned().fold(0.0f32, f32::max),
            fastcaps::util::fmt_thousands(t.total_cycles()),
            t.latency_s() * 1e3,
        );
    }
    println!(
        "\nsingle-frame: {:.1} FPS, {:.1} FPJ, {:.3} ms/frame  (weights are random — \
         predictions are not meaningful, timing is)",
        t.fps(),
        pm.fpj(t.fps(), &u, !cfg.is_pruned()),
        t.latency_s() * 1e3
    );
    println!(
        "pipelined:    {:.1} FPS steady-state ({} cycles/frame initiation interval), \
         batch of {} in {:.3} ms ({:.1} FPS effective)",
        out.timing.steady_state_fps(),
        fastcaps::util::fmt_thousands(out.timing.initiation_cycles()),
        out.timing.batch,
        out.timing.latency_s() * 1e3,
        out.timing.batch_fps(),
    );
    Ok(())
}

/// `fastcaps accumulate`: the offline accumulation pass. Runs the
/// iterative router over the deterministic calibration set (same seed
/// the backend factories self-calibrate with), averages the coupling
/// coefficients per (capsule, class), and writes them back into the
/// `.fcw` file as the `acc_coupling` sidecar tensor — `serve
/// --routing-mode accumulated` then loads them instead of
/// re-calibrating at every replica boot.
fn cmd_accumulate(args: &Args) -> Result<()> {
    use fastcaps::capsnet::{weights::Weights, CapsNet};

    let raw_dataset = args.get_or("dataset", "mnist");
    let task = Task::parse(raw_dataset).ok_or_else(|| {
        anyhow::anyhow!("unknown dataset '{raw_dataset}' (expected mnist|fmnist)")
    })?;
    let dataset = match task {
        Task::Digits => "mnist",
        Task::Garments => "fmnist",
    };
    // `pruned` matches the oracle/sim presets' weights file; `full`
    // matches the prune-at-deploy backends' `weights-<dataset>-full.fcw`.
    let arch_kind = args.get_or("arch", "pruned").to_string();
    let (arch, default_file) = match arch_kind.as_str() {
        "full" => (
            fastcaps::config::CapsNetConfig::paper_full(&format!("capsnet-{dataset}")),
            format!("weights-{dataset}-full.fcw"),
        ),
        "pruned" => (
            if task == Task::Garments {
                fastcaps::config::CapsNetConfig::paper_pruned_fmnist()
            } else {
                fastcaps::config::CapsNetConfig::paper_pruned_mnist()
            },
            format!("weights-{dataset}.fcw"),
        ),
        other => anyhow::bail!("unknown --arch '{other}' (expected pruned|full)"),
    };
    let path = match args.get("weights") {
        Some(p) => PathBuf::from(p),
        None => artifacts_dir(args).join(default_file),
    };
    let weights = if path.exists() {
        let w = Weights::load(&path)?;
        w.validate(&arch)?;
        w
    } else {
        println!(
            "(no weights at {}; using seeded random weights — coefficients are \
             structurally valid but not meaningful)",
            path.display()
        );
        Weights::random(&arch, &mut fastcaps::util::rng::Rng::new(args.get_u64("seed", 7)))
    };
    let net = CapsNet {
        config: arch,
        weights,
    };

    let frames = args.get_usize("frames", fastcaps::backend::CALIBRATION_FRAMES);
    // Fixed seed: every accumulation of the same weights produces the
    // same sidecar bits (and matches what a factory self-calibrates to).
    let images = fastcaps::data::generate(task, frames, 0xacc0).images;
    let iters = net.config.routing_iters;
    println!(
        "accumulating over {frames} calibration frames on {} \
         (iterative({iters}) → per-class mean coupling)",
        net.config.name,
    );
    let coupling = net.accumulate_coupling(&images)?;

    let n_caps = net.config.num_primary_caps();
    let n_classes = net.config.num_classes;
    // Per-class coupling mass: softmax columns each sum to ~n_caps/n_classes
    // under uniform routing; skew shows which classes dominate agreement.
    for j in 0..n_classes {
        let mass: f32 = (0..n_caps).map(|i| coupling[i * n_classes + j]).sum();
        print!("  class {j}: {:.4}", mass / n_caps as f32);
        if (j + 1) % 5 == 0 {
            println!();
        }
    }
    if n_classes % 5 != 0 {
        println!();
    }
    println!(
        "coupling: {n_caps}x{n_classes} f32 ({} KB on-chip), fingerprint {:#018x}",
        (n_caps * n_classes * 2) / 1024, // Q4.12 residency, 2 B/coefficient
        fastcaps::backend::coupling_fingerprint(&coupling),
    );

    let out = args.get("out").map(PathBuf::from).unwrap_or(path);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let sidecar = fastcaps::tensor::Tensor::from_vec(&[n_caps, n_classes], coupling)?;
    net.weights.save_with_coupling(&out, Some(&sidecar))?;
    println!(
        "wrote weights + acc_coupling sidecar to {} \
         (serve with: fastcaps serve --routing-mode accumulated)",
        out.display()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let backend_kind = args.get_or("backend", "sim").to_string();
    let n_requests = args.get_usize("requests", 64);
    let n_clients = args.get_usize("clients", 4).max(1);
    let max_wait = Duration::from_millis(args.get_u64("max-wait-ms", 5));

    // The client workload must match what the backend serves: an explicit
    // --dataset wins (any Task alias, e.g. "garments" ≡ "fmnist"),
    // otherwise the model name decides (an F-MNIST model used to be
    // driven with digit traffic here). Everything downstream uses the
    // canonical dataset name, so alias and model stay consistent.
    let explicit_model = args.get("model").map(|s| s.to_string());
    let task = match args.get("dataset") {
        Some(d) => Task::parse(d)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset '{d}' (expected mnist|fmnist)"))?,
        None => match &explicit_model {
            Some(m) if m.contains("fmnist") => Task::Garments,
            _ => Task::Digits,
        },
    };
    let dataset = match task {
        Task::Digits => "mnist".to_string(),
        Task::Garments => "fmnist".to_string(),
    };
    let model_name = explicit_model.unwrap_or_else(|| match task {
        Task::Digits => "capsnet-mnist-pruned".to_string(),
        Task::Garments => "capsnet-fmnist-pruned".to_string(),
    });

    // Routing override: `--routing-mode accumulated` serves the
    // zero-iteration fast path (the factory loads `fastcaps accumulate`'s
    // sidecar coefficients, or self-calibrates); `iterative[:N]` pins an
    // explicit schedule. No flag = the model config's schedule.
    let routing = match args.get("routing-mode") {
        Some(s) => Some(fastcaps::routing::RoutingMode::parse(s, 3).ok_or_else(|| {
            anyhow::anyhow!("unknown --routing-mode '{s}' (expected iterative[:N]|accumulated)")
        })?),
        None => None,
    };
    let workers = args.get_usize("workers", 1).max(1);

    let bcfg = BackendConfig {
        dataset: dataset.clone(),
        model: model_name.clone(),
        variant: args.get_or("config", "proposed").to_string(),
        artifacts: artifacts_dir(args),
        weights: None,
        seed: args.get_u64("seed", 7),
        routing,
        workers,
    };
    // Content-addressed cache: on by default for the TCP path (real
    // wire traffic repeats — retries, duplicated sensors, hot classes),
    // opt-in for the in-process workload (its generated frames are all
    // distinct, so a cache would only add lookups). --cache-entries 0
    // disables it explicitly.
    let cache_entries = args.get_usize(
        "cache-entries",
        if args.get("listen").is_some() { 4096 } else { 0 },
    );
    let registry = Arc::new(BackendRegistry::with_defaults());
    let kind = backend_kind.clone();
    let server = Server::builder(move || registry.build(&kind, &bcfg))
        .replicas(args.get_usize("replicas", 1))
        .max_wait(max_wait)
        .max_queue_depth(args.get_usize("max-queue", 1024))
        .cache(CacheConfig::with_entries(cache_entries))
        .start();
    if let Some(e) = server.init_error() {
        anyhow::bail!("starting backend '{backend_kind}': {e}");
    }
    let spec = server.spec().expect("init succeeded").clone();

    if args.get("listen").is_none() {
        println!(
            "serving {n_requests} requests from {n_clients} client threads \
             (backend={backend_kind}, model={}, dataset={dataset}, \
             replicas={}, buckets={:?}, {})",
            spec.model,
            server.pool_size(),
            spec.batch_buckets,
            spec.routing_summary(),
        );
    } else {
        println!(
            "serving over TCP (backend={backend_kind}, model={}, dataset={dataset}, \
             replicas={}, buckets={:?}, {})",
            spec.model,
            server.pool_size(),
            spec.batch_buckets,
            spec.routing_summary(),
        );
    }
    if let Some(c) = &spec.compression {
        println!(
            "each replica executes {}/{} conv kernels ({:.2}% pruned, {} B index memory)",
            c.survived_kernels,
            c.total_kernels,
            c.pruned_pct(),
            c.index_bytes,
        );
    }
    if cache_entries > 0 {
        println!(
            "inference cache: {cache_entries} entries, keyed on input bits + \
             deployment fingerprint {:016x}",
            spec.fingerprint,
        );
    }
    if let Some(listen) = args.get("listen") {
        // Socket front-end: serve the wire protocol instead of driving
        // in-process traffic. Blocks until a client requests a graceful
        // drain (`fastcaps bench-net --addr ... --stop`), then finishes
        // in-flight work and exits 0 — CI asserts exactly that.
        let cfg = NetConfig {
            io_shards: args.get_usize("io-shards", 2).max(1),
            ..NetConfig::default()
        };
        let net = NetServer::bind_with(listen, server, cfg)
            .map_err(|e| anyhow::anyhow!("starting TCP front-end on {listen}: {e}"))?;
        println!(
            "listening on {} (wire=v2 shards={} input {}x{}x{} f32; stop with: \
             fastcaps bench-net --addr {} --requests 0 --stop)",
            net.local_addr(),
            net.io_shards(),
            spec.input_shape.0,
            spec.input_shape.1,
            spec.input_shape.2,
            net.local_addr(),
        );
        net.wait_shutdown_requested();
        println!("shutdown requested over the wire; draining");
        let m = net.shutdown();
        println!("{}", m.summary());
        return Ok(());
    }
    drive_workload(server, task, n_requests, n_clients);
    Ok(())
}

/// `fastcaps bench-net`: open-loop load generator for a listening
/// `fastcaps serve --listen` process. Each client thread pipelines up to
/// `--window` requests on its own connection and measures end-to-end
/// (client-observed) latency; the report has the same shape as
/// `drive_workload`'s so in-process and socket numbers read side by
/// side.
fn cmd_bench_net(args: &Args) -> Result<()> {
    use fastcaps::coordinator::metrics::Metrics;
    use fastcaps::coordinator::net::Connection;
    use fastcaps::coordinator::wire::ErrorCode;
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;
    use std::time::Instant;

    /// Receive one response (any tag — v2 servers complete out of
    /// order), pricing it against its own send time. Typed server
    /// rejections are counted, not fatal; transport/protocol faults are.
    fn drain_one(
        client: &mut Connection,
        sent: &mut HashMap<u64, Instant>,
        local: &mut Metrics,
        rejected: &AtomicU64,
    ) -> Result<()> {
        match client.recv() {
            Ok((tag, _resp)) => {
                let t = sent
                    .remove(&tag)
                    .ok_or_else(|| anyhow::anyhow!("response for unknown tag {tag}"))?;
                local.record(t.elapsed().as_micros() as u64);
            }
            Err(e) if matches!(e.code, ErrorCode::Io | ErrorCode::Protocol) => {
                anyhow::bail!("recv: {e}");
            }
            Err(e) => {
                let tag = e
                    .tag
                    .ok_or_else(|| anyhow::anyhow!("connection-level server error: {e}"))?;
                anyhow::ensure!(sent.remove(&tag).is_some(), "rejection for unknown tag {tag}");
                rejected.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("bench-net requires --addr HOST:PORT"))?
        .to_string();
    let n_requests = args.get_usize("requests", 256);
    let n_clients = args.get_usize("clients", 4).max(1);
    let window = args.get_usize("window", 16).max(1);
    let wire_version = match args.get_or("wire", "v2") {
        "v1" | "1" => fastcaps::coordinator::wire::VERSION,
        "v2" | "2" => fastcaps::coordinator::wire::V2,
        other => anyhow::bail!("unknown --wire '{other}' (expected v1|v2)"),
    };
    let task = Task::parse(args.get_or("dataset", "mnist"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset (expected mnist|fmnist)"))?;
    // Duplicate traffic: with probability --dup-rate each request is
    // drawn from a --dup-pool-sized hot set shared by ALL clients (fixed
    // seed), instead of the client's own unique frames — the workload
    // that exercises the server's content-addressed cache and
    // single-flight coalescing across connections.
    let dup_rate = args.get_f64("dup-rate", 0.0).clamp(0.0, 1.0);
    let dup_pool_size = args.get_usize("dup-pool", 8).max(1);
    let dup_pool = (dup_rate > 0.0).then(|| fastcaps::data::generate(task, dup_pool_size, 9999));

    let soak_secs = args.get_f64("duration", 0.0);
    if soak_secs > 0.0 {
        bench_net_soak(&addr, n_clients, window, wire_version, task, soak_secs)?;
    }

    let metrics = Mutex::new(Metrics::default());
    let rejected = AtomicU64::new(0);
    let t0 = Instant::now();
    if soak_secs <= 0.0 && n_requests > 0 {
        if dup_rate > 0.0 {
            println!(
                "bench-net: {n_requests} requests from {n_clients} pipelined clients \
                 (window {window}, wire v{wire_version}, {:.0}% duplicates from a \
                 {dup_pool_size}-frame hot pool) against {addr}",
                dup_rate * 100.0,
            );
        } else {
            println!(
                "bench-net: {n_requests} requests from {n_clients} pipelined clients \
                 (window {window}, wire v{wire_version}) against {addr}"
            );
        }
        std::thread::scope(|scope| -> Result<()> {
            let mut workers = Vec::new();
            for c in 0..n_clients {
                let addr = addr.as_str();
                let metrics = &metrics;
                let rejected = &rejected;
                let dup_pool = dup_pool.as_ref();
                let share = n_requests / n_clients + usize::from(c < n_requests % n_clients);
                workers.push(scope.spawn(move || -> Result<()> {
                    let mut client = Connection::connect_with(addr, wire_version)
                        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
                    // A wedged server must fail the bench, not hang it
                    // (CI waits on this process).
                    client
                        .set_read_timeout(Some(Duration::from_secs(30)))
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    let data = fastcaps::data::generate(task, share, c as u64);
                    let mut rng = fastcaps::util::rng::Rng::new(0xBE7 + c as u64);
                    // Tag-keyed send times: v2 servers may complete out
                    // of order, and each response prices against its own
                    // request regardless of arrival order.
                    let mut sent: HashMap<u64, Instant> = HashMap::with_capacity(window);
                    let mut local = Metrics::default();
                    for img in &data.images {
                        let img = match dup_pool {
                            Some(pool) if rng.f64() < dup_rate => {
                                &pool.images[rng.below(pool.images.len())]
                            }
                            _ => img,
                        };
                        if sent.len() == window {
                            drain_one(&mut client, &mut sent, &mut local, rejected)?;
                        }
                        let t = Instant::now();
                        let tag = client
                            .submit(img)
                            .map_err(|e| anyhow::anyhow!("send: {e}"))?;
                        sent.insert(tag, t);
                    }
                    while !sent.is_empty() {
                        drain_one(&mut client, &mut sent, &mut local, rejected)?;
                    }
                    let mut m = metrics.lock().unwrap();
                    m.requests += local.requests;
                    m.latency.merge(&local.latency);
                    Ok(())
                }));
            }
            for w in workers {
                w.join().expect("bench-net client thread panicked")?;
            }
            Ok(())
        })?;
        let wall = t0.elapsed();
        let m = metrics.into_inner().unwrap();
        let rej = rejected.load(Ordering::Relaxed);
        println!(
            "requests={} rejected={rej} latency(mean={:.0}us p50={}us p99={}us max={}us)",
            m.requests,
            m.latency.mean_us(),
            m.latency.percentile_us(50.0),
            m.latency.percentile_us(99.0),
            m.latency.max_us(),
        );
        println!(
            "wall: {:.2}s  end-to-end throughput: {:.1} req/s",
            wall.as_secs_f64(),
            m.requests as f64 / wall.as_secs_f64()
        );
        anyhow::ensure!(
            m.requests + rej == n_requests as u64,
            "response accounting broken: {} ok + {rej} rejected != {n_requests}",
            m.requests
        );
    }

    if args.flag("stop") {
        let client = Connection::connect_with(&addr, wire_version)
            .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
        // Bound the wait for the ack the same way: a server that never
        // acks is a failure to report, not a hang.
        client
            .set_read_timeout(Some(Duration::from_secs(30)))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        client
            .shutdown_server()
            .map_err(|e| anyhow::anyhow!("shutdown: {e}"))?;
        println!("server acknowledged shutdown; draining");
    }
    Ok(())
}

/// `bench-net --duration SECS`: sustained closed-loop load against a
/// listening server, chopped into fixed windows. Per window it records
/// the client-observed p99 and samples the server's
/// `fastcaps_rss_bytes` gauge over the plaintext `METRICS` probe, then
/// asserts the server's memory stays flat (no per-frame leak — the
/// scratch-reuse/zero-alloc steady state) and the p99 stays stable
/// (no drift as the run ages). CI runs this at `--duration 5`; locally
/// 60s is a more convincing soak.
fn bench_net_soak(
    addr: &str,
    n_clients: usize,
    window: usize,
    wire_version: u8,
    task: Task,
    secs: f64,
) -> Result<()> {
    use fastcaps::coordinator::metrics::Metrics;
    use fastcaps::coordinator::net::Connection;
    use fastcaps::coordinator::wire::ErrorCode;
    use std::collections::HashMap;
    use std::io::{Read, Write};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;
    use std::time::Instant;

    /// Scrape `fastcaps_rss_bytes` over the plaintext probe sidecar.
    fn probe_rss(addr: &str) -> Result<u64> {
        let mut s = std::net::TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("probe connect {addr}: {e}"))?;
        s.set_read_timeout(Some(Duration::from_secs(10)))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        s.write_all(b"METRICS\n")
            .map_err(|e| anyhow::anyhow!("probe send: {e}"))?;
        let mut body = String::new();
        s.read_to_string(&mut body)
            .map_err(|e| anyhow::anyhow!("probe read: {e}"))?;
        for line in body.lines() {
            if let Some(v) = line.strip_prefix("fastcaps_rss_bytes ") {
                return Ok(v.trim().parse().unwrap_or(0));
            }
        }
        anyhow::bail!("METRICS reply has no fastcaps_rss_bytes gauge");
    }

    const WINDOWS: usize = 5;
    let win_len = Duration::from_secs_f64(secs / WINDOWS as f64);
    let stop = AtomicBool::new(false);
    let per_window: Mutex<Vec<Metrics>> =
        Mutex::new((0..WINDOWS).map(|_| Metrics::default()).collect());
    let t0 = Instant::now();
    println!(
        "bench-net soak: {n_clients} clients for {secs:.0}s \
         ({WINDOWS} windows of {:.1}s, window depth {window}, wire v{wire_version}) \
         against {addr}",
        win_len.as_secs_f64(),
    );

    let rss_samples = std::thread::scope(|scope| -> Result<Vec<u64>> {
        let mut workers = Vec::new();
        for c in 0..n_clients {
            let stop = &stop;
            let per_window = &per_window;
            workers.push(scope.spawn(move || -> Result<()> {
                let mut client = Connection::connect_with(addr, wire_version)
                    .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
                client
                    .set_read_timeout(Some(Duration::from_secs(30)))
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                // A small per-client frame pool, cycled for the whole
                // soak — steady-state traffic, not a growing working set.
                let data = fastcaps::data::generate(task, 32, 0x50AC + c as u64);
                let mut local: Vec<Metrics> =
                    (0..WINDOWS).map(|_| Metrics::default()).collect();
                let mut sent: HashMap<u64, Instant> = HashMap::with_capacity(window);
                let mut drain_one = |client: &mut Connection,
                                     sent: &mut HashMap<u64, Instant>,
                                     local: &mut [Metrics]|
                 -> Result<()> {
                    match client.recv() {
                        Ok((tag, _resp)) => {
                            let t = sent.remove(&tag).ok_or_else(|| {
                                anyhow::anyhow!("response for unknown tag {tag}")
                            })?;
                            let wi = ((t0.elapsed().as_secs_f64()
                                / win_len.as_secs_f64())
                                as usize)
                                .min(WINDOWS - 1);
                            local[wi].record(t.elapsed().as_micros() as u64);
                        }
                        Err(e) if matches!(e.code, ErrorCode::Io | ErrorCode::Protocol) => {
                            anyhow::bail!("recv: {e}");
                        }
                        Err(e) => {
                            // Typed rejection (queue full etc.): drop the
                            // sample, keep soaking.
                            let tag = e.tag.ok_or_else(|| {
                                anyhow::anyhow!("connection-level server error: {e}")
                            })?;
                            sent.remove(&tag);
                        }
                    }
                    Ok(())
                };
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    if sent.len() == window {
                        drain_one(&mut client, &mut sent, &mut local)?;
                    }
                    let img = &data.images[i % data.images.len()];
                    i += 1;
                    let t = Instant::now();
                    let tag = client
                        .submit(img)
                        .map_err(|e| anyhow::anyhow!("send: {e}"))?;
                    sent.insert(tag, t);
                }
                while !sent.is_empty() {
                    drain_one(&mut client, &mut sent, &mut local)?;
                }
                let mut shared = per_window.lock().unwrap();
                for (g, l) in shared.iter_mut().zip(&local) {
                    g.requests += l.requests;
                    g.latency.merge(&l.latency);
                }
                Ok(())
            }));
        }
        // The main thread samples the server's RSS at each window edge.
        let mut rss = Vec::with_capacity(WINDOWS);
        for _ in 0..WINDOWS {
            std::thread::sleep(win_len);
            rss.push(probe_rss(addr)?);
        }
        stop.store(true, Ordering::Relaxed);
        for w in workers {
            w.join().expect("soak client thread panicked")?;
        }
        Ok(rss)
    })?;

    let windows = per_window.into_inner().unwrap();
    let mut p99s = Vec::new();
    for (i, (m, &rss)) in windows.iter().zip(&rss_samples).enumerate() {
        let p99 = m.latency.percentile_us(99.0);
        println!(
            "window {i}: requests={} p99={p99}us rss={:.1}MiB",
            m.requests,
            rss as f64 / (1024.0 * 1024.0),
        );
        if m.requests > 0 {
            p99s.push(p99);
        }
    }
    anyhow::ensure!(
        !p99s.is_empty(),
        "soak completed zero requests — server not serving?"
    );

    // Flat RSS: the last sample may exceed the first only by a bounded
    // slack (allocator/cache warm-up), never grow per-frame. 0 means the
    // platform has no procfs — nothing to assert.
    let (first_rss, last_rss) = (rss_samples[0], *rss_samples.last().unwrap());
    if first_rss > 0 {
        let budget = first_rss + first_rss / 4 + (64 << 20);
        anyhow::ensure!(
            last_rss <= budget,
            "server RSS grew {first_rss} -> {last_rss} bytes over the soak \
             (budget {budget}): per-frame leak?"
        );
    } else {
        println!("rss gauge unavailable on this platform; skipping flatness assert");
    }

    // Stable p99: no window may degrade an order of magnitude past the
    // best window (generous — CI machines jitter, leaks don't hide).
    let best = p99s.iter().copied().min().unwrap().max(1);
    let worst = p99s.iter().copied().max().unwrap();
    anyhow::ensure!(
        worst <= best.saturating_mul(10),
        "p99 drifted over the soak: best window {best}us, worst {worst}us"
    );
    println!(
        "soak ok: rss {:.1} -> {:.1} MiB, p99 {best}..{worst}us over {WINDOWS} windows",
        first_rss as f64 / (1024.0 * 1024.0),
        last_rss as f64 / (1024.0 * 1024.0),
    );
    Ok(())
}

/// Drive `n_requests` generated frames from `n_clients` client threads
/// through a running server, then shut it down and print the metrics
/// summary. Shared by `serve` and the `prune --compile --serve` flow.
fn drive_workload(server: Server, task: Task, n_requests: usize, n_clients: usize) {
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let server = &server;
            // Distribute the remainder so all n_requests are sent, not
            // just n_clients * floor(n/k).
            let share = n_requests / n_clients + usize::from(c < n_requests % n_clients);
            scope.spawn(move || {
                let data = fastcaps::data::generate(task, share, c as u64);
                for img in data.images {
                    let _ = server.classify(img);
                }
            });
        }
    });
    let wall = t0.elapsed();
    let m = server.shutdown();
    println!("{}", m.summary());
    println!(
        "wall: {:.2}s  end-to-end throughput: {:.1} req/s",
        wall.as_secs_f64(),
        m.requests as f64 / wall.as_secs_f64()
    );
}

fn cmd_prune(args: &Args) -> Result<()> {
    use fastcaps::capsnet::{CapsNet, CompiledCapsNet};
    use fastcaps::pruning::{kp, lakp, AdjacencyNorms, KernelMask, NetworkMasks};

    let raw_dataset = args.get_or("dataset", "mnist");
    let task = Task::parse(raw_dataset).ok_or_else(|| {
        anyhow::anyhow!("unknown dataset '{raw_dataset}' (expected mnist|fmnist)")
    })?;
    let dataset = match task {
        Task::Digits => "mnist",
        Task::Garments => "fmnist",
    };
    let cfg = fastcaps::config::CapsNetConfig::paper_full(&format!("capsnet-{dataset}"));
    let sparsity = args.get_f64("sparsity", 0.9);
    let method = args.get_or("method", "lakp").to_string();
    let weights = match args.get("weights") {
        Some(p) => fastcaps::capsnet::weights::Weights::load(Path::new(p))?,
        None => {
            println!("(no --weights given; using random weights for the demo)");
            let mut rng = fastcaps::util::rng::Rng::new(1);
            fastcaps::capsnet::weights::Weights::random(&cfg, &mut rng)
        }
    };
    let adj_pc = AdjacencyNorms {
        prev: AdjacencyNorms::prev_from_conv(&weights.conv1_w),
        next: AdjacencyNorms::next_from_digitcaps(&weights.w_ij, cfg.pc_types, cfg.pc_dim),
    };
    let result = match method.as_str() {
        "kp" => kp::prune_layer(&weights.pc_w, sparsity),
        _ => lakp::prune_layer(&weights.pc_w, &adj_pc, sparsity),
    };
    let types = fastcaps::pruning::surviving_capsule_types(&result.mask, cfg.pc_dim);
    let (h2, w2) = cfg.pc_out();
    println!(
        "{method} @ sparsity {sparsity}: {} / {} kernels survive \
         ({} capsule types → {} primary capsules; index memory {} B)",
        result.mask.survived(),
        result.mask.total(),
        types,
        types * h2 * w2,
        result.mask.index_bytes(),
    );

    // `--compile`/`--serve` are boolean, but the parser turns a flag
    // followed by a stray non-dash token into a key=value option —
    // `prune --serve mnist` would silently skip serving. Treat either
    // form as "set" so a trailing typo can't swallow the step.
    let flagged = |name: &str| args.flag(name) || args.get(name).is_some();
    if !flagged("compile") {
        // --serve depends on a compiled model; ignoring it silently
        // would look like a successful serve that never happened.
        anyhow::ensure!(
            !flagged("serve"),
            "--serve requires --compile (serve runs the sparse-compiled model)"
        );
        return Ok(());
    }

    // prune → compile: pack the survivors into the CSR / Index-Control
    // layout and execute only alive kernels, bit-exact to masked-dense.
    let masks = NetworkMasks {
        conv1: KernelMask::all_alive(cfg.conv1_ch, cfg.input.0),
        pc: result.mask.clone(),
    };
    let net = CapsNet {
        config: cfg.clone(),
        weights,
    };
    let mut compiled = CompiledCapsNet::compile(&net, &masks)?;
    let stats = compiled.stats();
    println!(
        "compiled: {} / {} kernels packed ({:.2}% pruned, {} B index memory)",
        stats.survived_kernels,
        stats.total_kernels,
        stats.pruned_pct(),
        stats.index_bytes,
    );

    // Bit-exactness spot check + dense-vs-sparse wall-clock on one frame.
    let dense = net.masked(&masks);
    let frame = fastcaps::data::generate(task, 1, args.get_u64("seed", 7))
        .images
        .remove(0);
    let t0 = std::time::Instant::now();
    let want = dense.forward(&frame)?;
    let dense_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = std::time::Instant::now();
    let got = compiled.forward(&frame)?;
    let sparse_ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(
        got.routing.v == want.routing.v && got.primary_caps == want.primary_caps,
        "compiled forward diverged from masked-dense reference"
    );
    println!(
        "bit-exact vs masked-dense ✓   dense {dense_ms:.2} ms/frame, \
         sparse {sparse_ms:.2} ms/frame ({:.1}x)",
        dense_ms / sparse_ms.max(1e-9),
    );

    if !flagged("serve") {
        return Ok(());
    }

    // prune → compile → serve: replicas of the pruned model behind the
    // coordinator, driven with generated traffic. `--backend` picks the
    // executor: the sparse-compiled fp32 oracle (default) or the
    // fixed-point FPGA simulator deployed over the same CSR survivors.
    let n_requests = args.get_usize("requests", 64);
    let n_clients = args.get_usize("clients", 4).max(1);
    let backend_kind = args.get_or("backend", "oracle-sparse").to_string();
    let replicas = args.get_usize("replicas", 2);
    let max_wait = Duration::from_millis(args.get_u64("max-wait-ms", 5));
    let max_queue = args.get_usize("max-queue", 1024);
    // Routing fast path + per-replica batch sharding, same flags as
    // `serve`. Accumulated mode self-calibrates on the deterministic
    // calibration set through the freshly pruned model — a hand-pruned
    // deployment has no sidecar to load.
    let routing = match args.get("routing-mode") {
        Some(s) => Some(
            fastcaps::routing::RoutingMode::parse(s, cfg.routing_iters).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown --routing-mode '{s}' (expected iterative[:N]|accumulated)"
                )
            })?,
        ),
        None => None,
    };
    let workers = args.get_usize("workers", 1).max(1);
    let calib = || {
        fastcaps::data::generate(task, fastcaps::backend::CALIBRATION_FRAMES, 0xacc0).images
    };
    // Opt-in cache, like in-process `serve`. Each prune→compile→serve
    // deployment carries its own weight/mask fingerprint, so re-pruning
    // at different survivor counts changes every cache key — a fresh
    // deployment can never serve the previous one's responses.
    let cache = CacheConfig::with_entries(args.get_usize("cache-entries", 0));
    let server = match backend_kind.as_str() {
        "sim-sparse" => {
            let sys = SystemConfig::masked_with_counts(
                cfg.clone(),
                masks.conv1.survived(),
                masks.pc.survived(),
            );
            let mut deployed = DeployedModel::new(sys, &net.weights, &masks.conv1, &masks.pc)?;
            if let Some(mode) = routing {
                if mode.is_accumulated() {
                    let coupling = deployed.accumulate_coupling(&calib())?;
                    deployed.bake_accumulated(&coupling)?;
                } else {
                    deployed.set_routing_mode(mode)?;
                }
            }
            let t = deployed.estimate_batch(8);
            println!(
                "deployed on the sparse FPGA datapath: modeled {:.1} FPS steady-state \
                 ({:.2} ms single-frame, DDR bytes/frame {})",
                t.steady_state_fps(),
                t.frame.latency_s() * 1e3,
                deployed.ddr_bytes(),
            );
            Server::builder(move || {
                Ok(Box::new(fastcaps::backend::SimSparseBackend::with_workers(
                    deployed.clone(),
                    workers,
                )) as Box<dyn fastcaps::backend::InferenceBackend>)
            })
            .replicas(replicas)
            .max_wait(max_wait)
            .max_queue_depth(max_queue)
            .cache(cache)
            .start()
        }
        "oracle-sparse" => {
            if let Some(mode) = routing {
                if mode.is_accumulated() {
                    let coupling = compiled.accumulate_coupling(&calib())?;
                    compiled.bake_accumulated(coupling)?;
                } else {
                    compiled.routing = mode;
                }
            }
            Server::builder(move || {
                Ok(Box::new(fastcaps::backend::SparseOracleBackend::with_workers(
                    compiled.clone(),
                    workers,
                )) as Box<dyn fastcaps::backend::InferenceBackend>)
            })
            .replicas(replicas)
            .max_wait(max_wait)
            .max_queue_depth(max_queue)
            .cache(cache)
            .start()
        }
        other => anyhow::bail!(
            "prune --serve runs the pruned model: \
             --backend oracle-sparse|sim-sparse (got '{other}')"
        ),
    };
    if let Some(e) = server.init_error() {
        anyhow::bail!("starting compiled backend: {e}");
    }
    let spec = server.spec().expect("init succeeded").clone();
    println!(
        "serving {n_requests} requests from {n_clients} client threads \
         (backend={}, model={}, replicas={}, {:.2}% kernels pruned, {})",
        spec.kind,
        spec.model,
        server.pool_size(),
        spec.compression.as_ref().map(|c| c.pruned_pct()).unwrap_or(0.0),
        spec.routing_summary(),
    );
    drive_workload(server, task, n_requests, n_clients);
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    // 1. Simulator throughput shape.
    let orig = DeployedModel::synthetic(&SystemConfig::original("mnist"), 7)
        .estimate_frame()
        .fps();
    let prop = DeployedModel::synthetic(&SystemConfig::proposed("mnist"), 7)
        .estimate_frame()
        .fps();
    println!("[1/5] simulator: original {orig:.1} FPS, proposed {prop:.1} FPS");
    anyhow::ensure!(prop > 100.0 * orig, "speedup shape broken");

    // 2. Fixed-point units.
    use fastcaps::fixed::{taylor, Q12};
    let x = Q12::from_f32(0.7);
    let e = taylor::exp_taylor_q12(x).to_f32();
    anyhow::ensure!((e - 0.7f32.exp()).abs() < 0.01, "taylor exp off: {e}");
    println!(
        "[2/5] fixed-point Taylor exp(0.7) = {e:.4} (want {:.4})",
        0.7f32.exp()
    );

    // 3. Sparse compile: LAKP masks → CSR packing, bit-exact forward.
    {
        use fastcaps::capsnet::{CapsNet, CompiledCapsNet};
        use fastcaps::pruning::NetworkMasks;
        let cfg = fastcaps::config::CapsNetConfig::tiny();
        let mut rng = fastcaps::util::rng::Rng::new(7);
        let net = CapsNet::random(cfg.clone(), &mut rng);
        let masks = NetworkMasks::lakp(&net.weights, &cfg, 12, 64);
        let compiled = CompiledCapsNet::compile(&net, &masks)?;
        let img = fastcaps::tensor::Tensor::randn(&[1, 20, 20], 0.4, &mut rng)
            .map(|v| v.abs().min(1.0));
        let want = net.masked(&masks).forward(&img)?;
        let got = compiled.forward(&img)?;
        anyhow::ensure!(
            got.routing.v == want.routing.v,
            "compiled forward diverged from masked-dense"
        );
        let stats = compiled.stats();
        println!(
            "[3/5] sparse compile: {}/{} kernels packed ({:.1}% pruned), bit-exact ✓",
            stats.survived_kernels,
            stats.total_kernels,
            stats.pruned_pct()
        );
    }

    // 4. Sparse FPGA datapath: the CSR-packed DeployedModel must be
    //    bitwise identical to deploying the masked (zeroed) tensor
    //    densely, on a random kernel mask — the release-binary proof of
    //    the sparsity-aware Q-format datapath.
    {
        use fastcaps::capsnet::weights::Weights;
        use fastcaps::pruning::KernelMask;
        let cfg = SystemConfig::proposed("mnist");
        let m = cfg.model.clone();
        let mut rng = fastcaps::util::rng::Rng::new(23);
        let weights = Weights::random(&m, &mut rng);
        let mut conv1_mask = KernelMask::all_alive(m.conv1_ch, m.input.0);
        let mut pc_mask = KernelMask::all_alive(m.pc_channels(), m.conv1_ch);
        for o in 0..conv1_mask.out_ch {
            for i in 0..conv1_mask.in_ch {
                if rng.below(4) == 0 {
                    conv1_mask.set(o, i, false);
                }
            }
        }
        for o in 0..pc_mask.out_ch {
            for i in 0..pc_mask.in_ch {
                if rng.below(3) == 0 {
                    pc_mask.set(o, i, false);
                }
            }
        }
        let sparse = DeployedModel::new(cfg.clone(), &weights, &conv1_mask, &pc_mask)?;
        let mut masked = weights.clone();
        conv1_mask.apply(&mut masked.conv1_w);
        pc_mask.apply(&mut masked.pc_w);
        let dense = DeployedModel::new(
            cfg.clone(),
            &masked,
            &KernelMask::all_alive(m.conv1_ch, m.input.0),
            &KernelMask::all_alive(m.pc_channels(), m.conv1_ch),
        )?;
        let img = fastcaps::data::generate(Task::Digits, 1, 5).images.remove(0);
        let (cs, ls, _) = sparse.run_frame(&img)?;
        let (cd, ld, _) = dense.run_frame(&img)?;
        anyhow::ensure!(
            cs == cd && ls == ld,
            "sparse sim diverged from masked-dense deployment"
        );
        let c = sparse.compression();
        println!(
            "[4/5] sim-sparse datapath: {}/{} kernels packed, \
             CSR ≡ masked-dense bitwise ✓",
            c.survived_kernels,
            c.total_kernels,
        );
    }

    // 5. PJRT runtime if artifacts exist (and the `pjrt` feature is in).
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        match fastcaps::runtime::Runtime::open(dir) {
            Ok(rt) => {
                let engine =
                    rt.engine("capsnet-mnist-pruned", 1, &dir.join("weights-mnist.fcw"))?;
                let img = fastcaps::data::generate(Task::Digits, 1, 3).images.remove(0);
                let lengths = engine.run_batch(&[img])?;
                println!("[5/5] PJRT lengths: {:?}", lengths[0]);
                anyhow::ensure!(lengths[0].len() == 10);
            }
            Err(e) => println!("[5/5] skipped PJRT ({e})"),
        }
    } else {
        println!("[5/5] skipped PJRT (no artifacts/ — run `make artifacts`)");
    }
    println!("selftest OK");
    Ok(())
}
