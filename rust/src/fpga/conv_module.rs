//! Convolution Module (Fig. 10a): index-controlled conv over surviving
//! kernels on the PE array, with joint functional (Q8.8) and timing
//! semantics.
//!
//! Timing model: the PE array iterates output positions; per position the
//! index FIFO streams surviving kernels, each contributing k×k MACs. The
//! inner loop pipelines at II=1 in the optimized schedule (II=2 when
//! resource pressure prevents full partitioning, as in the original
//! design). Activations write out through the output BRAM banks.

use super::index_control::IndexControl;
use super::pe::PeArray;
use crate::fixed::Q8;
use crate::tensor::Tensor;

/// Timing summary of one stage of the accelerator.
#[derive(Debug, Clone)]
pub struct StageTiming {
    pub name: String,
    pub cycles: u64,
    pub macs: u64,
    /// BRAM words moved (reads + writes) that are not hidden inside the
    /// PE-local register files.
    pub mem_words: u64,
}

/// One conv layer as deployed: 16-bit weights in a per-layer dynamic
/// fixed-point format (Q-CapsNets-style [25]: the fraction width is chosen
/// from the layer's weight range, so small-magnitude layers like
/// PrimaryCaps keep precision), plus the survivor index list.
#[derive(Debug, Clone)]
pub struct ConvModule {
    /// OIHW weight raw values at `Q(16-frac_w).frac_w` (pruned kernels
    /// hold zeros and are skipped via the index list).
    pub weights: Vec<i16>,
    /// Fractional bits of the weight format (per-layer).
    pub frac_w: u32,
    /// Bias in activation format (Q8.8 raw).
    pub bias: Vec<i16>,
    pub out_ch: usize,
    pub in_ch: usize,
    pub k: usize,
    pub stride: usize,
    pub index: IndexControl,
    /// Apply ReLU to outputs (Conv1 yes, PrimaryCaps no).
    pub relu: bool,
}

/// Pick the largest fraction width (≤ 14) that keeps `max|w|` in i16.
fn pick_frac(max_abs: f32) -> u32 {
    let mut f = 14u32;
    while f > 0 && max_abs * (1i32 << f) as f32 > i16::MAX as f32 {
        f -= 1;
    }
    f
}

impl ConvModule {
    pub fn new(
        weights: &Tensor,
        bias: &Tensor,
        stride: usize,
        index: IndexControl,
        relu: bool,
    ) -> ConvModule {
        assert_eq!(weights.rank(), 4);
        let max_abs = weights.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let frac_w = pick_frac(max_abs.max(1e-6));
        let scale = (1i64 << frac_w) as f32;
        ConvModule {
            weights: weights
                .data
                .iter()
                .map(|&x| {
                    (x * scale)
                        .round()
                        .clamp(i16::MIN as f32, i16::MAX as f32) as i16
                })
                .collect(),
            frac_w,
            bias: bias.data.iter().map(|&x| Q8::from_f32(x).raw()).collect(),
            out_ch: weights.shape[0],
            in_ch: weights.shape[1],
            k: weights.shape[2],
            stride,
            index,
            relu,
        }
    }

    /// Output spatial dims for an input of `h × w`.
    pub fn out_dims(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h - self.k) / self.stride + 1,
            (w - self.k) / self.stride + 1,
        )
    }

    /// MACs per frame: output positions × surviving kernels × k².
    pub fn macs(&self, h: usize, w: usize) -> u64 {
        let (oh, ow) = self.out_dims(h, w);
        (oh * ow) as u64 * self.index.survived() as u64 * (self.k * self.k) as u64
    }

    /// Functional Q8.8 convolution over surviving kernels only (what the
    /// index-controlled PE array computes). Input/output layout `[C,H,W]`.
    pub fn forward(&self, input: &[Q8], h: usize, w: usize) -> Vec<Q8> {
        assert_eq!(input.len(), self.in_ch * h * w);
        let (oh, ow) = self.out_dims(h, w);
        // Wide accumulators per output position (DSP cascade register),
        // at scale 2^(8 + frac_w) (Q8.8 activations × Qf weights).
        let mut acc = vec![0i64; self.out_ch * oh * ow];
        for o in 0..self.out_ch {
            let b = (self.bias[o] as i64) << self.frac_w;
            for p in 0..oh * ow {
                acc[o * oh * ow + p] = b;
            }
        }
        let kk = self.k * self.k;
        for &(o, i) in &self.index.indices {
            let (o, i) = (o as usize, i as usize);
            let wbase = (o * self.in_ch + i) * kk;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut a = acc[(o * oh + oy) * ow + ox];
                    for ky in 0..self.k {
                        let iy = oy * self.stride + ky;
                        let irow = (i * h + iy) * w + ox * self.stride;
                        for kx in 0..self.k {
                            let wv = self.weights[wbase + ky * self.k + kx] as i64;
                            let xv = input[irow + kx].raw() as i64;
                            a += wv * xv;
                        }
                    }
                    acc[(o * oh + oy) * ow + ox] = a;
                }
            }
        }
        // Requantize to Q8.8 activations (round-to-nearest, saturate).
        let half = 1i64 << (self.frac_w - 1);
        acc.iter()
            .map(|&a| {
                let r = ((a + half) >> self.frac_w)
                    .clamp(i16::MIN as i64, i16::MAX as i64) as i16;
                let v = Q8::from_raw(r);
                if self.relu && v.raw() < 0 {
                    Q8::ZERO
                } else {
                    v
                }
            })
            .collect()
    }

    /// Functional Q8.8 convolution into caller-provided scratch — the
    /// batch hot path. Values are bitwise identical to
    /// [`ConvModule::forward`]: the accumulators are plain `i64` integers
    /// (the DSP cascade never overflows them), so the restructured
    /// summation order cannot change a single bit.
    ///
    /// The restructure is what makes the batch path fast host-side: the
    /// surviving kernel's 9-tap weight row is hoisted to a slice per
    /// `ky`, and the inner dot product runs over `zip`ped subslices
    /// instead of 4-array indexed accesses, so the per-tap bounds checks
    /// of the reference loop disappear and the compiler can unroll the
    /// k-wide window.
    pub fn forward_into(
        &self,
        input: &[Q8],
        h: usize,
        w: usize,
        acc: &mut Vec<i64>,
        out: &mut Vec<Q8>,
    ) {
        assert_eq!(input.len(), self.in_ch * h * w);
        let (oh, ow) = self.out_dims(h, w);
        acc.clear();
        acc.resize(self.out_ch * oh * ow, 0);
        for o in 0..self.out_ch {
            let b = (self.bias[o] as i64) << self.frac_w;
            acc[o * oh * ow..(o + 1) * oh * ow].fill(b);
        }
        let kk = self.k * self.k;
        for &(o, i) in &self.index.indices {
            let (o, i) = (o as usize, i as usize);
            let wk = &self.weights[(o * self.in_ch + i) * kk..][..kk];
            for oy in 0..oh {
                let arow_off = (o * oh + oy) * ow;
                let arow = &mut acc[arow_off..arow_off + ow];
                for ky in 0..self.k {
                    let iy = oy * self.stride + ky;
                    let irow = &input[(i * h + iy) * w..][..w];
                    let wrow = &wk[ky * self.k..][..self.k];
                    for (ox, a) in arow.iter_mut().enumerate() {
                        let win = &irow[ox * self.stride..][..self.k];
                        let mut s = 0i64;
                        for (&wv, xv) in wrow.iter().zip(win) {
                            s += wv as i64 * xv.raw() as i64;
                        }
                        *a += s;
                    }
                }
            }
        }
        // Requantize to Q8.8 activations (round-to-nearest, saturate) —
        // same collapse as `forward`.
        let half = 1i64 << (self.frac_w - 1);
        out.clear();
        out.reserve(acc.len());
        out.extend(acc.iter().map(|&a| {
            let r = ((a + half) >> self.frac_w)
                .clamp(i16::MIN as i64, i16::MAX as i64) as i16;
            let v = Q8::from_raw(r);
            if self.relu && v.raw() < 0 {
                Q8::ZERO
            } else {
                v
            }
        }));
    }

    /// Cycle cost of one frame through this module.
    pub fn timing(&self, h: usize, w: usize, pe: &PeArray, ii: u64, mem_bw: u64) -> StageTiming {
        let macs = self.macs(h, w);
        let (oh, ow) = self.out_dims(h, w);
        let out_words = (self.out_ch * oh * ow) as u64;
        let compute = pe.mac_cycles(macs, ii)
            + self.index.fetch_overhead_cycles()
            // Pipeline refill at each output-row boundary.
            + (oh as u64) * pe.depth;
        let mem = out_words.div_ceil(mem_bw.max(1));
        StageTiming {
            name: format!("conv{}x{}/{}", self.k, self.k, self.out_ch),
            cycles: compute.max(mem),
            macs,
            mem_words: out_words,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorOptions;
    use crate::pruning::KernelMask;
    use crate::tensor::conv2d;
    use crate::util::rng::Rng;

    fn fixture(o: usize, i: usize, k: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        (
            Tensor::randn(&[o, i, k, k], 0.3, &mut rng),
            Tensor::randn(&[o], 0.1, &mut rng),
        )
    }

    #[test]
    fn matches_f32_conv_when_dense() {
        let (w, b) = fixture(4, 2, 3, 1);
        let mut rng = Rng::new(2);
        let input_f = Tensor::randn(&[2, 8, 8], 0.3, &mut rng);
        let mask = KernelMask::all_alive(4, 2);
        let m = ConvModule::new(&w, &b, 1, IndexControl::from_mask(&mask), false);
        let input_q: Vec<Q8> = input_f.data.iter().map(|&x| Q8::from_f32(x)).collect();
        let got = m.forward(&input_q, 8, 8);
        let want = conv2d(&input_f, &w, Some(&b), 1).unwrap();
        for (g, wv) in got.iter().zip(&want.data) {
            // Q8.8 conv accumulates quantization error across 18 taps.
            assert!(
                (g.to_f32() - wv).abs() < 0.05,
                "{} vs {}",
                g.to_f32(),
                wv
            );
        }
    }

    #[test]
    fn pruned_kernels_are_skipped() {
        let (w, b) = fixture(2, 2, 3, 3);
        let mut mask = KernelMask::all_alive(2, 2);
        mask.set(0, 1, false);
        mask.set(1, 0, false);
        let m = ConvModule::new(&w, &b, 1, IndexControl::from_mask(&mask), false);
        // Equivalent dense conv with those kernels zeroed.
        let mut wz = w.clone();
        mask.apply(&mut wz);
        let mut rng = Rng::new(4);
        let input_f = Tensor::randn(&[2, 6, 6], 0.3, &mut rng);
        let input_q: Vec<Q8> = input_f.data.iter().map(|&x| Q8::from_f32(x)).collect();
        let got = m.forward(&input_q, 6, 6);
        let want = conv2d(&input_f, &wz, Some(&b), 1).unwrap();
        for (g, wv) in got.iter().zip(&want.data) {
            assert!((g.to_f32() - wv).abs() < 0.05);
        }
        // And the timing reflects only surviving kernels.
        assert_eq!(m.macs(6, 6), 16 * 2 * 9);
    }

    #[test]
    fn forward_into_is_bitwise_identical_to_forward() {
        // Integer accumulators make the restructured loop order exactly
        // equal, across strides, relu, and pruning patterns.
        let mut rng = Rng::new(7);
        for (stride, relu, seed) in [(1usize, false, 10u64), (2, true, 11), (2, false, 12)] {
            let (w, b) = fixture(6, 3, 3, seed);
            let mut mask = KernelMask::all_alive(6, 3);
            for o in 0..6 {
                for i in 0..3 {
                    if (o * 3 + i) % 4 == 0 {
                        mask.set(o, i, false);
                    }
                }
            }
            let m = ConvModule::new(&w, &b, stride, IndexControl::from_mask(&mask), relu);
            let input_f = Tensor::randn(&[3, 9, 9], 0.4, &mut rng);
            let input_q: Vec<Q8> = input_f.data.iter().map(|&x| Q8::from_f32(x)).collect();
            let want = m.forward(&input_q, 9, 9);
            let (mut acc, mut got) = (Vec::new(), Vec::new());
            m.forward_into(&input_q, 9, 9, &mut acc, &mut got);
            assert_eq!(got, want, "stride={stride} relu={relu}");
            // Reuse the same scratch for a second frame: no stale state.
            let input2: Vec<Q8> = Tensor::randn(&[3, 9, 9], 0.4, &mut rng)
                .data
                .iter()
                .map(|&x| Q8::from_f32(x))
                .collect();
            let want2 = m.forward(&input2, 9, 9);
            m.forward_into(&input2, 9, 9, &mut acc, &mut got);
            assert_eq!(got, want2);
        }
    }

    #[test]
    fn relu_clamps_negative() {
        let (w, b) = fixture(2, 1, 3, 5);
        let mask = KernelMask::all_alive(2, 1);
        let m = ConvModule::new(&w, &b, 1, IndexControl::from_mask(&mask), true);
        let input = vec![Q8::from_f32(-1.0); 25];
        let out = m.forward(&input, 5, 5);
        assert!(out.iter().all(|v| v.raw() >= 0));
    }

    #[test]
    fn pruning_cuts_cycles_proportionally() {
        let (w, b) = fixture(16, 16, 3, 6);
        let pe = PeArray::new(&AcceleratorOptions::optimized());
        let dense_mask = KernelMask::all_alive(16, 16);
        let dense =
            ConvModule::new(&w, &b, 1, IndexControl::from_mask(&dense_mask), false);
        let mut sparse_mask = KernelMask::all_alive(16, 16);
        for o in 0..16 {
            for i in 0..16 {
                if (o + i) % 4 != 0 {
                    sparse_mask.set(o, i, false);
                }
            }
        }
        let sparse =
            ConvModule::new(&w, &b, 1, IndexControl::from_mask(&sparse_mask), false);
        let td = dense.timing(16, 16, &pe, 1, 8);
        let ts = sparse.timing(16, 16, &pe, 1, 8);
        let ratio = td.cycles as f64 / ts.cycles as f64;
        assert!(ratio > 2.0, "pruning 4x should speed up >2x, got {ratio:.2}");
    }
}
