//! Convolution Module (Fig. 10a): index-controlled conv over surviving
//! kernels on the PE array, with joint functional (Q8.8) and timing
//! semantics.
//!
//! The module is sparsity-first: only surviving kernels are *stored*
//! (k·k 16-bit words per survivor, packed in the CSR order of
//! [`IndexControl::packed_rows`] — the same `PackedRows` the
//! sparse-compiled oracle packs against), and the execution loops walk
//! the CSR rows directly. Within a row the input channels ascend, which
//! is the dense loop-nest order, so the sparse traversal's integer
//! accumulation sequence is bit-for-bit the masked-dense one; a dense
//! layer is the degenerate all-rows-full case.
//!
//! Timing model: the PE array iterates output positions; per position the
//! Index Control Module streams surviving kernels, each contributing k×k
//! MACs (empty rows cost one row-pointer skip — see
//! [`super::index_control::PackedRows::fetch_overhead_cycles`]). The
//! inner loop pipelines at II=1 in the optimized schedule (II=2 when
//! resource pressure prevents full partitioning, as in the original
//! design). Activations write out through the output BRAM banks.

use super::index_control::{IndexControl, PackedRows};
use super::pe::PeArray;
use crate::fixed::{raw_slice, Q8};
use crate::kernels;
use crate::tensor::Tensor;

/// Timing summary of one stage of the accelerator.
#[derive(Debug, Clone)]
pub struct StageTiming {
    pub name: String,
    pub cycles: u64,
    pub macs: u64,
    /// BRAM words moved (reads + writes) that are not hidden inside the
    /// PE-local register files.
    pub mem_words: u64,
}

/// One conv layer as deployed: 16-bit weights in a per-layer dynamic
/// fixed-point format (Q-CapsNets-style [25]: the fraction width is chosen
/// from the layer's weight range, so small-magnitude layers like
/// PrimaryCaps keep precision), packed to the surviving kernels only.
#[derive(Debug, Clone)]
pub struct ConvModule {
    /// Packed kernel weights at `Q(16-frac_w).frac_w`: `k·k` raw values
    /// per *surviving* kernel, in `rows` order (out-channel major,
    /// input channels ascending within a row). Dead kernels are not
    /// stored at all — this is what the BRAM/DDR models account.
    pub weights: Vec<i16>,
    /// Fractional bits of the weight format (per-layer).
    pub frac_w: u32,
    /// Bias in activation format (Q8.8 raw).
    pub bias: Vec<i16>,
    pub out_ch: usize,
    pub in_ch: usize,
    pub k: usize,
    pub stride: usize,
    /// CSR alive-kernel layout — the representation the Index Control
    /// Module keeps on-chip, shared verbatim with the sparse-compiled
    /// oracle ([`crate::capsnet::compiled`]).
    pub rows: PackedRows,
    /// Apply ReLU to outputs (Conv1 yes, PrimaryCaps no).
    pub relu: bool,
}

/// Pick the largest fraction width (≤ 14) that keeps `max|w|` in i16.
fn pick_frac(max_abs: f32) -> u32 {
    let mut f = 14u32;
    while f > 0 && max_abs * (1i32 << f) as f32 > i16::MAX as f32 {
        f -= 1;
    }
    f
}

impl ConvModule {
    /// Fold this module's deployed content — geometry, CSR survivor
    /// index, quantized weight/bias raw bits, weight format — into a
    /// deployment fingerprint (see `DeployedModel::fingerprint`).
    pub(crate) fn absorb_fingerprint(&self, h: &mut crate::util::hash::Hash64) {
        for d in [self.out_ch, self.in_ch, self.k, self.stride] {
            h.absorb(d as u64);
        }
        h.absorb(self.frac_w as u64);
        h.absorb(u64::from(self.relu));
        h.absorb_u32s(&self.rows.row_ptr);
        h.absorb_u16s(&self.rows.cols);
        h.absorb_i16s(&self.weights);
        h.absorb_i16s(&self.bias);
    }

    pub fn new(
        weights: &Tensor,
        bias: &Tensor,
        stride: usize,
        index: IndexControl,
        relu: bool,
    ) -> ConvModule {
        assert_eq!(weights.rank(), 4);
        let (out_ch, in_ch, k) = (weights.shape[0], weights.shape[1], weights.shape[2]);
        assert_eq!(index.out_ch, out_ch, "index grid / weight grid mismatch");
        assert_eq!(index.in_ch, in_ch, "index grid / weight grid mismatch");
        let rows = index.packed_rows();
        let kk = k * k;
        // The dynamic fixed-point range is chosen from the *surviving*
        // kernels only: dead kernels never execute, so their magnitudes
        // must not cost the layer precision. This also makes a sparse
        // deployment of unmasked weights quantize exactly like a dense
        // deployment of the masked tensor (zeros never raise the range)
        // — the masked-dense bit-exactness contract.
        let mut max_abs = 0.0f32;
        let mut packed = Vec::with_capacity(rows.survived() * kk);
        for o in 0..out_ch {
            for &i in rows.row(o) {
                let base = (o * in_ch + i as usize) * kk;
                for &x in &weights.data[base..base + kk] {
                    max_abs = max_abs.max(x.abs());
                }
            }
        }
        let frac_w = pick_frac(max_abs.max(1e-6));
        let scale = (1i64 << frac_w) as f32;
        for o in 0..out_ch {
            for &i in rows.row(o) {
                let base = (o * in_ch + i as usize) * kk;
                packed.extend(weights.data[base..base + kk].iter().map(|&x| {
                    (x * scale)
                        .round()
                        .clamp(i16::MIN as f32, i16::MAX as f32) as i16
                }));
            }
        }
        ConvModule {
            weights: packed,
            frac_w,
            bias: bias.data.iter().map(|&x| Q8::from_f32(x).raw()).collect(),
            out_ch,
            in_ch,
            k,
            stride,
            rows,
            relu,
        }
    }

    /// Surviving kernels this module stores and executes.
    pub fn survived(&self) -> usize {
        self.rows.survived()
    }

    /// Kernels of the dense `out_ch × in_ch` grid.
    pub fn total(&self) -> usize {
        self.out_ch * self.in_ch
    }

    /// Bytes of packed 16-bit kernel weights (BRAM-resident for pruned
    /// deployments, replayed over DDR per frame by the original design).
    pub fn weight_bytes(&self) -> usize {
        self.weights.len() * 2
    }

    /// Output spatial dims for an input of `h × w`.
    pub fn out_dims(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h - self.k) / self.stride + 1,
            (w - self.k) / self.stride + 1,
        )
    }

    /// MACs per frame: output positions × surviving kernels × k².
    pub fn macs(&self, h: usize, w: usize) -> u64 {
        let (oh, ow) = self.out_dims(h, w);
        (oh * ow) as u64 * self.rows.survived() as u64 * (self.k * self.k) as u64
    }

    /// Functional Q8.8 convolution over surviving kernels only (what the
    /// index-controlled PE array computes). Input/output layout `[C,H,W]`.
    ///
    /// The CSR walk visits kernels in (out_ch, ascending in_ch) order —
    /// exactly the dense loop nest restricted to survivors — so the
    /// integer accumulation sequence, and therefore every output bit,
    /// matches a dense module run on the masked weight tensor.
    pub fn forward(&self, input: &[Q8], h: usize, w: usize) -> Vec<Q8> {
        assert_eq!(input.len(), self.in_ch * h * w);
        let (oh, ow) = self.out_dims(h, w);
        // Wide accumulators per output position (DSP cascade register),
        // at scale 2^(8 + frac_w) (Q8.8 activations × Qf weights).
        let mut acc = vec![0i64; self.out_ch * oh * ow];
        for o in 0..self.out_ch {
            let b = (self.bias[o] as i64) << self.frac_w;
            for p in 0..oh * ow {
                acc[o * oh * ow + p] = b;
            }
        }
        let kk = self.k * self.k;
        for o in 0..self.out_ch {
            let row_start = self.rows.row_ptr[o] as usize;
            for (n, &i) in self.rows.row(o).iter().enumerate() {
                let i = i as usize;
                let wbase = (row_start + n) * kk;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut a = acc[(o * oh + oy) * ow + ox];
                        for ky in 0..self.k {
                            let iy = oy * self.stride + ky;
                            let irow = (i * h + iy) * w + ox * self.stride;
                            for kx in 0..self.k {
                                let wv = self.weights[wbase + ky * self.k + kx] as i64;
                                let xv = input[irow + kx].raw() as i64;
                                a += wv * xv;
                            }
                        }
                        acc[(o * oh + oy) * ow + ox] = a;
                    }
                }
            }
        }
        // Requantize to Q8.8 activations (round-to-nearest, saturate).
        let half = 1i64 << (self.frac_w - 1);
        acc.iter()
            .map(|&a| {
                let r = ((a + half) >> self.frac_w)
                    .clamp(i16::MIN as i64, i16::MAX as i64) as i16;
                let v = Q8::from_raw(r);
                if self.relu && v.raw() < 0 {
                    Q8::ZERO
                } else {
                    v
                }
            })
            .collect()
    }

    /// Functional Q8.8 convolution into caller-provided scratch — the
    /// batch hot path. Values are bitwise identical to
    /// [`ConvModule::forward`]: the accumulators are plain `i64` integers
    /// (the DSP cascade never overflows them), so the restructured
    /// summation order cannot change a single bit.
    ///
    /// The restructure is what makes the batch path fast host-side: the
    /// loop nest runs tap-outer / output-column-inner, so each weight tap
    /// becomes one strided axpy over the whole output row and dispatches
    /// into the SIMD kernel layer ([`crate::kernels::axpy_strided_i16`]).
    /// Reordering the integer sum is free: the i64 accumulators never
    /// overflow, so any summation order produces identical bits.
    pub fn forward_into(
        &self,
        input: &[Q8],
        h: usize,
        w: usize,
        acc: &mut Vec<i64>,
        out: &mut Vec<Q8>,
    ) {
        assert_eq!(input.len(), self.in_ch * h * w);
        let (oh, ow) = self.out_dims(h, w);
        acc.clear();
        acc.resize(self.out_ch * oh * ow, 0);
        for o in 0..self.out_ch {
            let b = (self.bias[o] as i64) << self.frac_w;
            acc[o * oh * ow..(o + 1) * oh * ow].fill(b);
        }
        let kk = self.k * self.k;
        for o in 0..self.out_ch {
            let row_start = self.rows.row_ptr[o] as usize;
            for (n, &i) in self.rows.row(o).iter().enumerate() {
                let i = i as usize;
                let wk = &self.weights[(row_start + n) * kk..][..kk];
                for oy in 0..oh {
                    let arow_off = (o * oh + oy) * ow;
                    let arow = &mut acc[arow_off..arow_off + ow];
                    for ky in 0..self.k {
                        let iy = oy * self.stride + ky;
                        let irow = raw_slice(&input[(i * h + iy) * w..][..w]);
                        let wrow = &wk[ky * self.k..][..self.k];
                        for (kx, &wv) in wrow.iter().enumerate() {
                            kernels::axpy_strided_i16(arow, wv, &irow[kx..], self.stride);
                        }
                    }
                }
            }
        }
        // Requantize to Q8.8 activations (round-to-nearest, saturate) —
        // same collapse as `forward`.
        let half = 1i64 << (self.frac_w - 1);
        out.clear();
        out.reserve(acc.len());
        out.extend(acc.iter().map(|&a| {
            let r = ((a + half) >> self.frac_w)
                .clamp(i16::MIN as i64, i16::MAX as i64) as i16;
            let v = Q8::from_raw(r);
            if self.relu && v.raw() < 0 {
                Q8::ZERO
            } else {
                v
            }
        }));
    }

    /// Cycle cost of one frame through this module.
    pub fn timing(&self, h: usize, w: usize, pe: &PeArray, ii: u64, mem_bw: u64) -> StageTiming {
        let macs = self.macs(h, w);
        let (oh, ow) = self.out_dims(h, w);
        let out_words = (self.out_ch * oh * ow) as u64;
        let compute = pe.mac_cycles(macs, ii)
            + self.rows.fetch_overhead_cycles()
            // Pipeline refill at each output-row boundary.
            + (oh as u64) * pe.depth;
        let mem = out_words.div_ceil(mem_bw.max(1));
        StageTiming {
            name: format!("conv{}x{}/{}", self.k, self.k, self.out_ch),
            cycles: compute.max(mem),
            macs,
            mem_words: out_words,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorOptions;
    use crate::pruning::KernelMask;
    use crate::tensor::conv2d;
    use crate::util::rng::Rng;

    fn fixture(o: usize, i: usize, k: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        (
            Tensor::randn(&[o, i, k, k], 0.3, &mut rng),
            Tensor::randn(&[o], 0.1, &mut rng),
        )
    }

    #[test]
    fn matches_f32_conv_when_dense() {
        let (w, b) = fixture(4, 2, 3, 1);
        let mut rng = Rng::new(2);
        let input_f = Tensor::randn(&[2, 8, 8], 0.3, &mut rng);
        let mask = KernelMask::all_alive(4, 2);
        let m = ConvModule::new(&w, &b, 1, IndexControl::from_mask(&mask), false);
        let input_q: Vec<Q8> = input_f.data.iter().map(|&x| Q8::from_f32(x)).collect();
        let got = m.forward(&input_q, 8, 8);
        let want = conv2d(&input_f, &w, Some(&b), 1).unwrap();
        for (g, wv) in got.iter().zip(&want.data) {
            // Q8.8 conv accumulates quantization error across 18 taps.
            assert!(
                (g.to_f32() - wv).abs() < 0.05,
                "{} vs {}",
                g.to_f32(),
                wv
            );
        }
    }

    #[test]
    fn pruned_kernels_are_skipped() {
        let (w, b) = fixture(2, 2, 3, 3);
        let mut mask = KernelMask::all_alive(2, 2);
        mask.set(0, 1, false);
        mask.set(1, 0, false);
        let m = ConvModule::new(&w, &b, 1, IndexControl::from_mask(&mask), false);
        // Equivalent dense conv with those kernels zeroed.
        let mut wz = w.clone();
        mask.apply(&mut wz);
        let mut rng = Rng::new(4);
        let input_f = Tensor::randn(&[2, 6, 6], 0.3, &mut rng);
        let input_q: Vec<Q8> = input_f.data.iter().map(|&x| Q8::from_f32(x)).collect();
        let got = m.forward(&input_q, 6, 6);
        let want = conv2d(&input_f, &wz, Some(&b), 1).unwrap();
        for (g, wv) in got.iter().zip(&want.data) {
            assert!((g.to_f32() - wv).abs() < 0.05);
        }
        // And the timing reflects only surviving kernels.
        assert_eq!(m.macs(6, 6), 16 * 2 * 9);
    }

    #[test]
    fn forward_into_is_bitwise_identical_to_forward() {
        // Integer accumulators make the restructured loop order exactly
        // equal, across strides, relu, and pruning patterns.
        let mut rng = Rng::new(7);
        for (stride, relu, seed) in [(1usize, false, 10u64), (2, true, 11), (2, false, 12)] {
            let (w, b) = fixture(6, 3, 3, seed);
            let mut mask = KernelMask::all_alive(6, 3);
            for o in 0..6 {
                for i in 0..3 {
                    if (o * 3 + i) % 4 == 0 {
                        mask.set(o, i, false);
                    }
                }
            }
            let m = ConvModule::new(&w, &b, stride, IndexControl::from_mask(&mask), relu);
            let input_f = Tensor::randn(&[3, 9, 9], 0.4, &mut rng);
            let input_q: Vec<Q8> = input_f.data.iter().map(|&x| Q8::from_f32(x)).collect();
            let want = m.forward(&input_q, 9, 9);
            let (mut acc, mut got) = (Vec::new(), Vec::new());
            m.forward_into(&input_q, 9, 9, &mut acc, &mut got);
            assert_eq!(got, want, "stride={stride} relu={relu}");
            // Reuse the same scratch for a second frame: no stale state.
            let input2: Vec<Q8> = Tensor::randn(&[3, 9, 9], 0.4, &mut rng)
                .data
                .iter()
                .map(|&x| Q8::from_f32(x))
                .collect();
            let want2 = m.forward(&input2, 9, 9);
            m.forward_into(&input2, 9, 9, &mut acc, &mut got);
            assert_eq!(got, want2);
        }
    }

    #[test]
    fn property_csr_module_matches_masked_dense_bitwise() {
        // The packed module built from unmasked weights + a mask must be
        // bit-identical to a dense (all-alive) module built from the
        // masked tensor: the fraction width comes from the survivors
        // (zeros never raise the range), survivor quantization is
        // identical, the CSR walk keeps the dense accumulation order,
        // and a dead kernel's dense contribution is an exact integer 0.
        crate::testing::check_msg(
            "CSR conv ≡ masked-dense conv (bitwise)",
            10,
            91,
            |r| {
                let (o, i) = (1 + r.below(6), 1 + r.below(4));
                let stride = 1 + r.below(2);
                let relu = r.below(2) == 0;
                let w = Tensor::randn(&[o, i, 3, 3], 0.5, r);
                let b = Tensor::randn(&[o], 0.2, r);
                let mut mask = KernelMask::all_alive(o, i);
                for oc in 0..o {
                    for ic in 0..i {
                        if r.below(3) == 0 {
                            mask.set(oc, ic, false);
                        }
                    }
                }
                let input: Vec<Q8> = Tensor::randn(&[i, 10, 10], 0.4, r)
                    .data
                    .iter()
                    .map(|&x| Q8::from_f32(x))
                    .collect();
                (w, b, stride, relu, mask, input)
            },
            |(w, b, stride, relu, mask, input)| {
                let sparse =
                    ConvModule::new(w, b, *stride, IndexControl::from_mask(mask), *relu);
                if sparse.weights.len() != mask.survived() * 9 {
                    return Err(format!(
                        "packed {} words for {} survivors",
                        sparse.weights.len(),
                        mask.survived()
                    ));
                }
                let mut wz = w.clone();
                mask.apply(&mut wz);
                let alive = KernelMask::all_alive(mask.out_ch, mask.in_ch);
                let dense =
                    ConvModule::new(&wz, b, *stride, IndexControl::from_mask(&alive), *relu);
                if sparse.frac_w != dense.frac_w {
                    return Err(format!("frac_w {} != {}", sparse.frac_w, dense.frac_w));
                }
                let want = dense.forward(input, 10, 10);
                if sparse.forward(input, 10, 10) != want {
                    return Err("forward diverged from masked-dense".into());
                }
                let (mut acc, mut got) = (Vec::new(), Vec::new());
                sparse.forward_into(input, 10, 10, &mut acc, &mut got);
                if got != want {
                    return Err("forward_into diverged from masked-dense".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn relu_clamps_negative() {
        let (w, b) = fixture(2, 1, 3, 5);
        let mask = KernelMask::all_alive(2, 1);
        let m = ConvModule::new(&w, &b, 1, IndexControl::from_mask(&mask), true);
        let input = vec![Q8::from_f32(-1.0); 25];
        let out = m.forward(&input, 5, 5);
        assert!(out.iter().all(|v| v.raw() >= 0));
    }

    #[test]
    fn pruning_cuts_cycles_proportionally() {
        let (w, b) = fixture(16, 16, 3, 6);
        let pe = PeArray::new(&AcceleratorOptions::optimized());
        let dense_mask = KernelMask::all_alive(16, 16);
        let dense =
            ConvModule::new(&w, &b, 1, IndexControl::from_mask(&dense_mask), false);
        let mut sparse_mask = KernelMask::all_alive(16, 16);
        for o in 0..16 {
            for i in 0..16 {
                if (o + i) % 4 != 0 {
                    sparse_mask.set(o, i, false);
                }
            }
        }
        let sparse =
            ConvModule::new(&w, &b, 1, IndexControl::from_mask(&sparse_mask), false);
        let td = dense.timing(16, 16, &pe, 1, 8);
        let ts = sparse.timing(16, 16, &pe, 1, 8);
        let ratio = td.cycles as f64 / ts.cycles as f64;
        assert!(ratio > 2.0, "pruning 4x should speed up >2x, got {ratio:.2}");
    }
}
