//! Post-synthesis resource model (Table II / Table III / Fig. 14).
//!
//! Additive area model: every hardware unit the accelerator instantiates
//! contributes LUTs / LUT-RAM / DSP48E slices; BRAM comes from the
//! allocation ledger ([`super::bram`]) including HLS partitioning waste.
//! Per-unit constants are calibrated against the paper's Vivado reports
//! for the Zynq-7020 (each constant is annotated); the *structure* —
//! which units exist in which configuration — follows the architecture
//! directly, so config-to-config deltas are mechanistic, not fitted.

use super::bram::{csr_weight_bytes, BramLedger};
use crate::config::SystemConfig;

/// Resource utilization of one accelerator build.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Utilization {
    pub luts: u32,
    pub lutram: u32,
    pub bram36: f32,
    pub dsp48e: u32,
}

impl Utilization {
    pub fn percent_of(&self, budget: &crate::config::FpgaBudget) -> [f64; 4] {
        [
            100.0 * self.luts as f64 / budget.luts as f64,
            100.0 * self.lutram as f64 / budget.lutram as f64,
            100.0 * self.bram36 as f64 / budget.bram36 as f64,
            100.0 * self.dsp48e as f64 / budget.dsp48e as f64,
        ]
    }
}

/// LUT/LUTRAM/DSP contribution of each unit (calibrated constants).
mod unit {
    /// Platform: AXI-lite control, interrupt, clocking, PS interface.
    pub const PLATFORM_LUT: u32 = 9000;
    pub const PLATFORM_LUTRAM: u32 = 1500;
    pub const PLATFORM_DSP: u32 = 46;

    /// DDR weight-streaming datapath (original design only): m_axi FSMs,
    /// alignment, prefetch FIFOs.
    pub const DDR_STREAM_LUT: u32 = 5200;
    pub const DDR_STREAM_LUTRAM: u32 = 3400;

    /// PE array, per PE (9 multipliers + adder tree + local regs).
    pub const PE_LUT: u32 = 650;
    pub const PE_DSP: u32 = 9;

    /// Conv address generation / line buffers (per conv module).
    pub const CONV_CTRL_LUT: u32 = 2000;
    pub const CONV_ADDR_DSP: u32 = 6;

    /// Index Control Module (pruned deployments): FIFO + remap tables.
    pub const INDEX_LUT: u32 = 300;
    /// Per 100 surviving kernels (deeper remap tables).
    pub const INDEX_LUT_PER_100K: u32 = 120;
    pub const INDEX_LUTRAM: u32 = 280;

    /// Baseline non-linear units.
    pub const EXP_CORDIC_LUT: u32 = 1100;
    pub const EXP_CORDIC_DSP: u32 = 4;
    pub const DIV_ITERATIVE_LUT: u32 = 1900; // LUT-based restoring divider
    /// Scalar routing datapath (baseline: MAC lanes + muxes).
    pub const SCALAR_ROUTING_LUT: u32 = 2600;
    pub const SCALAR_ROUTING_DSP: u32 = 33;

    /// Optimized non-linear units (§III-B).
    pub const EXP_TAYLOR_LUT: u32 = 800; // Horner control; muls on PE array
    pub const DIV_EXPLOG_LUT: u32 = 700; // per instance
    pub const DIV_EXPLOG_DSP: u32 = 7; // 2 log (1 DSP) + exp poly (5)
    pub const SOFTMAX_TREE_DSP: u32 = 4;
    pub const REQUANT_DSP: u32 = 30; // output requantization lanes

    /// Squash unit (both designs): sqrt + scale.
    pub const SQUASH_LUT: u32 = 600;
    pub const SQUASH_DSP: u32 = 2;

    /// Routing sequencer: grows with capsule count (state machines index
    /// N capsules; comparators, counters, bank muxes).
    pub const ROUTING_CTRL_LUT_BASE: u32 = 1500;
    pub const ROUTING_CTRL_LUT_PER_CAP: f64 = 6.0;
    /// Routing-state FIFOs in LUTRAM, per capsule.
    pub const ROUTING_LUTRAM_PER_CAP: f64 = 10.8;
}

/// BRAM allocation for a configuration, itemized.
pub fn bram_plan(cfg: &SystemConfig) -> BramLedger {
    let m = &cfg.model;
    let s = &cfg.sparsity;
    let mut ledger = BramLedger::new();
    let (c_in, ih, iw) = m.input;
    let (h1, w1) = m.conv1_out();
    let (h2, w2) = m.pc_out();
    let n_caps = s.num_primary_caps(m);

    // 16-bit weights. The original design streams weights from DDR and
    // only holds stream buffers; pruned designs hold everything on-chip
    // in the CSR packing — packed survivor words plus the Index Control
    // Module's column/row-pointer memory, per layer.
    if cfg.is_pruned() {
        ledger.alloc(
            "weights.conv1(csr)",
            csr_weight_bytes(
                s.conv1_kernels,
                m.conv1_ch * c_in,
                m.conv1_k * m.conv1_k,
                m.conv1_ch,
            ),
            false,
        );
        ledger.alloc(
            "weights.pc(csr)",
            csr_weight_bytes(
                s.pc_kernels,
                m.pc_channels() * m.conv1_ch,
                m.pc_k * m.pc_k,
                m.pc_channels(),
            ),
            false,
        );
        let wij = s.pc_types * m.num_classes * m.pc_dim * m.dc_dim * 2;
        ledger.alloc("weights.w_ij", wij, false);
    } else {
        // Double-buffered stream tiles for weights (64 KB ping-pong).
        ledger.alloc("weights.stream_tiles", 64 * 1024, true);
    }

    // Activations (dataflow: input & conv1 double-buffered). HLS cyclic
    // partitioning spreads hot arrays over banks; each bank rounds up to
    // BRAM18 granularity — modeled by allocating per-bank slices.
    ledger.alloc("act.input", c_in * ih * iw * 2, true);
    let conv1_act = m.conv1_ch.min(if cfg.is_pruned() { s.conv1_channels } else { m.conv1_ch });
    // Partition conv1 activations over k taps (9 banks).
    let conv1_bytes = conv1_act * h1 * w1 * 2;
    for b in 0..9 {
        ledger.alloc(&format!("act.conv1.bank{b}"), conv1_bytes.div_ceil(9), true);
    }
    ledger.alloc("act.pc", s.pc_types * m.pc_dim * h2 * w2 * 2, false);

    // û storage, partitioned over 16 banks for the PE array.
    let u_bytes = n_caps * m.num_classes * m.dc_dim * 2;
    for b in 0..16 {
        ledger.alloc(&format!("routing.u_hat.bank{b}"), u_bytes.div_ceil(16), false);
    }
    // Routing state: logits + couplings (4 banks).
    let bc_bytes = n_caps * m.num_classes * 2 * 2;
    for b in 0..4 {
        ledger.alloc(&format!("routing.state.bank{b}"), bc_bytes.div_ceil(4), false);
    }
    ledger.alloc("routing.v", m.num_classes * m.dc_dim * 2, false);
    ledger.alloc("rom.exp_coeffs", 256, false);
    ledger.alloc("io.dma", 2 * 8 * 1024, true);
    ledger
}

/// Full resource estimate for a configuration.
pub fn estimate(cfg: &SystemConfig) -> Utilization {
    use unit::*;
    let m = &cfg.model;
    let s = &cfg.sparsity;
    let n_caps = s.num_primary_caps(m) as f64;
    let survived_kernels = (s.conv1_kernels + s.pc_kernels) as u32;

    let mut lut = PLATFORM_LUT;
    let mut lutram = PLATFORM_LUTRAM;
    let mut dsp = PLATFORM_DSP;

    // PE array + two conv modules.
    lut += cfg.options.num_pes as u32 * PE_LUT;
    dsp += cfg.options.num_pes as u32 * PE_DSP;
    lut += 2 * CONV_CTRL_LUT;
    dsp += 2 * CONV_ADDR_DSP;

    // Squash unit.
    lut += SQUASH_LUT;
    dsp += SQUASH_DSP;

    // Routing sequencer. Pruned designs keep per-capsule index/state FIFOs
    // in LUTRAM (they scale with capsule count); the original design has no
    // resources left for that — its routing state sits in BRAM behind a
    // fixed-size sequencer.
    if cfg.is_pruned() {
        lut += ROUTING_CTRL_LUT_BASE + (ROUTING_CTRL_LUT_PER_CAP * n_caps) as u32;
        lutram += (ROUTING_LUTRAM_PER_CAP * n_caps) as u32;
        lut += INDEX_LUT + INDEX_LUT_PER_100K * survived_kernels.div_ceil(100);
        lutram += INDEX_LUTRAM;
    } else {
        lut += ROUTING_CTRL_LUT_BASE + 1200;
        lutram += 1800;
        lut += DDR_STREAM_LUT;
        lutram += DDR_STREAM_LUTRAM;
    }

    if cfg.options.optimized_routing {
        lut += EXP_TAYLOR_LUT + 2 * DIV_EXPLOG_LUT;
        dsp += 2 * DIV_EXPLOG_DSP + SOFTMAX_TREE_DSP + REQUANT_DSP;
    } else {
        lut += EXP_CORDIC_LUT + DIV_ITERATIVE_LUT + SCALAR_ROUTING_LUT;
        dsp += EXP_CORDIC_DSP + SCALAR_ROUTING_DSP;
    }

    // BRAM from the ledger, clamped at the device budget (the original
    // design saturates it: Table II reports 140/140).
    let bram = bram_plan(cfg)
        .total_blocks()
        .min(cfg.budget.bram36);

    Utilization {
        luts: lut,
        lutram,
        bram36: bram,
        dsp48e: dsp,
    }
}

/// Paper-reported values for comparison in reports/tests.
pub fn paper_reported(config_name: &str) -> Option<Utilization> {
    match config_name {
        "original-mnist" => Some(Utilization {
            luts: 33_232,
            lutram: 6_751,
            bram36: 140.0,
            dsp48e: 187,
        }),
        "proposed-mnist" => Some(Utilization {
            luts: 25_559,
            lutram: 4_221,
            bram36: 131.5,
            dsp48e: 198,
        }),
        "proposed-fmnist" => Some(Utilization {
            luts: 28_247,
            lutram: 6_268,
            bram36: 131.5,
            dsp48e: 198,
        }),
        _ => None,
    }
}

/// Helper: relative error (%) between model and paper.
pub fn relative_error(model: f64, paper: f64) -> f64 {
    100.0 * (model - paper).abs() / paper
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    #[test]
    fn original_mnist_tracks_table2() {
        let u = estimate(&SystemConfig::original("mnist"));
        let p = paper_reported("original-mnist").unwrap();
        assert!(relative_error(u.dsp48e as f64, p.dsp48e as f64) < 8.0, "dsp {u:?}");
        assert!(relative_error(u.luts as f64, p.luts as f64) < 15.0, "lut {u:?}");
        assert_eq!(u.bram36, 140.0, "original saturates BRAM");
    }

    #[test]
    fn proposed_mnist_tracks_table2() {
        let u = estimate(&SystemConfig::proposed("mnist"));
        let p = paper_reported("proposed-mnist").unwrap();
        assert!(relative_error(u.dsp48e as f64, p.dsp48e as f64) < 8.0, "dsp {u:?}");
        assert!(relative_error(u.luts as f64, p.luts as f64) < 15.0, "lut {u:?}");
        assert!(relative_error(u.lutram as f64, p.lutram as f64) < 15.0, "lutram {u:?}");
        assert!(u.bram36 < 140.0, "pruned fits under budget: {u:?}");
    }

    #[test]
    fn fmnist_larger_than_mnist() {
        // Table III vs Table II col 2: F-MNIST variant uses more LUT and
        // LUTRAM (432 vs 252 capsules), same DSP.
        let m = estimate(&SystemConfig::proposed("mnist"));
        let f = estimate(&SystemConfig::proposed("fmnist"));
        assert!(f.luts > m.luts);
        assert!(f.lutram > m.lutram);
        assert_eq!(f.dsp48e, m.dsp48e);
    }

    #[test]
    fn optimization_shifts_div_from_lut_to_dsp() {
        // Fig. 14's signature: optimized design trades the LUT-hungry
        // iterative divider for DSP-based Taylor units.
        let base = estimate(&SystemConfig::pruned("mnist"));
        let opt = estimate(&SystemConfig::proposed("mnist"));
        assert!(opt.luts < base.luts, "{} vs {}", opt.luts, base.luts);
        assert!(opt.dsp48e > base.dsp48e);
    }

    #[test]
    fn everything_fits_the_device() {
        for cfg in [
            SystemConfig::original("mnist"),
            SystemConfig::pruned("mnist"),
            SystemConfig::proposed("mnist"),
            SystemConfig::proposed("fmnist"),
        ] {
            let u = estimate(&cfg);
            let b = &cfg.budget;
            assert!(u.luts <= b.luts, "{} luts", u.luts);
            assert!(u.lutram <= b.lutram);
            assert!(u.bram36 <= b.bram36);
            assert!(u.dsp48e <= b.dsp48e, "{} dsp", u.dsp48e);
        }
    }
}
