//! Cycle-level simulator of the FastCaps accelerator (Fig. 9) on the
//! PYNQ-Z1 budget — the evaluation platform substituting for the paper's
//! board (DESIGN.md §4).
//!
//! The simulator is *jointly functional and timed*: the same quantized
//! datapath that computes values (Q8.8 conv, Q4.12 routing, Taylor
//! non-linear units) is priced by the cycle model, so numerics and
//! timing cannot diverge. For benches that only need cycles,
//! [`DeployedModel::estimate_frame`] prices a frame without computing it;
//! a test pins both paths to identical cycle counts.

pub mod bram;
pub mod conv_module;
pub mod ddr;
pub mod index_control;
pub mod pe;
pub mod power;
pub mod resources;
pub mod routing_module;

use crate::capsnet::compiled::CompressionStats;
use crate::capsnet::weights::Weights;
use crate::config::{SparsityPlan, SystemConfig};
use crate::fixed::{raw_slice, Q12, Q8};
use crate::kernels;
use crate::pruning::KernelMask;
use crate::routing::fixed::{
    accumulated_routing_q12, dynamic_routing_q12, quantize_coupling, OpCounts, PredictionsQ12,
    RoutingScratch, SoftmaxMode,
};
use crate::routing::RoutingMode;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::Result;
use conv_module::{ConvModule, StageTiming};
use ddr::DdrModel;
use index_control::IndexControl;
use pe::PeArray;
use routing_module::{routing_timing, RoutingGeometry, RoutingHardware, RoutingTiming};

/// Timing report for one frame.
#[derive(Debug, Clone)]
pub struct FrameTiming {
    pub stages: Vec<StageTiming>,
    pub routing: RoutingTiming,
    /// DDR weight-streaming cycles (original design only; overlapped with
    /// compute, so the frame takes max(compute, stream)).
    pub ddr_cycles: u64,
    pub clock_mhz: f64,
}

impl FrameTiming {
    pub fn compute_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.cycles).sum()
    }

    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles().max(self.ddr_cycles)
    }

    pub fn latency_s(&self) -> f64 {
        self.total_cycles() as f64 / (self.clock_mhz * 1e6)
    }

    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s()
    }
}

/// Timing for a batch of frames streamed through the accelerator's stage
/// sequence (conv1 → primarycaps → squash → routing).
///
/// The stages are spatially separate units on the fabric (Fig. 9), so
/// while frame *n* occupies the routing module, frame *n+1* can already
/// run on the conv modules — the frame-level analogue of CapsAcc's
/// PE-array reuse across overlapped work (arXiv:1811.08932). In steady
/// state the pipeline issues one frame per initiation interval — the
/// slowest stage's cycles (and, for the original design, the serial DDR
/// weight stream, which must replay per frame). The first frame still
/// pays the full single-frame latency to fill the pipeline.
///
/// [`FrameTiming`] (one frame in isolation) is untouched: every paper
/// anchor — Table II latency, Fig. 1 single-frame FPS — still reads it.
#[derive(Debug, Clone)]
pub struct BatchTiming {
    pub frame: FrameTiming,
    pub batch: usize,
}

impl BatchTiming {
    /// Cycles between consecutive frame completions once the pipeline is
    /// full: the slowest stage, floored by the per-frame DDR stream
    /// (a single serial resource that cannot overlap with itself).
    pub fn initiation_cycles(&self) -> u64 {
        self.frame
            .stages
            .iter()
            .map(|s| s.cycles)
            .max()
            .unwrap_or(0)
            .max(self.frame.ddr_cycles)
    }

    /// Total cycles for the whole batch: pipeline fill (one full frame
    /// latency) plus one initiation interval per further frame.
    pub fn total_cycles(&self) -> u64 {
        if self.batch == 0 {
            return 0;
        }
        self.frame.total_cycles() + (self.batch as u64 - 1) * self.initiation_cycles()
    }

    /// Modeled wall time for the whole batch.
    pub fn latency_s(&self) -> f64 {
        self.total_cycles() as f64 / (self.frame.clock_mhz * 1e6)
    }

    /// Throughput once the pipeline is full — the sustained-serving
    /// number (1 / initiation interval), as opposed to
    /// [`FrameTiming::fps`]'s 1 / latency.
    pub fn steady_state_fps(&self) -> f64 {
        self.frame.clock_mhz * 1e6 / self.initiation_cycles() as f64
    }

    /// Effective FPS over this batch, fill latency included — between
    /// [`FrameTiming::fps`] and [`BatchTiming::steady_state_fps`] for
    /// any real batch (0.0 for an empty one).
    pub fn batch_fps(&self) -> f64 {
        if self.batch == 0 {
            return 0.0;
        }
        self.batch as f64 / self.latency_s()
    }
}

/// A deployed model: quantized weights + kernel survivor indices.
#[derive(Debug, Clone)]
pub struct DeployedModel {
    pub config: SystemConfig,
    pub conv1: ConvModule,
    pub pc: ConvModule,
    /// DigitCaps transform in Q4.12: `[pc_types][n_classes][d_in][d_out]`.
    pub w_ij: Vec<Q12>,
    /// Active routing schedule. Defaults to the config's iteration count;
    /// [`DeployedModel::bake_accumulated`] switches to the
    /// iteration-free accumulated-coefficients path.
    pub routing: RoutingMode,
    /// Baked per-class mean coupling coefficients in the Q4.12 datapath
    /// format (`[n_caps][n_classes]`), present once accumulated mode has
    /// been baked. At 23 KB for the full 1152×10 geometry they sit in
    /// BRAM next to the survivor weights, so the DDR model never prices
    /// them — accumulated mode is exactly the effective-r=0 schedule.
    acc_coupling_q: Option<Vec<Q12>>,
}

impl DeployedModel {
    /// Deploy trained weights with explicit pruning masks.
    pub fn new(
        cfg: SystemConfig,
        weights: &Weights,
        conv1_mask: &KernelMask,
        pc_mask: &KernelMask,
    ) -> Result<DeployedModel> {
        weights.validate(&cfg.model)?;
        anyhow::ensure!(
            conv1_mask.out_ch == cfg.model.conv1_ch
                && conv1_mask.in_ch == cfg.model.input.0,
            "conv1 mask shape mismatch"
        );
        anyhow::ensure!(
            pc_mask.out_ch == cfg.model.pc_channels()
                && pc_mask.in_ch == cfg.model.conv1_ch,
            "pc mask shape mismatch"
        );
        let conv1 = ConvModule::new(
            &weights.conv1_w,
            &weights.conv1_b,
            cfg.model.conv1_stride,
            IndexControl::from_mask(conv1_mask),
            true,
        );
        let pc = ConvModule::new(
            &weights.pc_w,
            &weights.pc_b,
            cfg.model.pc_stride,
            IndexControl::from_mask(pc_mask),
            false,
        );
        let w_ij = weights.w_ij.data.iter().map(|&x| Q12::from_f32(x)).collect();
        let routing = RoutingMode::Iterative(cfg.model.routing_iters);
        Ok(DeployedModel {
            config: cfg,
            conv1,
            pc,
            w_ij,
            routing,
            acc_coupling_q: None,
        })
    }

    /// Synthetic deployment matching a config's sparsity plan — random
    /// weights, masks with the plan's survivor counts. Used by functional
    /// tests/examples where values must be plausible.
    pub fn synthetic(cfg: &SystemConfig, seed: u64) -> DeployedModel {
        let mut rng = Rng::new(seed);
        let weights = Weights::random(&cfg.model, &mut rng);
        let (conv1_mask, pc_mask) = synthetic_masks(&cfg.model, &cfg.sparsity, &mut rng);
        DeployedModel::new(cfg.clone(), &weights, &conv1_mask, &pc_mask)
            .expect("synthetic deployment is always consistent")
    }

    /// Timing-only deployment: zero weights, plan-accurate masks. ~50×
    /// cheaper to build than [`DeployedModel::synthetic`] (no 5M-element
    /// random init); `estimate_frame`/resource reports depend only on the
    /// survivor geometry. §Perf L3 optimization for the report/bench path.
    pub fn timing_stub(cfg: &SystemConfig, seed: u64) -> DeployedModel {
        let mut rng = Rng::new(seed);
        let m = &cfg.model;
        let (c_in, _, _) = m.input;
        let weights = Weights {
            conv1_w: crate::tensor::Tensor::zeros(&[m.conv1_ch, c_in, m.conv1_k, m.conv1_k]),
            conv1_b: crate::tensor::Tensor::zeros(&[m.conv1_ch]),
            pc_w: crate::tensor::Tensor::zeros(&[m.pc_channels(), m.conv1_ch, m.pc_k, m.pc_k]),
            pc_b: crate::tensor::Tensor::zeros(&[m.pc_channels()]),
            w_ij: crate::tensor::Tensor::zeros(&[m.pc_types, m.num_classes, m.pc_dim, m.dc_dim]),
        };
        let (conv1_mask, pc_mask) = synthetic_masks(m, &cfg.sparsity, &mut rng);
        DeployedModel::new(cfg.clone(), &weights, &conv1_mask, &pc_mask)
            .expect("timing stub is always consistent")
    }

    /// Content fingerprint over the *quantized* deployed state: both
    /// conv modules (geometry, CSR survivor index, raw i16 weight/bias
    /// bits, weight formats) and the Q4.12 DigitCaps transform. A new
    /// prune plan changes the survivor index, a requantization changes
    /// the raw bits — either way the inference cache re-keys. Hashing
    /// the quantized bits (not the f32 source) matters: two f32 weight
    /// sets that quantize identically compute identically here.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::hash::Hash64::new(0x6670_6761); // "fpga"
        h.absorb_str(&self.config.model.name);
        self.conv1.absorb_fingerprint(&mut h);
        self.pc.absorb_fingerprint(&mut h);
        h.absorb(self.w_ij.len() as u64);
        for q in &self.w_ij {
            h.absorb(q.raw() as u16 as u64);
        }
        // Routing mode + any baked accumulated coefficients are part of
        // the computed function: the same weight bits route differently
        // under Iterative(r) vs Accumulated, so the inference cache must
        // re-key. Worker count is deliberately absent — sharding is
        // bit-identical by construction (`util::parallel`).
        h.absorb(self.routing.fingerprint_tag());
        if let Some(c) = &self.acc_coupling_q {
            h.absorb(c.len() as u64);
            for q in c {
                h.absorb(q.raw() as u16 as u64);
            }
        }
        h.finish()
    }

    /// Routing iterations the cycle model prices: `r` for
    /// `Iterative(r)`, 0 for `Accumulated` (no softmax / agreement /
    /// logit passes; the single FC + squash rides the û projection).
    pub fn effective_iters(&self) -> usize {
        self.routing.effective_iters()
    }

    /// Baked accumulated coupling coefficients, if any.
    pub fn acc_coupling(&self) -> Option<&[Q12]> {
        self.acc_coupling_q.as_deref()
    }

    /// Bake an f32 accumulated-coupling matrix (from
    /// [`DeployedModel::accumulate_coupling`] or a `.fcw` sidecar) into
    /// the Q4.12 datapath and switch to accumulated routing.
    pub fn bake_accumulated(&mut self, coupling: &[f32]) -> Result<()> {
        let m = &self.config.model;
        let n = self.config.sparsity.num_primary_caps(m) * m.num_classes;
        anyhow::ensure!(
            coupling.len() == n,
            "accumulated coupling has {} entries, geometry needs {n}",
            coupling.len()
        );
        self.acc_coupling_q = Some(quantize_coupling(coupling));
        self.routing = RoutingMode::Accumulated;
        Ok(())
    }

    /// Select the routing schedule. `Accumulated` requires coefficients
    /// baked first ([`DeployedModel::bake_accumulated`]).
    pub fn set_routing_mode(&mut self, mode: RoutingMode) -> Result<()> {
        anyhow::ensure!(
            !(mode.is_accumulated() && self.acc_coupling_q.is_none()),
            "accumulated routing requires baked coupling coefficients (run `fastcaps accumulate`)"
        );
        self.routing = mode;
        Ok(())
    }

    /// Offline accumulation pass (Zhao et al.): run the *iterative*
    /// Q4.12 pipeline over a calibration set and average the converged
    /// coupling coefficients per (capsule, class) in f64. The result
    /// feeds [`DeployedModel::bake_accumulated`] — derived on the same
    /// quantized datapath it will later replace.
    pub fn accumulate_coupling(&self, images: &[Tensor]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            !images.is_empty(),
            "accumulate needs at least one calibration frame"
        );
        let m = &self.config.model;
        let n = self.config.sparsity.num_primary_caps(m) * m.num_classes;
        let iters = m.routing_iters.max(1);
        let mode = self.softmax_mode();
        let mut scratch = BatchScratch::new();
        let mut sum = vec![0f64; n];
        for image in images {
            self.project_frame(image, &mut scratch)?;
            let out = scratch.routing.run(iters, mode);
            for (s, q) in sum.iter_mut().zip(&out.coupling) {
                *s += q.to_f32() as f64;
            }
        }
        let inv = 1.0 / images.len() as f64;
        Ok(sum.into_iter().map(|s| (s * inv) as f32).collect())
    }

    fn pe(&self) -> PeArray {
        PeArray::new(&self.config.options)
    }

    fn routing_hw(&self) -> RoutingHardware {
        if self.config.options.optimized_routing {
            RoutingHardware::optimized()
        } else {
            RoutingHardware::baseline()
        }
    }

    fn softmax_mode(&self) -> SoftmaxMode {
        if self.config.options.optimized_routing {
            SoftmaxMode::Taylor
        } else {
            SoftmaxMode::Baseline
        }
    }

    /// Bytes moved over DDR per frame, from two survivor-aware terms.
    ///
    /// **Weight replay**: all resident weights once, priced from the
    /// conv modules' *actual* CSR survivors
    /// ([`ddr::conv_weight_stream_bytes`]: packed words plus, for sparse
    /// layers, the index sidecar; a fully pruned layer streams nothing).
    /// Weights stay resident only when the deployment is pruned *and*
    /// its packed survivors (+ w_ij) actually fit the device — a
    /// lightly-pruned model whose CSR packing still overflows the
    /// 560 KB budget replays its weights like the original does.
    ///
    /// **û spill**: the unpruned design always spills û — its ledger
    /// saturates the device — and a *pruned* deployment spills too when
    /// its own BRAM plan overflows the budget: the masked (uncompacted)
    /// model keeps all 1152 capsules, whose 369 KB û cannot sit next to
    /// the activations, so û is written once and re-read by every FC
    /// and Agreement pass. The compacted presets fit (131.5 blocks) and
    /// pay nothing.
    ///
    /// At 100% density this reproduces the dense `param_counts` replay
    /// exactly (no sidecar, û spilled), keeping the 5-FPS anchor.
    pub fn ddr_bytes(&self) -> u64 {
        let m = &self.config.model;
        let s = &self.config.sparsity;
        let budget_bytes =
            (self.config.budget.bram36 as f64 * bram::BRAM36_BYTES as f64) as u64;
        let packed_resident = bram::csr_weight_bytes(
            self.conv1.survived(),
            self.conv1.total(),
            self.conv1.k * self.conv1.k,
            self.conv1.out_ch,
        ) as u64
            + bram::csr_weight_bytes(
                self.pc.survived(),
                self.pc.total(),
                self.pc.k * self.pc.k,
                self.pc.out_ch,
            ) as u64
            + (s.pc_types * m.num_classes * m.pc_dim * m.dc_dim * 2) as u64;
        let weights_resident = self.config.is_pruned() && packed_resident <= budget_bytes;
        let weights = if weights_resident {
            0
        } else {
            let conv_stream = ddr::conv_weight_stream_bytes(
                self.conv1.survived() as u64,
                self.conv1.total() as u64,
                (self.conv1.k * self.conv1.k) as u64,
                self.conv1.out_ch as u64,
            ) + ddr::conv_weight_stream_bytes(
                self.pc.survived() as u64,
                self.pc.total() as u64,
                (self.pc.k * self.pc.k) as u64,
                self.pc.out_ch as u64,
            );
            let (_, _, dc) = m.param_counts();
            conv_stream + dc * 2
        };
        let u_spilled = !self.config.is_pruned()
            || !resources::bram_plan(&self.config).fits(self.config.budget.bram36);
        let u_spill = if u_spilled {
            let u_bytes =
                (self.config.sparsity.num_primary_caps(m) * m.num_classes * m.dc_dim)
                    as u64
                    * 2;
            let r = self.effective_iters() as u64;
            // 1 write + R FC reads + (R−1) agreement reads, with R the
            // *effective* iteration count: accumulated mode runs zero
            // routing iterations, so its û traffic is exactly the
            // Iterative(0) figure (pinned by test). The agreement term
            // saturates: with r = 0 there is no agreement pass at all (a
            // plain `r - 1` would underflow u64 and panic in debug /
            // wrap to ~2⁶⁴ streamed bytes in release).
            u_bytes * (1 + r + r.saturating_sub(1))
        } else {
            0
        };
        weights + u_spill
    }

    /// Packing summary of the deployed conv layers — the same
    /// [`CompressionStats`] the sparse-compiled oracle reports, derived
    /// from the modules' actual CSR survivors so any deployment (preset,
    /// `sim-sparse`, or hand-built masks) can surface what it executes.
    pub fn compression(&self) -> CompressionStats {
        CompressionStats {
            survived_kernels: self.conv1.survived() + self.pc.survived(),
            total_kernels: self.conv1.total() + self.pc.total(),
            index_bytes: self.conv1.rows.index_bytes() + self.pc.rows.index_bytes(),
        }
    }

    /// Timing-only estimate of one frame (no values computed).
    pub fn estimate_frame(&self) -> FrameTiming {
        let m = &self.config.model;
        let pe = self.pe();
        let hw = self.routing_hw();
        let (_, ih, iw) = m.input;
        let (h1, w1) = m.conv1_out();
        // The original design is resource-starved (II=2 conv schedule).
        let conv_ii = if self.config.is_pruned() { 1 } else { 2 };
        let mem_bw = hw.mem_bw;

        let t1 = self.conv1.timing(ih, iw, &pe, conv_ii, mem_bw);
        let t2 = self.pc.timing(h1, w1, &pe, conv_ii, mem_bw);
        let n_caps = self.config.sparsity.num_primary_caps(m);
        let mut g = RoutingGeometry::from_config(m, n_caps);
        // Price the *effective* schedule: accumulated mode collapses the
        // routing stage to the 0-iteration formula (û projection only).
        g.iterations = self.effective_iters();
        let rt = routing_timing(&g, &hw, &pe);
        // Primary-capsule squash stage (before routing): n_caps squashes
        // through the dedicated Squash unit.
        use crate::fixed::latency::Op;
        let per_squash = (m.pc_dim as u64).div_ceil(pe.macs_per_pe as u64)
            + Op::Sqrt.cycles()
            + Op::DivFixed.cycles()
            + 2;
        let squash_cycles = if self.config.options.optimized_routing {
            // Capsules pipeline through the unit at the sqrt/div II bound.
            per_squash
                + (n_caps as u64 - 1)
                    * Op::Sqrt.initiation_interval().max(Op::DivFixed.initiation_interval())
        } else {
            n_caps as u64 * per_squash
        };
        let squash_stage = StageTiming {
            name: "primary-squash".into(),
            cycles: squash_cycles,
            macs: (n_caps * m.pc_dim) as u64,
            mem_words: (n_caps * m.pc_dim) as u64 * 2,
        };
        let routing_stage = routing_module::as_stage(&g, &hw, &pe);
        // The unpruned design cannot infer AXI bursts (the paper:
        // resource exhaustion "limits the usage of Vivado HLS
        // optimization directives"), so its replay pays single-beat
        // reads; a pruned fabric has the slack for the HP-port burst
        // DMA when its û spills.
        let ddr = match self.ddr_bytes() {
            0 => 0,
            bytes if self.config.is_pruned() => {
                DdrModel::default().stream_cycles_burst(bytes)
            }
            bytes => DdrModel::default().stream_cycles_single(bytes),
        };
        FrameTiming {
            stages: vec![t1, t2, squash_stage, routing_stage],
            routing: rt,
            ddr_cycles: ddr,
            clock_mhz: self.config.budget.clock_mhz,
        }
    }

    /// Timing-only estimate of a batch streaming through the stage
    /// pipeline (see [`BatchTiming`]).
    pub fn estimate_batch(&self, batch: usize) -> BatchTiming {
        BatchTiming {
            frame: self.estimate_frame(),
            batch,
        }
    }

    /// Run a batch of frames functionally through the quantized datapath,
    /// reusing one [`BatchScratch`] across frames — the production
    /// serving path. Values are bitwise identical to per-frame
    /// [`DeployedModel::run_frame`] (the datapath is integer arithmetic in
    /// wide accumulators, so the batch path's restructured traversals
    /// cannot change a bit; a property test pins this), but the host-side
    /// cost per marginal frame is much lower: conv runs through the
    /// slice-optimized [`ConvModule::forward_into`], û is projected
    /// weight-block-stationary straight into the routing scratch, nothing
    /// allocates per frame, and the cycle model is priced once per batch
    /// instead of once per frame.
    pub fn run_batch(&self, images: &[Tensor], scratch: &mut BatchScratch) -> Result<BatchOutput> {
        let mode = self.softmax_mode();
        let mut classes = Vec::with_capacity(images.len());
        let mut lengths = Vec::with_capacity(images.len());
        for image in images {
            self.project_frame(image, scratch)?;
            let out = match self.routing {
                RoutingMode::Iterative(r) => scratch.routing.run(r, mode),
                RoutingMode::Accumulated => scratch.routing.run_accumulated(
                    self.acc_coupling_q
                        .as_deref()
                        .expect("accumulated mode always carries baked coupling"),
                ),
            };
            let lens = out.lengths_f32();
            classes.push(crate::util::argmax(&lens));
            lengths.push(lens);
        }
        Ok(BatchOutput {
            classes,
            lengths,
            timing: self.estimate_batch(images.len()),
        })
    }

    /// Shard a batch over up to `workers` cores (contiguous frame
    /// chunks, one scoped thread + private [`BatchScratch`] each) and
    /// splice the per-chunk results back in input order. Frames are
    /// independent, so the output is bit-identical to
    /// [`DeployedModel::run_batch`] for every worker count (pinned by a
    /// property test); the batch timing still models one fabric.
    pub fn run_batch_sharded(&self, images: &[Tensor], workers: usize) -> Result<BatchOutput> {
        if workers <= 1 || images.len() <= 1 {
            let mut scratch = BatchScratch::new();
            return self.run_batch(images, &mut scratch);
        }
        let chunks = crate::util::parallel::shard_chunks(images, workers, |frames| {
            let mut scratch = BatchScratch::new();
            self.run_batch(frames, &mut scratch)
        });
        let mut classes = Vec::with_capacity(images.len());
        let mut lengths = Vec::with_capacity(images.len());
        for chunk in chunks {
            let out = chunk?;
            classes.extend(out.classes);
            lengths.extend(out.lengths);
        }
        Ok(BatchOutput {
            classes,
            lengths,
            timing: self.estimate_batch(images.len()),
        })
    }

    /// Per-frame front half of the serving pipeline: quantized conv
    /// stages, capsule regroup + squash, and the weight-block-stationary
    /// û projection, leaving `scratch.routing` prepared with û filled.
    /// Shared verbatim by [`DeployedModel::run_batch`] (both routing
    /// modes) and [`DeployedModel::accumulate_coupling`], so the
    /// calibration pass sees exactly the serving datapath.
    fn project_frame(&self, image: &Tensor, scratch: &mut BatchScratch) -> Result<()> {
        let m = &self.config.model;
        let (c_in, ih, iw) = m.input;
        let (h1, w1) = m.conv1_out();
        let (h2, w2) = m.pc_out();
        let n_caps = self.config.sparsity.num_primary_caps(m);
        let types = self.config.sparsity.pc_types.min(m.pc_types);
        let d = m.pc_dim;
        let spatial = h2 * w2;
        let n_out = m.num_classes;
        let d_out = m.dc_dim;
        anyhow::ensure!(
            image.shape == vec![c_in, ih, iw],
            "input shape {:?} != {:?}",
            image.shape,
            (c_in, ih, iw)
        );
        // Conv stages in Q8.8.
        scratch.input_q.clear();
        scratch
            .input_q
            .extend(image.data.iter().map(|&x| Q8::from_f32(x)));
        self.conv1.forward_into(
            &scratch.input_q,
            ih,
            iw,
            &mut scratch.conv_acc,
            &mut scratch.conv1_out,
        );
        self.pc.forward_into(
            &scratch.conv1_out,
            h1,
            w1,
            &mut scratch.conv_acc,
            &mut scratch.pc_out,
        );

        // Regroup into capsules and squash (Q4.12 from here on).
        let mut counts = OpCounts::default();
        scratch.primary.clear();
        scratch.primary.resize(n_caps * d, Q12::ZERO);
        for t in 0..types {
            for p in 0..spatial {
                let cap = t * spatial + p;
                scratch.s_raw.clear();
                scratch
                    .s_raw
                    .extend((0..d).map(|k| scratch.pc_out[(t * d + k) * spatial + p].raw()));
                crate::routing::fixed::squash_q88_into(
                    &scratch.s_raw,
                    &mut scratch.primary[cap * d..(cap + 1) * d],
                    &mut counts,
                );
            }
        }

        // û projection on the PE array, weight-block-stationary over
        // (type, class), written straight into the routing scratch.
        scratch.routing.prepare(n_caps, n_out, d_out);
        let u_hat = scratch.routing.u_hat_mut();
        for t in 0..types {
            for j in 0..n_out {
                let base = ((t * n_out) + j) * d * d_out;
                let wblock = &self.w_ij[base..base + d * d_out];
                for p in 0..spatial {
                    let cap = t * spatial + p;
                    let u = &scratch.primary[cap * d..(cap + 1) * d];
                    // Capsule-row-stationary: each û row accumulates all
                    // d_out lanes at once, one axpy per input dim. The
                    // i64 accumulators make the reorder bit-free, and the
                    // contiguous `d_out`-wide weight rows vectorize.
                    scratch.u_acc.clear();
                    scratch.u_acc.resize(d_out, 0);
                    for (kk, &uk) in u.iter().enumerate() {
                        kernels::axpy_i16(
                            &mut scratch.u_acc,
                            uk.raw(),
                            raw_slice(&wblock[kk * d_out..(kk + 1) * d_out]),
                        );
                    }
                    let urow = &mut u_hat[(cap * n_out + j) * d_out..][..d_out];
                    for (o, &a) in urow.iter_mut().zip(&scratch.u_acc) {
                        *o = Q12::from_acc(a);
                    }
                }
            }
        }
        Ok(())
    }

    /// Run one frame functionally (quantized datapath) and return the
    /// predicted class, DigitCaps lengths, and the frame timing.
    pub fn run_frame(&self, image: &Tensor) -> Result<(usize, Vec<f32>, FrameTiming)> {
        let m = &self.config.model;
        let (c_in, ih, iw) = m.input;
        anyhow::ensure!(
            image.shape == vec![c_in, ih, iw],
            "input shape {:?} != {:?}",
            image.shape,
            (c_in, ih, iw)
        );
        // Conv stages in Q8.8.
        let input_q: Vec<Q8> = image.data.iter().map(|&x| Q8::from_f32(x)).collect();
        let conv1_out = self.conv1.forward(&input_q, ih, iw);
        let (h1, w1) = m.conv1_out();
        let pc_out = self.pc.forward(&conv1_out, h1, w1);
        let (h2, w2) = m.pc_out();

        // Regroup into capsules and squash (Q4.12 from here on).
        let n_caps = self.config.sparsity.num_primary_caps(m);
        let types = self.config.sparsity.pc_types.min(m.pc_types);
        let d = m.pc_dim;
        let spatial = h2 * w2;
        let mut counts = crate::routing::fixed::OpCounts::default();
        let mut primary = vec![Q12::ZERO; n_caps * d];
        for t in 0..types {
            for p in 0..spatial {
                let cap = t * spatial + p;
                // pc activations are already Q8.8 — feed the Squash unit's
                // wide-input port directly.
                let s_raw: Vec<i16> = (0..d)
                    .map(|k| pc_out[(t * d + k) * spatial + p].raw())
                    .collect();
                let v = crate::routing::fixed::squash_q88(&s_raw, &mut counts);
                primary[cap * d..(cap + 1) * d].copy_from_slice(&v);
            }
        }

        // û projection on the PE array (shared transform per type).
        let n_out = m.num_classes;
        let d_out = m.dc_dim;
        let mut u_hat = vec![Q12::ZERO; n_caps * n_out * d_out];
        for cap in 0..n_caps {
            let t = cap / spatial;
            let u = &primary[cap * d..(cap + 1) * d];
            for j in 0..n_out {
                for k_out in 0..d_out {
                    // Column k_out of W[t][j] (stride d_out).
                    let base = ((t * n_out) + j) * d * d_out + k_out;
                    let mut acc = 0i64;
                    for (kk, &uk) in u.iter().enumerate() {
                        acc = uk.mac(self.w_ij[base + kk * d_out], acc);
                    }
                    u_hat[(cap * n_out + j) * d_out + k_out] = Q12::from_acc(acc);
                }
            }
        }
        let pred = PredictionsQ12 {
            n_in: n_caps,
            n_out,
            d_out,
            u_hat,
        };
        let out = match self.routing {
            RoutingMode::Iterative(r) => dynamic_routing_q12(&pred, r, self.softmax_mode()),
            RoutingMode::Accumulated => accumulated_routing_q12(
                &pred,
                self.acc_coupling_q
                    .as_deref()
                    .expect("accumulated mode always carries baked coupling"),
            ),
        };
        let lengths = out.lengths_f32();
        let class = crate::util::argmax(&lengths);
        Ok((class, lengths, self.estimate_frame()))
    }
}

/// Reusable working buffers for [`DeployedModel::run_batch`]: the
/// quantized input, conv accumulator/activation arrays (one accumulator
/// shared by both conv stages), primary capsules, and the routing
/// scratch. One `BatchScratch` lives for an executor's whole life, so
/// steady-state serving allocates nothing per frame.
#[derive(Debug, Default)]
pub struct BatchScratch {
    input_q: Vec<Q8>,
    conv_acc: Vec<i64>,
    conv1_out: Vec<Q8>,
    pc_out: Vec<Q8>,
    primary: Vec<Q12>,
    s_raw: Vec<i16>,
    /// i64 accumulator row for the û projection (one `dc_dim` row).
    u_acc: Vec<i64>,
    routing: RoutingScratch,
}

impl BatchScratch {
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }
}

/// Functional + timing result of [`DeployedModel::run_batch`].
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Predicted class per frame (NaN-safe argmax of the lengths).
    pub classes: Vec<usize>,
    /// DigitCaps lengths per frame.
    pub lengths: Vec<Vec<f32>>,
    /// Pipelined cycle model for the whole batch.
    pub timing: BatchTiming,
}

/// Build synthetic kernel masks matching a sparsity plan: survivors spread
/// round-robin over output channels so every capsule type stays alive.
pub fn synthetic_masks(
    model: &crate::config::CapsNetConfig,
    plan: &SparsityPlan,
    rng: &mut Rng,
) -> (KernelMask, KernelMask) {
    let c_in = model.input.0;
    let mut conv1 = KernelMask::all_alive(model.conv1_ch, c_in);
    let total1 = model.conv1_ch * c_in;
    let keep1 = plan.conv1_kernels.min(total1);
    let mut order: Vec<usize> = (0..total1).collect();
    rng.shuffle(&mut order);
    for &n in order.iter().skip(keep1) {
        conv1.set(n / c_in, n % c_in, false);
    }

    let pc_ch = model.pc_channels();
    let mut pc = KernelMask::all_alive(pc_ch, model.conv1_ch);
    let total2 = pc_ch * model.conv1_ch;
    let keep2 = plan.pc_kernels.min(total2);
    if keep2 < total2 {
        // Round-robin over output channels (keeps every capsule type
        // alive), shuffled input channels within each row.
        let mut per_row = vec![0usize; pc_ch];
        for n in 0..keep2 {
            per_row[n % pc_ch] += 1;
        }
        let mut cols: Vec<usize> = (0..model.conv1_ch).collect();
        for (oc, &keep_row) in per_row.iter().enumerate() {
            rng.shuffle(&mut cols);
            for &ic in cols.iter().skip(keep_row) {
                pc.set(oc, ic, false);
            }
        }
    }
    (conv1, pc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    #[test]
    fn deployment_fingerprint_tracks_survivor_masks() {
        // Zero weights, plan-accurate masks: the only seed-dependent
        // content is the survivor index, so this pins that a re-prune
        // alone (same weight bits) re-keys the deployment.
        let cfg = SystemConfig::masked("mnist");
        let a = DeployedModel::timing_stub(&cfg, 7);
        assert_eq!(
            a.fingerprint(),
            DeployedModel::timing_stub(&cfg, 7).fingerprint(),
            "same config + seed must fingerprint identically"
        );
        assert_ne!(
            a.fingerprint(),
            DeployedModel::timing_stub(&cfg, 8).fingerprint(),
            "different masks must fingerprint differently"
        );
    }

    #[test]
    fn synthetic_masks_match_plan() {
        let cfg = SystemConfig::proposed("mnist");
        let mut rng = Rng::new(1);
        let (c1, pc) = synthetic_masks(&cfg.model, &cfg.sparsity, &mut rng);
        assert_eq!(c1.survived(), cfg.sparsity.conv1_kernels);
        assert_eq!(pc.survived(), cfg.sparsity.pc_kernels);
        // Every capsule type alive.
        assert_eq!(
            crate::pruning::surviving_capsule_types(&pc, cfg.model.pc_dim),
            cfg.model.pc_types
        );
    }

    #[test]
    fn paper_throughput_shape() {
        // Fig. 1 / Table II anchors: 5 → 82 → 1351 FPS (MNIST) and
        // 48 → 934 (F-MNIST). The simulator must land in the right decade
        // and preserve every ordering/ratio.
        let fps =
            |cfg: &SystemConfig| DeployedModel::synthetic(cfg, 7).estimate_frame().fps();
        let orig = fps(&SystemConfig::original("mnist"));
        let pruned = fps(&SystemConfig::pruned("mnist"));
        let prop = fps(&SystemConfig::proposed("mnist"));
        let pruned_f = fps(&SystemConfig::pruned("fmnist"));
        let prop_f = fps(&SystemConfig::proposed("fmnist"));

        assert!((3.0..8.0).contains(&orig), "original {orig:.1} FPS (paper 5)");
        assert!((55.0..120.0).contains(&pruned), "pruned {pruned:.0} (paper 82)");
        assert!((900.0..2000.0).contains(&prop), "proposed {prop:.0} (paper 1351)");
        assert!((32.0..70.0).contains(&pruned_f), "pruned-f {pruned_f:.0} (paper 48)");
        assert!((600.0..1400.0).contains(&prop_f), "proposed-f {prop_f:.0} (paper 934)");
        // Orderings.
        assert!(orig < pruned && pruned < prop);
        assert!(pruned_f < pruned, "F-MNIST slower (more capsules)");
        assert!(prop_f < prop);
        // Headline speedup (paper: 270×).
        let speedup = prop / orig;
        assert!(
            (150.0..450.0).contains(&speedup),
            "speedup {speedup:.0}x (paper 270x)"
        );
    }

    #[test]
    fn original_is_ddr_bound() {
        let d = DeployedModel::synthetic(&SystemConfig::original("mnist"), 3);
        let t = d.estimate_frame();
        assert!(t.ddr_cycles > t.compute_cycles(), "streaming dominates");
        // Latency ~0.19 s (Table II).
        assert!(
            (0.1..0.3).contains(&t.latency_s()),
            "latency {}",
            t.latency_s()
        );
    }

    #[test]
    fn proposed_latency_sub_millisecond_scale() {
        // Table II: 0.74 ms.
        let d = DeployedModel::synthetic(&SystemConfig::proposed("mnist"), 3);
        let t = d.estimate_frame();
        assert!(t.latency_s() < 0.0015, "latency {}", t.latency_s());
        assert_eq!(t.ddr_cycles, 0, "everything on-chip");
    }

    #[test]
    fn functional_run_agrees_with_estimate() {
        // run_frame's timing is estimate_frame — one code path.
        let cfg = SystemConfig::proposed("mnist");
        let d = DeployedModel::synthetic(&cfg, 5);
        let mut rng = Rng::new(9);
        let img = crate::data::digits::render(3, &mut rng);
        let (class, lengths, t) = d.run_frame(&img).unwrap();
        assert!(class < 10);
        assert_eq!(lengths.len(), 10);
        assert!(lengths.iter().all(|&l| (0.0..1.05).contains(&l)));
        assert_eq!(t.total_cycles(), d.estimate_frame().total_cycles());
    }

    #[test]
    fn property_run_batch_bitwise_matches_run_frame() {
        // One scratch threaded across batches and both routing modes: the
        // batch path must reproduce run_frame bit for bit (integer
        // datapath — reordering is exact), with no state leaking between
        // frames.
        let proposed = DeployedModel::synthetic(&SystemConfig::proposed("mnist"), 5);
        let pruned = DeployedModel::synthetic(&SystemConfig::pruned("mnist"), 5);
        let mut scratch = BatchScratch::new();
        crate::testing::check_msg(
            "run_batch == per-frame run_frame (bitwise)",
            6,
            13,
            |r| {
                let n = 1 + r.below(4);
                let imgs: Vec<Tensor> = (0..n)
                    .map(|_| crate::data::digits::render(r.below(10), r))
                    .collect();
                (r.below(2) == 0, imgs)
            },
            |(use_proposed, imgs)| {
                let model = if *use_proposed { &proposed } else { &pruned };
                let out = model.run_batch(imgs, &mut scratch).map_err(|e| e.to_string())?;
                for (i, img) in imgs.iter().enumerate() {
                    let (class, lens, _) = model.run_frame(img).map_err(|e| e.to_string())?;
                    if out.classes[i] != class {
                        return Err(format!("class {} != {}", out.classes[i], class));
                    }
                    if out.lengths[i] != lens {
                        return Err(format!(
                            "lengths diverge at frame {i}: {:?} vs {:?}",
                            out.lengths[i], lens
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn batch_pipeline_beats_single_frame_for_proposed() {
        // Steady-state FPS (1 / slowest stage) must exceed the 1/latency
        // FPS for the on-chip designs, where no single stage dominates
        // the whole frame; the DDR-streaming original stays bound by the
        // serial weight stream in both views.
        for dataset in ["mnist", "fmnist"] {
            let d = DeployedModel::timing_stub(&SystemConfig::proposed(dataset), 7);
            let frame = d.estimate_frame();
            let batch = d.estimate_batch(8);
            assert!(
                batch.steady_state_fps() > frame.fps(),
                "{dataset}: pipelined {:.0} FPS should beat single-frame {:.0}",
                batch.steady_state_fps(),
                frame.fps()
            );
            // First frame pays the full latency; each further frame costs
            // exactly one initiation interval.
            assert_eq!(
                batch.total_cycles(),
                frame.total_cycles() + 7 * batch.initiation_cycles()
            );
            assert_eq!(d.estimate_batch(1).total_cycles(), frame.total_cycles());
            // Effective batch FPS sits between the two throughput views.
            assert!(batch.batch_fps() > frame.fps());
            assert!(batch.batch_fps() < batch.steady_state_fps());
            assert_eq!(d.estimate_batch(0).batch_fps(), 0.0);
        }
        let orig = DeployedModel::timing_stub(&SystemConfig::original("mnist"), 7);
        let bt = orig.estimate_batch(8);
        assert_eq!(
            bt.initiation_cycles(),
            orig.estimate_frame().total_cycles(),
            "original stays DDR-bound frame to frame"
        );
    }

    #[test]
    fn property_sparse_deployment_matches_masked_dense() {
        // Acceptance pin: the CSR-packed deployment of unmasked weights
        // under a random mask is bitwise identical to deploying the
        // masked (zeroed) tensor densely — same frac_w, same survivor
        // quantization, same integer accumulation order; dead kernels
        // contribute exact zeros in the dense run.
        let cfg = SystemConfig::proposed("mnist");
        let model_cfg = cfg.model.clone();
        let mut scratch_s = BatchScratch::new();
        let mut scratch_d = BatchScratch::new();
        crate::testing::check_msg(
            "CSR DeployedModel ≡ masked-dense deployment (bitwise)",
            3,
            29,
            |r| {
                let weights = Weights::random(&model_cfg, r);
                let mut conv1 =
                    KernelMask::all_alive(model_cfg.conv1_ch, model_cfg.input.0);
                let mut pc =
                    KernelMask::all_alive(model_cfg.pc_channels(), model_cfg.conv1_ch);
                for o in 0..conv1.out_ch {
                    for i in 0..conv1.in_ch {
                        if r.below(4) == 0 {
                            conv1.set(o, i, false);
                        }
                    }
                }
                for o in 0..pc.out_ch {
                    for i in 0..pc.in_ch {
                        if r.below(3) == 0 {
                            pc.set(o, i, false);
                        }
                    }
                }
                let imgs: Vec<Tensor> =
                    (0..2).map(|c| crate::data::digits::render(c, r)).collect();
                (weights, conv1, pc, imgs)
            },
            |(weights, conv1, pc, imgs)| {
                let sparse = DeployedModel::new(cfg.clone(), weights, conv1, pc)
                    .map_err(|e| e.to_string())?;
                let mut masked = weights.clone();
                conv1.apply(&mut masked.conv1_w);
                pc.apply(&mut masked.pc_w);
                let a1 = KernelMask::all_alive(model_cfg.conv1_ch, model_cfg.input.0);
                let a2 =
                    KernelMask::all_alive(model_cfg.pc_channels(), model_cfg.conv1_ch);
                let dense = DeployedModel::new(cfg.clone(), &masked, &a1, &a2)
                    .map_err(|e| e.to_string())?;
                for img in imgs {
                    let (cs, ls, _) = sparse.run_frame(img).map_err(|e| e.to_string())?;
                    let (cd, ld, _) = dense.run_frame(img).map_err(|e| e.to_string())?;
                    if cs != cd || ls != ld {
                        return Err(format!("run_frame diverged: {ls:?} vs {ld:?}"));
                    }
                }
                let bs = sparse
                    .run_batch(imgs, &mut scratch_s)
                    .map_err(|e| e.to_string())?;
                let bd = dense
                    .run_batch(imgs, &mut scratch_d)
                    .map_err(|e| e.to_string())?;
                if bs.lengths != bd.lengths {
                    return Err("run_batch diverged".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn density_one_timing_equals_prerefactor_dense_model() {
        // On every paper anchor geometry, the CSR cycle model at 100%
        // density must reproduce the pre-refactor dense timing exactly —
        // conv stages, DDR replay bytes, and the pipelined batch totals
        // (the no-regression pin for the Fig. 1 / Table II numbers).
        let presets = [
            SystemConfig::original("mnist"),
            SystemConfig::original("fmnist"),
            SystemConfig::pruned("mnist"),
            SystemConfig::pruned("fmnist"),
            SystemConfig::proposed("mnist"),
            SystemConfig::proposed("fmnist"),
        ];
        for preset in presets {
            let sparsity = SparsityPlan::dense(&preset.model);
            let cfg = SystemConfig { sparsity, ..preset };
            let d = DeployedModel::timing_stub(&cfg, 11);
            let m = &cfg.model;
            let pe = PeArray::new(&cfg.options);
            let hw = if cfg.options.optimized_routing {
                RoutingHardware::optimized()
            } else {
                RoutingHardware::baseline()
            };
            let ii = if cfg.is_pruned() { 1 } else { 2 };
            // Pre-refactor dense conv stage: flat survivor list over the
            // full grid, fetch overhead 4 + kernels/64, no row terms.
            let stage = |out_ch: usize, in_ch: usize, k: usize, stride: usize, h: usize, w: usize| {
                let oh = (h - k) / stride + 1;
                let ow = (w - k) / stride + 1;
                let kernels = (out_ch * in_ch) as u64;
                let macs = (oh * ow) as u64 * kernels * (k * k) as u64;
                let compute =
                    pe.mac_cycles(macs, ii) + 4 + kernels / 64 + oh as u64 * pe.depth;
                let mem = ((out_ch * oh * ow) as u64).div_ceil(hw.mem_bw.max(1));
                (compute.max(mem), macs)
            };
            let (_, ih, iw) = m.input;
            let (h1, w1) = m.conv1_out();
            let want1 = stage(m.conv1_ch, m.input.0, m.conv1_k, m.conv1_stride, ih, iw);
            let want2 = stage(m.pc_channels(), m.conv1_ch, m.pc_k, m.pc_stride, h1, w1);
            let t = d.estimate_frame();
            assert_eq!((t.stages[0].cycles, t.stages[0].macs), want1, "{} conv1", m.name);
            assert_eq!((t.stages[1].cycles, t.stages[1].macs), want2, "{} pc", m.name);
            // Pre-refactor DDR replay: dense param counts, no sidecar.
            let (c1, pc_p, dc) = m.param_counts();
            let u = (m.num_primary_caps() * m.num_classes * m.dc_dim) as u64 * 2;
            let r = m.routing_iters as u64;
            let want_bytes = (c1 + pc_p + dc) * 2 + u * (1 + r + r.saturating_sub(1));
            assert_eq!(d.ddr_bytes(), want_bytes, "{} ddr", m.name);
            assert_eq!(
                t.ddr_cycles,
                DdrModel::default().stream_cycles_single(want_bytes)
            );
            // Batch totals compose from the pinned stage numbers.
            let b = d.estimate_batch(8);
            let init = want1
                .0
                .max(want2.0)
                .max(t.stages[2].cycles)
                .max(t.stages[3].cycles)
                .max(t.ddr_cycles);
            assert_eq!(b.initiation_cycles(), init, "{}", m.name);
            assert_eq!(b.total_cycles(), t.total_cycles() + 7 * init);
        }
    }

    #[test]
    fn masked_sparse_sim_strictly_dominates_dense_sim() {
        // Acceptance anchor: at the paper's survivor counts the
        // sparsity-aware datapath strictly beats the dense simulator in
        // modeled steady-state FPS, and streams nothing over DDR.
        for ds in ["mnist", "fmnist"] {
            let dense = DeployedModel::timing_stub(&SystemConfig::original(ds), 7);
            let sparse = DeployedModel::timing_stub(&SystemConfig::masked(ds), 7);
            // Survivor weights live on-chip; only the uncompacted û
            // spills (1152 capsules × 10 × 16 × 2 B, written once +
            // 3 FC reads + 2 agreement reads) — a fraction of the dense
            // design's full replay.
            let u_spill = (1152 * 10 * 16 * 2) as u64 * 6;
            assert_eq!(sparse.ddr_bytes(), u_spill, "only û spills");
            assert!(dense.ddr_bytes() > 4 * sparse.ddr_bytes());
            let (db, sb) = (dense.estimate_batch(8), sparse.estimate_batch(8));
            assert!(
                sb.steady_state_fps() > db.steady_state_fps(),
                "{ds}: sparse {:.1} FPS !> dense {:.1} FPS",
                sb.steady_state_fps(),
                db.steady_state_fps()
            );
            assert!(sparse.estimate_frame().fps() > dense.estimate_frame().fps());
            let c = sparse.compression();
            assert!(c.pruned_pct() > 98.0, "{}", c.pruned_pct());
            assert_eq!(c.total_kernels, 256 + 65536);
        }
    }

    #[test]
    fn ddr_bytes_survive_zero_routing_iterations() {
        // Regression: the (r − 1) agreement-read term used to underflow
        // u64 for routing_iters = 0.
        let mut cfg = SystemConfig::original("mnist");
        cfg.model.routing_iters = 0;
        let d = DeployedModel::timing_stub(&cfg, 3);
        let t = d.estimate_frame();
        assert!(t.ddr_cycles > 0, "weights still stream");
        // Sanity: fewer iterations stream strictly fewer bytes.
        let full = DeployedModel::timing_stub(&SystemConfig::original("mnist"), 3);
        assert!(t.ddr_cycles < full.estimate_frame().ddr_cycles);
    }

    #[test]
    fn taylor_mode_preserves_prediction() {
        // §IV-B "did not lead to a reduction in accuracy": baseline and
        // optimized datapaths agree on the argmax for real inputs.
        let mut rng = Rng::new(11);
        let base_cfg = SystemConfig::pruned("mnist");
        let opt_cfg = SystemConfig::proposed("mnist");
        // Same weights/masks for both (same seed).
        let d_base = DeployedModel::synthetic(&base_cfg, 21);
        let d_opt = DeployedModel::synthetic(&opt_cfg, 21);
        let mut agree = 0;
        let n = 6;
        for c in 0..n {
            let img = crate::data::digits::render(c, &mut rng);
            let (a, _, _) = d_base.run_frame(&img).unwrap();
            let (b, _, _) = d_opt.run_frame(&img).unwrap();
            if a == b {
                agree += 1;
            }
        }
        assert!(agree >= n - 1, "only {agree}/{n} predictions agree");
    }

    #[test]
    fn accumulated_timing_equals_iterative_zero() {
        // Satellite pin: the cycle/DDR model treats accumulated routing
        // as exactly the 0-iteration schedule — coefficients are modeled
        // resident in BRAM, so no term differs from Iterative(0).
        for cfg in [
            SystemConfig::proposed("mnist"),
            SystemConfig::original("mnist"),
            SystemConfig::masked("fmnist"),
        ] {
            let base = DeployedModel::timing_stub(&cfg, 7);
            let n = cfg.sparsity.num_primary_caps(&cfg.model) * cfg.model.num_classes;
            let mut acc = base.clone();
            acc.bake_accumulated(&vec![0.1f32; n]).unwrap();
            let mut iter0 = base.clone();
            iter0.set_routing_mode(RoutingMode::Iterative(0)).unwrap();
            assert_eq!(acc.ddr_bytes(), iter0.ddr_bytes(), "{}", cfg.model.name);
            let (ta, t0) = (acc.estimate_frame(), iter0.estimate_frame());
            assert_eq!(ta.routing.total(), t0.routing.total(), "{}", cfg.model.name);
            assert_eq!(ta.total_cycles(), t0.total_cycles(), "{}", cfg.model.name);
            // And strictly cheaper than the iterative default (r ≥ 3
            // softmax/FC/agreement passes all vanish).
            assert!(
                ta.total_cycles() < base.estimate_frame().total_cycles(),
                "{}",
                cfg.model.name
            );
        }
    }

    #[test]
    fn property_sharded_run_batch_bit_identical_across_worker_counts() {
        // Satellite pin: run_batch output is bit-identical for worker
        // counts 1/2/4 (and an oversubscribed 9), in both routing modes
        // — worker count can never key a cache entry.
        let mut base = DeployedModel::synthetic(&SystemConfig::proposed("mnist"), 5);
        let mut rng = Rng::new(17);
        let imgs: Vec<Tensor> = (0..6)
            .map(|c| crate::data::digits::render(c % 10, &mut rng))
            .collect();
        let coupling = base.accumulate_coupling(&imgs).unwrap();
        let iterative = base.clone();
        base.bake_accumulated(&coupling).unwrap();
        for model in [&iterative, &base] {
            let mut scratch = BatchScratch::new();
            let serial = model.run_batch(&imgs, &mut scratch).unwrap();
            for workers in [1usize, 2, 4, 9] {
                let sharded = model.run_batch_sharded(&imgs, workers).unwrap();
                assert_eq!(
                    serial.classes, sharded.classes,
                    "workers={workers} ({})",
                    model.routing
                );
                assert_eq!(
                    serial.lengths, sharded.lengths,
                    "workers={workers} ({})",
                    model.routing
                );
            }
        }
    }

    #[test]
    fn accumulated_mode_rekeys_fingerprint_and_stays_frame_batch_bitwise() {
        let cfg = SystemConfig::proposed("mnist");
        let mut d = DeployedModel::synthetic(&cfg, 9);
        let fp_iter = d.fingerprint();
        assert!(
            d.set_routing_mode(RoutingMode::Accumulated).is_err(),
            "accumulated mode must refuse to run without baked coefficients"
        );
        let mut rng = Rng::new(3);
        let cal: Vec<Tensor> = (0..8)
            .map(|c| crate::data::digits::render(c % 10, &mut rng))
            .collect();
        let coupling = d.accumulate_coupling(&cal).unwrap();
        assert_eq!(
            coupling.len(),
            cfg.sparsity.num_primary_caps(&cfg.model) * cfg.model.num_classes
        );
        d.bake_accumulated(&coupling).unwrap();
        assert!(d.routing.is_accumulated());
        assert_ne!(
            d.fingerprint(),
            fp_iter,
            "mode + coefficients must re-key the deployment"
        );
        // run_frame and run_batch stay bitwise identical in accumulated
        // mode (same datapath invariant as the iterative pin above).
        let mut scratch = BatchScratch::new();
        let out = d.run_batch(&cal, &mut scratch).unwrap();
        for (i, img) in cal.iter().enumerate() {
            let (class, lens, _) = d.run_frame(img).unwrap();
            assert_eq!(out.classes[i], class, "frame {i}");
            assert_eq!(out.lengths[i], lens, "frame {i}");
        }
    }
}
