//! Dynamic Routing Module (Fig. 10b): cycle model for every step of the
//! routing algorithm, in the baseline and §III-B-optimized schedules.
//!
//! Baseline (Code 1, before optimization):
//! * û projection, FC and Agreement run on the scalar datapath HLS infers
//!   (1 MAC/cycle — §III-B parallelizes them onto the PE array, so before
//!   that they are not on it).
//! * softmax uses the serial 27-cycle `exp` and 49-cycle divider, one
//!   evaluation at a time (the iterative units cannot pipeline).
//!
//! Optimized (Code 2 + Eq. 2/3):
//! * û projection, FC and Agreement pipeline on the PE array at II=1
//!   (loop reorder removes the `b[i][j]` write conflict).
//! * softmax evaluates Eq. 2 on a 10-lane exp array (II=1) and divides
//!   through 2 exp/log divider instances (II=1) — rows pipeline.
//! * Squash is unchanged in both (dedicated unit: MAC tree, sqrt 16,
//!   exact div 49 — the paper excludes Squash from the PE array).
//!
//! The *functional* values come from `routing::fixed`; this module only
//! prices the schedule, so numbers and timing stay in lockstep via
//! [`OpCounts`].

use super::conv_module::StageTiming;
use super::pe::PeArray;
use crate::fixed::latency::{parallel_cycles, pipelined_cycles, Op};

/// Routing problem geometry.
#[derive(Debug, Clone, Copy)]
pub struct RoutingGeometry {
    pub n_caps: usize,
    pub n_classes: usize,
    pub d_in: usize,
    pub d_out: usize,
    pub iterations: usize,
}

impl RoutingGeometry {
    pub fn from_config(cfg: &crate::config::CapsNetConfig, n_caps: usize) -> Self {
        RoutingGeometry {
            n_caps,
            n_classes: cfg.num_classes,
            d_in: cfg.pc_dim,
            d_out: cfg.dc_dim,
            iterations: cfg.routing_iters,
        }
    }
}

/// Per-step cycle breakdown — the rows of Fig. 8.
#[derive(Debug, Clone)]
pub struct RoutingTiming {
    pub u_hat: u64,
    pub softmax: u64,
    pub fc: u64,
    pub agreement: u64,
    pub squash: u64,
    pub logit_update: u64,
}

impl RoutingTiming {
    pub fn total(&self) -> u64 {
        self.u_hat + self.softmax + self.fc + self.agreement + self.squash + self.logit_update
    }

    pub fn stages(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("u_hat (FC projection)", self.u_hat),
            ("softmax", self.softmax),
            ("FC (weighted sum)", self.fc),
            ("agreement", self.agreement),
            ("squash", self.squash),
            ("logit update", self.logit_update),
        ]
    }
}

/// Hardware knobs of the routing module.
#[derive(Debug, Clone, Copy)]
pub struct RoutingHardware {
    pub optimized: bool,
    /// Exp lanes in the optimized softmax (paper: array of 10 PEs).
    pub exp_lanes: u64,
    /// Eq. 3 divider instances.
    pub div_units: u64,
    /// Routing-state BRAM bandwidth, words/cycle (banks × ports).
    pub mem_bw: u64,
}

impl RoutingHardware {
    pub fn baseline() -> Self {
        RoutingHardware {
            optimized: false,
            exp_lanes: 1,
            div_units: 1,
            mem_bw: 2,
        }
    }

    pub fn optimized() -> Self {
        RoutingHardware {
            optimized: true,
            exp_lanes: 10,
            div_units: 2,
            // û partitioned over 16 dual-port banks (see
            // `resources::bram_plan`), read one word per port per cycle.
            mem_bw: 16,
        }
    }
}

/// Cycle model for the full routing stage of one frame.
pub fn routing_timing(g: &RoutingGeometry, hw: &RoutingHardware, pe: &PeArray) -> RoutingTiming {
    let n = g.n_caps as u64;
    let j = g.n_classes as u64;
    let r = g.iterations as u64;
    let d_in = g.d_in as u64;
    let d_out = g.d_out as u64;

    // û projection: N·J·d_in·d_out MACs, once per frame.
    let u_hat_macs = n * j * d_in * d_out;
    // FC weighted sum: N·J·d_out MACs per iteration.
    let fc_macs = n * j * d_out;
    // Agreement: N·J·d_out MACs, iterations−1 times.
    let agree_macs = n * j * d_out;
    // Memory: û is written once and read every FC + agreement pass.
    let u_words = n * j * d_out;

    if hw.optimized {
        // PE array, II=1; rows pipeline through the softmax units.
        let mem = |words: u64| words.div_ceil(hw.mem_bw);
        let u_hat = pe.mac_cycles(u_hat_macs, 1).max(mem(u_words * 2));
        // Softmax per iteration: N rows; per row J exps over `exp_lanes`
        // then J divisions over `div_units`; rows pipeline at
        // II = max(J/lanes, J/divs).
        let row_ii = (j.div_ceil(hw.exp_lanes)).max(j.div_ceil(hw.div_units));
        let fill = Op::ExpTaylor.cycles() + Op::DivExpLog.cycles() + 4;
        let softmax = r * (fill + (n - 1).max(0) * row_ii + n * j / hw.mem_bw);
        let fc = r * pe.mac_cycles(fc_macs, 1).max(mem(u_words));
        // r = 0 runs no agreement pass at all (saturating: plain r − 1
        // would underflow u64).
        let agreement = r.saturating_sub(1) * pe.mac_cycles(agree_macs, 1).max(mem(u_words));
        // Squash: J capsules per iteration through the dedicated unit.
        let per_squash = d_out.div_ceil(pe.macs_per_pe as u64)
            + Op::Sqrt.cycles()
            + Op::DivFixed.cycles()
            + d_out.div_ceil(pe.macs_per_pe as u64)
            + 2;
        let squash = r * j * per_squash;
        // Logit update: N·J adds, pipelined.
        let logit_update = r.saturating_sub(1) * pipelined_cycles(Op::Add, n * j);
        RoutingTiming {
            u_hat,
            softmax,
            fc,
            agreement,
            squash,
            logit_update,
        }
    } else {
        // Scalar MACs; serial non-pipelined exp/div.
        let u_hat = PeArray::scalar_mac_cycles(u_hat_macs, 1);
        let per_row = parallel_cycles(Op::ExpFull, j, 1)
            + j * Op::DivFixed.cycles()
            + j * Op::Add.cycles();
        let softmax = r * n * per_row;
        let fc = r * PeArray::scalar_mac_cycles(fc_macs, 1);
        let agreement = r.saturating_sub(1) * PeArray::scalar_mac_cycles(agree_macs, 1);
        let per_squash = d_out * Op::Mac.cycles()
            + Op::Sqrt.cycles()
            + Op::DivFixed.cycles()
            + d_out * Op::Mul.cycles()
            + 2;
        let squash = r * j * per_squash;
        let logit_update = r.saturating_sub(1) * n * j * Op::Add.cycles();
        RoutingTiming {
            u_hat,
            softmax,
            fc,
            agreement,
            squash,
            logit_update,
        }
    }
}

/// Collapse to a stage timing for the frame report.
pub fn as_stage(g: &RoutingGeometry, hw: &RoutingHardware, pe: &PeArray) -> StageTiming {
    let t = routing_timing(g, hw, pe);
    let n = g.n_caps as u64;
    let j = g.n_classes as u64;
    StageTiming {
        name: "dynamic-routing".into(),
        cycles: t.total(),
        macs: n * j * (g.d_in as u64) * (g.d_out as u64)
            + (g.iterations as u64) * n * j * (g.d_out as u64) * 2,
        mem_words: n * j * (g.d_out as u64) * (1 + 2 * g.iterations as u64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorOptions, CapsNetConfig};

    fn pe() -> PeArray {
        PeArray::new(&AcceleratorOptions::optimized())
    }

    fn mnist_pruned_geometry() -> RoutingGeometry {
        let cfg = CapsNetConfig::paper_pruned_mnist();
        RoutingGeometry::from_config(&cfg, cfg.num_primary_caps())
    }

    #[test]
    fn optimized_routing_is_order_of_magnitude_faster() {
        let g = mnist_pruned_geometry();
        let base = routing_timing(&g, &RoutingHardware::baseline(), &pe());
        let opt = routing_timing(&g, &RoutingHardware::optimized(), &pe());
        let speedup = base.total() as f64 / opt.total() as f64;
        assert!(
            speedup > 10.0 && speedup < 100.0,
            "routing speedup {speedup:.1}"
        );
    }

    #[test]
    fn softmax_dominates_baseline() {
        // The premise of §III-B: exp/div serialization is the bottleneck.
        let g = mnist_pruned_geometry();
        let t = routing_timing(&g, &RoutingHardware::baseline(), &pe());
        assert!(t.softmax > t.fc + t.agreement + t.squash);
        assert!(t.softmax as f64 > 0.4 * t.total() as f64);
    }

    #[test]
    fn softmax_latency_reduced_85_percent() {
        // §III-C: "The latency of softmax() operation is reduced by 85%".
        let g = mnist_pruned_geometry();
        let base = routing_timing(&g, &RoutingHardware::baseline(), &pe());
        let opt = routing_timing(&g, &RoutingHardware::optimized(), &pe());
        let reduction = 1.0 - opt.softmax as f64 / base.softmax as f64;
        assert!(
            reduction > 0.85,
            "softmax reduction {:.1}%",
            reduction * 100.0
        );
    }

    #[test]
    fn squash_unchanged_by_optimization() {
        let g = mnist_pruned_geometry();
        let base = routing_timing(&g, &RoutingHardware::baseline(), &pe());
        let opt = routing_timing(&g, &RoutingHardware::optimized(), &pe());
        // Same unit, same serial schedule — within the MAC-tree difference.
        let ratio = base.squash as f64 / opt.squash as f64;
        assert!((0.5..=2.5).contains(&ratio), "squash ratio {ratio}");
    }

    #[test]
    fn scales_with_capsule_count() {
        let m = mnist_pruned_geometry();
        let cfg_f = CapsNetConfig::paper_pruned_fmnist();
        let f = RoutingGeometry::from_config(&cfg_f, cfg_f.num_primary_caps());
        for hw in [RoutingHardware::baseline(), RoutingHardware::optimized()] {
            let tm = routing_timing(&m, &hw, &pe()).total();
            let tf = routing_timing(&f, &hw, &pe()).total();
            let ratio = tf as f64 / tm as f64;
            // 432/252 ≈ 1.71 capsules.
            assert!((1.3..=2.0).contains(&ratio), "ratio {ratio}");
        }
    }
}
