//! Processing-element array (Fig. 9/10): `num_pes` PEs, each performing
//! `macs_per_pe` element-wise 16-bit multiplies feeding an adder tree
//! (paper: 10 PEs × 9 multipliers). Fully pipelined at II = 1 when the
//! optimized schedule applies; loop-carried dependencies raise the II.

use crate::config::AcceleratorOptions;
use crate::fixed::Q12;

/// Timing + functional model of the PE array.
#[derive(Debug, Clone, Copy)]
pub struct PeArray {
    pub num_pes: usize,
    pub macs_per_pe: usize,
    /// Pipeline depth of one PE (multiplier 3 + ceil(log2(9)) adder-tree
    /// stages + 1 writeback).
    pub depth: u64,
}

impl PeArray {
    pub fn new(opts: &AcceleratorOptions) -> PeArray {
        let depth = 3 + (opts.macs_per_pe as f64).log2().ceil() as u64 + 1;
        PeArray {
            num_pes: opts.num_pes,
            macs_per_pe: opts.macs_per_pe,
            depth,
        }
    }

    /// Peak MACs per cycle with every PE busy.
    pub fn peak_macs_per_cycle(&self) -> u64 {
        (self.num_pes * self.macs_per_pe) as u64
    }

    /// Cycles to execute `macs` multiply-accumulates spread over the
    /// array with initiation interval `ii` (II > 1 models loop-carried
    /// dependencies / write conflicts, as in the non-reordered Code 1).
    pub fn mac_cycles(&self, macs: u64, ii: u64) -> u64 {
        if macs == 0 {
            return 0;
        }
        let issues = macs.div_ceil(self.peak_macs_per_cycle());
        self.depth + (issues.max(1) - 1) * ii.max(1) + 1
    }

    /// Cycles when only a single scalar MAC lane is available (the
    /// non-optimized routing datapath: §III-B parallelizes the Agreement
    /// and FC steps onto the PE array — before that they run on the
    /// scalar datapath HLS infers).
    pub fn scalar_mac_cycles(macs: u64, ii: u64) -> u64 {
        macs * ii.max(1)
    }

    /// Functional: one PE dot-product step — `Σ_k a[k]·b[k]` into a wide
    /// accumulator, exactly what the adder tree produces.
    pub fn dot(a: &[Q12], b: &[Q12]) -> i64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0i64;
        for (&x, &y) in a.iter().zip(b) {
            acc = x.mac(y, acc);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn array() -> PeArray {
        PeArray::new(&AcceleratorOptions::optimized())
    }

    #[test]
    fn paper_geometry() {
        let pe = array();
        assert_eq!(pe.peak_macs_per_cycle(), 90);
        assert_eq!(pe.depth, 3 + 4 + 1);
    }

    #[test]
    fn pipelined_throughput_approaches_peak() {
        let pe = array();
        let macs = 9_000_000u64;
        let cycles = pe.mac_cycles(macs, 1);
        let per_cycle = macs as f64 / cycles as f64;
        assert!(per_cycle > 89.9, "throughput {per_cycle}");
    }

    #[test]
    fn ii_scales_cycles() {
        let pe = array();
        let c1 = pe.mac_cycles(90_000, 1);
        let c3 = pe.mac_cycles(90_000, 3);
        assert!(c3 > 2 * c1 && c3 < 4 * c1);
    }

    #[test]
    fn zero_work_costs_nothing() {
        assert_eq!(array().mac_cycles(0, 1), 0);
        assert_eq!(PeArray::scalar_mac_cycles(0, 1), 0);
    }

    #[test]
    fn dot_matches_scalar() {
        let a: Vec<Q12> = [0.5f32, -1.0, 2.0].iter().map(|&x| Q12::from_f32(x)).collect();
        let b: Vec<Q12> = [1.0f32, 0.25, 0.5].iter().map(|&x| Q12::from_f32(x)).collect();
        let acc = PeArray::dot(&a, &b);
        assert!((Q12::from_acc(acc).to_f32() - 1.25).abs() < 1e-3);
    }
}
