//! Power and energy model (Fig. 1).
//!
//! `P = P_static + Σ resource·toggle + P_ddr·(streaming)` — the standard
//! Zynq decomposition: PS + fabric static power, per-resource dynamic
//! power at 100 MHz, and the DDR controller/PHY term that only the
//! weight-streaming original design pays. Coefficients calibrated so the
//! paper's energy-efficiency anchors reproduce:
//! original-MNIST ≈ 1.8 FPJ at 5 FPS, pruned ≈ 41.8 FPJ at 82 FPS,
//! pruned-F-MNIST ≈ 24.5 FPJ at 48 FPS (all implying ~2–2.8 W boards).

use super::resources::Utilization;

/// Power model coefficients (watts).
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    pub static_w: f64,
    pub per_dsp_w: f64,
    pub per_bram_w: f64,
    pub per_lut_w: f64,
    pub ddr_stream_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            static_w: 1.0,       // PS idle + fabric static
            per_dsp_w: 0.0015,   // 16-bit multiply at 100 MHz
            per_bram_w: 0.003,   // active dual-port block
            per_lut_w: 1.0e-5,   // logic toggle
            ddr_stream_w: 0.6,   // DDR controller + PHY while streaming
        }
    }
}

impl PowerModel {
    /// Board power (W) for a build with the given utilization.
    pub fn watts(&self, u: &Utilization, ddr_streaming: bool) -> f64 {
        self.static_w
            + self.per_dsp_w * u.dsp48e as f64
            + self.per_bram_w * u.bram36 as f64
            + self.per_lut_w * u.luts as f64
            + if ddr_streaming { self.ddr_stream_w } else { 0.0 }
    }

    /// Frames per joule at a given throughput.
    pub fn fpj(&self, fps: f64, u: &Utilization, ddr_streaming: bool) -> f64 {
        fps / self.watts(u, ddr_streaming)
    }

    /// Energy per frame (mJ).
    pub fn mj_per_frame(&self, fps: f64, u: &Utilization, ddr_streaming: bool) -> f64 {
        1000.0 * self.watts(u, ddr_streaming) / fps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::fpga::resources::estimate;

    #[test]
    fn board_power_in_pynq_range() {
        let pm = PowerModel::default();
        let orig = estimate(&SystemConfig::original("mnist"));
        let prop = estimate(&SystemConfig::proposed("mnist"));
        let p_orig = pm.watts(&orig, true);
        let p_prop = pm.watts(&prop, false);
        assert!((2.0..3.2).contains(&p_orig), "original {p_orig} W");
        assert!((1.5..2.5).contains(&p_prop), "proposed {p_prop} W");
        assert!(p_orig > p_prop, "DDR streaming costs power");
    }

    #[test]
    fn paper_fpj_anchors() {
        // Fig. 1 anchors at the paper's measured FPS points.
        let pm = PowerModel::default();
        let orig = estimate(&SystemConfig::original("mnist"));
        let fpj_orig = pm.fpj(5.0, &orig, true);
        assert!((fpj_orig - 1.8).abs() < 0.5, "original {fpj_orig} FPJ");

        let pruned = estimate(&SystemConfig::pruned("mnist"));
        let fpj_pruned = pm.fpj(82.0, &pruned, false);
        assert!((fpj_pruned - 41.8).abs() < 6.0, "pruned {fpj_pruned} FPJ");

        let pruned_f = estimate(&SystemConfig::pruned("fmnist"));
        let fpj_f = pm.fpj(48.0, &pruned_f, false);
        assert!((fpj_f - 24.5).abs() < 4.0, "pruned fmnist {fpj_f} FPJ");
    }

    #[test]
    fn masked_sparse_deployment_wins_on_energy_efficiency() {
        // The masked (sim-sparse) deployment still pays the DDR term —
        // its uncompacted 1152-capsule û spills — so board power stays
        // in the original's range; the energy win is throughput-driven.
        // Modeled FPJ must dominate the original's ~1.8 by an order of
        // magnitude even before compaction.
        use crate::fpga::DeployedModel;
        let pm = PowerModel::default();
        let orig_cfg = SystemConfig::original("mnist");
        let masked_cfg = SystemConfig::masked("mnist");
        let orig_fps = DeployedModel::timing_stub(&orig_cfg, 7).estimate_frame().fps();
        let masked_fps = DeployedModel::timing_stub(&masked_cfg, 7).estimate_frame().fps();
        let fpj_orig = pm.fpj(orig_fps, &estimate(&orig_cfg), true);
        let fpj_masked = pm.fpj(masked_fps, &estimate(&masked_cfg), true);
        assert!(
            fpj_masked > 10.0 * fpj_orig,
            "masked {fpj_masked:.1} FPJ vs original {fpj_orig:.1} FPJ"
        );
    }

    #[test]
    fn energy_per_frame_monotone_in_fps() {
        let pm = PowerModel::default();
        let u = estimate(&SystemConfig::proposed("mnist"));
        assert!(pm.mj_per_frame(100.0, &u, false) > pm.mj_per_frame(1000.0, &u, false));
    }
}
