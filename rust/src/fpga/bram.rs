//! On-chip BRAM model: allocation ledger over the device's BRAM36 blocks
//! plus a port-contention factor for the conv inner loop.
//!
//! A BRAM36 holds 4 KB (36 Kbit with parity, 32 Kbit usable at the byte
//! granularity HLS partitions use). Buffers are allocated in whole blocks;
//! the ledger records every named buffer so resource reports (Table II/III,
//! Fig. 14) can itemize where the blocks went.

/// Usable bytes per BRAM36 block (32 Kbit data).
pub const BRAM36_BYTES: usize = 4096;

/// On-chip bytes of one conv layer's resident weights in the CSR packing
/// ([`super::index_control::PackedRows`]): packed 16-bit weight words
/// plus, for a sparse layer, the index memory the Index Control Module
/// walks — one `u16` column per survivor and `out_ch + 1` `u32` row
/// pointers. A dense layer (`survived == total`) carries no index
/// (the address generators enumerate the grid), so 100% density
/// degenerates to the plain `2 × params` accounting. A fully pruned
/// layer still holds its row pointers: the on-chip sequencer needs the
/// (all-equal) offsets to skip every row.
pub fn csr_weight_bytes(survived: usize, total: usize, kk: usize, out_ch: usize) -> usize {
    let weights = survived * kk * 2;
    if survived == total {
        weights
    } else {
        weights + survived * 2 + (out_ch + 1) * 4
    }
}

/// One allocated buffer.
#[derive(Debug, Clone)]
pub struct Buffer {
    pub name: String,
    pub bytes: usize,
    pub blocks: f32,
    /// Double-buffered (ping-pong) for dataflow overlap.
    pub double_buffered: bool,
}

/// BRAM allocation ledger.
#[derive(Debug, Clone, Default)]
pub struct BramLedger {
    pub buffers: Vec<Buffer>,
}

impl BramLedger {
    pub fn new() -> BramLedger {
        BramLedger::default()
    }

    /// Allocate a buffer. BRAM18 granularity lets small buffers take half
    /// a block — hence fractional blocks (the paper reports 131.5).
    pub fn alloc(&mut self, name: &str, bytes: usize, double_buffered: bool) -> f32 {
        let eff_bytes = if double_buffered { bytes * 2 } else { bytes };
        let halves = eff_bytes.div_ceil(BRAM36_BYTES / 2);
        let blocks = halves as f32 / 2.0;
        self.buffers.push(Buffer {
            name: name.to_string(),
            bytes: eff_bytes,
            blocks,
            double_buffered,
        });
        blocks
    }

    /// Total BRAM36 blocks allocated.
    pub fn total_blocks(&self) -> f32 {
        self.buffers.iter().map(|b| b.blocks).sum()
    }

    pub fn total_bytes(&self) -> usize {
        self.buffers.iter().map(|b| b.bytes).sum()
    }

    /// Whether the allocation fits a device budget of `budget` blocks.
    pub fn fits(&self, budget: f32) -> bool {
        self.total_blocks() <= budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_rounding_half_granularity() {
        let mut l = BramLedger::new();
        assert_eq!(l.alloc("tiny", 100, false), 0.5);
        assert_eq!(l.alloc("one-block", 4096, false), 1.0);
        assert_eq!(l.alloc("just-over", 4097, false), 1.5);
        assert_eq!(l.total_blocks(), 3.0);
    }

    #[test]
    fn double_buffering_doubles() {
        let mut l = BramLedger::new();
        let single = l.alloc("a", 8192, false);
        let dbl = l.alloc("b", 8192, true);
        assert_eq!(dbl, 2.0 * single);
    }

    #[test]
    fn csr_weight_accounting() {
        // Dense: exactly 2 bytes/param, no index.
        assert_eq!(csr_weight_bytes(64, 64, 81, 64), 64 * 81 * 2);
        // Sparse: packed words + u16 cols + u32 row pointers.
        assert_eq!(
            csr_weight_bytes(423, 3584, 81, 56),
            423 * 81 * 2 + 423 * 2 + 57 * 4
        );
        // Fully pruned: only the row pointers remain on-chip.
        assert_eq!(csr_weight_bytes(0, 3584, 81, 56), 57 * 4);
    }

    #[test]
    fn fits_budget() {
        let mut l = BramLedger::new();
        l.alloc("w", 500_000, false);
        assert!(l.fits(140.0));
        l.alloc("x", 200_000, false);
        assert!(!l.fits(140.0)); // 700KB > 140 * 4KB = 560KB... blocks: 171
    }
}
