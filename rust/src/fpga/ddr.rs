//! Off-chip DDR streaming model.
//!
//! The *original* (unpruned) CapsNet's 10.7 MB of 16-bit parameters cannot
//! fit the PYNQ-Z1's 560 KB of BRAM, so every frame must stream weights
//! from DDR through the PS AXI ports. The paper notes the original model
//! "limits the usage of Vivado HLS optimization directives due to the
//! excessive usage of available resources" — without burst inference the
//! HLS `m_axi` reads issue one beat at a time. That, not compute, is what
//! pins the original design at 5 FPS.

/// AXI streaming cost model.
#[derive(Debug, Clone, Copy)]
pub struct DdrModel {
    /// Bytes per AXI beat (32-bit data bus on the GP port).
    pub bytes_per_beat: u64,
    /// Cycles per beat for non-burst (HLS default) single reads:
    /// address + latency, no pipelining.
    pub cycles_per_beat_single: u64,
    /// Cycles per beat inside an inferred burst (HP port, pipelined).
    pub cycles_per_beat_burst: u64,
}

impl Default for DdrModel {
    fn default() -> Self {
        DdrModel {
            bytes_per_beat: 4,
            cycles_per_beat_single: 5,
            cycles_per_beat_burst: 1,
        }
    }
}

/// Per-frame DDR traffic of one conv layer's weight replay under the CSR
/// packing: the packed 16-bit kernel weights, plus — when the layer is
/// actually sparse — the index sidecar the Index Control Module consumes
/// (one `u16` column per survivor and `out_ch + 1` `u32` row pointers).
///
/// Two boundary cases are load-bearing:
/// * **Dense** (`survived == total`): no sidecar streams — the dense
///   design has no Index Control Module and its address generators
///   enumerate the grid — so the original design's 10.7 MB replay is the
///   exact degenerate case and the paper's 5-FPS anchor is unchanged.
/// * **Fully pruned** (`survived == 0`): the layer's DMA descriptor is
///   elided entirely, so *nothing* streams — not even row pointers. The
///   accounting must saturate at 0 here; charging the fixed
///   `(out_ch + 1)` pointer sidecar (or letting a `survived - 1`-style
///   inter-kernel term wrap) would invent traffic for a layer the
///   accelerator never touches.
pub fn conv_weight_stream_bytes(survived: u64, total: u64, kk: u64, out_ch: u64) -> u64 {
    if survived == 0 {
        return 0;
    }
    // One cost model for the packed layout: the DDR replay moves exactly
    // what BRAM would hold resident, minus the fully-pruned case above.
    super::bram::csr_weight_bytes(
        survived as usize,
        total as usize,
        kk as usize,
        out_ch as usize,
    ) as u64
}

impl DdrModel {
    /// Cycles to stream `bytes` with single-beat (non-burst) reads.
    pub fn stream_cycles_single(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.bytes_per_beat) * self.cycles_per_beat_single
    }

    /// Cycles to stream `bytes` in bursts (64-beat bursts + setup).
    pub fn stream_cycles_burst(&self, bytes: u64) -> u64 {
        let beats = bytes.div_ceil(self.bytes_per_beat);
        let bursts = beats.div_ceil(64);
        beats * self.cycles_per_beat_burst + bursts * 8
    }

    /// Effective bandwidth (MB/s) of the single-beat path at `clock_mhz`.
    pub fn single_bandwidth_mbps(&self, clock_mhz: f64) -> f64 {
        self.bytes_per_beat as f64 * clock_mhz / self.cycles_per_beat_single as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_beat_bandwidth_is_the_bottleneck() {
        let m = DdrModel::default();
        // 80 MB/s at 100 MHz — the regime that yields ~5 FPS for 10.7MB
        // of weights + activations per frame.
        let bw = m.single_bandwidth_mbps(100.0);
        assert!((bw - 80.0).abs() < 1e-9);
        // Original CapsNet weights: ~10.7 MB -> ~13.4M cycles just to
        // stream (0.134 s of the paper's 0.19 s latency).
        let cycles = m.stream_cycles_single(10_700_000);
        assert!(cycles > 13_000_000 && cycles < 14_000_000);
    }

    #[test]
    fn bursts_are_order_of_magnitude_faster() {
        let m = DdrModel::default();
        let single = m.stream_cycles_single(1_000_000);
        let burst = m.stream_cycles_burst(1_000_000);
        assert!(single > 4 * burst);
    }

    #[test]
    fn zero_bytes() {
        let m = DdrModel::default();
        assert_eq!(m.stream_cycles_single(0), 0);
        assert_eq!(m.stream_cycles_burst(0), 0);
    }

    #[test]
    fn dense_layer_streams_exactly_its_weights() {
        // Degenerate 100%-density case: 2 bytes per weight, no sidecar —
        // the original design's replay accounting, unchanged.
        assert_eq!(conv_weight_stream_bytes(3584, 3584, 81, 256), 3584 * 81 * 2);
    }

    #[test]
    fn sparse_layer_adds_the_index_sidecar() {
        let bytes = conv_weight_stream_bytes(423, 65536, 81, 256);
        assert_eq!(bytes, 423 * 81 * 2 + 423 * 2 + 257 * 4);
        // The sidecar is a rounding error next to the weights it saves.
        assert!(bytes < conv_weight_stream_bytes(65536, 65536, 81, 256) / 100);
    }

    #[test]
    fn fully_pruned_layer_streams_zero_bytes() {
        // Regression (saturation fix): a fully pruned layer must yield 0
        // stream bytes — no row-pointer sidecar, no wrapped subtraction.
        assert_eq!(conv_weight_stream_bytes(0, 65536, 81, 256), 0);
        assert_eq!(conv_weight_stream_bytes(0, 1, 9, 1), 0);
        // And a single survivor immediately pays weights + sidecar.
        assert_eq!(conv_weight_stream_bytes(1, 4, 9, 2), 9 * 2 + 2 + 3 * 4);
    }
}
