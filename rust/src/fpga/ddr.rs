//! Off-chip DDR streaming model.
//!
//! The *original* (unpruned) CapsNet's 10.7 MB of 16-bit parameters cannot
//! fit the PYNQ-Z1's 560 KB of BRAM, so every frame must stream weights
//! from DDR through the PS AXI ports. The paper notes the original model
//! "limits the usage of Vivado HLS optimization directives due to the
//! excessive usage of available resources" — without burst inference the
//! HLS `m_axi` reads issue one beat at a time. That, not compute, is what
//! pins the original design at 5 FPS.

/// AXI streaming cost model.
#[derive(Debug, Clone, Copy)]
pub struct DdrModel {
    /// Bytes per AXI beat (32-bit data bus on the GP port).
    pub bytes_per_beat: u64,
    /// Cycles per beat for non-burst (HLS default) single reads:
    /// address + latency, no pipelining.
    pub cycles_per_beat_single: u64,
    /// Cycles per beat inside an inferred burst (HP port, pipelined).
    pub cycles_per_beat_burst: u64,
}

impl Default for DdrModel {
    fn default() -> Self {
        DdrModel {
            bytes_per_beat: 4,
            cycles_per_beat_single: 5,
            cycles_per_beat_burst: 1,
        }
    }
}

impl DdrModel {
    /// Cycles to stream `bytes` with single-beat (non-burst) reads.
    pub fn stream_cycles_single(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.bytes_per_beat) * self.cycles_per_beat_single
    }

    /// Cycles to stream `bytes` in bursts (64-beat bursts + setup).
    pub fn stream_cycles_burst(&self, bytes: u64) -> u64 {
        let beats = bytes.div_ceil(self.bytes_per_beat);
        let bursts = beats.div_ceil(64);
        beats * self.cycles_per_beat_burst + bursts * 8
    }

    /// Effective bandwidth (MB/s) of the single-beat path at `clock_mhz`.
    pub fn single_bandwidth_mbps(&self, clock_mhz: f64) -> f64 {
        self.bytes_per_beat as f64 * clock_mhz / self.cycles_per_beat_single as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_beat_bandwidth_is_the_bottleneck() {
        let m = DdrModel::default();
        // 80 MB/s at 100 MHz — the regime that yields ~5 FPS for 10.7MB
        // of weights + activations per frame.
        let bw = m.single_bandwidth_mbps(100.0);
        assert!((bw - 80.0).abs() < 1e-9);
        // Original CapsNet weights: ~10.7 MB -> ~13.4M cycles just to
        // stream (0.134 s of the paper's 0.19 s latency).
        let cycles = m.stream_cycles_single(10_700_000);
        assert!(cycles > 13_000_000 && cycles < 14_000_000);
    }

    #[test]
    fn bursts_are_order_of_magnitude_faster() {
        let m = DdrModel::default();
        let single = m.stream_cycles_single(1_000_000);
        let burst = m.stream_cycles_burst(1_000_000);
        assert!(single > 4 * burst);
    }

    #[test]
    fn zero_bytes() {
        let m = DdrModel::default();
        assert_eq!(m.stream_cycles_single(0), 0);
        assert_eq!(m.stream_cycles_burst(0), 0);
    }
}
