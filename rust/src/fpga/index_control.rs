//! Index Control Module (§III-C, Fig. 9/10a): maps surviving-kernel
//! indices to weight/input addresses so the PE array only computes over
//! kernels that survived pruning, and tracks the on-chip index memory.

use crate::pruning::KernelMask;

/// Index control state for one pruned conv layer.
#[derive(Debug, Clone)]
pub struct IndexControl {
    /// (out_ch, in_ch) of each surviving kernel, in execution order.
    pub indices: Vec<(u16, u16)>,
    pub out_ch: usize,
    pub in_ch: usize,
}

impl IndexControl {
    pub fn from_mask(mask: &KernelMask) -> IndexControl {
        IndexControl {
            indices: mask.survivor_indices(),
            out_ch: mask.out_ch,
            in_ch: mask.in_ch,
        }
    }

    pub fn survived(&self) -> usize {
        self.indices.len()
    }

    /// On-chip index memory in bytes (u16 pair per kernel).
    pub fn index_bytes(&self) -> usize {
        self.indices.len() * 4
    }

    /// Cycles of index-fetch overhead for one pass over the layer: the
    /// index FIFO feeds the address generators one entry per kernel, fully
    /// overlapped except the initial fill.
    pub fn fetch_overhead_cycles(&self) -> u64 {
        // FIFO fill depth 4 + 1 cycle per kernel switch not hidden by the
        // k×k-deep MAC schedule (hidden for k² ≥ 4, i.e. always here).
        4 + self.indices.len() as u64 / 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_mask_survivors() {
        let mut m = KernelMask::all_alive(4, 4);
        for i in 0..4 {
            m.set(2, i, false);
        }
        let ic = IndexControl::from_mask(&m);
        assert_eq!(ic.survived(), 12);
        assert_eq!(ic.index_bytes(), 48);
        assert!(ic.indices.iter().all(|&(o, _)| o != 2));
    }

    #[test]
    fn overhead_nearly_free() {
        let m = KernelMask::all_alive(56, 64);
        let ic = IndexControl::from_mask(&m);
        // 3584 kernels -> 60 cycles of overhead: negligible vs the
        // ~1.2M MAC issues of the layer.
        assert!(ic.fetch_overhead_cycles() < 100);
    }
}
