//! Index Control Module (§III-C, Fig. 9/10a): maps surviving-kernel
//! indices to weight/input addresses so the PE array only computes over
//! kernels that survived pruning, and tracks the on-chip index memory.

use crate::pruning::KernelMask;

/// Index control state for one pruned conv layer.
#[derive(Debug, Clone)]
pub struct IndexControl {
    /// (out_ch, in_ch) of each surviving kernel, in execution order.
    pub indices: Vec<(u16, u16)>,
    pub out_ch: usize,
    pub in_ch: usize,
}

impl IndexControl {
    pub fn from_mask(mask: &KernelMask) -> IndexControl {
        IndexControl {
            indices: mask.survivor_indices(),
            out_ch: mask.out_ch,
            in_ch: mask.in_ch,
        }
    }

    pub fn survived(&self) -> usize {
        self.indices.len()
    }

    /// On-chip index memory in bytes (u16 pair per kernel).
    pub fn index_bytes(&self) -> usize {
        self.indices.len() * 4
    }

    /// Cycles of index-fetch overhead for one pass over the layer: the
    /// index FIFO feeds the address generators one entry per kernel, fully
    /// overlapped except the initial fill.
    pub fn fetch_overhead_cycles(&self) -> u64 {
        // FIFO fill depth 4 + 1 cycle per kernel switch not hidden by the
        // k×k-deep MAC schedule (hidden for k² ≥ 4, i.e. always here).
        4 + self.indices.len() as u64 / 64
    }

    /// The survivor list regrouped per output channel — the CSR-style
    /// layout both executors consume: the hardware address generators
    /// walk one output channel's alive kernels back to back, and the
    /// software sparse path ([`crate::capsnet::compiled`]) packs its
    /// weights in exactly this order, so the two models share one
    /// sparsity representation.
    ///
    /// `row_ptr[o]..row_ptr[o + 1]` indexes `cols`; `cols[n]` is the
    /// input channel of the n-th surviving kernel. Within a row the
    /// input channels are ascending (the mask enumeration order), which
    /// is what keeps a sparse traversal's accumulation order identical
    /// to the dense loop nest.
    pub fn packed_rows(&self) -> PackedRows {
        // `indices` is sorted by (o, i) — guaranteed by `from_mask`, but
        // the field is public, so enforce the precondition instead of
        // silently mis-assigning survivors to the wrong row. A hard
        // assert: this runs only at pack time (O(survivors), startup
        // path), and release builds are exactly where a silent
        // wrong-weights packing would otherwise go unnoticed.
        assert!(
            self.indices.windows(2).all(|w| w[0] < w[1]),
            "IndexControl.indices must be strictly sorted by (out_ch, in_ch)"
        );
        // One pass suffices on sorted input: each row's end offset is the
        // running count, and empty rows inherit the previous offset
        // afterwards.
        let mut row_ptr = vec![0u32; self.out_ch + 1];
        let mut cols = Vec::with_capacity(self.indices.len());
        for &(ko, ki) in &self.indices {
            cols.push(ki);
            row_ptr[ko as usize + 1] = cols.len() as u32;
        }
        for o in 1..=self.out_ch {
            row_ptr[o] = row_ptr[o].max(row_ptr[o - 1]);
        }
        PackedRows {
            row_ptr,
            cols,
            in_ch: self.in_ch,
        }
    }
}

/// CSR-style alive-kernel index lists (see [`IndexControl::packed_rows`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedRows {
    /// `out_ch + 1` offsets into `cols`.
    pub row_ptr: Vec<u32>,
    /// Input channel of each surviving kernel, row-major by out channel.
    pub cols: Vec<u16>,
    /// Input channels of the dense grid (lets the packing know when it
    /// is degenerate-dense and needs no index memory at all).
    pub in_ch: usize,
}

impl PackedRows {
    /// The surviving input channels of output channel `o`.
    pub fn row(&self, o: usize) -> &[u16] {
        &self.cols[self.row_ptr[o] as usize..self.row_ptr[o + 1] as usize]
    }

    pub fn survived(&self) -> usize {
        self.cols.len()
    }

    /// Number of output channels (rows) in the packing.
    pub fn out_ch(&self) -> usize {
        self.row_ptr.len() - 1
    }

    /// Output channels that kept no kernel at all. The address
    /// generators must still visit their row pointer to skip them.
    pub fn empty_rows(&self) -> usize {
        (0..self.out_ch())
            .filter(|&o| self.row_ptr[o] == self.row_ptr[o + 1])
            .count()
    }

    /// Whether every kernel of the dense grid survived.
    pub fn is_dense(&self) -> bool {
        self.survived() == self.out_ch() * self.in_ch
    }

    /// On-chip index memory this packing costs (§III-C): one `u16`
    /// column per surviving kernel plus `out_ch + 1` `u32` row pointers
    /// — the same sidecar the BRAM/DDR models charge
    /// ([`super::bram::csr_weight_bytes`]), so every consumer of the
    /// packing reports one number; a degenerate-dense packing needs no
    /// index at all (the address generators enumerate the grid). The
    /// flat survivor-*list* form, [`IndexControl::index_bytes`], keeps
    /// the paper's u16-pair cost for the un-packed representation.
    pub fn index_bytes(&self) -> usize {
        if self.is_dense() {
            0
        } else {
            self.survived() * 2 + (self.out_ch() + 1) * 4
        }
    }

    /// Cycles of index-fetch overhead for one pass of the Index Control
    /// Module over this packing: the FIFO fill, the per-kernel switch
    /// cost not hidden by the k×k-deep MAC schedule (1 in 64), and one
    /// cycle per *empty* row — a row-pointer advance with no MACs to
    /// hide behind. At 100% density no row is empty, so this equals the
    /// flat survivor-list model ([`IndexControl::fetch_overhead_cycles`])
    /// exactly, which is what keeps the dense paper anchors bit-stable
    /// across the CSR refactor.
    pub fn fetch_overhead_cycles(&self) -> u64 {
        4 + self.survived() as u64 / 64 + self.empty_rows() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_mask_survivors() {
        let mut m = KernelMask::all_alive(4, 4);
        for i in 0..4 {
            m.set(2, i, false);
        }
        let ic = IndexControl::from_mask(&m);
        assert_eq!(ic.survived(), 12);
        assert_eq!(ic.index_bytes(), 48);
        assert!(ic.indices.iter().all(|&(o, _)| o != 2));
    }

    #[test]
    fn packed_rows_group_survivors_per_out_channel() {
        let mut m = KernelMask::all_alive(4, 3);
        m.set(0, 1, false);
        for i in 0..3 {
            m.set(2, i, false); // row 2 fully dead
        }
        let p = IndexControl::from_mask(&m).packed_rows();
        assert_eq!(p.row_ptr, vec![0, 2, 5, 5, 8]);
        assert_eq!(p.row(0), &[0, 2]);
        assert_eq!(p.row(1), &[0, 1, 2]);
        assert_eq!(p.row(2), &[] as &[u16]);
        assert_eq!(p.row(3), &[0, 1, 2]);
        assert_eq!(p.survived(), m.survived());
    }

    #[test]
    fn packed_rows_match_mask_on_random_patterns() {
        crate::testing::check(
            "packed_rows ≡ mask survivors, rows ascending",
            20,
            77,
            |r| {
                let (o, i) = (1 + r.below(9), 1 + r.below(9));
                let mut m = KernelMask::all_alive(o, i);
                for oc in 0..o {
                    for ic in 0..i {
                        if r.below(3) == 0 {
                            m.set(oc, ic, false);
                        }
                    }
                }
                m
            },
            |m| {
                let p = IndexControl::from_mask(m).packed_rows();
                if p.survived() != m.survived() {
                    return false;
                }
                (0..m.out_ch).all(|o| {
                    let row = p.row(o);
                    row.windows(2).all(|w| w[0] < w[1])
                        && row.iter().all(|&i| m.get(o, i as usize))
                        && row.len()
                            == (0..m.in_ch).filter(|&i| m.get(o, i)).count()
                })
            },
        );
    }

    #[test]
    fn packed_overhead_matches_flat_model_at_full_density() {
        // No empty rows at density 1.0 → the CSR overhead model is the
        // exact degenerate case of the flat survivor-list model.
        let m = KernelMask::all_alive(56, 64);
        let ic = IndexControl::from_mask(&m);
        let p = ic.packed_rows();
        assert_eq!(p.empty_rows(), 0);
        assert_eq!(p.out_ch(), 56);
        assert_eq!(p.fetch_overhead_cycles(), ic.fetch_overhead_cycles());
    }

    #[test]
    fn empty_rows_cost_a_pointer_skip() {
        let mut m = KernelMask::all_alive(8, 4);
        for i in 0..4 {
            m.set(2, i, false);
            m.set(5, i, false);
        }
        let p = IndexControl::from_mask(&m).packed_rows();
        assert_eq!(p.empty_rows(), 2);
        assert_eq!(p.fetch_overhead_cycles(), 4 + 24 / 64 + 2);
    }

    #[test]
    fn overhead_nearly_free() {
        let m = KernelMask::all_alive(56, 64);
        let ic = IndexControl::from_mask(&m);
        // 3584 kernels -> 60 cycles of overhead: negligible vs the
        // ~1.2M MAC issues of the layer.
        assert!(ic.fetch_overhead_cycles() < 100);
    }
}
