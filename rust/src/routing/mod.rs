//! Dynamic routing between capsules (Sabour et al., Fig. 4 of the paper).
//!
//! This module holds the *functional* implementations:
//!
//! * f32 reference (this file) — the correctness oracle for everything
//!   else (Python's `ref.py` mirrors it; the Pallas kernels and the
//!   fixed-point datapath are tested against it).
//! * [`fixed`] — the Q4.12 datapath in both the baseline form (exact
//!   divider softmax, Code-1 loop order) and the paper's optimized form
//!   (Eq. 2 Taylor exp + Eq. 3 exp/log divider, Code-2 loop order).
//!
//! Cycle accounting for both forms lives in `fpga::routing_module`, which
//! wraps these functions so values and timing come from the same code.

pub mod fixed;

/// Squash non-linearity: `v = (‖s‖² / (1 + ‖s‖²)) · s / ‖s‖`.
pub fn squash(s: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; s.len()];
    squash_into(s, &mut out);
    out
}

/// [`squash`] into a caller-provided buffer (batch hot path: no per-call
/// allocation). Identical arithmetic to the allocating form.
pub fn squash_into(s: &[f32], out: &mut [f32]) {
    debug_assert_eq!(s.len(), out.len());
    let norm2: f32 = s.iter().map(|x| x * x).sum();
    if norm2 == 0.0 {
        out.fill(0.0);
        return;
    }
    let norm = norm2.sqrt();
    let scale = norm2 / (1.0 + norm2) / norm;
    for (o, &x) in out.iter_mut().zip(s) {
        *o = x * scale;
    }
}

/// Row softmax: `c_j = e^{b_j} / Σ_k e^{b_k}` (max-shifted for stability).
pub fn softmax(b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; b.len()];
    softmax_into(b, &mut out);
    out
}

/// [`softmax`] into a caller-provided buffer. Identical arithmetic.
pub fn softmax_into(b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(b.len(), out.len());
    let max = b.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    for (o, &x) in out.iter_mut().zip(b) {
        *o = (x - max).exp();
    }
    let sum: f32 = out.iter().sum();
    for o in out.iter_mut() {
        *o /= sum;
    }
}

/// Prediction vectors `û_{j|i}` laid out as `[n_in][n_out][d_out]` flat.
#[derive(Debug, Clone)]
pub struct Predictions {
    pub n_in: usize,
    pub n_out: usize,
    pub d_out: usize,
    pub u_hat: Vec<f32>,
}

impl Predictions {
    pub fn new(n_in: usize, n_out: usize, d_out: usize, u_hat: Vec<f32>) -> Self {
        assert_eq!(u_hat.len(), n_in * n_out * d_out);
        Predictions {
            n_in,
            n_out,
            d_out,
            u_hat,
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> &[f32] {
        let off = (i * self.n_out + j) * self.d_out;
        &self.u_hat[off..off + self.d_out]
    }
}

/// Routing output: final capsule vectors `v_j` (`[n_out][d_out]`) and the
/// final coupling coefficients (`[n_in][n_out]`, useful for tests).
#[derive(Debug, Clone)]
pub struct RoutingOutput {
    pub v: Vec<f32>,
    pub coupling: Vec<f32>,
    pub n_out: usize,
    pub d_out: usize,
}

impl RoutingOutput {
    pub fn capsule(&self, j: usize) -> &[f32] {
        &self.v[j * self.d_out..(j + 1) * self.d_out]
    }

    /// Capsule lengths — class probabilities in CapsNet.
    pub fn lengths(&self) -> Vec<f32> {
        (0..self.n_out)
            .map(|j| {
                self.capsule(j)
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt()
            })
            .collect()
    }
}

/// The dynamic routing algorithm (Fig. 4), f32 reference.
///
/// ```text
/// b ← 0
/// for r iterations:
///   c_i ← softmax(b_i)                       (over output capsules)
///   s_j ← Σ_i c_ij · û_{j|i}                 (fully-connected step)
///   v_j ← squash(s_j)
///   b_ij ← b_ij + û_{j|i} · v_j              (agreement step)
/// ```
pub fn dynamic_routing(pred: &Predictions, iterations: usize) -> RoutingOutput {
    dynamic_routing_with(pred, iterations, &mut RoutingScratch::new())
}

/// Reusable working buffers for [`dynamic_routing_with`]: the logits,
/// coupling, output-capsule, and weighted-sum arrays that the routing
/// loop would otherwise allocate on every call. Batch callers
/// ([`crate::capsnet::CapsNet::forward_batch`]) thread one scratch
/// across all frames; buffers are resized and reset per call, so reuse
/// can never leak state between frames.
#[derive(Debug, Default)]
pub struct RoutingScratch {
    b: Vec<f32>,
    c: Vec<f32>,
    v: Vec<f32>,
    s: Vec<f32>,
}

impl RoutingScratch {
    pub fn new() -> RoutingScratch {
        RoutingScratch::default()
    }
}

/// [`dynamic_routing`] with caller-owned scratch — identical arithmetic
/// (the allocating form delegates here), no per-frame allocation beyond
/// the returned output.
pub fn dynamic_routing_with(
    pred: &Predictions,
    iterations: usize,
    scratch: &mut RoutingScratch,
) -> RoutingOutput {
    let (n_in, n_out, d) = (pred.n_in, pred.n_out, pred.d_out);
    let RoutingScratch { b, c, v, s } = scratch;
    b.clear();
    b.resize(n_in * n_out, 0.0);
    c.clear();
    c.resize(n_in * n_out, 0.0);
    v.clear();
    v.resize(n_out * d, 0.0);
    s.clear();
    s.resize(d, 0.0);

    for it in 0..iterations {
        // Softmax over each input capsule's row of logits.
        for i in 0..n_in {
            softmax_into(
                &b[i * n_out..(i + 1) * n_out],
                &mut c[i * n_out..(i + 1) * n_out],
            );
        }
        // Weighted sum and squash per output capsule.
        for j in 0..n_out {
            s.fill(0.0);
            for i in 0..n_in {
                let cij = c[i * n_out + j];
                let u = pred.at(i, j);
                for (sk, &uk) in s.iter_mut().zip(u) {
                    *sk += cij * uk;
                }
            }
            squash_into(s, &mut v[j * d..(j + 1) * d]);
        }
        // Agreement update (skipped after the last iteration — the logits
        // would never be read again).
        if it + 1 < iterations {
            for i in 0..n_in {
                for j in 0..n_out {
                    let u = pred.at(i, j);
                    let vj = &v[j * d..(j + 1) * d];
                    let agree: f32 =
                        u.iter().zip(vj).map(|(a, b)| a * b).sum();
                    b[i * n_out + j] += agree;
                }
            }
        }
    }
    RoutingOutput {
        v: v.clone(),
        coupling: c.clone(),
        n_out,
        d_out: d,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_allclose;
    use crate::util::rng::Rng;

    #[test]
    fn squash_limits() {
        // Tiny vectors shrink quadratically; long vectors approach unit norm.
        let small = squash(&[1e-4, 0.0]);
        assert!(small[0] < 1e-6);
        let large = squash(&[100.0, 0.0]);
        assert!((large[0] - 1.0).abs() < 1e-3);
        // Norm is always < 1.
        let v = squash(&[0.3, -0.4, 1.2]);
        let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(n < 1.0);
        // Direction preserved.
        assert!(v[0] > 0.0 && v[1] < 0.0 && v[2] > 0.0);
        // Zero maps to zero.
        assert_eq!(squash(&[0.0, 0.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn softmax_properties() {
        let c = softmax(&[1.0, 2.0, 3.0]);
        let sum: f32 = c.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(c[2] > c[1] && c[1] > c[0]);
        // Shift invariance.
        let c2 = softmax(&[101.0, 102.0, 103.0]);
        assert_allclose(&c, &c2, 1e-6, 0.0, "softmax shift invariance");
        // Uniform logits -> uniform coupling (routing iteration 0).
        let u = softmax(&[0.0; 10]);
        for &x in &u {
            assert!((x - 0.1).abs() < 1e-6);
        }
    }

    #[test]
    fn routing_uniform_on_first_iteration() {
        // With one iteration, coupling stays uniform: s_j is the mean of
        // predictions.
        let mut rng = Rng::new(1);
        let (n_in, n_out, d) = (5, 3, 4);
        let u: Vec<f32> = (0..n_in * n_out * d)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let pred = Predictions::new(n_in, n_out, d, u);
        let out = dynamic_routing(&pred, 1);
        for &c in &out.coupling {
            assert!((c - 1.0 / n_out as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn routing_converges_to_agreeing_capsule() {
        // All input capsules predict the same vector for output 0 and
        // random noise for output 1 → routing should couple to 0.
        let mut rng = Rng::new(2);
        let (n_in, n_out, d) = (8, 2, 4);
        let target = [0.9f32, -0.5, 0.3, 0.7];
        let mut u = vec![0.0f32; n_in * n_out * d];
        for i in 0..n_in {
            for k in 0..d {
                u[(i * n_out) * d + k] = target[k];
                u[(i * n_out + 1) * d + k] = rng.normal_f32(0.0, 0.5);
            }
        }
        let pred = Predictions::new(n_in, n_out, d, u);
        let out = dynamic_routing(&pred, 3);
        let lens = out.lengths();
        assert!(
            lens[0] > lens[1] + 0.1,
            "agreeing capsule should win: {lens:?}"
        );
        // Coupling to capsule 0 grew beyond uniform.
        let mean_c0: f32 = (0..n_in)
            .map(|i| out.coupling[i * n_out])
            .sum::<f32>()
            / n_in as f32;
        assert!(mean_c0 > 0.5, "coupling {mean_c0}");
    }

    #[test]
    fn routing_iterations_refine() {
        // More iterations → sharper coupling (monotone for this workload).
        let mut rng = Rng::new(3);
        let (n_in, n_out, d) = (16, 4, 8);
        let mut u = vec![0.0f32; n_in * n_out * d];
        for i in 0..n_in {
            for j in 0..n_out {
                for k in 0..d {
                    let signal = if j == 0 { 0.8 } else { 0.0 };
                    u[(i * n_out + j) * d + k] =
                        signal + rng.normal_f32(0.0, 0.3);
                }
            }
        }
        let pred = Predictions::new(n_in, n_out, d, u);
        let c1 = dynamic_routing(&pred, 1);
        let c3 = dynamic_routing(&pred, 3);
        let sharp = |o: &RoutingOutput| -> f32 {
            (0..n_in).map(|i| o.coupling[i * n_out]).sum::<f32>()
        };
        assert!(sharp(&c3) > sharp(&c1));
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // One scratch threaded across problems of *different* geometry
        // must reproduce the allocating path bit for bit — stale buffer
        // contents may never leak between frames.
        let mut rng = Rng::new(9);
        let mut scratch = RoutingScratch::new();
        for (n_in, n_out, d) in [(12, 4, 8), (5, 3, 4), (20, 10, 16), (5, 3, 4)] {
            let u: Vec<f32> = (0..n_in * n_out * d)
                .map(|_| rng.normal_f32(0.0, 0.7))
                .collect();
            let pred = Predictions::new(n_in, n_out, d, u);
            let fresh = dynamic_routing(&pred, 3);
            let reused = dynamic_routing_with(&pred, 3, &mut scratch);
            assert_eq!(fresh.v, reused.v);
            assert_eq!(fresh.coupling, reused.coupling);
        }
    }

    #[test]
    fn capsule_lengths_below_one() {
        let mut rng = Rng::new(4);
        let pred = Predictions::new(
            20,
            10,
            16,
            (0..20 * 10 * 16).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let out = dynamic_routing(&pred, 3);
        for l in out.lengths() {
            assert!((0.0..1.0).contains(&l), "length {l}");
        }
    }
}
