//! Dynamic routing between capsules (Sabour et al., Fig. 4 of the paper).
//!
//! This module holds the *functional* implementations:
//!
//! * f32 reference (this file) — the correctness oracle for everything
//!   else (Python's `ref.py` mirrors it; the Pallas kernels and the
//!   fixed-point datapath are tested against it).
//! * [`fixed`] — the Q4.12 datapath in both the baseline form (exact
//!   divider softmax, Code-1 loop order) and the paper's optimized form
//!   (Eq. 2 Taylor exp + Eq. 3 exp/log divider, Code-2 loop order).
//!
//! Cycle accounting for both forms lives in `fpga::routing_module`, which
//! wraps these functions so values and timing come from the same code.

pub mod fixed;

use std::fmt;

/// How the routing stage runs at inference time.
///
/// * `Iterative(r)` — the classic Sabour et al. loop: `r` rounds of
///   softmax → weighted sum → squash → agreement. This is what the
///   paper accelerates and what training produces.
/// * `Accumulated` — the Zhao et al. fast path ("Fast Inference in
///   Capsule Networks Using Accumulated Routing Coefficients"): the
///   coupling coefficients are *precomputed offline* as the mean of the
///   final iterative coefficients over a calibration set, so serving
///   does zero routing iterations — one weighted sum + squash, no
///   softmax, no agreement updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingMode {
    Iterative(usize),
    Accumulated,
}

impl RoutingMode {
    /// Routing iterations the cycle/DDR models should price: `r` for
    /// the iterative loop, `0` for the accumulated fast path (the FC +
    /// squash work rides the existing û stage; all per-iteration
    /// softmax/agreement/logit terms vanish).
    pub fn effective_iters(self) -> usize {
        match self {
            RoutingMode::Iterative(r) => r,
            RoutingMode::Accumulated => 0,
        }
    }

    /// True for the accumulated-coefficients fast path.
    pub fn is_accumulated(self) -> bool {
        matches!(self, RoutingMode::Accumulated)
    }

    /// Parse a CLI spelling: `accumulated`, `iterative` (model default
    /// `r`), or `iterative:N`.
    pub fn parse(s: &str, default_iters: usize) -> Option<RoutingMode> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "accumulated" | "acc" => Some(RoutingMode::Accumulated),
            "iterative" | "iter" => Some(RoutingMode::Iterative(default_iters)),
            _ => {
                let rest = s.strip_prefix("iterative:").or_else(|| s.strip_prefix("iter:"))?;
                rest.parse::<usize>().ok().map(RoutingMode::Iterative)
            }
        }
    }

    /// Stable tag mixed into deployment fingerprints: the cache must
    /// never alias an iterative deployment with an accumulated one (or
    /// two iterative deployments with different iteration counts).
    /// Worker counts are deliberately *not* part of any fingerprint —
    /// sharding a batch across cores is bit-identical by construction.
    pub fn fingerprint_tag(self) -> u64 {
        match self {
            RoutingMode::Iterative(r) => 0x6974_6572_0000_0000 | r as u64,
            RoutingMode::Accumulated => 0x6163_6375_6d5f_636f,
        }
    }
}

impl fmt::Display for RoutingMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RoutingMode::Iterative(r) => write!(f, "iterative({r})"),
            RoutingMode::Accumulated => write!(f, "accumulated"),
        }
    }
}

/// Squash non-linearity: `v = (‖s‖² / (1 + ‖s‖²)) · s / ‖s‖`.
pub fn squash(s: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; s.len()];
    squash_into(s, &mut out);
    out
}

/// [`squash`] into a caller-provided buffer (batch hot path: no per-call
/// allocation). Identical arithmetic to the allocating form.
pub fn squash_into(s: &[f32], out: &mut [f32]) {
    debug_assert_eq!(s.len(), out.len());
    let norm2: f32 = s.iter().map(|x| x * x).sum();
    if norm2 == 0.0 {
        out.fill(0.0);
        return;
    }
    let norm = norm2.sqrt();
    let scale = norm2 / (1.0 + norm2) / norm;
    crate::kernels::mul_f32(s, scale, out);
}

/// Row softmax: `c_j = e^{b_j} / Σ_k e^{b_k}` (max-shifted for stability).
pub fn softmax(b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; b.len()];
    softmax_into(b, &mut out);
    out
}

/// [`softmax`] into a caller-provided buffer. Identical arithmetic.
pub fn softmax_into(b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(b.len(), out.len());
    let max = b.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    for (o, &x) in out.iter_mut().zip(b) {
        *o = (x - max).exp();
    }
    let sum: f32 = out.iter().sum();
    crate::kernels::div_in_place_f32(out, sum);
}

/// Prediction vectors `û_{j|i}` laid out as `[n_in][n_out][d_out]` flat.
#[derive(Debug, Clone)]
pub struct Predictions {
    pub n_in: usize,
    pub n_out: usize,
    pub d_out: usize,
    pub u_hat: Vec<f32>,
}

impl Predictions {
    pub fn new(n_in: usize, n_out: usize, d_out: usize, u_hat: Vec<f32>) -> Self {
        assert_eq!(u_hat.len(), n_in * n_out * d_out);
        Predictions {
            n_in,
            n_out,
            d_out,
            u_hat,
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> &[f32] {
        let off = (i * self.n_out + j) * self.d_out;
        &self.u_hat[off..off + self.d_out]
    }
}

/// Routing output: final capsule vectors `v_j` (`[n_out][d_out]`) and the
/// final coupling coefficients (`[n_in][n_out]`, useful for tests).
#[derive(Debug, Clone)]
pub struct RoutingOutput {
    pub v: Vec<f32>,
    pub coupling: Vec<f32>,
    pub n_out: usize,
    pub d_out: usize,
}

impl RoutingOutput {
    pub fn capsule(&self, j: usize) -> &[f32] {
        &self.v[j * self.d_out..(j + 1) * self.d_out]
    }

    /// Capsule lengths — class probabilities in CapsNet.
    pub fn lengths(&self) -> Vec<f32> {
        (0..self.n_out)
            .map(|j| {
                self.capsule(j)
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt()
            })
            .collect()
    }
}

/// The dynamic routing algorithm (Fig. 4), f32 reference.
///
/// ```text
/// b ← 0
/// for r iterations:
///   c_i ← softmax(b_i)                       (over output capsules)
///   s_j ← Σ_i c_ij · û_{j|i}                 (fully-connected step)
///   v_j ← squash(s_j)
///   b_ij ← b_ij + û_{j|i} · v_j              (agreement step)
/// ```
pub fn dynamic_routing(pred: &Predictions, iterations: usize) -> RoutingOutput {
    dynamic_routing_with(pred, iterations, &mut RoutingScratch::new())
}

/// Reusable working buffers for [`dynamic_routing_with`]: the logits,
/// coupling, output-capsule, and weighted-sum arrays that the routing
/// loop would otherwise allocate on every call. Batch callers
/// ([`crate::capsnet::CapsNet::forward_batch`]) thread one scratch
/// across all frames; buffers are resized and reset per call, so reuse
/// can never leak state between frames.
#[derive(Debug, Default)]
pub struct RoutingScratch {
    b: Vec<f32>,
    c: Vec<f32>,
    v: Vec<f32>,
    s: Vec<f32>,
}

impl RoutingScratch {
    pub fn new() -> RoutingScratch {
        RoutingScratch::default()
    }
}

/// [`dynamic_routing`] with caller-owned scratch — identical arithmetic
/// (the allocating form delegates here), no per-frame allocation beyond
/// the returned output.
pub fn dynamic_routing_with(
    pred: &Predictions,
    iterations: usize,
    scratch: &mut RoutingScratch,
) -> RoutingOutput {
    let (n_in, n_out, d) = (pred.n_in, pred.n_out, pred.d_out);
    let RoutingScratch { b, c, v, s } = scratch;
    b.clear();
    b.resize(n_in * n_out, 0.0);
    c.clear();
    c.resize(n_in * n_out, 0.0);
    v.clear();
    v.resize(n_out * d, 0.0);
    s.clear();
    s.resize(d, 0.0);

    for it in 0..iterations {
        // Softmax over each input capsule's row of logits.
        for i in 0..n_in {
            softmax_into(
                &b[i * n_out..(i + 1) * n_out],
                &mut c[i * n_out..(i + 1) * n_out],
            );
        }
        // Weighted sum and squash per output capsule.
        for j in 0..n_out {
            s.fill(0.0);
            for i in 0..n_in {
                let cij = c[i * n_out + j];
                let u = pred.at(i, j);
                crate::kernels::axpy_f32(s, cij, u);
            }
            squash_into(s, &mut v[j * d..(j + 1) * d]);
        }
        // Agreement update (skipped after the last iteration — the logits
        // would never be read again).
        if it + 1 < iterations {
            for i in 0..n_in {
                for j in 0..n_out {
                    let u = pred.at(i, j);
                    let vj = &v[j * d..(j + 1) * d];
                    let agree: f32 =
                        u.iter().zip(vj).map(|(a, b)| a * b).sum();
                    b[i * n_out + j] += agree;
                }
            }
        }
    }
    RoutingOutput {
        v: v.clone(),
        coupling: c.clone(),
        n_out,
        d_out: d,
    }
}

/// Accumulated-coefficients routing (Zhao et al.): the coupling matrix
/// is a precomputed constant, so the whole routing stage collapses to
/// one weighted sum + squash per output capsule — no softmax, no
/// agreement, no iterations.
pub fn accumulated_routing(pred: &Predictions, coupling: &[f32]) -> RoutingOutput {
    accumulated_routing_with(pred, coupling, &mut RoutingScratch::new())
}

/// [`accumulated_routing`] with caller-owned scratch. The FC + squash
/// loop body is *identical* (same accumulation order, element for
/// element) to one pass of [`dynamic_routing_with`]'s weighted-sum
/// stage, so the fast path inherits the iterative path's numerics.
pub fn accumulated_routing_with(
    pred: &Predictions,
    coupling: &[f32],
    scratch: &mut RoutingScratch,
) -> RoutingOutput {
    let (n_in, n_out, d) = (pred.n_in, pred.n_out, pred.d_out);
    assert_eq!(
        coupling.len(),
        n_in * n_out,
        "accumulated coupling shape mismatch"
    );
    let RoutingScratch { c, v, s, .. } = scratch;
    c.clear();
    c.extend_from_slice(coupling);
    v.clear();
    v.resize(n_out * d, 0.0);
    s.clear();
    s.resize(d, 0.0);
    for j in 0..n_out {
        s.fill(0.0);
        for i in 0..n_in {
            let cij = c[i * n_out + j];
            let u = pred.at(i, j);
            crate::kernels::axpy_f32(s, cij, u);
        }
        squash_into(s, &mut v[j * d..(j + 1) * d]);
    }
    RoutingOutput {
        v: v.clone(),
        coupling: c.clone(),
        n_out,
        d_out: d,
    }
}

/// Mean of per-frame final coupling matrices — the offline accumulation
/// pass. Every matrix must share one `[n_in][n_out]` geometry; each row
/// of the mean still sums to ~1 (a convex combination of softmax rows).
pub fn mean_coupling<'a>(matrices: impl Iterator<Item = &'a [f32]>) -> Vec<f32> {
    let mut sum: Vec<f64> = Vec::new();
    let mut n = 0usize;
    for m in matrices {
        if sum.is_empty() {
            sum.resize(m.len(), 0.0);
        }
        assert_eq!(sum.len(), m.len(), "coupling geometry mismatch");
        for (s, &x) in sum.iter_mut().zip(m) {
            *s += x as f64;
        }
        n += 1;
    }
    assert!(n > 0, "mean_coupling needs at least one frame");
    sum.iter().map(|&s| (s / n as f64) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_allclose;
    use crate::util::rng::Rng;

    #[test]
    fn squash_limits() {
        // Tiny vectors shrink quadratically; long vectors approach unit norm.
        let small = squash(&[1e-4, 0.0]);
        assert!(small[0] < 1e-6);
        let large = squash(&[100.0, 0.0]);
        assert!((large[0] - 1.0).abs() < 1e-3);
        // Norm is always < 1.
        let v = squash(&[0.3, -0.4, 1.2]);
        let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(n < 1.0);
        // Direction preserved.
        assert!(v[0] > 0.0 && v[1] < 0.0 && v[2] > 0.0);
        // Zero maps to zero.
        assert_eq!(squash(&[0.0, 0.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn softmax_properties() {
        let c = softmax(&[1.0, 2.0, 3.0]);
        let sum: f32 = c.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(c[2] > c[1] && c[1] > c[0]);
        // Shift invariance.
        let c2 = softmax(&[101.0, 102.0, 103.0]);
        assert_allclose(&c, &c2, 1e-6, 0.0, "softmax shift invariance");
        // Uniform logits -> uniform coupling (routing iteration 0).
        let u = softmax(&[0.0; 10]);
        for &x in &u {
            assert!((x - 0.1).abs() < 1e-6);
        }
    }

    #[test]
    fn routing_uniform_on_first_iteration() {
        // With one iteration, coupling stays uniform: s_j is the mean of
        // predictions.
        let mut rng = Rng::new(1);
        let (n_in, n_out, d) = (5, 3, 4);
        let u: Vec<f32> = (0..n_in * n_out * d)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let pred = Predictions::new(n_in, n_out, d, u);
        let out = dynamic_routing(&pred, 1);
        for &c in &out.coupling {
            assert!((c - 1.0 / n_out as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn routing_converges_to_agreeing_capsule() {
        // All input capsules predict the same vector for output 0 and
        // random noise for output 1 → routing should couple to 0.
        let mut rng = Rng::new(2);
        let (n_in, n_out, d) = (8, 2, 4);
        let target = [0.9f32, -0.5, 0.3, 0.7];
        let mut u = vec![0.0f32; n_in * n_out * d];
        for i in 0..n_in {
            for k in 0..d {
                u[(i * n_out) * d + k] = target[k];
                u[(i * n_out + 1) * d + k] = rng.normal_f32(0.0, 0.5);
            }
        }
        let pred = Predictions::new(n_in, n_out, d, u);
        let out = dynamic_routing(&pred, 3);
        let lens = out.lengths();
        assert!(
            lens[0] > lens[1] + 0.1,
            "agreeing capsule should win: {lens:?}"
        );
        // Coupling to capsule 0 grew beyond uniform.
        let mean_c0: f32 = (0..n_in)
            .map(|i| out.coupling[i * n_out])
            .sum::<f32>()
            / n_in as f32;
        assert!(mean_c0 > 0.5, "coupling {mean_c0}");
    }

    #[test]
    fn routing_iterations_refine() {
        // More iterations → sharper coupling (monotone for this workload).
        let mut rng = Rng::new(3);
        let (n_in, n_out, d) = (16, 4, 8);
        let mut u = vec![0.0f32; n_in * n_out * d];
        for i in 0..n_in {
            for j in 0..n_out {
                for k in 0..d {
                    let signal = if j == 0 { 0.8 } else { 0.0 };
                    u[(i * n_out + j) * d + k] =
                        signal + rng.normal_f32(0.0, 0.3);
                }
            }
        }
        let pred = Predictions::new(n_in, n_out, d, u);
        let c1 = dynamic_routing(&pred, 1);
        let c3 = dynamic_routing(&pred, 3);
        let sharp = |o: &RoutingOutput| -> f32 {
            (0..n_in).map(|i| o.coupling[i * n_out]).sum::<f32>()
        };
        assert!(sharp(&c3) > sharp(&c1));
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // One scratch threaded across problems of *different* geometry
        // must reproduce the allocating path bit for bit — stale buffer
        // contents may never leak between frames.
        let mut rng = Rng::new(9);
        let mut scratch = RoutingScratch::new();
        for (n_in, n_out, d) in [(12, 4, 8), (5, 3, 4), (20, 10, 16), (5, 3, 4)] {
            let u: Vec<f32> = (0..n_in * n_out * d)
                .map(|_| rng.normal_f32(0.0, 0.7))
                .collect();
            let pred = Predictions::new(n_in, n_out, d, u);
            let fresh = dynamic_routing(&pred, 3);
            let reused = dynamic_routing_with(&pred, 3, &mut scratch);
            assert_eq!(fresh.v, reused.v);
            assert_eq!(fresh.coupling, reused.coupling);
        }
    }

    #[test]
    fn routing_mode_parse_and_effective_iters() {
        assert_eq!(
            RoutingMode::parse("accumulated", 3),
            Some(RoutingMode::Accumulated)
        );
        assert_eq!(
            RoutingMode::parse("iterative", 3),
            Some(RoutingMode::Iterative(3))
        );
        assert_eq!(
            RoutingMode::parse("iterative:5", 3),
            Some(RoutingMode::Iterative(5))
        );
        assert_eq!(RoutingMode::parse("warp", 3), None);
        assert_eq!(RoutingMode::Iterative(3).effective_iters(), 3);
        assert_eq!(RoutingMode::Accumulated.effective_iters(), 0);
        assert_eq!(RoutingMode::Accumulated.to_string(), "accumulated");
        assert_eq!(RoutingMode::Iterative(3).to_string(), "iterative(3)");
        // Fingerprint tags never collide across modes or iteration
        // counts — the cache-isolation satellite rides on this.
        assert_ne!(
            RoutingMode::Accumulated.fingerprint_tag(),
            RoutingMode::Iterative(0).fingerprint_tag()
        );
        assert_ne!(
            RoutingMode::Iterative(0).fingerprint_tag(),
            RoutingMode::Iterative(3).fingerprint_tag()
        );
    }

    #[test]
    fn accumulated_with_uniform_coupling_matches_one_iteration() {
        // One iterative round uses exactly-uniform coupling (softmax of
        // zero logits), so the accumulated path fed the same uniform
        // matrix must reproduce it bit for bit — same FC loop body.
        let mut rng = Rng::new(11);
        let (n_in, n_out, d) = (12, 4, 8);
        let u: Vec<f32> = (0..n_in * n_out * d)
            .map(|_| rng.normal_f32(0.0, 0.8))
            .collect();
        let pred = Predictions::new(n_in, n_out, d, u);
        let iter1 = dynamic_routing(&pred, 1);
        let uniform = vec![1.0f32 / n_out as f32; n_in * n_out];
        let acc = accumulated_routing(&pred, &uniform);
        assert_eq!(iter1.v, acc.v);
        assert_eq!(acc.coupling, uniform);
    }

    #[test]
    fn accumulated_scratch_reuse_is_bitwise() {
        let mut rng = Rng::new(12);
        let mut scratch = RoutingScratch::new();
        for (n_in, n_out, d) in [(12, 4, 8), (5, 3, 4), (20, 10, 16)] {
            let u: Vec<f32> = (0..n_in * n_out * d)
                .map(|_| rng.normal_f32(0.0, 0.7))
                .collect();
            let c: Vec<f32> = (0..n_in * n_out)
                .map(|_| rng.normal_f32(0.25, 0.05).abs())
                .collect();
            let pred = Predictions::new(n_in, n_out, d, u);
            let fresh = accumulated_routing(&pred, &c);
            let reused = accumulated_routing_with(&pred, &c, &mut scratch);
            assert_eq!(fresh.v, reused.v);
            assert_eq!(fresh.coupling, reused.coupling);
        }
    }

    #[test]
    fn mean_coupling_rows_stay_normalized() {
        // The offline accumulation pass averages softmax rows, so each
        // row of the mean is a convex combination and still sums to ~1.
        let mut rng = Rng::new(13);
        let (n_in, n_out, d) = (10, 4, 8);
        let outs: Vec<RoutingOutput> = (0..6)
            .map(|_| {
                let u: Vec<f32> = (0..n_in * n_out * d)
                    .map(|_| rng.normal_f32(0.0, 0.6))
                    .collect();
                dynamic_routing(&Predictions::new(n_in, n_out, d, u), 3)
            })
            .collect();
        let mean = mean_coupling(outs.iter().map(|o| o.coupling.as_slice()));
        assert_eq!(mean.len(), n_in * n_out);
        for i in 0..n_in {
            let row: f32 = mean[i * n_out..(i + 1) * n_out].iter().sum();
            assert!((row - 1.0).abs() < 1e-4, "row {i} sums to {row}");
        }
    }

    #[test]
    fn capsule_lengths_below_one() {
        let mut rng = Rng::new(4);
        let pred = Predictions::new(
            20,
            10,
            16,
            (0..20 * 10 * 16).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        );
        let out = dynamic_routing(&pred, 3);
        for l in out.lengths() {
            assert!((0.0..1.0).contains(&l), "length {l}");
        }
    }
}
