//! Q4.12 fixed-point dynamic routing — the accelerator datapath.
//!
//! Two variants, selected by [`SoftmaxMode`]:
//!
//! * `Baseline` — softmax with the iterative CORDIC-style `exp` (27 cy)
//!   and the exact fixed-point divider (49 cy). This is what Vivado HLS
//!   synthesizes from the naive routing code.
//! * `Taylor` — the paper's §III-B rewrite: Eq. 2 polynomial `exp`
//!   (14 cy, pipelineable) and Eq. 3 `exp(log a − log b)` divider (36 cy,
//!   pipelineable). Values differ from `Baseline` only by approximation
//!   error, which the tests bound against the f32 reference.
//!
//! Both variants compute identical *schedules* of arithmetic; the cycle
//! difference is modeled in `fpga::routing_module`, which replays the op
//! counts exposed by [`OpCounts`] against `fixed::latency`.

use crate::fixed::taylor;
use crate::fixed::{raw_slice, raw_slice_mut, Q12};
use crate::kernels;

/// Which softmax/divider hardware the datapath uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SoftmaxMode {
    Baseline,
    Taylor,
}

/// Count of each non-linear/datapath op executed — the contract between
/// the functional code here and the cycle model in `fpga`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub macs: u64,
    pub muls: u64,
    pub adds: u64,
    pub exps: u64,
    pub divs: u64,
    pub sqrts: u64,
}

impl OpCounts {
    pub fn merge(&mut self, other: &OpCounts) {
        self.macs += other.macs;
        self.muls += other.muls;
        self.adds += other.adds;
        self.exps += other.exps;
        self.divs += other.divs;
        self.sqrts += other.sqrts;
    }
}

/// Integer square root of a u64 (non-restoring, 32 iterations — the
/// Squash unit's sqrt for wide norm² accumulators).
fn isqrt_u64(x: u64) -> u64 {
    if x == 0 {
        return 0;
    }
    let mut res: u64 = 0;
    let mut bit: u64 = 1 << 62;
    let mut v = x;
    while bit > x {
        bit >>= 2;
    }
    while bit != 0 {
        if v >= res + bit {
            v -= res + bit;
            res = (res >> 1) + bit;
        } else {
            res >>= 1;
        }
        bit >>= 2;
    }
    res
}

/// Squash on Q8.8 inputs — the production datapath form. The FC step's
/// weighted sums can reach ±30 (well past Q4.12's ±8), so the Squash unit
/// takes its input in the activation format (Q8.8, range ±128) and keeps
/// norm² in the wide accumulator. Output capsules have norm < 1 and are
/// returned in Q4.12.
pub fn squash_q88(s_raw: &[i16], counts: &mut OpCounts) -> Vec<Q12> {
    let mut out = vec![Q12::ZERO; s_raw.len()];
    squash_q88_into(s_raw, &mut out, counts);
    out
}

/// [`squash_q88`] into a caller-provided buffer (batch hot path: no
/// per-capsule allocation). Identical arithmetic and op counts.
pub fn squash_q88_into(s_raw: &[i16], out: &mut [Q12], counts: &mut OpCounts) {
    debug_assert_eq!(s_raw.len(), out.len());
    // norm² in Q16.16 (sum of squared Q8.8 raws) — wide integer
    // accumulation, so the SIMD kernel is bit-identical in any order.
    let acc: i64 = kernels::sumsq_i16(s_raw);
    counts.macs += s_raw.len() as u64;
    if acc == 0 {
        out.fill(Q12::ZERO);
        return;
    }
    // ‖s‖ in Q8.8 = isqrt of the Q16.16 accumulator.
    let norm_q88 = isqrt_u64(acc as u64) as i64;
    counts.sqrts += 1;
    // scale = ‖s‖ / (1 + ‖s‖²) in Q4.12:
    // (Q8.8 << 20) / Q16.16 -> Q12 raw.
    let denom = (1i64 << 16) + acc;
    counts.adds += 1;
    let scale_q12 = ((norm_q88 << 20) / denom).clamp(0, i16::MAX as i64);
    counts.divs += 1;
    counts.muls += s_raw.len() as u64;
    // Q8.8 × Q4.12 -> shift 8 -> Q4.12. The product fits i32 exactly
    // (|x| ≤ 2¹⁵, 0 ≤ scale ≤ 2¹⁵−1), so the lane kernel's i32 path is
    // bit-identical to the old i64 element loop.
    kernels::scale_i16_q::<8>(s_raw, scale_q12 as i32, raw_slice_mut(out));
}

/// Q4.12 squash on the dedicated Squash unit (Fig. 11a): norm² via MAC
/// adder tree, non-restoring sqrt, and scale `‖s‖ / (1 + ‖s‖²)` — computed
/// with the exact divider in both modes (the paper keeps Squash off the
/// PE array and unchanged by the optimization). Valid for inputs within
/// Q4.12 range (primary capsules); the FC step uses [`squash_q88`].
pub fn squash_q12(s: &[Q12], counts: &mut OpCounts) -> Vec<Q12> {
    // norm² accumulates in the wide (Q8.24) register.
    let mut acc: i64 = 0;
    for &x in s {
        acc = x.mac(x, acc);
    }
    counts.macs += s.len() as u64;
    if acc == 0 {
        return vec![Q12::ZERO; s.len()];
    }
    let norm = taylor::sqrt_q12(acc); // Q4.12
    counts.sqrts += 1;
    // scale = norm / (1 + norm²) with the denominator kept in the wide
    // Q8.24 accumulator (1 + ‖s‖² can reach d·64, far past Q4.12's range;
    // the divider reads the accumulator register directly).
    let denom_acc = (1i64 << 24) + acc;
    counts.adds += 1;
    let scale_raw = ((norm.raw() as i64) << 24) / denom_acc;
    let scale = Q12::from_raw(scale_raw.clamp(0, i16::MAX as i64) as i16);
    counts.divs += 1;
    counts.muls += s.len() as u64;
    s.iter().map(|&x| x.mul(scale)).collect()
}

/// Q4.12 softmax over a logit row (Fig. 11b).
///
/// Baseline: `exp` per element + exact division per element.
/// Taylor: max-shift, Eq. 2 exp per element, Eq. 3 division per element.
pub fn softmax_q12(b: &[Q12], mode: SoftmaxMode, counts: &mut OpCounts) -> Vec<Q12> {
    let mut out = vec![Q12::ZERO; b.len()];
    softmax_q12_into(b, &mut out, mode, counts);
    out
}

/// [`softmax_q12`] into a caller-provided buffer (the exponentials are
/// staged in `out` itself, then normalized in place). Identical
/// arithmetic and op counts to the allocating form.
pub fn softmax_q12_into(b: &[Q12], out: &mut [Q12], mode: SoftmaxMode, counts: &mut OpCounts) {
    debug_assert_eq!(b.len(), out.len());
    // Max-shift for range safety (a comparator tree in hardware; counted
    // as adds). Max is order-independent, so the SIMD fold is exact.
    let max = Q12::from_raw(kernels::max_i16(raw_slice(b)));
    counts.adds += b.len() as u64;
    for (o, &x) in out.iter_mut().zip(b) {
        *o = taylor::exp_taylor_q12(x.sub(max));
    }
    counts.exps += b.len() as u64;
    // Σ e^x in the wide accumulator (the denominator can exceed the
    // Q4.12 range — the divider/log unit reads the accumulator register).
    let acc = kernels::sum_i16(raw_slice(out)).max(1);
    counts.adds += b.len() as u64;
    counts.divs += b.len() as u64;
    match mode {
        SoftmaxMode::Baseline => {
            for o in out.iter_mut() {
                *o = taylor::div_exact_acc_q12(*o, acc);
            }
        }
        SoftmaxMode::Taylor => {
            for o in out.iter_mut() {
                *o = taylor::div_explog_acc_q12(*o, acc);
            }
        }
    }
}

/// Fixed-point predictions `û_{j|i}` in Q4.12, `[n_in][n_out][d_out]`.
#[derive(Debug, Clone)]
pub struct PredictionsQ12 {
    pub n_in: usize,
    pub n_out: usize,
    pub d_out: usize,
    pub u_hat: Vec<Q12>,
}

impl PredictionsQ12 {
    /// Quantize f32 predictions.
    pub fn quantize(pred: &super::Predictions) -> PredictionsQ12 {
        PredictionsQ12 {
            n_in: pred.n_in,
            n_out: pred.n_out,
            d_out: pred.d_out,
            u_hat: pred.u_hat.iter().map(|&x| Q12::from_f32(x)).collect(),
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> &[Q12] {
        let off = (i * self.n_out + j) * self.d_out;
        &self.u_hat[off..off + self.d_out]
    }
}

/// Q4.12 routing result.
#[derive(Debug, Clone)]
pub struct RoutingOutputQ12 {
    pub v: Vec<Q12>,
    pub coupling: Vec<Q12>,
    pub n_out: usize,
    pub d_out: usize,
    pub counts: OpCounts,
}

impl RoutingOutputQ12 {
    pub fn lengths_f32(&self) -> Vec<f32> {
        (0..self.n_out)
            .map(|j| {
                self.v[j * self.d_out..(j + 1) * self.d_out]
                    .iter()
                    .map(|x| {
                        let f = x.to_f32();
                        f * f
                    })
                    .sum::<f32>()
                    .sqrt()
            })
            .collect()
    }
}

/// Reusable working buffers for fixed-point routing — the û tensor,
/// logit/coupling/output arrays, and the FC-stage staging registers that
/// [`dynamic_routing_q12`] would otherwise allocate per frame. Batch
/// callers ([`crate::fpga::DeployedModel::run_batch`]) keep one scratch
/// alive across all frames: [`RoutingScratch::prepare`] resizes and
/// resets state for a geometry, the caller fills
/// [`RoutingScratch::u_hat_mut`] with the frame's predictions, and
/// [`RoutingScratch::run`] executes the routing iterations over them.
#[derive(Debug, Default)]
pub struct RoutingScratch {
    n_in: usize,
    n_out: usize,
    d_out: usize,
    u_hat: Vec<Q12>,
    b: Vec<Q12>,
    c: Vec<Q12>,
    v: Vec<Q12>,
    s_acc: Vec<i64>,
    s_raw: Vec<i16>,
}

impl RoutingScratch {
    pub fn new() -> RoutingScratch {
        RoutingScratch::default()
    }

    /// Size every buffer for a routing geometry and reset all state
    /// (logits to zero, û to zero). Reallocation only happens when the
    /// geometry grows past the retained capacity.
    pub fn prepare(&mut self, n_in: usize, n_out: usize, d_out: usize) {
        self.n_in = n_in;
        self.n_out = n_out;
        self.d_out = d_out;
        self.u_hat.clear();
        self.u_hat.resize(n_in * n_out * d_out, Q12::ZERO);
        self.b.clear();
        self.b.resize(n_in * n_out, Q12::ZERO);
        self.c.clear();
        self.c.resize(n_in * n_out, Q12::ZERO);
        self.v.clear();
        self.v.resize(n_out * d_out, Q12::ZERO);
        self.s_acc.clear();
        self.s_acc.resize(d_out, 0);
        self.s_raw.clear();
        self.s_raw.resize(d_out, 0);
    }

    /// The û buffer (`[n_in][n_out][d_out]` flat) for the caller to fill
    /// after [`RoutingScratch::prepare`] — e.g. the PE-array projection
    /// writes its outputs straight in here, skipping an intermediate
    /// tensor.
    pub fn u_hat_mut(&mut self) -> &mut [Q12] {
        &mut self.u_hat
    }

    /// Accumulated-coefficients routing over the prepared buffers
    /// (Zhao et al. fast path, Q4.12): the coupling matrix is a
    /// precomputed constant loaded straight into the `c` buffer, and
    /// the stage runs exactly one FC + squash pass — the same loop
    /// body, accumulation order, and wide-register staging as one
    /// iteration of [`RoutingScratch::run`], with zero softmax,
    /// agreement, or logit-update ops in the [`OpCounts`].
    pub fn run_accumulated(&mut self, coupling: &[Q12]) -> RoutingOutputQ12 {
        let (n_in, n_out, d) = (self.n_in, self.n_out, self.d_out);
        assert_eq!(
            coupling.len(),
            n_in * n_out,
            "accumulated coupling shape mismatch"
        );
        let RoutingScratch {
            u_hat,
            c,
            v,
            s_acc,
            s_raw,
            ..
        } = self;
        c.copy_from_slice(coupling);
        let mut counts = OpCounts::default();
        for j in 0..n_out {
            s_acc.fill(0);
            for i in 0..n_in {
                let cij = c[i * n_out + j];
                let u = &u_hat[(i * n_out + j) * d..][..d];
                // acc += c_ij · û lane-parallel in wide registers
                // (bit-identical to the serial MAC chain).
                kernels::axpy_i16(s_acc, cij.raw(), raw_slice(u));
            }
            counts.macs += (n_in * d) as u64;
            for (r, &a) in s_raw.iter_mut().zip(s_acc.iter()) {
                *r = ((a + (1 << 15)) >> 16).clamp(i16::MIN as i64, i16::MAX as i64)
                    as i16;
            }
            squash_q88_into(s_raw, &mut v[j * d..(j + 1) * d], &mut counts);
        }
        RoutingOutputQ12 {
            v: v.clone(),
            coupling: c.clone(),
            n_out,
            d_out: d,
            counts,
        }
    }

    /// Run dynamic routing over the prepared buffers. Identical
    /// arithmetic, schedule, and [`OpCounts`] to [`dynamic_routing_q12`]
    /// (which delegates here) — only the allocations differ.
    pub fn run(&mut self, iterations: usize, mode: SoftmaxMode) -> RoutingOutputQ12 {
        let (n_in, n_out, d) = (self.n_in, self.n_out, self.d_out);
        let RoutingScratch {
            u_hat,
            b,
            c,
            v,
            s_acc,
            s_raw,
            ..
        } = self;
        let mut counts = OpCounts::default();

        for it in 0..iterations {
            for i in 0..n_in {
                softmax_q12_into(
                    &b[i * n_out..(i + 1) * n_out],
                    &mut c[i * n_out..(i + 1) * n_out],
                    mode,
                    &mut counts,
                );
            }
            for j in 0..n_out {
                // s_j accumulates per-dimension in wide registers (Q8.24).
                s_acc.fill(0);
                for i in 0..n_in {
                    let cij = c[i * n_out + j];
                    let u = &u_hat[(i * n_out + j) * d..][..d];
                    for (a, &uk) in s_acc.iter_mut().zip(u) {
                        *a = cij.mac(uk, *a);
                    }
                }
                counts.macs += (n_in * d) as u64;
                // Stage s in Q8.8 (range ±128 — weighted sums exceed
                // Q4.12) and squash on the wide-input unit.
                for (r, &a) in s_raw.iter_mut().zip(s_acc.iter()) {
                    *r = ((a + (1 << 15)) >> 16).clamp(i16::MIN as i64, i16::MAX as i64)
                        as i16;
                }
                squash_q88_into(s_raw, &mut v[j * d..(j + 1) * d], &mut counts);
            }
            if it + 1 < iterations {
                for i in 0..n_in {
                    for j in 0..n_out {
                        let u = &u_hat[(i * n_out + j) * d..][..d];
                        let vj = &v[j * d..(j + 1) * d];
                        let acc = kernels::dot_i16(raw_slice(u), raw_slice(vj));
                        counts.macs += d as u64;
                        b[i * n_out + j] = b[i * n_out + j].add(Q12::from_acc(acc));
                        counts.adds += 1;
                    }
                }
            }
        }
        RoutingOutputQ12 {
            v: v.clone(),
            coupling: c.clone(),
            n_out,
            d_out: d,
            counts,
        }
    }
}

/// Fixed-point dynamic routing. Functionally identical for both loop
/// orders (Code 1 vs Code 2 reorder only changes write patterns/timing),
/// so one implementation serves both; `mode` selects the non-linear units.
pub fn dynamic_routing_q12(
    pred: &PredictionsQ12,
    iterations: usize,
    mode: SoftmaxMode,
) -> RoutingOutputQ12 {
    dynamic_routing_q12_with(pred, iterations, mode, &mut RoutingScratch::new())
}

/// [`dynamic_routing_q12`] with caller-owned scratch: copies the
/// predictions into the scratch û buffer and runs. Callers that can
/// write û in place (the simulator's projection stage) should instead
/// use [`RoutingScratch::prepare`] + [`RoutingScratch::u_hat_mut`] +
/// [`RoutingScratch::run`] and skip the copy.
pub fn dynamic_routing_q12_with(
    pred: &PredictionsQ12,
    iterations: usize,
    mode: SoftmaxMode,
    scratch: &mut RoutingScratch,
) -> RoutingOutputQ12 {
    scratch.prepare(pred.n_in, pred.n_out, pred.d_out);
    scratch.u_hat_mut().copy_from_slice(&pred.u_hat);
    scratch.run(iterations, mode)
}

/// Accumulated-coefficients routing on the Q4.12 datapath (allocating
/// form; [`RoutingScratch::run_accumulated`] is the batch hot path).
pub fn accumulated_routing_q12(pred: &PredictionsQ12, coupling: &[Q12]) -> RoutingOutputQ12 {
    let mut scratch = RoutingScratch::new();
    scratch.prepare(pred.n_in, pred.n_out, pred.d_out);
    scratch.u_hat_mut().copy_from_slice(&pred.u_hat);
    scratch.run_accumulated(coupling)
}

/// Quantize an f32 accumulated-coupling matrix to the Q4.12 datapath
/// format. Coefficients live in [0, 1], so each entry round-trips
/// within one Q12 LSB (1/4096) of the f32 value — pinned by test.
pub fn quantize_coupling(coupling: &[f32]) -> Vec<Q12> {
    coupling.iter().map(|&x| Q12::from_f32(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::{dynamic_routing, Predictions};
    use crate::util::rng::Rng;

    fn random_predictions(n_in: usize, n_out: usize, d: usize, seed: u64) -> Predictions {
        let mut rng = Rng::new(seed);
        Predictions::new(
            n_in,
            n_out,
            d,
            (0..n_in * n_out * d)
                .map(|_| rng.normal_f32(0.0, 0.5))
                .collect(),
        )
    }

    #[test]
    fn q12_routing_tracks_f32_reference() {
        let pred = random_predictions(24, 10, 8, 1);
        let f32_out = dynamic_routing(&pred, 3);
        let q = PredictionsQ12::quantize(&pred);
        for mode in [SoftmaxMode::Baseline, SoftmaxMode::Taylor] {
            let q_out = dynamic_routing_q12(&q, 3, mode);
            let ql = q_out.lengths_f32();
            let fl = f32_out.lengths();
            for (a, b) in ql.iter().zip(&fl) {
                assert!(
                    (a - b).abs() < 0.05,
                    "{mode:?}: length {a} vs f32 {b}"
                );
            }
        }
    }

    #[test]
    fn taylor_and_baseline_agree() {
        // §IV-B: "the proposed optimization approach did not lead to a
        // reduction in the accuracy" — argmax must match, values close.
        let pred = random_predictions(36, 10, 8, 2);
        let q = PredictionsQ12::quantize(&pred);
        let base = dynamic_routing_q12(&q, 3, SoftmaxMode::Baseline);
        let tay = dynamic_routing_q12(&q, 3, SoftmaxMode::Taylor);
        let bl = base.lengths_f32();
        let tl = tay.lengths_f32();
        // NaN-safe total-order argmax (util::argmax) — the local
        // partial_cmp().unwrap() closure this replaces would panic on a
        // corrupt length instead of ranking it out.
        assert_eq!(crate::util::argmax(&bl), crate::util::argmax(&tl));
        for (a, b) in bl.iter().zip(&tl) {
            assert!((a - b).abs() < 0.03, "taylor {a} vs baseline {b}");
        }
    }

    #[test]
    fn softmax_q12_sums_to_one() {
        let mut counts = OpCounts::default();
        let b: Vec<Q12> = [0.5f32, -0.2, 1.1, 0.0]
            .iter()
            .map(|&x| Q12::from_f32(x))
            .collect();
        for mode in [SoftmaxMode::Baseline, SoftmaxMode::Taylor] {
            let c = softmax_q12(&b, mode, &mut counts);
            let sum: f32 = c.iter().map(|x| x.to_f32()).sum();
            assert!((sum - 1.0).abs() < 0.02, "{mode:?} sum {sum}");
        }
    }

    #[test]
    fn squash_q12_tracks_f32() {
        let mut counts = OpCounts::default();
        let s_f32 = [0.8f32, -0.3, 0.5, 0.1];
        let s: Vec<Q12> = s_f32.iter().map(|&x| Q12::from_f32(x)).collect();
        let got = squash_q12(&s, &mut counts);
        let want = crate::routing::squash(&s_f32);
        for (g, w) in got.iter().zip(&want) {
            assert!((g.to_f32() - w).abs() < 0.01, "{} vs {}", g.to_f32(), w);
        }
        assert_eq!(counts.sqrts, 1);
        assert_eq!(counts.divs, 1);
    }

    #[test]
    fn op_counts_scale_with_problem() {
        let pred = random_predictions(12, 4, 8, 3);
        let q = PredictionsQ12::quantize(&pred);
        let out1 = dynamic_routing_q12(&q, 1, SoftmaxMode::Taylor);
        let out3 = dynamic_routing_q12(&q, 3, SoftmaxMode::Taylor);
        // 3 iterations do ~3x the softmax work of 1.
        assert_eq!(out3.counts.exps, 3 * out1.counts.exps);
        // exps = iterations × n_in × n_out.
        assert_eq!(out1.counts.exps, 12 * 4);
        // divs = softmax divs + squash divs.
        assert_eq!(out1.counts.divs, 12 * 4 + 4);
    }

    #[test]
    fn scratch_reuse_is_stateless_and_bitwise() {
        // One scratch threaded across frames of different geometry must
        // reproduce the allocating path bit for bit — including the op
        // counts the cycle model replays.
        let mut scratch = RoutingScratch::new();
        for (seed, (n_in, n_out, d)) in
            [(4u64, (24, 10, 8)), (5, (8, 4, 16)), (6, (24, 10, 8))]
        {
            let pred = random_predictions(n_in, n_out, d, seed);
            let q = PredictionsQ12::quantize(&pred);
            for mode in [SoftmaxMode::Baseline, SoftmaxMode::Taylor] {
                let fresh = dynamic_routing_q12(&q, 3, mode);
                let reused = dynamic_routing_q12_with(&q, 3, mode, &mut scratch);
                assert_eq!(fresh.v, reused.v);
                assert_eq!(fresh.coupling, reused.coupling);
                assert_eq!(fresh.counts, reused.counts);
            }
        }
    }

    #[test]
    fn accumulated_q12_matches_one_pass_of_iterative_fc() {
        // Feed the accumulated path the coupling the iterative path just
        // computed: the FC + squash bodies are the same code shape, so v
        // must match bit for bit.
        let pred = random_predictions(24, 10, 8, 21);
        let q = PredictionsQ12::quantize(&pred);
        let iter1 = dynamic_routing_q12(&q, 1, SoftmaxMode::Taylor);
        let acc = accumulated_routing_q12(&q, &iter1.coupling);
        assert_eq!(iter1.v, acc.v);
        assert_eq!(iter1.coupling, acc.coupling);
    }

    #[test]
    fn accumulated_q12_op_counts_collapse() {
        // The fast path's entire budget is one FC pass + squash: zero
        // exps, zero softmax divides, zero agreement/logit updates.
        let (n_in, n_out, d) = (12, 4, 8);
        let pred = random_predictions(n_in, n_out, d, 22);
        let q = PredictionsQ12::quantize(&pred);
        let coupling = vec![Q12::from_f32(1.0 / n_out as f32); n_in * n_out];
        let out = accumulated_routing_q12(&q, &coupling);
        assert_eq!(out.counts.exps, 0);
        // divs/sqrts come from squash only: one per output capsule.
        assert_eq!(out.counts.divs, n_out as u64);
        assert_eq!(out.counts.sqrts, n_out as u64);
        // macs: FC (n_in·d per capsule) + squash norm² (d per capsule).
        assert_eq!(out.counts.macs, (n_out * (n_in * d + d)) as u64);
    }

    #[test]
    fn quantized_coupling_round_trips_within_one_lsb() {
        // Coupling coefficients live in [0, 1]; Q4.12 represents them
        // within one LSB (1/4096) of the f32 accumulation.
        let pred = random_predictions(20, 10, 8, 23);
        let f32_out = dynamic_routing(&pred, 3);
        let q = quantize_coupling(&f32_out.coupling);
        let lsb = 1.0 / 4096.0;
        for (&qc, &fc) in q.iter().zip(&f32_out.coupling) {
            assert!(
                (qc.to_f32() - fc).abs() <= lsb,
                "q12 {} vs f32 {fc}",
                qc.to_f32()
            );
        }
    }

    #[test]
    fn accumulated_q12_tracks_f32_accumulated() {
        let pred = random_predictions(24, 10, 8, 24);
        let f32_iter = dynamic_routing(&pred, 3);
        let mean = crate::routing::mean_coupling(
            std::iter::once(f32_iter.coupling.as_slice()),
        );
        let f32_acc = crate::routing::accumulated_routing(&pred, &mean);
        let q = PredictionsQ12::quantize(&pred);
        let q_acc = accumulated_routing_q12(&q, &quantize_coupling(&mean));
        for (a, b) in q_acc.lengths_f32().iter().zip(&f32_acc.lengths()) {
            assert!((a - b).abs() < 0.05, "q12 length {a} vs f32 {b}");
        }
    }

    #[test]
    fn property_q12_lengths_bounded() {
        crate::testing::check(
            "q12 capsule lengths in [0,1)",
            25,
            7,
            |r| {
                let n_in = 4 + r.below(12);
                let n_out = 2 + r.below(6);
                random_predictions(n_in, n_out, 8, r.next_u64())
            },
            |pred| {
                let q = PredictionsQ12::quantize(pred);
                let out = dynamic_routing_q12(&q, 3, SoftmaxMode::Taylor);
                out.lengths_f32().iter().all(|&l| (0.0..1.05).contains(&l))
            },
        );
    }
}
