//! Sharded readiness event loop: the IO half of the network front-end.
//!
//! Each *shard* is one thread owning a set of nonblocking connections,
//! multiplexed with `poll(2)` (std-only: the symbol is reached through
//! a direct `extern "C"` declaration — std already links libc — with a
//! portable short-sleep sweep fallback off unix). The acceptor hands
//! sockets round-robin to shards over an [`Event`] channel; a one-byte
//! [`Waker`] pipe gets a parked shard out of `poll` when an event
//! arrives.
//!
//! ```text
//!  acceptor ──Accept──► shard 0 ─┬─ conn: rbuf ─ parse ─ submit_sink ──► pool
//!            round-robin shard 1 │        wbuf ◄─ encode ◄─ Done/Failed ◄─┘
//!                        …       └─ waker pipe (event arrived, leave poll)
//! ```
//!
//! The executor pool never touches a socket: a completed request comes
//! back as an [`Event::Done`] carried by the [`ShardSink`] the request
//! was submitted with, and the shard that owns the connection encodes
//! and writes the frame. Writes go through a bounded per-connection
//! buffer — a peer that stops reading first loses read service (its
//! requests stop being parsed at half the budget) and is then
//! disconnected outright when the buffer overflows
//! (`net_slow_client_drops` in the metrics), so a slowloris reader can
//! never stall a replica thread or grow server memory.
//!
//! Per-connection protocol state lives in [`Conn`]: wire version
//! latching (v1 in-order emulation via a tag reorder buffer, v2 writes
//! completions as they land), graceful-shutdown acks deferred until the
//! connection's in-flight requests drain, and a lingering close on
//! desynchronized streams so the typed error frame survives instead of
//! being destroyed by a TCP reset. The same listener also answers
//! plaintext probes (`HEALTH`/`READY`/`METRICS`, or HTTP `GET
//! /healthz|/readyz|/metrics`): the first bytes of a connection are
//! sniffed, and anything that is neither a probe token nor frame magic
//! still gets the typed `BadMagic` error frame.

use super::net::NetShared;
use super::server::ReplySink;
use super::wire::{self, ErrorCode, Fault, FrameType};
use super::Response;
use crate::backend::BackendError;
use crate::tensor::Tensor;
use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Read granularity; also the per-`read` cap a single connection gets
/// before the shard moves on (fairness under a firehose sender).
const READ_CHUNK: usize = 16 * 1024;
/// Reads one connection may issue per readiness tick.
const READ_ROUNDS: usize = 4;
/// Lingering-close window on a desynchronized stream: how long (and how
/// many bytes) of already-sent peer data to swallow so our FIN is not
/// turned into a RST while the error frame is still in flight.
const LINGER: Duration = Duration::from_millis(200);
const LINGER_BUDGET: usize = 64 * 1024;
/// Hard ceiling on a graceful drain: past this, connections that still
/// have not flushed are dropped.
const DRAIN_FORCE: Duration = Duration::from_secs(10);
/// Largest probe/HTTP request head we accept before declaring the text
/// peer broken.
const MAX_TEXT_HEAD: usize = 4096;

// ---------------------------------------------------------------------
// readiness primitive

/// Minimal `poll(2)` surface. std links libc, so the symbol resolves
/// without any external crate; the constants and layouts below are the
/// POSIX-mandated ones.
#[cfg(unix)]
mod sys {
    use std::io;
    use std::os::unix::io::RawFd;

    #[repr(C)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    #[cfg(target_os = "linux")]
    type NfdsT = u64;
    #[cfg(not(target_os = "linux"))]
    type NfdsT = u32;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
    }

    /// Block until any fd is ready or `timeout_ms` passes, retrying
    /// signal interruptions.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        loop {
            // SAFETY: `fds` is a live `&mut [PollFd]`, so the pointer
            // and length describe valid, writable memory for the call.
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

/// Gets a shard out of a blocked `poll` when an event is queued from
/// another thread (acceptor handoff, executor completion). One byte
/// down a nonblocking socketpair; a full pipe is fine — the shard is
/// already guaranteed to wake.
#[cfg(unix)]
pub(crate) struct Waker {
    tx: std::os::unix::net::UnixStream,
    rx: std::os::unix::net::UnixStream,
}

#[cfg(unix)]
impl Waker {
    fn new() -> io::Result<Waker> {
        let (tx, rx) = std::os::unix::net::UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok(Waker { tx, rx })
    }

    pub(crate) fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }

    fn drain(&self) {
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }

    fn read_fd(&self) -> std::os::unix::io::RawFd {
        use std::os::unix::io::AsRawFd;
        self.rx.as_raw_fd()
    }
}

/// Off unix the shard falls back to a short-sleep sweep, so the waker
/// has nothing to do.
#[cfg(not(unix))]
pub(crate) struct Waker;

#[cfg(not(unix))]
impl Waker {
    fn new() -> io::Result<Waker> {
        Ok(Waker)
    }

    pub(crate) fn wake(&self) {}

    fn drain(&self) {}
}

// ---------------------------------------------------------------------
// shard mailbox

/// Everything that reaches a shard from outside its own sockets.
pub(crate) enum Event {
    /// A connection the acceptor assigned to this shard.
    Accept(TcpStream),
    /// The executor finished a request submitted by this shard.
    Done { conn: u64, tag: u64, resp: Response },
    /// The executor dropped the request without a response (backend
    /// failure, shutdown race): the tag gets a typed `Unavailable`.
    Failed { conn: u64, tag: u64 },
}

/// The delivery half of one submitted request: carries the owning
/// connection id and tag back to the shard as an [`Event`]. Dropping a
/// sink that never sent reports [`Event::Failed`] — exactly the
/// disconnected-channel semantics the in-process path gets from a
/// dropped `mpsc::Sender`.
pub(crate) struct ShardSink {
    conn: u64,
    tag: u64,
    tx: mpsc::Sender<Event>,
    waker: Arc<Waker>,
    sent: bool,
}

impl ShardSink {
    pub(crate) fn send(mut self, resp: Response) {
        self.sent = true;
        let _ = self.tx.send(Event::Done {
            conn: self.conn,
            tag: self.tag,
            resp,
        });
        self.waker.wake();
    }

    /// Consume without any event — for synchronous rejections where the
    /// shard already answered the tag with a typed error frame.
    pub(crate) fn dispose(mut self) {
        self.sent = true;
    }
}

impl Drop for ShardSink {
    fn drop(&mut self) {
        if !self.sent {
            let _ = self.tx.send(Event::Failed {
                conn: self.conn,
                tag: self.tag,
            });
            self.waker.wake();
        }
    }
}

/// The acceptor's (and drain's) handle to one shard.
#[derive(Clone)]
pub(crate) struct ShardHandle {
    tx: mpsc::Sender<Event>,
    waker: Arc<Waker>,
}

impl ShardHandle {
    pub(crate) fn accept(&self, stream: TcpStream) {
        let _ = self.tx.send(Event::Accept(stream));
        self.waker.wake();
    }

    pub(crate) fn wake(&self) {
        self.waker.wake();
    }
}

/// Spawn one IO shard thread. Fails only on resource exhaustion at
/// bind time (thread or socketpair), before any traffic is accepted.
pub(crate) fn spawn_shard(
    idx: usize,
    shared: Arc<NetShared>,
) -> io::Result<(ShardHandle, JoinHandle<()>)> {
    let (tx, rx) = mpsc::channel();
    let waker = Arc::new(Waker::new()?);
    let handle = ShardHandle {
        tx: tx.clone(),
        waker: waker.clone(),
    };
    let join = std::thread::Builder::new()
        .name(format!("fastcaps-net-shard-{idx}"))
        .spawn(move || {
            Shard {
                idx,
                shared,
                rx,
                tx,
                waker,
                conns: HashMap::new(),
                drain_deadline: None,
            }
            .run()
        })?;
    Ok((handle, join))
}

// ---------------------------------------------------------------------
// connection state machine

/// What the first bytes of a connection turned out to be. A connection
/// that has not produced enough bytes to decide has no mode yet
/// (`Conn::mode` is `None`).
#[derive(Clone, Copy)]
enum Mode {
    /// FastCaps frames (v1 or v2, latched on the first frame).
    Binary,
    /// A plaintext probe (`HEALTH`/`READY`/`METRICS` or HTTP GET).
    Text,
}

const TEXT_PREFIXES: [&[u8]; 5] = [b"HEALTH", b"READY", b"METRICS", b"GET ", b"HEAD "];

/// One connection owned by one shard. All IO is nonblocking; the shard
/// only touches it when `poll` reports readiness.
struct Conn {
    id: u64,
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    /// `None` until the first bytes disambiguate the protocol.
    mode: Option<Mode>,
    /// Wire version latched from the first frame (0 = not yet latched).
    /// Mixing versions afterwards is a `Malformed` desync.
    version: u8,
    /// v1 clients don't tag requests: the server assigns sequential
    /// internal tags and restores strict request order on the way out.
    next_v1_tag: u64,
    /// v1 response order: front = next tag whose frame may be written.
    inorder: VecDeque<u64>,
    /// v1 completions that arrived out of order, parked until their
    /// turn. Bounded by the connection's own in-flight requests.
    parked: HashMap<u64, Vec<u8>>,
    /// Requests submitted to the pool and not yet completed/failed.
    outstanding: usize,
    /// Stop parsing new requests (shutdown frame, desync, drain, EOF).
    read_closed: bool,
    /// Close once everything owed has been written.
    close_after_flush: bool,
    /// A graceful-shutdown ack is owed once in-flight work drains.
    ack_when_drained: bool,
    /// Lingering close (desync): swallow peer bytes until the deadline,
    /// the byte budget, or EOF.
    linger_until: Option<Instant>,
    linger_budget: usize,
    /// Set by `poll` (or optimistically at accept); consumed by the
    /// service pass.
    ready_read: bool,
    ready_write: bool,
    peer_eof: bool,
    /// Fatal transport state: reap without further IO.
    dead: bool,
    /// Dead specifically because the write buffer overflowed.
    slow_drop: bool,
    wire_requests: u64,
    wire_errors: u64,
}

impl Conn {
    fn new(id: u64, stream: TcpStream) -> Conn {
        Conn {
            id,
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            mode: None,
            version: 0,
            next_v1_tag: 0,
            inorder: VecDeque::new(),
            parked: HashMap::new(),
            outstanding: 0,
            read_closed: false,
            close_after_flush: false,
            ack_when_drained: false,
            linger_until: None,
            linger_budget: 0,
            ready_read: true, // the client may have sent bytes already
            ready_write: false,
            peer_eof: false,
            dead: false,
            slow_drop: false,
            wire_requests: 0,
            wire_errors: 0,
        }
    }

    fn pending_write(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    fn lingering(&self) -> bool {
        self.linger_until.is_some() && !self.peer_eof && self.linger_budget > 0
    }

    fn wants_read(&self, max_wbuf: usize) -> bool {
        !self.dead
            && ((self.lingering())
                || (!self.read_closed && self.pending_write() < max_wbuf / 2))
    }

    /// Deliver one completed tag's encoded frame: v2 writes it straight
    /// out; v1 holds it to the strict request order.
    fn complete(&mut self, tag: u64, frame: Vec<u8>) {
        if self.version == wire::V2 {
            self.wbuf.extend_from_slice(&frame);
        } else {
            self.parked.insert(tag, frame);
            while let Some(&front) = self.inorder.front() {
                match self.parked.remove(&front) {
                    Some(f) => {
                        self.wbuf.extend_from_slice(&f);
                        self.inorder.pop_front();
                    }
                    None => break,
                }
            }
        }
        self.maybe_ack();
    }

    /// Emit the deferred shutdown ack once every in-flight request on
    /// this connection has been answered (v1: and written in order).
    fn maybe_ack(&mut self) {
        if self.ack_when_drained && self.outstanding == 0 && self.inorder.is_empty() {
            self.ack_when_drained = false;
            let v = if self.version == 0 { wire::VERSION } else { self.version };
            let ack = wire::encode_empty(v, FrameType::ShutdownAck);
            self.wbuf.extend_from_slice(&ack);
            self.close_after_flush = true;
        }
    }

    /// Connection-level failure: typed error frame, then a lingering
    /// close. On a latched v1 stream the error takes a response slot in
    /// order (after every pipelined response, like the blocking
    /// front-end wrote it); otherwise it is written directly — with the
    /// connection tag on v2.
    fn fail_stream(&mut self, code: ErrorCode, msg: &str) {
        self.wire_errors += 1;
        if self.version == wire::VERSION {
            let tag = self.next_v1_tag;
            self.next_v1_tag += 1;
            self.inorder.push_back(tag);
            let frame = wire::encode_error(wire::VERSION, tag, code, msg);
            self.complete(tag, frame);
        } else {
            let v = if self.version == 0 { wire::VERSION } else { self.version };
            let frame = wire::encode_error(v, wire::CONN_TAG, code, msg);
            self.wbuf.extend_from_slice(&frame);
        }
        self.read_closed = true;
        self.close_after_flush = true;
        self.linger_until = Some(Instant::now() + LINGER);
        self.linger_budget = LINGER_BUDGET;
    }

    /// Nonblocking flush of the write buffer.
    fn flush_wbuf(&mut self) {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > READ_CHUNK {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
    }

    /// Nonblocking read into the parse buffer (bounded per tick).
    fn read_some(&mut self) {
        let mut buf = [0u8; READ_CHUNK];
        for _ in 0..READ_ROUNDS {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.peer_eof = true;
                    self.read_closed = true;
                    self.close_after_flush = true;
                    break;
                }
                Ok(n) => self.rbuf.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
    }

    /// Lingering-close read: swallow and discard peer bytes.
    fn linger_read(&mut self) {
        let mut buf = [0u8; 4096];
        while self.linger_budget > 0 {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.peer_eof = true;
                    break;
                }
                Ok(n) => self.linger_budget = self.linger_budget.saturating_sub(n),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
    }

    /// Whether the shard may reap this connection now.
    fn should_close(&self, now: Instant) -> bool {
        if self.dead {
            return true;
        }
        if !self.close_after_flush || self.pending_write() > 0 {
            return false;
        }
        if self.outstanding > 0 || !self.inorder.is_empty() || self.ack_when_drained {
            return false;
        }
        match self.linger_until {
            None => true,
            Some(t) => self.peer_eof || self.linger_budget == 0 || now >= t,
        }
    }
}

/// Decide what a fresh connection is from its first bytes. `None` =
/// still ambiguous (a strict prefix of a probe token), read more.
fn sniff(buf: &[u8]) -> Option<Mode> {
    if buf.is_empty() {
        return None;
    }
    for p in TEXT_PREFIXES {
        if buf.len() >= p.len() {
            if &buf[..p.len()] == p {
                return Some(Mode::Text);
            }
        } else if p.starts_with(buf) {
            return None;
        }
    }
    Some(Mode::Binary)
}

// ---------------------------------------------------------------------
// the shard itself

struct Shard {
    idx: usize,
    shared: Arc<NetShared>,
    rx: mpsc::Receiver<Event>,
    /// Kept so submitted sinks always have a live channel; also cloned
    /// into every [`ShardSink`].
    tx: mpsc::Sender<Event>,
    waker: Arc<Waker>,
    conns: HashMap<u64, Conn>,
    drain_deadline: Option<Instant>,
}

impl Shard {
    fn run(mut self) {
        loop {
            self.drain_events();
            let draining = self.shared.draining.load(Ordering::SeqCst);
            if draining && self.drain_deadline.is_none() {
                self.drain_deadline = Some(Instant::now() + DRAIN_FORCE);
                for c in self.conns.values_mut() {
                    c.read_closed = true;
                    c.close_after_flush = true;
                    c.maybe_ack();
                }
            }

            // Service pass: write what's owed, read what's ready, parse.
            let ids: Vec<u64> = self.conns.keys().copied().collect();
            let now = Instant::now();
            for id in ids {
                let Some(mut conn) = self.conns.remove(&id) else {
                    continue;
                };
                self.service(&mut conn);
                if conn.should_close(now) {
                    self.close_conn(conn);
                } else {
                    self.conns.insert(id, conn);
                }
            }

            if draining {
                if self.conns.is_empty() {
                    return;
                }
                if self.drain_deadline.is_some_and(|d| Instant::now() >= d) {
                    // Force the stragglers: whatever has not flushed by
                    // now is not going to.
                    let leftovers: Vec<Conn> =
                        self.conns.drain().map(|(_, c)| c).collect();
                    for c in leftovers {
                        self.close_conn(c);
                    }
                    return;
                }
            }

            self.wait_ready(draining);
        }
    }

    fn drain_events(&mut self) {
        while let Ok(ev) = self.rx.try_recv() {
            match ev {
                Event::Accept(stream) => self.accept(stream),
                Event::Done { conn, tag, resp } => {
                    if let Some(c) = self.conns.get_mut(&conn) {
                        c.outstanding = c.outstanding.saturating_sub(1);
                        let frame = wire::encode_response(c.version, tag, &resp);
                        c.complete(tag, frame);
                    }
                }
                Event::Failed { conn, tag } => {
                    if let Some(c) = self.conns.get_mut(&conn) {
                        c.outstanding = c.outstanding.saturating_sub(1);
                        c.wire_errors += 1;
                        let frame = wire::encode_error(
                            c.version,
                            tag,
                            ErrorCode::Unavailable,
                            "executor dropped the request (backend failure or shutdown)",
                        );
                        c.complete(tag, frame);
                    }
                }
            }
        }
    }

    fn accept(&mut self, stream: TcpStream) {
        if self.shared.draining.load(Ordering::SeqCst) {
            return; // dropping the stream closes it
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let id = self.shared.next_conn.fetch_add(1, Ordering::Relaxed);
        self.shared.server.with_metrics(|m| {
            m.record_connection_opened();
            m.record_shard_connection(self.idx);
        });
        self.conns.insert(id, Conn::new(id, stream));
    }

    fn close_conn(&self, conn: Conn) {
        self.shared.server.with_metrics(|m| {
            m.record_connection_closed(conn.wire_requests, conn.wire_errors);
            if conn.slow_drop {
                m.record_slow_client_drop();
            }
        });
        // Dropping `conn.stream` closes the socket.
    }

    fn service(&mut self, conn: &mut Conn) {
        if conn.dead {
            return;
        }
        if conn.ready_write || conn.pending_write() > 0 {
            conn.flush_wbuf();
        }
        if conn.ready_read && !conn.dead {
            if conn.lingering() {
                conn.linger_read();
            } else if !conn.read_closed
                && conn.pending_write() < self.shared.max_wbuf / 2
            {
                conn.read_some();
            }
        }
        conn.ready_read = false;
        conn.ready_write = false;
        self.parse(conn);
        if conn.pending_write() > 0 {
            conn.flush_wbuf();
        }
        if conn.pending_write() > self.shared.max_wbuf {
            conn.dead = true;
            conn.slow_drop = true;
        }
    }

    fn parse(&mut self, conn: &mut Conn) {
        if conn.dead || conn.read_closed {
            return;
        }
        let mode = match conn.mode {
            Some(m) => m,
            None => match sniff(&conn.rbuf) {
                // Still ambiguous: wait for more bytes.
                None => return,
                Some(m) => {
                    conn.mode = Some(m);
                    m
                }
            },
        };
        match mode {
            Mode::Text => self.handle_text(conn),
            Mode::Binary => self.handle_binary(conn),
        }
    }

    fn handle_binary(&mut self, conn: &mut Conn) {
        loop {
            match wire::scan_frame(&conn.rbuf) {
                Ok(None) => break,
                Ok(Some(f)) => {
                    // Detach the read buffer so the payload can be
                    // borrowed from it while `process_frame` mutates the
                    // connection — steady state moves a pointer instead
                    // of copying the payload (the alloc-regression test
                    // pins the decode path allocation-free).
                    let rbuf = std::mem::take(&mut conn.rbuf);
                    self.process_frame(conn, f.version, f.ty, &rbuf[wire::HEADER_LEN..f.total_len]);
                    conn.rbuf = rbuf;
                    conn.rbuf.drain(..f.total_len);
                    if conn.read_closed || conn.dead {
                        break;
                    }
                }
                Err(fault) => {
                    let code = match fault {
                        Fault::Oversized(_) => ErrorCode::Oversized,
                        _ => ErrorCode::Malformed,
                    };
                    conn.fail_stream(code, &fault.to_string());
                    break;
                }
            }
        }
    }

    fn process_frame(&mut self, conn: &mut Conn, version: u8, ty: FrameType, payload: &[u8]) {
        if conn.version == 0 {
            conn.version = version;
        } else if conn.version != version {
            let negotiated = conn.version;
            conn.fail_stream(
                ErrorCode::Malformed,
                &format!(
                    "mixed protocol versions on one connection \
                     (negotiated v{negotiated}, then got a v{version} frame)"
                ),
            );
            return;
        }
        match ty {
            FrameType::Classify => self.process_classify(conn, version, payload),
            FrameType::Shutdown => {
                self.shared.request_shutdown();
                conn.read_closed = true;
                conn.ack_when_drained = true;
                conn.maybe_ack();
            }
            other => {
                conn.fail_stream(
                    ErrorCode::Malformed,
                    &format!("client sent server-side frame type {other:?}"),
                );
            }
        }
    }

    fn process_classify(&mut self, conn: &mut Conn, version: u8, payload: &[u8]) {
        conn.wire_requests += 1;
        let (tag, image_bytes) = if version == wire::V2 {
            match wire::decode_classify_v2(payload) {
                Ok(split) => split,
                Err(f) => {
                    conn.fail_stream(ErrorCode::Malformed, &f.to_string());
                    return;
                }
            }
        } else {
            let tag = conn.next_v1_tag;
            conn.next_v1_tag += 1;
            conn.inorder.push_back(tag);
            (tag, payload)
        };
        let (c, h, w) = self.shared.input_shape;
        let expected_bytes = self.shared.expected_bytes;
        let len = image_bytes.len();
        if len != expected_bytes as usize {
            // Spec-driven shape validation at the wire boundary: typed
            // error, connection survives.
            conn.wire_errors += 1;
            let frame = wire::encode_error(
                version,
                tag,
                ErrorCode::InvalidRequest,
                &format!(
                    "image payload is {len} bytes; backend input shape \
                     ({c}, {h}, {w}) needs exactly {expected_bytes} \
                     bytes of f32-le data"
                ),
            );
            conn.complete(tag, frame);
            return;
        }
        let image = match wire::decode_classify(image_bytes)
            .map_err(|f| f.to_string())
            .and_then(|data| Tensor::from_vec(&[c, h, w], data).map_err(|e| e.to_string()))
        {
            Ok(img) => img,
            Err(msg) => {
                conn.wire_errors += 1;
                let frame =
                    wire::encode_error(version, tag, ErrorCode::InvalidRequest, &msg);
                conn.complete(tag, frame);
                return;
            }
        };
        let sink = ReplySink::Shard(ShardSink {
            conn: conn.id,
            tag,
            tx: self.tx.clone(),
            waker: self.waker.clone(),
            sent: false,
        });
        match self.shared.server.submit_sink(image, sink) {
            Ok(()) => conn.outstanding += 1,
            Err(e) => {
                conn.wire_errors += 1;
                let code = match &e {
                    BackendError::QueueFull { .. } => ErrorCode::QueueFull,
                    BackendError::Unavailable(_) => ErrorCode::Unavailable,
                    _ => ErrorCode::Execution,
                };
                let frame = wire::encode_error(version, tag, code, &e.to_string());
                conn.complete(tag, frame);
            }
        }
    }

    /// Plaintext sidecar: raw probe tokens answer on the first line;
    /// HTTP requests wait for the full header block, answer, and close.
    fn handle_text(&mut self, conn: &mut Conn) {
        let Some(line_end) = conn.rbuf.iter().position(|&b| b == b'\n') else {
            if conn.rbuf.len() > MAX_TEXT_HEAD {
                conn.dead = true;
            }
            return;
        };
        let line = String::from_utf8_lossy(&conn.rbuf[..line_end])
            .trim_end_matches('\r')
            .to_string();
        let reply: Vec<u8> = if line.starts_with("GET ") || line.starts_with("HEAD ") {
            // Wait for the end of the request head so closing our side
            // doesn't race the client still sending headers.
            let done = conn.rbuf.windows(4).any(|w| w == b"\r\n\r\n")
                || conn.rbuf.windows(2).any(|w| w == b"\n\n");
            if !done {
                if conn.rbuf.len() > MAX_TEXT_HEAD {
                    conn.dead = true;
                }
                return;
            }
            let path = line.split_whitespace().nth(1).unwrap_or("/");
            let head_only = line.starts_with("HEAD ");
            let (status, body) = match path {
                "/healthz" => ("200 OK", "ok\n".to_string()),
                "/readyz" => {
                    if self.shared.ready() {
                        ("200 OK", "ready\n".to_string())
                    } else {
                        ("503 Service Unavailable", "not ready\n".to_string())
                    }
                }
                "/metrics" => ("200 OK", self.shared.server.with_metrics(|m| m.exposition())),
                _ => ("404 Not Found", "not found\n".to_string()),
            };
            let mut resp = format!(
                "HTTP/1.0 {status}\r\nContent-Type: text/plain; charset=utf-8\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n",
                body.len()
            );
            if !head_only {
                resp.push_str(&body);
            }
            resp.into_bytes()
        } else {
            match line.as_str() {
                "HEALTH" => b"OK\n".to_vec(),
                "READY" => {
                    if self.shared.ready() {
                        b"READY\n".to_vec()
                    } else {
                        b"NOT_READY\n".to_vec()
                    }
                }
                "METRICS" => self
                    .shared
                    .server
                    .with_metrics(|m| m.exposition())
                    .into_bytes(),
                other => format!("ERR unknown probe {other:?}\n").into_bytes(),
            }
        };
        conn.rbuf.clear();
        conn.wbuf.extend_from_slice(&reply);
        conn.read_closed = true;
        conn.close_after_flush = true;
    }

    /// Park until a socket is ready, an event arrives (waker), or the
    /// tick expires (linger/drain deadlines need a clock).
    #[cfg(unix)]
    fn wait_ready(&mut self, draining: bool) {
        use std::os::unix::io::AsRawFd;
        let timeout_ms = if draining || self.conns.values().any(|c| c.linger_until.is_some())
        {
            20
        } else {
            250
        };
        let mut fds = Vec::with_capacity(self.conns.len() + 1);
        let mut ids = Vec::with_capacity(self.conns.len());
        fds.push(sys::PollFd {
            fd: self.waker.read_fd(),
            events: sys::POLLIN,
            revents: 0,
        });
        for (id, c) in &self.conns {
            let mut events = 0i16;
            if c.wants_read(self.shared.max_wbuf) {
                events |= sys::POLLIN;
            }
            if c.pending_write() > 0 {
                events |= sys::POLLOUT;
            }
            if events == 0 {
                continue;
            }
            fds.push(sys::PollFd {
                fd: c.stream.as_raw_fd(),
                events,
                revents: 0,
            });
            ids.push(*id);
        }
        match sys::poll_fds(&mut fds, timeout_ms) {
            Ok(n) if n > 0 => {
                for (i, id) in ids.iter().enumerate() {
                    let r = fds[i + 1].revents;
                    if r == 0 {
                        continue;
                    }
                    if let Some(c) = self.conns.get_mut(id) {
                        c.ready_read = r & (sys::POLLIN | sys::POLLERR | sys::POLLHUP) != 0;
                        c.ready_write = r & (sys::POLLOUT | sys::POLLERR | sys::POLLHUP) != 0;
                    }
                }
            }
            Ok(_) => {}
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
        self.waker.drain();
    }

    /// Portable fallback: a short-sleep sweep that treats every
    /// connection as ready (nonblocking IO makes that correct, just
    /// less efficient).
    #[cfg(not(unix))]
    fn wait_ready(&mut self, _draining: bool) {
        std::thread::sleep(Duration::from_millis(2));
        self.waker.drain();
        for c in self.conns.values_mut() {
            c.ready_read = true;
            c.ready_write = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sniff_classifies_probe_binary_and_ambiguous_prefixes() {
        assert!(matches!(sniff(b"HEALTH\n"), Some(Mode::Text)));
        assert!(matches!(sniff(b"READY\n"), Some(Mode::Text)));
        assert!(matches!(sniff(b"METRICS\n"), Some(Mode::Text)));
        assert!(matches!(sniff(b"GET /metrics HTTP/1.1"), Some(Mode::Text)));
        assert!(matches!(sniff(b"HEAD /healthz"), Some(Mode::Text)));
        assert!(matches!(sniff(b"FCAP"), Some(Mode::Binary)));
        assert!(matches!(sniff(b"garbage"), Some(Mode::Binary)));
        // Strict prefixes of probe tokens stay ambiguous: wait for more.
        assert!(sniff(b"").is_none());
        assert!(sniff(b"HEA").is_none());
        assert!(sniff(b"GET").is_none());
        assert!(sniff(b"METRIC").is_none());
        // Diverging early resolves immediately.
        assert!(matches!(sniff(b"HEX"), Some(Mode::Binary)));
    }

    #[test]
    fn v1_reorder_buffer_restores_request_order() {
        let stream = loopback_stream();
        let mut conn = Conn::new(1, stream);
        conn.version = wire::VERSION;
        // Three requests in flight, completing 2, 0, 1.
        for t in 0..3u64 {
            conn.inorder.push_back(t);
        }
        conn.complete(2, vec![b'c']);
        assert_eq!(conn.pending_write(), 0, "tag 2 must wait for 0 and 1");
        conn.complete(0, vec![b'a']);
        assert_eq!(conn.wbuf, b"a", "tag 0 flushes alone");
        conn.complete(1, vec![b'b']);
        assert_eq!(conn.wbuf, b"abc", "1 then parked 2 flush together");
        assert!(conn.inorder.is_empty());
    }

    #[test]
    fn v2_completions_write_through_immediately() {
        let stream = loopback_stream();
        let mut conn = Conn::new(1, stream);
        conn.version = wire::V2;
        conn.complete(7, vec![b'x']);
        conn.complete(3, vec![b'y']);
        assert_eq!(conn.wbuf, b"xy", "v2 writes in completion order");
    }

    #[test]
    fn shutdown_ack_defers_until_drained() {
        let stream = loopback_stream();
        let mut conn = Conn::new(1, stream);
        conn.version = wire::V2;
        conn.outstanding = 1;
        conn.ack_when_drained = true;
        conn.maybe_ack();
        assert_eq!(conn.pending_write(), 0, "ack must wait for in-flight work");
        conn.outstanding = 0;
        conn.complete(0, Vec::new());
        assert!(conn.pending_write() > 0, "drained: ack frame written");
        assert!(conn.close_after_flush);
    }

    /// A real connected socket pair so Conn has a stream to own; the
    /// tests above never perform IO on it.
    fn loopback_stream() -> TcpStream {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let _server = listener.accept().unwrap();
        client
    }
}
