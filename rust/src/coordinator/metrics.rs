//! Serving metrics: fixed-bucket latency histogram + counters.
//! Allocation-free on the record path (the executor thread calls
//! [`Metrics::record`] per response).

use std::time::Instant;

/// Log-spaced latency histogram from 1 µs to ~17 s.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^{i+1}) µs.
    buckets: [u64; 25],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: [0; 25],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(24);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile (upper edge of the containing bucket).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    /// Requests refused at admission (queue at max depth).
    pub rejected: u64,
    /// Requests dropped because a backend batch failed.
    pub backend_errors: u64,
    pub started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            latency: LatencyHistogram::default(),
            requests: 0,
            batches: 0,
            padded_slots: 0,
            rejected: 0,
            backend_errors: 0,
            started: Instant::now(),
        }
    }
}

impl Metrics {
    pub fn record(&mut self, latency_us: u64) {
        self.latency.record(latency_us);
        self.requests += 1;
    }

    pub fn record_batch(&mut self, bucket: usize, take: usize) {
        self.batches += 1;
        self.padded_slots += (bucket - take) as u64;
    }

    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn record_backend_errors(&mut self, n: u64) {
        self.backend_errors += n;
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} rejected={} errors={} batches={} mean_batch={:.2} padded={} \
             latency(mean={:.0}us p50={}us p99={}us max={}us)",
            self.requests,
            self.rejected,
            self.backend_errors,
            self.batches,
            self.mean_batch_size(),
            self.padded_slots,
            self.latency.mean_us(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(99.0),
            self.latency.max_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record(us);
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile_us(50.0) <= h.percentile_us(99.0));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::default();
        m.record_batch(8, 6);
        m.record_batch(8, 8);
        for _ in 0..14 {
            m.record(100);
        }
        assert_eq!(m.padded_slots, 2);
        assert_eq!(m.requests, 14);
        assert!((m.mean_batch_size() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_monotone_property() {
        crate::testing::check(
            "histogram percentile monotone in p",
            50,
            17,
            |r| {
                let mut h = LatencyHistogram::default();
                for _ in 0..(1 + r.below(500)) {
                    h.record(1 + r.below(1_000_000) as u64);
                }
                h
            },
            |h| {
                let ps = [10.0, 50.0, 90.0, 99.0];
                ps.windows(2)
                    .all(|w| h.percentile_us(w[0]) <= h.percentile_us(w[1]))
            },
        );
    }
}
