//! Serving metrics: fixed-bucket latency histogram + counters.
//! Allocation-free on the record path (the executor thread calls
//! [`Metrics::record`] per response).
//!
//! [`Server::metrics`](crate::coordinator::server::Server::metrics) hands
//! out *snapshots* ([`Metrics::snapshot`]): the elapsed wall time is
//! frozen at snapshot time, so a summary printed seconds after shutdown
//! reports the throughput the server actually sustained, not a number
//! that decays while the snapshot sits on the caller's stack.

use std::time::{Duration, Instant};

/// Log-spaced latency histogram from 1 µs to ~17 s.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^{i+1}) µs.
    buckets: [u64; 25],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: [0; 25],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(24);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Fold another histogram in (same fixed buckets): used by
    /// client-side load generators that record per-thread and merge.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Approximate percentile: the upper edge of the containing bucket,
    /// clamped to the observed maximum. The clamp matters twice: a bucket
    /// edge can exceed every sample in it (one 10 µs sample would
    /// otherwise report p99 = 16 µs > max = 10 µs), and the top bucket is
    /// open-ended (its edge, ~33 s, is a format artifact, not a latency).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                if i + 1 == self.buckets.len() {
                    // Open-ended top bucket: its nominal edge is below
                    // samples beyond it; max_us is the only true bound.
                    return self.max_us;
                }
                return (1u64 << (i + 1)).min(self.max_us);
            }
        }
        self.max_us
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    /// Requests refused at admission (queue at max depth).
    pub rejected: u64,
    /// Requests dropped because a backend batch failed.
    pub backend_errors: u64,
    /// Executor replicas that exited abnormally (backend panic). A
    /// normal drain leaves this 0 — the regression the counter pins.
    pub replicas_died: u64,
    /// TCP connections accepted by the network front-end.
    pub connections_opened: u64,
    /// Connections that finished (client close, drain, or wire fault).
    pub connections_closed: u64,
    /// Classify frames decoded at the wire boundary (includes requests
    /// later rejected at admission — compare with `requests`).
    pub wire_requests: u64,
    /// Typed error frames sent back over the wire.
    pub wire_errors: u64,
    /// Requests answered straight from the inference cache (no backend
    /// work, no queue admission). Cache hits still count in `requests`
    /// and the latency histogram.
    pub cache_hits: u64,
    /// Requests that missed the cache and led an inference flight.
    pub cache_misses: u64,
    /// Requests coalesced onto an already-in-flight identical request
    /// (single-flight): they wait for the leader's response instead of
    /// enqueuing their own job.
    pub cache_coalesced: u64,
    /// Entries evicted from the cache store to make room.
    pub cache_evicted: u64,
    /// Cached entries found under a request's key with a *different*
    /// deployment fingerprint. The fingerprint is hashed into the key,
    /// so this is structurally impossible and must stay 0 — a nonzero
    /// value means the key derivation broke.
    pub cache_stale: u64,
    /// Connections dropped because their bounded write buffer overflowed
    /// (a slowloris reader that stops draining responses). The executor
    /// pool never blocks on these; the connection pays instead.
    pub slow_client_drops: u64,
    /// Connections accepted per IO shard (index = shard id). Grows to
    /// the shard count on first use; all-zero on in-process serving.
    pub shard_connections: Vec<u64>,
    pub started: Instant,
    /// Wall time frozen by [`Metrics::snapshot`]; `None` while the
    /// metrics are live inside the server.
    elapsed: Option<Duration>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            latency: LatencyHistogram::default(),
            requests: 0,
            batches: 0,
            padded_slots: 0,
            rejected: 0,
            backend_errors: 0,
            replicas_died: 0,
            connections_opened: 0,
            connections_closed: 0,
            wire_requests: 0,
            wire_errors: 0,
            cache_hits: 0,
            cache_misses: 0,
            cache_coalesced: 0,
            cache_evicted: 0,
            cache_stale: 0,
            slow_client_drops: 0,
            shard_connections: Vec::new(),
            started: Instant::now(),
            elapsed: None,
        }
    }
}

impl Metrics {
    pub fn record(&mut self, latency_us: u64) {
        self.latency.record(latency_us);
        self.requests += 1;
    }

    pub fn record_batch(&mut self, bucket: usize, take: usize) {
        self.batches += 1;
        self.padded_slots += (bucket - take) as u64;
    }

    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn record_backend_errors(&mut self, n: u64) {
        self.backend_errors += n;
    }

    pub fn record_replica_died(&mut self) {
        self.replicas_died += 1;
    }

    pub fn record_connection_opened(&mut self) {
        self.connections_opened += 1;
    }

    /// Attribute an accepted connection to its IO shard.
    pub fn record_shard_connection(&mut self, shard: usize) {
        if self.shard_connections.len() <= shard {
            self.shard_connections.resize(shard + 1, 0);
        }
        self.shard_connections[shard] += 1;
    }

    pub fn record_slow_client_drop(&mut self) {
        self.slow_client_drops += 1;
    }

    /// Fold one finished connection's counters in (called once when the
    /// connection handler exits, so the record path stays per-connection
    /// local and lock-free).
    pub fn record_connection_closed(&mut self, wire_requests: u64, wire_errors: u64) {
        self.connections_closed += 1;
        self.wire_requests += wire_requests;
        self.wire_errors += wire_errors;
    }

    pub fn record_cache_hit(&mut self) {
        self.cache_hits += 1;
    }

    pub fn record_cache_miss(&mut self) {
        self.cache_misses += 1;
    }

    pub fn record_cache_coalesced(&mut self) {
        self.cache_coalesced += 1;
    }

    pub fn record_cache_evicted(&mut self, n: u64) {
        self.cache_evicted += n;
    }

    pub fn record_cache_stale(&mut self) {
        self.cache_stale += 1;
    }

    /// True once any cache-layer event has been observed (used to keep
    /// the summary line cache-free on uncached deployments).
    pub fn cache_active(&self) -> bool {
        self.cache_hits + self.cache_misses + self.cache_coalesced + self.cache_evicted > 0
    }

    /// A copy whose wall clock is frozen *now*: `throughput_rps` on the
    /// returned value stays constant no matter when it is read. Live
    /// metrics (no snapshot) keep using the running clock.
    pub fn snapshot(&self) -> Metrics {
        let mut m = self.clone();
        m.elapsed = Some(self.elapsed());
        m
    }

    /// Wall time this metrics window covers: frozen at snapshot time,
    /// or still running for the live instance.
    pub fn elapsed(&self) -> Duration {
        self.elapsed.unwrap_or_else(|| self.started.elapsed())
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} rejected={} errors={} batches={} mean_batch={:.2} padded={} \
             latency(mean={:.0}us p50={}us p99={}us max={}us)",
            self.requests,
            self.rejected,
            self.backend_errors,
            self.batches,
            self.mean_batch_size(),
            self.padded_slots,
            self.latency.mean_us(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(99.0),
            self.latency.max_us(),
        );
        if self.connections_opened > 0 {
            s.push_str(&format!(
                " net(conns={}/{} wire_reqs={} wire_errs={})",
                self.connections_closed,
                self.connections_opened,
                self.wire_requests,
                self.wire_errors,
            ));
        }
        if self.cache_active() {
            s.push_str(&format!(
                " cache(hits={} misses={} coalesced={} evicted={} stale={})",
                self.cache_hits,
                self.cache_misses,
                self.cache_coalesced,
                self.cache_evicted,
                self.cache_stale,
            ));
        }
        if self.slow_client_drops > 0 {
            s.push_str(&format!(" slow_client_drops={}", self.slow_client_drops));
        }
        if self.replicas_died > 0 {
            s.push_str(&format!(" replicas_died={}", self.replicas_died));
        }
        s
    }

    /// Plaintext exposition of every counter, one `fastcaps_*` metric
    /// per line in the conventional `# TYPE` + `name value` format, so
    /// any scraper that speaks the text exposition format can ingest
    /// the `METRICS` sidecar endpoint (or `GET /metrics`) directly.
    pub fn exposition(&self) -> String {
        let mut s = String::with_capacity(1536);
        let mut counter = |name: &str, help: &str, v: u64| {
            s.push_str(&format!(
                "# HELP fastcaps_{name} {help}\n# TYPE fastcaps_{name} counter\nfastcaps_{name} {v}\n"
            ));
        };
        counter("requests_total", "Requests completed.", self.requests);
        counter("rejected_total", "Requests rejected at admission.", self.rejected);
        counter("backend_errors_total", "Requests failed in the backend.", self.backend_errors);
        counter("batches_total", "Batches executed.", self.batches);
        counter("padded_slots_total", "Padded (wasted) batch slots.", self.padded_slots);
        counter("replicas_died_total", "Executor replicas that died.", self.replicas_died);
        counter("connections_opened_total", "TCP connections accepted.", self.connections_opened);
        counter("connections_closed_total", "TCP connections closed.", self.connections_closed);
        counter("wire_requests_total", "Classify frames received.", self.wire_requests);
        counter("wire_errors_total", "Error frames sent.", self.wire_errors);
        counter(
            "net_slow_client_drops_total",
            "Connections dropped for write-buffer overflow.",
            self.slow_client_drops,
        );
        counter("cache_hits_total", "Inference cache hits.", self.cache_hits);
        counter("cache_misses_total", "Inference cache misses.", self.cache_misses);
        counter(
            "cache_coalesced_total",
            "Requests coalesced onto an in-flight duplicate.",
            self.cache_coalesced,
        );
        counter("cache_evicted_total", "Cache entries evicted.", self.cache_evicted);
        counter(
            "cache_stale_total",
            "Wrong-fingerprint cache sightings (must stay 0).",
            self.cache_stale,
        );
        s.push_str("# HELP fastcaps_shard_connections_total Connections accepted per IO shard.\n");
        s.push_str("# TYPE fastcaps_shard_connections_total counter\n");
        for (i, v) in self.shard_connections.iter().enumerate() {
            s.push_str(&format!("fastcaps_shard_connections_total{{shard=\"{i}\"}} {v}\n"));
        }
        let mut gauge = |s: &mut String, name: &str, help: &str, v: String| {
            s.push_str(&format!(
                "# HELP fastcaps_{name} {help}\n# TYPE fastcaps_{name} gauge\nfastcaps_{name} {v}\n"
            ));
        };
        gauge(
            &mut s,
            "latency_mean_us",
            "Mean request latency (µs).",
            format!("{:.0}", self.latency.mean_us()),
        );
        gauge(
            &mut s,
            "latency_p50_us",
            "p50 request latency (µs).",
            self.latency.percentile_us(50.0).to_string(),
        );
        gauge(
            &mut s,
            "latency_p99_us",
            "p99 request latency (µs).",
            self.latency.percentile_us(99.0).to_string(),
        );
        gauge(
            &mut s,
            "latency_max_us",
            "Max request latency (µs).",
            self.latency.max_us().to_string(),
        );
        gauge(
            &mut s,
            "uptime_seconds",
            "Seconds this metrics window covers.",
            format!("{:.3}", self.elapsed().as_secs_f64()),
        );
        gauge(
            &mut s,
            "rss_bytes",
            "Resident set size of the serving process (0 where unavailable).",
            resident_set_bytes().to_string(),
        );
        s
    }
}

/// Resident set size of this process in bytes, read from
/// `/proc/self/status` (`VmRSS`). Returns 0 on platforms without procfs
/// — the gauge is then present but inert, so scrapers and the bench-net
/// soak mode degrade gracefully.
pub fn resident_set_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record(us);
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile_us(50.0) <= h.percentile_us(99.0));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::default();
        m.record_batch(8, 6);
        m.record_batch(8, 8);
        for _ in 0..14 {
            m.record(100);
        }
        assert_eq!(m.padded_slots, 2);
        assert_eq!(m.requests, 14);
        assert!((m.mean_batch_size() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_clamped_to_max() {
        // The regression from ISSUE 5: one 10 µs sample lands in bucket
        // [8,16), whose upper edge (16) used to be reported as p50/p99 —
        // a percentile above the observed maximum.
        let mut h = LatencyHistogram::default();
        h.record(10);
        assert_eq!(h.max_us(), 10);
        assert_eq!(h.percentile_us(50.0), 10);
        assert_eq!(h.percentile_us(99.0), 10);
        // Top (open-ended) bucket: the edge is a format artifact (~33 s);
        // the report must stay at the observed max.
        let mut h = LatencyHistogram::default();
        h.record(60_000_000); // 60 s, beyond the last bucket edge
        assert_eq!(h.percentile_us(99.0), 60_000_000);
    }

    #[test]
    fn percentile_never_exceeds_max_property() {
        crate::testing::check(
            "percentile_us(p) <= max_us for all p",
            60,
            19,
            |r| {
                let mut h = LatencyHistogram::default();
                for _ in 0..(1 + r.below(400)) {
                    // span every bucket including the open-ended top one
                    h.record(1 + r.below(50_000_000) as u64);
                }
                h
            },
            |h| {
                (1..=100)
                    .map(|p| p as f64)
                    .all(|p| h.percentile_us(p) <= h.max_us())
            },
        );
    }

    #[test]
    fn histogram_merge_equals_joint_recording() {
        let mut joint = LatencyHistogram::default();
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        for (i, us) in [3u64, 17, 900, 42_000, 5, 1_000_000].iter().enumerate() {
            joint.record(*us);
            if i % 2 == 0 {
                a.record(*us);
            } else {
                b.record(*us);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), joint.count());
        assert_eq!(a.max_us(), joint.max_us());
        assert_eq!(a.mean_us(), joint.mean_us());
        for p in [10.0, 50.0, 99.0] {
            assert_eq!(a.percentile_us(p), joint.percentile_us(p));
        }
    }

    #[test]
    fn snapshot_rps_is_stable_across_a_sleep() {
        let mut m = Metrics::default();
        for _ in 0..100 {
            m.record(50);
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
        let snap = m.snapshot();
        let r1 = snap.throughput_rps();
        assert!(r1 > 0.0);
        std::thread::sleep(std::time::Duration::from_millis(30));
        // The snapshot froze its wall clock: identical reading later.
        assert_eq!(snap.throughput_rps(), r1, "snapshot RPS decayed");
        // The live instance keeps its running clock (decays as designed).
        assert!(m.throughput_rps() < r1);
        // A snapshot of a snapshot keeps the original frozen window.
        assert_eq!(snap.snapshot().throughput_rps(), r1);
    }

    #[test]
    fn connection_counters_in_summary() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("net("));
        m.record_connection_opened();
        m.record_connection_opened();
        m.record_connection_closed(5, 1);
        let s = m.summary();
        assert!(s.contains("net(conns=1/2 wire_reqs=5 wire_errs=1)"), "{s}");
    }

    #[test]
    fn cache_counters_in_summary_only_when_active() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("cache("));
        m.record_cache_miss();
        m.record_cache_hit();
        m.record_cache_hit();
        m.record_cache_coalesced();
        m.record_cache_evicted(3);
        let s = m.summary();
        assert!(
            s.contains("cache(hits=2 misses=1 coalesced=1 evicted=3 stale=0)"),
            "{s}"
        );
    }

    #[test]
    fn slow_client_drops_in_summary_only_when_nonzero() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("slow_client_drops"));
        m.record_slow_client_drop();
        assert!(m.summary().contains(" slow_client_drops=1"));
    }

    #[test]
    fn shard_connection_counters_grow_on_demand() {
        let mut m = Metrics::default();
        m.record_shard_connection(2);
        m.record_shard_connection(0);
        m.record_shard_connection(2);
        assert_eq!(m.shard_connections, vec![1, 0, 2]);
    }

    #[test]
    fn exposition_lists_every_counter_family() {
        let mut m = Metrics::default();
        m.record(100);
        m.record_shard_connection(0);
        m.record_shard_connection(1);
        m.record_slow_client_drop();
        let e = m.exposition();
        for name in [
            "fastcaps_requests_total 1",
            "fastcaps_rejected_total 0",
            "fastcaps_wire_requests_total 0",
            "fastcaps_net_slow_client_drops_total 1",
            "fastcaps_cache_hits_total 0",
            "fastcaps_shard_connections_total{shard=\"0\"} 1",
            "fastcaps_shard_connections_total{shard=\"1\"} 1",
            "fastcaps_latency_p99_us 100",
            "fastcaps_uptime_seconds",
        ] {
            assert!(e.contains(name), "missing {name} in:\n{e}");
        }
        // Exposition format discipline: every non-comment line is
        // `name value` (or `name{labels} value`).
        for line in e.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split(' ').count(), 2, "bad line: {line}");
            assert!(line.starts_with("fastcaps_"), "bad line: {line}");
        }
    }

    #[test]
    fn percentile_monotone_property() {
        crate::testing::check(
            "histogram percentile monotone in p",
            50,
            17,
            |r| {
                let mut h = LatencyHistogram::default();
                for _ in 0..(1 + r.below(500)) {
                    h.record(1 + r.below(1_000_000) as u64);
                }
                h
            },
            |h| {
                let ps = [10.0, 50.0, 90.0, 99.0];
                ps.windows(2)
                    .all(|w| h.percentile_us(w[0]) <= h.percentile_us(w[1]))
            },
        );
    }
}
