//! Wire protocol for the network serving front-end: length-prefixed
//! binary frames over a byte stream (TCP in practice; the codec is
//! written against `io::Read`/`io::Write` so tests run it over
//! in-memory buffers).
//!
//! ```text
//!  frame  := header payload
//!  header := magic[4]=b"FCAP"  version:u8  type:u8  len:u32le
//!
//!  client → server                server → client
//!    Classify  (len = C·H·W·4       Response    (lengths, predicted,
//!               f32-le image)                    latency_us, batch)
//!    Shutdown  (len = 0, asks        Error       (code:u8, utf-8 msg)
//!               a graceful drain)    ShutdownAck (len = 0)
//! ```
//!
//! **Protocol v2** keeps the same header layout but prefixes every
//! `Classify`, `Response`, and `Error` payload with a `tag: u64le` the
//! client chose. The server echoes the tag on the frame that answers
//! that request, so responses may complete *out of order* — the event
//! loop front-end writes each response the moment its inference
//! finishes instead of head-of-line-blocking the connection. Version is
//! negotiated per connection: the version byte of the first frame a
//! client sends latches the connection's dialect, and mixing versions
//! afterwards is a [`ErrorCode::Malformed`] fault. `Shutdown` /
//! `ShutdownAck` stay tagless in both versions. A v2 `Error` frame that
//! answers no particular request (a connection-level fault like bad
//! magic) carries the reserved [`CONN_TAG`] sentinel.
//!
//! Error frames are *typed* ([`ErrorCode`]): admission overload
//! (`QueueFull`), spec violations (`InvalidRequest` — e.g. a payload
//! whose byte count is not the backend's input shape), dead/stopped
//! server (`Unavailable`), and framing faults (`Malformed`,
//! `Oversized`). Recoverable faults (wrong shape, queue full) leave the
//! connection usable; stream-desynchronizing faults (bad magic,
//! oversized prefix) get an error frame and then the connection closes,
//! since the byte stream cannot be resynchronized.
//!
//! All integers are little-endian; f32 payloads are IEEE-754 bit
//! patterns, so a round-tripped response is bit-identical to the
//! in-process [`super::Response`] it encodes.

use super::Response;
use std::io::{self, Read, Write};

/// Frame preamble: identifies a FastCaps peer before any length field
/// is trusted.
pub const MAGIC: [u8; 4] = *b"FCAP";
/// Protocol version 1: untagged frames, strict in-order replies.
pub const VERSION: u8 = 1;
/// Protocol version 2: tagged frames, out-of-order completion.
pub const V2: u8 = 2;
/// Reserved v2 tag for connection-level errors that answer no request
/// (bad magic, oversized prefix). Clients must not submit it.
pub const CONN_TAG: u64 = u64::MAX;
/// Hard cap on any payload (4 MiB — far above any spec input shape). A
/// larger length prefix is a [`Fault::Oversized`] and the connection is
/// dropped rather than allocating attacker-controlled sizes.
pub const MAX_PAYLOAD: u32 = 4 << 20;
/// Fixed header size: magic + version + type + length prefix.
pub const HEADER_LEN: usize = 10;

/// Frame discriminant (the `type` header byte). Client→server types are
/// low, server→client types have the high bit set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameType {
    /// f32-le image payload in the server's spec input shape.
    Classify = 0x01,
    /// Ask the server for a graceful drain (empty payload).
    Shutdown = 0x02,
    /// Successful classification result.
    Response = 0x81,
    /// Typed error ([`ErrorCode`] + message).
    Error = 0x82,
    /// Acknowledges a [`FrameType::Shutdown`] before the drain starts.
    ShutdownAck = 0x83,
}

impl FrameType {
    pub fn from_u8(v: u8) -> Option<FrameType> {
        match v {
            0x01 => Some(FrameType::Classify),
            0x02 => Some(FrameType::Shutdown),
            0x81 => Some(FrameType::Response),
            0x82 => Some(FrameType::Error),
            0x83 => Some(FrameType::ShutdownAck),
            _ => None,
        }
    }
}

/// Typed error codes carried by [`FrameType::Error`] frames — the wire
/// image of [`crate::backend::BackendError`] plus the framing faults
/// that only exist at this boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Admission queue at capacity; retry later. Connection survives.
    QueueFull = 1,
    /// Malformed request (wrong input shape/byte count). Connection
    /// survives.
    InvalidRequest = 2,
    /// Server shut down or every replica died. Connection survives
    /// (each subsequent request gets the same answer).
    Unavailable = 3,
    /// Unrecognized magic/version/frame type; the stream cannot be
    /// resynchronized, so the connection closes after this frame.
    Malformed = 4,
    /// Length prefix beyond [`MAX_PAYLOAD`]; connection closes.
    Oversized = 5,
    /// The backend failed executing a well-formed request.
    Execution = 6,
    /// Client-local: the transport failed (connect/read/write error,
    /// timeout). Never sent by a server.
    Io = 100,
    /// Client-local: the peer violated the protocol (unexpected frame,
    /// undecodable payload). Never sent by a server.
    Protocol = 101,
}

impl ErrorCode {
    pub fn from_u8(v: u8) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::QueueFull),
            2 => Some(ErrorCode::InvalidRequest),
            3 => Some(ErrorCode::Unavailable),
            4 => Some(ErrorCode::Malformed),
            5 => Some(ErrorCode::Oversized),
            6 => Some(ErrorCode::Execution),
            // The client-local codes decode too, so a WireError written
            // into an error frame in a test round-trips losslessly.
            100 => Some(ErrorCode::Io),
            101 => Some(ErrorCode::Protocol),
            _ => None,
        }
    }
}

/// What went wrong while reading a frame. `Closed` is the clean
/// end-of-stream between frames; everything else is a protocol or
/// transport fault.
#[derive(Debug)]
pub enum Fault {
    /// Peer closed the stream at a frame boundary (normal end).
    Closed,
    /// Stream ended mid-frame (truncated header or payload).
    Truncated,
    /// First four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Unsupported protocol version.
    BadVersion(u8),
    /// Unknown frame-type byte.
    UnknownType(u8),
    /// Length prefix above [`MAX_PAYLOAD`].
    Oversized(u32),
    /// Payload did not decode as the declared frame type.
    BadPayload(String),
    /// Underlying transport error.
    Io(String),
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fault::Closed => write!(f, "connection closed"),
            Fault::Truncated => write!(f, "stream truncated mid-frame"),
            Fault::BadMagic(m) => write!(f, "bad magic {m:02x?} (want {MAGIC:02x?})"),
            Fault::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (want {VERSION} or {V2})")
            }
            Fault::UnknownType(t) => write!(f, "unknown frame type {t:#04x}"),
            Fault::Oversized(n) => {
                write!(f, "length prefix {n} exceeds max payload {MAX_PAYLOAD}")
            }
            Fault::BadPayload(m) => write!(f, "bad payload: {m}"),
            Fault::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl From<io::Error> for Fault {
    fn from(e: io::Error) -> Fault {
        match e.kind() {
            io::ErrorKind::UnexpectedEof => Fault::Truncated,
            _ => Fault::Io(e.to_string()),
        }
    }
}

/// A decoded server→client frame.
#[derive(Debug)]
pub enum ServerFrame {
    Response(WireResponse),
    Error { code: ErrorCode, message: String },
    ShutdownAck,
}

/// The client-side image of [`super::Response`]. `lengths` round-trips
/// the f32 bit patterns exactly, so equality with the in-process
/// response is bitwise.
#[derive(Debug, Clone, PartialEq)]
pub struct WireResponse {
    pub lengths: Vec<f32>,
    pub predicted: u16,
    pub latency_us: u64,
    pub batch: u16,
}

// ---------------------------------------------------------------------
// encoding

fn frame_bytes(version: u8, ty: FrameType, payload: &[u8]) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_PAYLOAD as usize);
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.push(version);
    buf.push(ty as u8);
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

fn response_payload(tag: Option<u64>, resp: &Response) -> Vec<u8> {
    let mut p = Vec::with_capacity(10 + resp.lengths.len() * 4 + 12);
    if let Some(t) = tag {
        p.extend_from_slice(&t.to_le_bytes());
    }
    p.extend_from_slice(&(resp.lengths.len() as u16).to_le_bytes());
    for v in &resp.lengths {
        p.extend_from_slice(&v.to_le_bytes());
    }
    p.extend_from_slice(&(resp.predicted as u16).to_le_bytes());
    p.extend_from_slice(&resp.latency_us.to_le_bytes());
    p.extend_from_slice(&(resp.batch as u16).to_le_bytes());
    p
}

fn error_payload(tag: Option<u64>, code: ErrorCode, message: &str) -> Vec<u8> {
    // Bound the message so the frame itself can't be oversized.
    let msg = &message.as_bytes()[..message.len().min(1024)];
    let mut p = Vec::with_capacity(11 + msg.len());
    if let Some(t) = tag {
        p.extend_from_slice(&t.to_le_bytes());
    }
    p.push(code as u8);
    p.extend_from_slice(&(msg.len() as u16).to_le_bytes());
    p.extend_from_slice(msg);
    p
}

fn classify_payload(tag: Option<u64>, image: &[f32]) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + image.len() * 4);
    if let Some(t) = tag {
        p.extend_from_slice(&t.to_le_bytes());
    }
    for v in image {
        p.extend_from_slice(&v.to_le_bytes());
    }
    p
}

/// Encode a `Response` frame in either dialect. `tag` is ignored for v1
/// (untagged) frames. The event loop appends these bytes to per-conn
/// write buffers; the `write_*` helpers below wrap them for stream IO.
pub fn encode_response(version: u8, tag: u64, resp: &Response) -> Vec<u8> {
    let t = (version == V2).then_some(tag);
    frame_bytes(version, FrameType::Response, &response_payload(t, resp))
}

/// Encode a typed `Error` frame in either dialect (tag ignored for v1).
pub fn encode_error(version: u8, tag: u64, code: ErrorCode, message: &str) -> Vec<u8> {
    let t = (version == V2).then_some(tag);
    frame_bytes(version, FrameType::Error, &error_payload(t, code, message))
}

/// Encode an empty-payload frame (`Shutdown` / `ShutdownAck`) — tagless
/// in both dialects.
pub fn encode_empty(version: u8, ty: FrameType) -> Vec<u8> {
    frame_bytes(version, ty, &[])
}

/// Encode a classify request in either dialect (tag ignored for v1).
pub fn encode_classify(version: u8, tag: u64, image: &[f32]) -> Vec<u8> {
    let t = (version == V2).then_some(tag);
    frame_bytes(version, FrameType::Classify, &classify_payload(t, image))
}

/// Write a v1 classify request: the image as f32-le words.
pub fn write_classify(w: &mut impl Write, image: &[f32]) -> io::Result<()> {
    w.write_all(&encode_classify(VERSION, 0, image))
}

/// Write a v1 empty-payload frame (`Shutdown` / `ShutdownAck`).
pub fn write_empty(w: &mut impl Write, ty: FrameType) -> io::Result<()> {
    w.write_all(&encode_empty(VERSION, ty))
}

/// Write a v1 successful classification response.
pub fn write_response(w: &mut impl Write, resp: &Response) -> io::Result<()> {
    w.write_all(&encode_response(VERSION, 0, resp))
}

/// Write a v1 typed error frame.
pub fn write_error(w: &mut impl Write, code: ErrorCode, message: &str) -> io::Result<()> {
    w.write_all(&encode_error(VERSION, 0, code, message))
}

// ---------------------------------------------------------------------
// decoding

fn read_exact_or(r: &mut impl Read, buf: &mut [u8], at_boundary: bool) -> Result<(), Fault> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                // EOF before the first byte of a frame is a clean close;
                // anywhere else the stream died mid-frame.
                return Err(if at_boundary && filled == 0 {
                    Fault::Closed
                } else {
                    Fault::Truncated
                });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Read and validate a frame header. Returns the frame type and payload
/// length; the caller reads the payload next.
pub fn read_header(r: &mut impl Read) -> Result<(FrameType, u32), Fault> {
    let mut h = [0u8; HEADER_LEN];
    read_exact_or(r, &mut h, true)?;
    if h[0..4] != MAGIC {
        return Err(Fault::BadMagic([h[0], h[1], h[2], h[3]]));
    }
    if h[4] != VERSION {
        return Err(Fault::BadVersion(h[4]));
    }
    let ty = FrameType::from_u8(h[5]).ok_or(Fault::UnknownType(h[5]))?;
    let len = u32::from_le_bytes([h[6], h[7], h[8], h[9]]);
    if len > MAX_PAYLOAD {
        return Err(Fault::Oversized(len));
    }
    Ok((ty, len))
}

/// Read exactly `len` payload bytes.
pub fn read_payload(r: &mut impl Read, len: u32) -> Result<Vec<u8>, Fault> {
    let mut p = vec![0u8; len as usize];
    read_exact_or(r, &mut p, false)?;
    Ok(p)
}

/// Decode a classify payload into f32 words. The *shape* check against
/// the backend spec is the server's job; this only checks alignment.
pub fn decode_classify(payload: &[u8]) -> Result<Vec<f32>, Fault> {
    let mut out = Vec::new();
    decode_classify_into(payload, &mut out)?;
    Ok(out)
}

/// [`decode_classify`] into a caller-owned buffer: once the buffer has
/// grown to the spec's input size, repeated decodes reuse its capacity
/// and the steady-state decode path performs no heap allocation (pinned
/// by `tests/alloc_regression.rs`).
pub fn decode_classify_into(payload: &[u8], out: &mut Vec<f32>) -> Result<(), Fault> {
    if payload.len() % 4 != 0 {
        return Err(Fault::BadPayload(format!(
            "classify payload of {} bytes is not a whole number of f32 words",
            payload.len()
        )));
    }
    out.clear();
    out.extend(
        payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
    );
    Ok(())
}

struct Cursor<'a> {
    p: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], Fault> {
        if self.off + n > self.p.len() {
            return Err(Fault::BadPayload(format!(
                "payload too short: wanted {} more bytes at offset {} of {}",
                n,
                self.off,
                self.p.len()
            )));
        }
        let s = &self.p[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16, Fault> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u64(&mut self) -> Result<u64, Fault> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32, Fault> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

fn decode_response(payload: &[u8]) -> Result<WireResponse, Fault> {
    let mut c = Cursor { p: payload, off: 0 };
    let n = c.u16()? as usize;
    let mut lengths = Vec::with_capacity(n);
    for _ in 0..n {
        lengths.push(c.f32()?);
    }
    Ok(WireResponse {
        lengths,
        predicted: c.u16()?,
        latency_us: c.u64()?,
        batch: c.u16()?,
    })
}

fn decode_error(payload: &[u8]) -> Result<(ErrorCode, String), Fault> {
    let mut c = Cursor { p: payload, off: 0 };
    let code = c.take(1)?[0];
    let code = ErrorCode::from_u8(code)
        .ok_or_else(|| Fault::BadPayload(format!("unknown error code {code}")))?;
    let n = c.u16()? as usize;
    let msg = String::from_utf8_lossy(c.take(n)?).into_owned();
    Ok((code, msg))
}

/// Read one server→client frame (header + payload + decode).
pub fn read_server_frame(r: &mut impl Read) -> Result<ServerFrame, Fault> {
    let (ty, len) = read_header(r)?;
    let payload = read_payload(r, len)?;
    match ty {
        FrameType::Response => Ok(ServerFrame::Response(decode_response(&payload)?)),
        FrameType::Error => {
            let (code, message) = decode_error(&payload)?;
            Ok(ServerFrame::Error { code, message })
        }
        FrameType::ShutdownAck => Ok(ServerFrame::ShutdownAck),
        other => Err(Fault::BadPayload(format!(
            "unexpected client-side frame type {other:?} from server"
        ))),
    }
}

// ---------------------------------------------------------------------
// incremental (buffer-based) parsing — the event-loop front-end and the
// tag-aware client never block in a frame reader; they accumulate bytes
// in a receive buffer and scan complete frames out of it.

/// One complete frame scanned out of a receive buffer. `payload` is
/// `buf[HEADER_LEN..total_len]`; the caller drains `total_len` bytes.
#[derive(Debug, Clone, Copy)]
pub struct ScannedFrame {
    pub version: u8,
    pub ty: FrameType,
    pub total_len: usize,
}

/// Scan the front of a receive buffer for one complete frame.
///
/// * `Ok(Some(_))` — a whole frame (header + payload) is buffered.
/// * `Ok(None)` — the buffer holds a valid prefix; read more bytes.
/// * `Err(_)` — the stream is desynchronized (bad magic/version/type or
///   oversized length); the connection cannot be resynchronized.
///
/// Accepts both [`VERSION`] and [`V2`] headers — per-connection version
/// pinning is the caller's policy, not the codec's.
pub fn scan_frame(buf: &[u8]) -> Result<Option<ScannedFrame>, Fault> {
    if buf.len() >= 4 && buf[0..4] != MAGIC {
        return Err(Fault::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
    }
    if buf.len() < HEADER_LEN {
        // Cheap early reject: a short prefix that already diverges from
        // the magic can fault without waiting for a full header.
        if !MAGIC.starts_with(&buf[..buf.len().min(4)]) {
            let mut m = [0u8; 4];
            m[..buf.len().min(4)].copy_from_slice(&buf[..buf.len().min(4)]);
            return Err(Fault::BadMagic(m));
        }
        return Ok(None);
    }
    let version = buf[4];
    if version != VERSION && version != V2 {
        return Err(Fault::BadVersion(version));
    }
    let ty = FrameType::from_u8(buf[5]).ok_or(Fault::UnknownType(buf[5]))?;
    let len = u32::from_le_bytes([buf[6], buf[7], buf[8], buf[9]]);
    if len > MAX_PAYLOAD {
        return Err(Fault::Oversized(len));
    }
    let total_len = HEADER_LEN + len as usize;
    if buf.len() < total_len {
        return Ok(None);
    }
    Ok(Some(ScannedFrame {
        version,
        ty,
        total_len,
    }))
}

/// Split a v2 classify payload into its tag and the raw image bytes.
pub fn decode_classify_v2(payload: &[u8]) -> Result<(u64, &[u8]), Fault> {
    if payload.len() < 8 {
        return Err(Fault::BadPayload(format!(
            "v2 classify payload of {} bytes is shorter than its 8-byte tag",
            payload.len()
        )));
    }
    let tag = u64::from_le_bytes(payload[..8].try_into().expect("8 bytes"));
    Ok((tag, &payload[8..]))
}

/// Decode a server→client payload in either dialect. Returns the echoed
/// tag (`None` for v1 frames and tagless v2 frames like `ShutdownAck`).
pub fn decode_server_payload(
    version: u8,
    ty: FrameType,
    payload: &[u8],
) -> Result<(Option<u64>, ServerFrame), Fault> {
    let (tag, body) = if version == V2 && matches!(ty, FrameType::Response | FrameType::Error) {
        let (t, rest) = decode_classify_v2(payload).map_err(|_| {
            Fault::BadPayload(format!("v2 {ty:?} payload too short for its tag"))
        })?;
        (Some(t), rest)
    } else {
        (None, payload)
    };
    let frame = match ty {
        FrameType::Response => ServerFrame::Response(decode_response(body)?),
        FrameType::Error => {
            let (code, message) = decode_error(body)?;
            ServerFrame::Error { code, message }
        }
        FrameType::ShutdownAck => ServerFrame::ShutdownAck,
        other => {
            return Err(Fault::BadPayload(format!(
                "unexpected client-side frame type {other:?} from server"
            )))
        }
    };
    Ok((tag, frame))
}

// ---------------------------------------------------------------------
// unified error taxonomy

/// The one typed error surface shared by client, server, and `bench-net`
/// — a typed server fault round-trips losslessly instead of being
/// flattened to a string. `code` is the wire taxonomy; `tag` is the
/// request the error answers (`None`: connection-level fault, a v1
/// stream, or a client-side transport error).
#[derive(Debug, Clone)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
    pub tag: Option<u64>,
}

impl WireError {
    pub fn new(code: ErrorCode, message: impl Into<String>, tag: Option<u64>) -> WireError {
        WireError {
            code,
            message: message.into(),
            tag,
        }
    }

    /// Client-side transport failure (never sent by a server).
    pub fn io(e: &io::Error) -> WireError {
        WireError::new(ErrorCode::Io, format!("io error: {e}"), None)
    }

    /// Client-side protocol violation by the peer (never sent by a
    /// server).
    pub fn protocol(message: impl Into<String>) -> WireError {
        WireError::new(ErrorCode::Protocol, message, None)
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.tag {
            Some(t) => write!(f, "{:?} (tag {t}): {}", self.code, self.message),
            None => write!(f, "{:?}: {}", self.code, self.message),
        }
    }
}

impl std::error::Error for WireError {}

impl From<Fault> for WireError {
    fn from(fault: Fault) -> WireError {
        match fault {
            Fault::Io(m) => WireError::new(ErrorCode::Io, format!("io error: {m}"), None),
            other => WireError::protocol(other.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_response(resp: &Response) -> WireResponse {
        let mut buf = Vec::new();
        write_response(&mut buf, resp).unwrap();
        match read_server_frame(&mut buf.as_slice()).unwrap() {
            ServerFrame::Response(w) => w,
            other => panic!("expected response, got {other:?}"),
        }
    }

    #[test]
    fn response_roundtrips_bit_identical() {
        let resp = Response {
            id: 42,
            lengths: vec![0.1, 0.9, f32::MIN_POSITIVE, 1.0e-20, 0.25],
            predicted: 1,
            latency_us: 123_456_789,
            batch: 8,
        };
        let w = roundtrip_response(&resp);
        // Bitwise equality, not approximate: the wire must not perturb
        // the classification result.
        assert_eq!(w.lengths.len(), resp.lengths.len());
        for (a, b) in w.lengths.iter().zip(&resp.lengths) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(w.predicted, 1);
        assert_eq!(w.latency_us, 123_456_789);
        assert_eq!(w.batch, 8);
    }

    #[test]
    fn classify_roundtrips() {
        let image = vec![0.0f32, -1.5, 3.25, f32::EPSILON];
        let mut buf = Vec::new();
        write_classify(&mut buf, &image).unwrap();
        let (ty, len) = read_header(&mut buf.as_slice()).unwrap();
        assert_eq!(ty, FrameType::Classify);
        assert_eq!(len as usize, image.len() * 4);
        let payload = read_payload(&mut &buf[HEADER_LEN..], len).unwrap();
        let got = decode_classify(&payload).unwrap();
        for (a, b) in got.iter().zip(&image) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn error_frame_roundtrips() {
        let mut buf = Vec::new();
        write_error(&mut buf, ErrorCode::QueueFull, "queue full (max depth 64)").unwrap();
        match read_server_frame(&mut buf.as_slice()).unwrap() {
            ServerFrame::Error { code, message } => {
                assert_eq!(code, ErrorCode::QueueFull);
                assert!(message.contains("64"));
            }
            other => panic!("expected error frame, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_detected() {
        let mut buf = Vec::new();
        write_empty(&mut buf, FrameType::Shutdown).unwrap();
        buf[0] = b'X';
        assert!(matches!(
            read_header(&mut buf.as_slice()),
            Err(Fault::BadMagic(_))
        ));
    }

    #[test]
    fn bad_version_and_type_detected() {
        let mut buf = Vec::new();
        write_empty(&mut buf, FrameType::Shutdown).unwrap();
        let mut v = buf.clone();
        v[4] = 99;
        assert!(matches!(
            read_header(&mut v.as_slice()),
            Err(Fault::BadVersion(99))
        ));
        let mut t = buf;
        t[5] = 0x7f;
        assert!(matches!(
            read_header(&mut t.as_slice()),
            Err(Fault::UnknownType(0x7f))
        ));
    }

    #[test]
    fn oversized_length_prefix_detected() {
        let mut buf = Vec::new();
        write_empty(&mut buf, FrameType::Classify).unwrap();
        buf[6..10].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(
            read_header(&mut buf.as_slice()),
            Err(Fault::Oversized(_))
        ));
    }

    #[test]
    fn clean_close_vs_truncation() {
        // Empty stream = clean close at a frame boundary.
        assert!(matches!(read_header(&mut [].as_slice()), Err(Fault::Closed)));
        // A partial header = truncation.
        let mut buf = Vec::new();
        write_empty(&mut buf, FrameType::Shutdown).unwrap();
        assert!(matches!(
            read_header(&mut buf[..5].as_ref()),
            Err(Fault::Truncated)
        ));
        // Full header promising a payload that never arrives = truncation.
        let mut buf = Vec::new();
        write_classify(&mut buf, &[1.0; 16]).unwrap();
        let stream = &buf[..HEADER_LEN + 7];
        let mut r = stream;
        let (_, len) = read_header(&mut r).unwrap();
        assert!(matches!(read_payload(&mut r, len), Err(Fault::Truncated)));
    }

    #[test]
    fn misaligned_classify_payload_rejected() {
        assert!(matches!(
            decode_classify(&[0u8; 7]),
            Err(Fault::BadPayload(_))
        ));
    }

    #[test]
    fn short_response_payload_rejected() {
        // Claim 100 lengths, deliver 1: decode must fail typed, not read
        // out of bounds.
        let resp = Response {
            id: 1,
            lengths: vec![0.5],
            predicted: 0,
            latency_us: 5,
            batch: 1,
        };
        let mut buf = Vec::new();
        write_response(&mut buf, &resp).unwrap();
        buf[HEADER_LEN..HEADER_LEN + 2].copy_from_slice(&100u16.to_le_bytes());
        assert!(matches!(
            read_server_frame(&mut buf.as_slice()),
            Err(Fault::BadPayload(_))
        ));
    }

    /// A random well-formed server→client frame (all three types).
    fn random_server_frame(r: &mut crate::util::rng::Rng) -> Vec<u8> {
        let mut buf = Vec::new();
        match r.below(3) {
            0 => {
                let resp = Response {
                    id: r.below(1000) as u64,
                    lengths: (0..r.below(12)).map(|_| r.f32()).collect(),
                    predicted: r.below(10),
                    latency_us: r.below(100_000) as u64,
                    batch: 1 + r.below(16),
                };
                write_response(&mut buf, &resp).unwrap();
            }
            1 => {
                let msg = "x".repeat(r.below(40));
                write_error(&mut buf, ErrorCode::Execution, &msg).unwrap();
            }
            _ => write_empty(&mut buf, FrameType::ShutdownAck).unwrap(),
        }
        buf
    }

    #[test]
    fn truncated_prefixes_fault_typed_never_panic_property() {
        // Every strict prefix of a well-formed frame must decode to the
        // typed boundary faults (clean close at byte 0, truncation
        // anywhere else) — never a panic, hang, or out-of-bounds read —
        // while the untruncated frame still decodes fine.
        crate::testing::check(
            "strict frame prefixes fault as Closed/Truncated",
            40,
            29,
            random_server_frame,
            |buf| {
                (0..buf.len()).all(|cut| {
                    matches!(
                        read_server_frame(&mut &buf[..cut]),
                        Err(Fault::Closed | Fault::Truncated)
                    )
                }) && read_server_frame(&mut buf.as_slice()).is_ok()
            },
        );
    }

    #[test]
    fn single_bit_flips_decode_typed_or_ok_never_panic_property() {
        // One flipped bit anywhere in a well-formed frame: the decoder
        // must terminate with Ok or a typed Fault. Ok is legitimate —
        // e.g. a flip inside an f32 length word yields a different but
        // well-formed response — the property pinned here is that no
        // corruption panics the decoder or drives a wild allocation.
        crate::testing::check(
            "bit-flipped frames decode without panicking",
            80,
            31,
            |r| {
                let mut buf = random_server_frame(r);
                let bit = r.below(buf.len() * 8);
                buf[bit / 8] ^= 1 << (bit % 8);
                (buf, bit)
            },
            |(buf, _bit)| {
                let _ = read_server_frame(&mut buf.as_slice());
                true
            },
        );
    }

    #[test]
    fn corrupted_classify_frames_fault_typed_property() {
        // Client→server direction: truncations and bit flips of a
        // classify frame must surface as typed faults (or decode to
        // some f32 image), never panic the header/payload readers.
        crate::testing::check(
            "classify frame corruption is typed",
            40,
            37,
            |r| {
                let image: Vec<f32> = (0..(1 + r.below(64))).map(|_| r.f32()).collect();
                let mut buf = Vec::new();
                write_classify(&mut buf, &image).unwrap();
                let bit = r.below(buf.len() * 8);
                let cut = r.below(buf.len());
                (buf, bit, cut)
            },
            |(buf, bit, cut)| {
                // Truncated prefix: typed boundary fault.
                let prefix_ok = {
                    let mut s = &buf[..*cut];
                    match read_header(&mut s) {
                        Err(Fault::Closed | Fault::Truncated) => true,
                        Ok((_, len)) => matches!(
                            read_payload(&mut s, len),
                            Ok(_) | Err(Fault::Truncated)
                        ),
                        Err(_) => false,
                    }
                };
                // Bit flip: typed fault or a decodable (different) frame.
                let mut flipped = buf.clone();
                flipped[bit / 8] ^= 1 << (bit % 8);
                let mut s = flipped.as_slice();
                let flip_ok = match read_header(&mut s) {
                    Ok((_, len)) => match read_payload(&mut s, len) {
                        Ok(p) => decode_classify(&p).is_ok() || p.len() % 4 != 0,
                        Err(Fault::Truncated) => true,
                        Err(_) => false,
                    },
                    Err(Fault::Closed) => false, // header bytes exist
                    Err(_) => true, // BadMagic/BadVersion/UnknownType/Oversized
                };
                prefix_ok && flip_ok
            },
        );
    }

    #[test]
    fn error_message_truncated_to_bound() {
        let long = "x".repeat(5000);
        let mut buf = Vec::new();
        write_error(&mut buf, ErrorCode::Execution, &long).unwrap();
        match read_server_frame(&mut buf.as_slice()).unwrap() {
            ServerFrame::Error { message, .. } => assert_eq!(message.len(), 1024),
            other => panic!("expected error frame, got {other:?}"),
        }
    }

    #[test]
    fn v2_response_roundtrips_with_tag() {
        let resp = Response {
            id: 7,
            lengths: vec![0.25, 0.75, 1.0e-20],
            predicted: 1,
            latency_us: 987,
            batch: 4,
        };
        let buf = encode_response(V2, 0xDEAD_BEEF_0000_0042, &resp);
        let f = scan_frame(&buf).unwrap().expect("complete frame");
        assert_eq!(f.version, V2);
        assert_eq!(f.ty, FrameType::Response);
        assert_eq!(f.total_len, buf.len());
        let (tag, frame) =
            decode_server_payload(f.version, f.ty, &buf[HEADER_LEN..f.total_len]).unwrap();
        assert_eq!(tag, Some(0xDEAD_BEEF_0000_0042));
        match frame {
            ServerFrame::Response(w) => {
                for (a, b) in w.lengths.iter().zip(&resp.lengths) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                assert_eq!(w.predicted, 1);
                assert_eq!(w.batch, 4);
            }
            other => panic!("expected response, got {other:?}"),
        }
    }

    #[test]
    fn v2_error_roundtrips_with_tag_and_code() {
        let buf = encode_error(V2, 9, ErrorCode::QueueFull, "admission queue full");
        let f = scan_frame(&buf).unwrap().unwrap();
        let (tag, frame) =
            decode_server_payload(f.version, f.ty, &buf[HEADER_LEN..f.total_len]).unwrap();
        assert_eq!(tag, Some(9));
        match frame {
            ServerFrame::Error { code, message } => {
                assert_eq!(code, ErrorCode::QueueFull);
                assert!(message.contains("queue"));
            }
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn v2_classify_splits_tag_from_image() {
        let image = vec![1.0f32, -2.5, 0.125];
        let buf = encode_classify(V2, 31337, &image);
        let f = scan_frame(&buf).unwrap().unwrap();
        assert_eq!(f.version, V2);
        assert_eq!(f.ty, FrameType::Classify);
        let (tag, raw) = decode_classify_v2(&buf[HEADER_LEN..f.total_len]).unwrap();
        assert_eq!(tag, 31337);
        let got = decode_classify(raw).unwrap();
        for (a, b) in got.iter().zip(&image) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn v2_classify_shorter_than_tag_is_typed() {
        assert!(matches!(
            decode_classify_v2(&[0u8; 7]),
            Err(Fault::BadPayload(_))
        ));
    }

    #[test]
    fn scan_frame_is_incremental_and_typed() {
        let buf = encode_classify(V2, 5, &[0.5f32; 8]);
        // Every strict prefix: either "need more bytes" or — never — a
        // fault, since the prefix stays magic-consistent.
        for cut in 0..buf.len() {
            assert!(
                matches!(scan_frame(&buf[..cut]), Ok(None)),
                "prefix of {cut} bytes should be incomplete, not a fault"
            );
        }
        let f = scan_frame(&buf).unwrap().unwrap();
        assert_eq!(f.total_len, buf.len());
        // Garbage faults immediately, even before a full header arrives.
        assert!(matches!(scan_frame(b"XX"), Err(Fault::BadMagic(_))));
        assert!(matches!(
            scan_frame(b"XXXXgarbage-not-a-frame"),
            Err(Fault::BadMagic(_))
        ));
        // Bad version / unknown type / oversized are typed.
        let mut v = buf.clone();
        v[4] = 99;
        assert!(matches!(scan_frame(&v), Err(Fault::BadVersion(99))));
        let mut t = buf.clone();
        t[5] = 0x7f;
        assert!(matches!(scan_frame(&t), Err(Fault::UnknownType(0x7f))));
        let mut o = buf;
        o[6..10].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(scan_frame(&o), Err(Fault::Oversized(_))));
    }

    #[test]
    fn wire_error_reports_code_and_tag() {
        let e = WireError::new(ErrorCode::QueueFull, "depth 64", Some(3));
        let s = e.to_string();
        assert!(s.contains("QueueFull") && s.contains("tag 3"), "{s}");
        assert_eq!(ErrorCode::from_u8(ErrorCode::Io as u8), Some(ErrorCode::Io));
        assert_eq!(
            ErrorCode::from_u8(ErrorCode::Protocol as u8),
            Some(ErrorCode::Protocol)
        );
    }
}
