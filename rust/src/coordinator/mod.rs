//! Serving coordinator (S8) — the L3 event loop that keeps Python off the
//! request path.
//!
//! Architecture (vLLM-router-shaped, scaled to this workload):
//!
//! ```text
//!  clients ──► Router ──► Batcher ──► Executor (PJRT engine / FPGA sim)
//!                 │           │             │
//!                 ▼           ▼             ▼
//!               admission   batch-size    response
//!               + metrics   buckets       dispatch
//! ```
//!
//! * [`batcher`] — dynamic batching: collect requests up to the largest
//!   available bucket or a deadline, then pick the best bucket
//!   (vLLM-style bucketed batching; the AOT artifacts provide b=1 and
//!   b=8 executables, padding fills the remainder).
//! * [`server`] — thread topology: N client handlers feed an MPSC queue;
//!   one batcher thread; one executor thread owning the PJRT engines
//!   (PJRT executables are single-owner by design here); responses fan
//!   back out through per-request channels.
//! * [`metrics`] — latency histogram + throughput counters.
//!
//! Everything is std-only (threads + channels); the vendored crate set
//! has no tokio, and the workload (sub-ms model steps) doesn't need
//! async I/O.

pub mod batcher;
pub mod metrics;
pub mod server;

use crate::tensor::Tensor;
use std::time::Instant;

/// A classification request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub image: Tensor,
    pub enqueued: Instant,
}

/// A classification response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// DigitCaps lengths (class scores).
    pub lengths: Vec<f32>,
    pub predicted: usize,
    /// End-to-end latency in microseconds.
    pub latency_us: u64,
    /// Batch size the request was served in.
    pub batch: usize,
}

impl Response {
    pub fn from_lengths(
        id: u64,
        lengths: Vec<f32>,
        enqueued: Instant,
        batch: usize,
    ) -> Response {
        let predicted = lengths
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        Response {
            id,
            lengths,
            predicted,
            latency_us: enqueued.elapsed().as_micros() as u64,
            batch,
        }
    }
}
