//! Serving coordinator (S8) — the L3 event loop that keeps Python off the
//! request path.
//!
//! Architecture (vLLM-router-shaped, scaled to this workload):
//!
//! ```text
//!  clients ──► admission ──► shared queue ──► executor pool (N replicas)
//!                 │          (bounded)          │  each owns one
//!                 ▼                             │  InferenceBackend
//!             QueueFull                         ▼
//!             rejection                  batch → infer → responses
//! ```
//!
//! * [`server`] — [`server::ServerBuilder`] configures max queue depth
//!   (admission rejection with a typed
//!   [`crate::backend::BackendError::QueueFull`]), the batch policy, and
//!   an executor pool of N backend replicas fed from one shared work
//!   queue. Replicas are built *on* their own threads via a factory, so
//!   single-owner engines (PJRT) never cross threads; the backend's
//!   [`crate::backend::BackendSpec::max_replicas`] clamps the pool
//!   (`sim`/`oracle` scale across cores, `pjrt` pins 1).
//! * [`batcher`] — dynamic batching: collect requests up to the largest
//!   available bucket or a deadline, then pick the best bucket
//!   (vLLM-style bucketed batching; the AOT artifacts provide b=1 and
//!   b=8 executables, padding fills the remainder).
//! * [`metrics`] — latency histogram + throughput, rejection, error,
//!   and network-connection counters shared across the pool. Snapshots
//!   freeze their wall clock so reported RPS doesn't decay after the
//!   fact.
//! * [`wire`] / [`net`] / [`event_loop`] — the network front-end: a
//!   length-prefixed binary protocol ([`wire`], v1 in-order and v2
//!   tagged out-of-order), a sharded readiness event loop over
//!   nonblocking sockets ([`event_loop`]), and the listener + client
//!   surface ([`net::NetServer`], [`net::Connection`]), so processes
//!   that are not `fastcaps` can classify images through the same
//!   admission queue. The listener doubles as a plaintext sidecar for
//!   `HEALTH`/`READY` probes and a metrics exposition dump.
//!
//! Everything is std-only (threads + condvar queue + `poll(2)` via a
//! direct FFI declaration); the vendored crate set has no tokio, and
//! the workload (sub-ms model steps) doesn't need async I/O.

pub mod batcher;
pub mod event_loop;
pub mod metrics;
pub mod net;
pub mod server;
pub mod wire;

use crate::tensor::Tensor;
use std::time::Instant;

/// A classification request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub image: Tensor,
    pub enqueued: Instant,
}

/// A classification response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// DigitCaps lengths (class scores).
    pub lengths: Vec<f32>,
    pub predicted: usize,
    /// End-to-end latency in microseconds.
    pub latency_us: u64,
    /// Batch size the request was served in.
    pub batch: usize,
}

impl Response {
    pub fn from_lengths(
        id: u64,
        lengths: Vec<f32>,
        enqueued: Instant,
        batch: usize,
    ) -> Response {
        // NaN-safe: a NaN length must not panic the executor thread
        // (argmax ignores NaN entries instead).
        let predicted = crate::util::argmax(&lengths);
        Response {
            id,
            lengths,
            predicted,
            latency_us: enqueued.elapsed().as_micros() as u64,
            batch,
        }
    }
}
