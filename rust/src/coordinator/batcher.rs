//! Dynamic batcher with bucketed batch sizes.
//!
//! The AOT path compiles one executable per batch size (the buckets), so
//! the batcher's job is: collect queued requests until either the largest
//! bucket fills or the oldest request's deadline expires, then choose the
//! largest bucket ≤ the queue length (falling back to padding the
//! smallest bucket when the queue is short).

use std::time::Duration;

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Available batch sizes, ascending (from the artifact manifest).
    pub buckets: Vec<usize>,
    /// Max time the oldest request may wait before a partial batch ships.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(mut buckets: Vec<usize>, max_wait: Duration) -> BatchPolicy {
        assert!(!buckets.is_empty(), "need at least one bucket");
        buckets.sort_unstable();
        buckets.dedup();
        BatchPolicy { buckets, max_wait }
    }

    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Decide what to ship given `queued` requests and whether the oldest
    /// request has hit its deadline. Returns `Some((bucket, take))`:
    /// `take` real requests padded up to `bucket`.
    pub fn decide(&self, queued: usize, deadline_hit: bool) -> Option<(usize, usize)> {
        if queued == 0 {
            return None;
        }
        if queued >= self.max_bucket() {
            let b = self.max_bucket();
            return Some((b, b));
        }
        if !deadline_hit {
            return None; // keep collecting
        }
        // Deadline: ship now. Prefer a *full* bucket when it holds at
        // least half of what the covering bucket would — padding frames
        // cost real compute on host-synchronous backends (the sim runs
        // every blank through the whole datapath), while the small
        // remainder ships in the very next decision. On a dense pow2
        // ladder this never pads; on a sparse AOT ladder (e.g. {1, 8})
        // a short queue still pads the covering bucket rather than
        // fragmenting into many tiny batches.
        let cover = self
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= queued)
            .unwrap_or(self.max_bucket());
        if let Some(full) = self.buckets.iter().copied().rev().find(|&b| b <= queued) {
            if full * 2 >= cover {
                return Some((full, full));
            }
        }
        Some((cover, queued.min(cover)))
    }

    /// Padding waste (fraction of bucket slots unused) for a decision.
    pub fn waste(bucket: usize, take: usize) -> f64 {
        (bucket - take) as f64 / bucket as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![8, 1], Duration::from_millis(2))
    }

    #[test]
    fn buckets_sorted_deduped() {
        let p = BatchPolicy::new(vec![8, 1, 8], Duration::from_millis(1));
        assert_eq!(p.buckets, vec![1, 8]);
        assert_eq!(p.max_bucket(), 8);
    }

    #[test]
    fn empty_queue_waits() {
        assert_eq!(policy().decide(0, true), None);
        assert_eq!(policy().decide(0, false), None);
    }

    #[test]
    fn full_bucket_ships_immediately() {
        assert_eq!(policy().decide(8, false), Some((8, 8)));
        assert_eq!(policy().decide(20, false), Some((8, 8)));
    }

    #[test]
    fn partial_waits_until_deadline() {
        assert_eq!(policy().decide(3, false), None);
        assert_eq!(policy().decide(3, true), Some((8, 3)));
        assert_eq!(policy().decide(1, true), Some((1, 1)));
    }

    #[test]
    fn deadline_prefers_full_bucket_over_heavy_padding() {
        // The sim backend's dense ladder: 9–15 queued at deadline ship a
        // full 8-bucket with zero padding instead of a 16-bucket with up
        // to 7 blank frames of real host compute; the remainder ships in
        // the next decision.
        let p = BatchPolicy::new(vec![1, 2, 4, 8, 16], Duration::from_millis(1));
        assert_eq!(p.decide(9, true), Some((8, 8)));
        assert_eq!(p.decide(15, true), Some((8, 8)));
        assert_eq!(p.decide(3, true), Some((2, 2)));
        assert_eq!(p.decide(1, true), Some((1, 1)));
        // Sparse AOT-style ladder: a full bucket under half the cover
        // would fragment the batch, so short queues still pad (the
        // pinned behavior of `partial_waits_until_deadline`).
        let sparse = BatchPolicy::new(vec![1, 8], Duration::from_millis(1));
        assert_eq!(sparse.decide(3, true), Some((8, 3)));
        assert_eq!(sparse.decide(7, true), Some((8, 7)));
    }

    #[test]
    fn waste_accounting() {
        assert_eq!(BatchPolicy::waste(8, 8), 0.0);
        assert_eq!(BatchPolicy::waste(8, 6), 0.25);
    }

    #[test]
    fn queue_larger_than_biggest_bucket_ships_full_max_bucket() {
        // Backlog deeper than every bucket: ship a full max bucket now
        // (never a partial one, never more than the bucket holds).
        let p = BatchPolicy::new(vec![4, 8], Duration::from_millis(1));
        assert_eq!(p.decide(9, false), Some((8, 8)));
        assert_eq!(p.decide(9, true), Some((8, 8)));
        assert_eq!(p.decide(1000, false), Some((8, 8)));
    }

    #[test]
    fn deadline_with_queue_smaller_than_smallest_bucket_pads() {
        // Smallest bucket is 4: two deadline-hit requests ship padded
        // into it rather than waiting forever for a full batch.
        let p = BatchPolicy::new(vec![4, 8], Duration::from_millis(1));
        assert_eq!(p.decide(2, false), None);
        assert_eq!(p.decide(2, true), Some((4, 2)));
        assert_eq!(p.decide(3, true), Some((4, 3)));
    }

    #[test]
    fn shutdown_drain_always_terminates() {
        // The executor's drain path calls decide(queued, true) until the
        // queue empties; a None for a non-empty queue would loop forever.
        for buckets in [vec![1, 8], vec![4, 8], vec![3], vec![2, 5, 16]] {
            let p = BatchPolicy::new(buckets.clone(), Duration::from_millis(1));
            for start in 1..40usize {
                let mut queued = start;
                let mut steps = 0;
                while queued > 0 {
                    let (bucket, take) = p
                        .decide(queued, true)
                        .unwrap_or_else(|| panic!("drain stuck at {queued} ({buckets:?})"));
                    assert!(take > 0 && take <= bucket && take <= queued);
                    queued -= take;
                    steps += 1;
                    assert!(steps <= start, "drain not making progress");
                }
            }
        }
    }

    #[test]
    fn property_decisions_are_valid() {
        let p = BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(1));
        crate::testing::check(
            "batcher picks a valid bucket",
            200,
            3,
            |r| (r.below(40), r.below(2) == 0),
            |&(q, dl)| match p.decide(q, dl) {
                None => q == 0 || (!dl && q < 8),
                Some((bucket, take)) => {
                    p.buckets.contains(&bucket) && take <= bucket && take <= q && take > 0
                }
            },
        );
    }
}
