//! Server topology: clients → bounded shared work queue → executor pool
//! of N backend replicas → per-request response channels.
//!
//! Each replica thread *creates* its own backend via the factory and
//! owns it for its whole life — nothing engine-related ever crosses a
//! thread boundary (PJRT executables wrap raw pointers and additionally
//! pin the pool to one replica via [`BackendSpec::max_replicas`]).
//!
//! Admission control is at the queue: when `max_queue_depth` requests
//! are already waiting, [`Server::submit`] rejects with
//! [`BackendError::QueueFull`] instead of growing the backlog — the
//! caller sheds load instead of the tail latency exploding.

use super::batcher::BatchPolicy;
use super::metrics::Metrics;
use super::{Request, Response};
use crate::backend::{BackendError, BackendSpec, InferRequest, InferenceBackend};
use crate::cache::flight::{FlightLead, Waiter};
use crate::cache::{CacheConfig, CacheStore, InferenceCache, Lookup};
use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

type Job = (Request, Completion);

/// Where a finished response is delivered. The in-process path is a
/// plain mpsc sender; the event-loop path hands the response back to
/// the IO shard that owns the submitting connection (as a queued
/// completion event plus a waker byte — the executor never blocks on a
/// slow client).
///
/// Dropping a sink without sending is the failure notification: a
/// `Channel` receiver disconnects (typed `Unavailable` at `classify`),
/// a `Shard` sink enqueues a `Failed` event for its tag.
pub(crate) enum ReplySink {
    Channel(mpsc::Sender<Response>),
    Shard(crate::coordinator::event_loop::ShardSink),
}

impl ReplySink {
    pub(crate) fn send(self, resp: Response) {
        match self {
            ReplySink::Channel(tx) => {
                let _ = tx.send(resp); // receiver may have gone away; fine
            }
            ReplySink::Shard(sink) => sink.send(resp),
        }
    }

    /// Consume the sink *without* any notification — for synchronous
    /// rejections where the submitter already holds the typed error and
    /// a `Failed` event would double-report.
    fn dispose(self) {
        if let ReplySink::Shard(sink) = self {
            sink.dispose();
        }
    }
}

/// Where a finished job's response goes: straight back to the one
/// submitter, or through the single-flight lead — which also publishes
/// the response to the cache and fans it out to coalesced waiters.
///
/// Dropping a `Flight` completion without delivering (admission
/// rejection, failed batch, pool death, shutdown with a cleared queue)
/// drops the [`FlightLead`], which aborts the flight: every parked
/// waiter's sink drops undelivered and surfaces as the same typed
/// `Unavailable` the leader gets.
pub(crate) enum Completion {
    Direct(ReplySink),
    Flight { sink: ReplySink, lead: FlightLead },
}

impl Completion {
    /// Deliver the response (metrics for the leader itself are recorded
    /// by the caller; `complete` records each coalesced waiter's own
    /// latency).
    fn deliver(self, resp: Response, m: &mut Metrics) {
        match self {
            Completion::Direct(sink) => sink.send(resp),
            Completion::Flight { sink, mut lead } => {
                lead.complete(&resp, m);
                sink.send(resp);
            }
        }
    }

    /// Tear down a completion after a synchronous admission rejection:
    /// the submitter's own sink is disposed silently (it has the typed
    /// error in hand), while a flight lead drops normally so coalesced
    /// waiters still get their abort notification.
    fn reject(self) {
        match self {
            Completion::Direct(sink) => sink.dispose(),
            Completion::Flight { sink, lead } => {
                sink.dispose();
                drop(lead);
            }
        }
    }
}

/// Builds one backend replica. Called once per replica, *on* the
/// replica's own thread.
pub type ReplicaFactory =
    Arc<dyn Fn() -> Result<Box<dyn InferenceBackend>, BackendError> + Send + Sync>;

/// Lock a mutex, recovering the data from a poisoned lock. A replica
/// panic already fails its in-flight work via [`ReplicaGuard`], and
/// every guarded section leaves `QueueState`/`Metrics` consistent at
/// each unlock, so propagating the poison would only cascade one panic
/// into server-wide unwinding.
fn lock_clean<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// State shared between submitters and the executor pool.
struct Shared {
    state: Mutex<QueueState>,
    cv: Condvar,
    metrics: Mutex<Metrics>,
    max_depth: usize,
    max_wait: Duration,
    /// Replicas that finished init and are serving.
    live: AtomicUsize,
    /// Replicas spawned but still inside their factory. The pool is
    /// only *dead* when both `live` and `booting` are 0 — a panic on
    /// the last live replica while another is still building must not
    /// condemn the queue that replica is about to serve.
    booting: AtomicUsize,
    /// Set when the pool died *while the queue was still open* (every
    /// replica exited abnormally, e.g. a backend panic) — as opposed to
    /// a requested shutdown. Admission reads it to return the right
    /// typed error instead of a misleading "server is shut down".
    pool_died: AtomicBool,
}

impl Shared {
    /// Close admission iff no replica is live *and* none is still
    /// booting. Called whenever a replica exits or fails init. The
    /// check runs under the state mutex, and the booting→live
    /// transition ([`Shared::mark_replica_live`]) takes the same mutex,
    /// so a replica finishing init can never slip between this check
    /// and the close. No-op during a requested shutdown (`open` already
    /// false).
    fn close_if_pool_dead(&self) {
        let mut st = lock_clean(&self.state);
        if st.open
            && self.live.load(Ordering::SeqCst) == 0
            && self.booting.load(Ordering::SeqCst) == 0
        {
            // No executor will ever drain the queue again. Close
            // admission and drop the queued jobs — dropping the senders
            // disconnects every waiting `recv()`, so callers fail fast
            // instead of hanging.
            self.pool_died.store(true, Ordering::SeqCst);
            st.open = false;
            st.jobs.clear();
            drop(st);
            self.cv.notify_all();
        }
    }

    /// Atomically (w.r.t. [`Shared::close_if_pool_dead`]) move one
    /// replica from booting to live, so the pool never looks
    /// transiently dead while a healthy replica finishes init.
    fn mark_replica_live(&self) {
        let _st = lock_clean(&self.state);
        self.live.fetch_add(1, Ordering::SeqCst);
        self.booting.fetch_sub(1, Ordering::SeqCst);
    }
}

struct QueueState {
    jobs: VecDeque<Job>,
    open: bool,
}

/// Configures and starts a [`Server`]. Replaces the old
/// `Server::start(closure, max_wait)` signature.
pub struct ServerBuilder {
    factory: ReplicaFactory,
    replicas: usize,
    max_wait: Duration,
    max_queue_depth: usize,
    max_batch: Option<usize>,
    cache: Option<CacheConfig>,
    cache_store: Option<Arc<CacheStore>>,
}

impl ServerBuilder {
    pub fn new<F>(factory: F) -> ServerBuilder
    where
        F: Fn() -> Result<Box<dyn InferenceBackend>, BackendError> + Send + Sync + 'static,
    {
        ServerBuilder {
            factory: Arc::new(factory),
            replicas: 1,
            max_wait: Duration::from_millis(5),
            max_queue_depth: 1024,
            max_batch: None,
            cache: None,
            cache_store: None,
        }
    }

    /// Desired executor replicas; clamped to the backend's
    /// [`BackendSpec::max_replicas`] capability at start.
    pub fn replicas(mut self, n: usize) -> Self {
        self.replicas = n.max(1);
        self
    }

    /// Batch policy: max time the oldest request waits before a partial
    /// batch ships.
    pub fn max_wait(mut self, d: Duration) -> Self {
        self.max_wait = d;
        self
    }

    /// Admission limit: queued (not yet executing) requests beyond this
    /// are rejected with [`BackendError::QueueFull`].
    pub fn max_queue_depth(mut self, n: usize) -> Self {
        self.max_queue_depth = n.max(1);
        self
    }

    /// Batch policy: ignore backend buckets above this size.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = Some(n.max(1));
        self
    }

    /// Enable the content-addressed inference cache (off by default).
    /// The cache is keyed by the input bits *and* the backend's
    /// deployment fingerprint, so it never serves responses across
    /// model redeployments. `entries == 0` leaves it off.
    pub fn cache(mut self, cfg: CacheConfig) -> Self {
        self.cache = Some(cfg);
        self
    }

    /// Enable the cache bound to an existing store: a redeploy keeps
    /// the allocation, while the new deployment's fingerprint makes the
    /// old entries unreachable. Takes precedence over [`Self::cache`].
    pub fn cache_store(mut self, store: Arc<CacheStore>) -> Self {
        self.cache_store = Some(store);
        self
    }

    /// Spawn the pool. Blocks until the first replica's backend is
    /// built, so the returned server either has a known [`BackendSpec`]
    /// or is already marked unavailable (init failure).
    pub fn start(self) -> Server {
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                open: true,
            }),
            cv: Condvar::new(),
            metrics: Mutex::new(Metrics::default()),
            max_depth: self.max_queue_depth,
            max_wait: self.max_wait,
            live: AtomicUsize::new(0),
            booting: AtomicUsize::new(0),
            pool_died: AtomicBool::new(false),
        });

        let (spec_tx, spec_rx) = mpsc::channel::<Result<BackendSpec, BackendError>>();
        let mut handles = Vec::with_capacity(self.replicas);
        shared.booting.fetch_add(1, Ordering::SeqCst);
        handles.push(spawn_replica(
            0,
            shared.clone(),
            self.factory.clone(),
            self.max_batch,
            Some(spec_tx),
        ));

        let first = spec_rx
            .recv()
            .unwrap_or_else(|_| Err(BackendError::Init("replica 0 vanished".into())));
        let (spec, init_error) = match first {
            Ok(spec) => (Some(spec), None),
            Err(e) => {
                // No executor will ever serve; close the queue so
                // submitters fail fast instead of hanging.
                lock_clean(&shared.state).open = false;
                (None, Some(e))
            }
        };

        if let Some(spec) = &spec {
            let cap = spec.max_replicas.unwrap_or(usize::MAX);
            for idx in 1..self.replicas.min(cap) {
                shared.booting.fetch_add(1, Ordering::SeqCst);
                handles.push(spawn_replica(
                    idx,
                    shared.clone(),
                    self.factory.clone(),
                    self.max_batch,
                    None,
                ));
            }
        }

        // The cache binds to the *served* deployment's fingerprint, so
        // it can only exist once the spec is known (init failure ⇒ no
        // cache; nothing would ever fill it anyway).
        let cache = match (&spec, self.cache_store, self.cache) {
            (Some(s), Some(store), _) => Some(InferenceCache::with_store(store, s.fingerprint)),
            (Some(s), None, Some(cfg)) if cfg.enabled() => {
                Some(InferenceCache::new(&cfg, s.fingerprint))
            }
            _ => None,
        };

        Server {
            shared,
            handles,
            spec,
            init_error,
            cache,
            next_id: AtomicU64::new(1),
        }
    }
}

/// Handle to a running executor pool.
pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<Result<(), BackendError>>>,
    spec: Option<BackendSpec>,
    init_error: Option<BackendError>,
    cache: Option<InferenceCache>,
    next_id: AtomicU64,
}

impl Server {
    /// Start building a server around a replica factory.
    pub fn builder<F>(factory: F) -> ServerBuilder
    where
        F: Fn() -> Result<Box<dyn InferenceBackend>, BackendError> + Send + Sync + 'static,
    {
        ServerBuilder::new(factory)
    }

    /// The spec of the backend the pool runs (None if init failed).
    pub fn spec(&self) -> Option<&BackendSpec> {
        self.spec.as_ref()
    }

    /// Why the server is unavailable, if replica 0 failed to build.
    pub fn init_error(&self) -> Option<&BackendError> {
        self.init_error.as_ref()
    }

    /// Replicas currently serving. Replicas beyond the first build
    /// asynchronously, so right after start this may still be below
    /// [`Server::pool_size`].
    pub fn live_replicas(&self) -> usize {
        self.shared.live.load(Ordering::Relaxed)
    }

    /// Executor threads spawned for this pool (after clamping to the
    /// backend's `max_replicas` capability).
    pub fn pool_size(&self) -> usize {
        self.handles.len()
    }

    /// The cache's backing store, when the cache layer is enabled —
    /// hand it to the next deployment's [`ServerBuilder::cache_store`]
    /// to keep the allocation across a redeploy.
    pub fn cache_store(&self) -> Option<&Arc<CacheStore>> {
        self.cache.as_ref().map(|c| c.store())
    }

    /// Submit an image; returns the response channel, or a typed
    /// rejection when the server is down or the queue is at capacity.
    ///
    /// With the cache layer enabled, the request is resolved against
    /// the cache *before* admission: a hit answers immediately without
    /// touching the queue, a duplicate of an in-flight request parks on
    /// that flight (single-flight coalescing), and only a genuine miss
    /// pays queue admission and a backend pass.
    pub fn submit(&self, image: Tensor) -> Result<mpsc::Receiver<Response>, BackendError> {
        let (rtx, rrx) = mpsc::channel();
        self.submit_sink(image, ReplySink::Channel(rtx))?;
        Ok(rrx)
    }

    /// Submit with an explicit delivery sink — the entry point the
    /// event-loop front-end uses so a completion lands back on the IO
    /// shard that owns the connection. On a typed rejection the sink is
    /// consumed *silently* (no `Failed` event): the caller holds the
    /// error and answers the request itself.
    pub(crate) fn submit_sink(&self, image: Tensor, sink: ReplySink) -> Result<(), BackendError> {
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            enqueued: Instant::now(),
        };
        let completion = match &self.cache {
            None => Completion::Direct(sink),
            Some(cache) => {
                let key = cache.key_of(&req.image);
                let waiter = Waiter {
                    id: req.id,
                    enqueued: req.enqueued,
                    sink,
                };
                match cache.lookup(key, waiter) {
                    Lookup::Hit(out, waiter) => {
                        let resp = out.to_response(req.id, req.enqueued);
                        {
                            let mut m = lock_clean(&self.shared.metrics);
                            m.record_cache_hit();
                            m.record(resp.latency_us);
                        }
                        waiter.sink.send(resp);
                        return Ok(());
                    }
                    Lookup::Joined => {
                        lock_clean(&self.shared.metrics).record_cache_coalesced();
                        return Ok(());
                    }
                    Lookup::Lead {
                        lead,
                        waiter,
                        stale,
                    } => {
                        let mut m = lock_clean(&self.shared.metrics);
                        m.record_cache_miss();
                        if stale {
                            m.record_cache_stale();
                        }
                        drop(m);
                        Completion::Flight {
                            sink: waiter.sink,
                            lead,
                        }
                    }
                }
            }
        };
        {
            let mut st = lock_clean(&self.shared.state);
            // Queue closed ⟺ no executor will ever drain new work: set by
            // shutdown, by an init failure, or by `ReplicaGuard` when the
            // last replica dies. Enqueueing past this point would strand
            // the caller's `recv()` forever, so fail typed instead.
            if !st.open {
                drop(st);
                completion.reject();
                return Err(BackendError::Unavailable(match &self.init_error {
                    Some(e) => format!("backend never started: {e}"),
                    None if self.shared.pool_died.load(Ordering::SeqCst) => {
                        "all executor replicas have died (backend failure); \
                         server accepts no work"
                            .into()
                    }
                    None => "server is shut down".into(),
                }));
            }
            if st.jobs.len() >= self.shared.max_depth {
                drop(st);
                lock_clean(&self.shared.metrics).record_rejected();
                // A rejected lead drops its `Completion::Flight`, which
                // aborts the flight and fails any waiters that managed
                // to coalesce onto it — nobody hangs.
                completion.reject();
                return Err(BackendError::QueueFull {
                    depth: self.shared.max_depth,
                });
            }
            st.jobs.push_back((req, completion));
        }
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Submit and wait for the response.
    pub fn classify(&self, image: Tensor) -> Result<Response, BackendError> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| {
            BackendError::Unavailable(
                "executor dropped the request (backend failure or shutdown)".into(),
            )
        })
    }

    /// Whether the pool died while serving (every replica exited
    /// abnormally), as opposed to a requested shutdown.
    pub fn pool_died(&self) -> bool {
        self.shared.pool_died.load(Ordering::SeqCst)
    }

    /// A point-in-time metrics snapshot: its wall clock is frozen, so
    /// `throughput_rps` stays stable no matter when the caller prints it.
    pub fn metrics(&self) -> Metrics {
        lock_clean(&self.shared.metrics).snapshot()
    }

    /// Run a closure against the live shared metrics. Crate-internal
    /// hook for the network front-end's per-connection counters.
    pub(crate) fn with_metrics<R>(&self, f: impl FnOnce(&mut Metrics) -> R) -> R {
        f(&mut lock_clean(&self.shared.metrics))
    }

    /// Drain and stop the pool. Returns final (frozen) metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.close_and_join();
        self.metrics()
    }

    fn close_and_join(&mut self) {
        lock_clean(&self.shared.state).open = false;
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// Decrements the live count when a replica exits — by return, error,
/// or *panic* (unwind runs Drop) — and fails pending work fast once the
/// last replica is gone, instead of leaving `classify` callers hanging
/// on a queue nobody serves.
///
/// Pool *death* (last replica gone, none still booting, while the
/// queue is still open) is distinguished from normal shutdown (queue
/// already closed by `close_and_join` before replicas exit): only
/// death sets [`Shared::pool_died`] and drop-notifies the queued
/// waiters — see [`Shared::close_if_pool_dead`]. Racing a normal
/// shutdown is safe because the state mutex serializes the close with
/// both `submit` and `close_and_join`; racing a still-booting replica
/// is safe because its init outcome re-runs the same check.
struct ReplicaGuard {
    shared: Arc<Shared>,
}

impl Drop for ReplicaGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            // Abnormal exit (backend panic): make the death observable
            // in the metrics even when surviving replicas keep serving.
            lock_clean(&self.shared.metrics).record_replica_died();
        }
        self.shared.live.fetch_sub(1, Ordering::SeqCst);
        self.shared.close_if_pool_dead();
        self.shared.cv.notify_all();
    }
}

fn spawn_replica(
    idx: usize,
    shared: Arc<Shared>,
    factory: ReplicaFactory,
    max_batch: Option<usize>,
    spec_tx: Option<mpsc::Sender<Result<BackendSpec, BackendError>>>,
) -> JoinHandle<Result<(), BackendError>> {
    std::thread::Builder::new()
        .name(format!("fastcaps-executor-{idx}"))
        .spawn(move || {
            let init = factory()
                .and_then(|b| effective_buckets(b.spec(), max_batch).map(|bk| (b, bk)));
            let (mut backend, buckets) = match init {
                Ok(ok) => ok,
                Err(e) => {
                    shared.booting.fetch_sub(1, Ordering::SeqCst);
                    if let Some(tx) = spec_tx {
                        // Replica 0: the builder observes the error and
                        // closes the queue itself.
                        let _ = tx.send(Err(e.clone()));
                    } else {
                        // A degraded pool is easy to miss; say so.
                        eprintln!("[coordinator] replica {idx} failed to init: {e}");
                        // If this was the last hope (nothing live,
                        // nothing else booting), fail pending work.
                        shared.close_if_pool_dead();
                    }
                    return Err(e);
                }
            };
            shared.mark_replica_live();
            let _guard = ReplicaGuard {
                shared: shared.clone(),
            };
            if let Some(tx) = spec_tx {
                let _ = tx.send(Ok(backend.spec().clone()));
            }
            replica_loop(&shared, &mut *backend, buckets)
        })
        .expect("spawning executor thread")
}

/// Batch buckets the policy may use: the backend's, optionally capped by
/// [`ServerBuilder::max_batch`]. A cap below the smallest bucket is a
/// configuration error — silently exceeding it would break whatever
/// (memory, latency) motivated the cap.
fn effective_buckets(
    spec: &BackendSpec,
    max_batch: Option<usize>,
) -> Result<Vec<usize>, BackendError> {
    let mut buckets = spec.batch_buckets.clone();
    if buckets.is_empty() {
        // validate() would reject every batch against an empty bucket
        // list — surface the misconfiguration at start, not per request.
        return Err(BackendError::Init(
            "backend declares no batch buckets".into(),
        ));
    }
    if let Some(cap) = max_batch {
        let smallest = *buckets.iter().min().expect("non-empty");
        buckets.retain(|&b| b <= cap);
        if buckets.is_empty() {
            return Err(BackendError::Init(format!(
                "max_batch({cap}) is below the smallest backend bucket ({smallest})"
            )));
        }
    }
    Ok(buckets)
}

fn replica_loop(
    shared: &Shared,
    backend: &mut dyn InferenceBackend,
    buckets: Vec<usize>,
) -> Result<(), BackendError> {
    let spec = backend.spec().clone();
    let policy = BatchPolicy::new(buckets, shared.max_wait);
    let (c, h, w) = spec.input_shape;
    let blank = Tensor::zeros(&[c, h, w]);

    loop {
        // Phase 1: take a batch decision under the queue lock.
        let (bucket, jobs) = {
            let mut st = lock_clean(&shared.state);
            loop {
                if st.jobs.is_empty() {
                    if !st.open {
                        return Ok(());
                    }
                    st = shared.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
                    continue;
                }
                let draining = !st.open;
                let deadline_hit = draining
                    || st
                        .jobs
                        .front()
                        .map(|(r, _)| r.enqueued.elapsed() >= shared.max_wait)
                        .unwrap_or(false);
                if let Some((bucket, take)) = policy.decide(st.jobs.len(), deadline_hit) {
                    let jobs: Vec<Job> = st.jobs.drain(..take).collect();
                    break (bucket, jobs);
                }
                if draining {
                    // Defensive: `decide` refused a non-empty queue during
                    // drain. Force the smallest bucket so shutdown always
                    // terminates instead of looping on `None`.
                    let bucket = policy.buckets[0];
                    let take = st.jobs.len().min(bucket);
                    let jobs: Vec<Job> = st.jobs.drain(..take).collect();
                    break (bucket, jobs);
                }
                // Policy wants to collect more; sleep until the oldest
                // request's deadline (new arrivals notify the condvar).
                let oldest = st
                    .jobs
                    .front()
                    .map(|(r, _)| r.enqueued.elapsed())
                    .unwrap_or_default();
                let budget = shared.max_wait.saturating_sub(oldest);
                let (guard, _) = shared
                    .cv
                    .wait_timeout(st, budget)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                st = guard;
            }
        };

        // Phase 2: run the batch with the lock released — this is where
        // N replicas overlap and the pool scales across cores.
        run_and_reply(backend, bucket, jobs, &blank, &shared.metrics);
        // We may have consumed the only pending wakeup; pass it on if
        // more work is queued.
        shared.cv.notify_one();
    }
}

fn run_and_reply(
    backend: &mut dyn InferenceBackend,
    bucket: usize,
    jobs: Vec<Job>,
    blank: &Tensor,
    metrics: &Mutex<Metrics>,
) {
    let take = jobs.len();
    let mut images: Vec<Tensor> = jobs.iter().map(|(r, _)| r.image.clone()).collect();
    images.resize(bucket, blank.clone());
    match backend.infer(&InferRequest::new(images)) {
        Ok(out) => {
            let mut m = lock_clean(metrics);
            m.record_batch(bucket, take);
            for ((req, done), lens) in jobs.into_iter().zip(out.lengths) {
                let resp = Response::from_lengths(req.id, lens, req.enqueued, bucket);
                m.record(resp.latency_us);
                done.deliver(resp, &mut m);
            }
        }
        Err(e) => {
            // Dropping the completions disconnects the per-request
            // channels (and aborts any single-flight leads, dropping
            // their coalesced waiters too), so each caller observes a
            // typed Unavailable error from `classify` — one bad batch
            // does not kill the replica.
            lock_clean(metrics).record_backend_errors(take as u64);
            eprintln!("[coordinator] backend error on batch of {take}: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::InferOutput;
    use std::sync::atomic::AtomicUsize;

    /// Deterministic toy backend: "lengths" encode the image's mean.
    struct ToyBackend {
        spec: BackendSpec,
        delay: Duration,
        calls: Arc<AtomicUsize>,
    }

    impl ToyBackend {
        fn new(delay: Duration, calls: Arc<AtomicUsize>) -> ToyBackend {
            ToyBackend {
                spec: BackendSpec {
                    kind: "toy".into(),
                    model: "toy".into(),
                    input_shape: (1, 4, 4),
                    batch_buckets: vec![1, 4],
                    reports_timing: false,
                    max_replicas: None,
                    compression: None,
                    fingerprint: 0,
                    routing: String::new(),
                    workers: 1,
                    coupling_fingerprint: None,
                },
                delay,
                calls,
            }
        }
    }

    impl InferenceBackend for ToyBackend {
        fn spec(&self) -> &BackendSpec {
            &self.spec
        }

        fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
            self.validate(req)?;
            self.calls.fetch_add(1, Ordering::Relaxed);
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            let lengths = req
                .images
                .iter()
                .map(|img| {
                    let m = img.sum() / img.len() as f32;
                    let mut l = vec![0.1f32; 10];
                    l[(m * 10.0) as usize % 10] = 0.9;
                    l
                })
                .collect();
            Ok(InferOutput::untimed(lengths))
        }
    }

    fn toy_server(delay: Duration, calls: Arc<AtomicUsize>) -> ServerBuilder {
        Server::builder(move || {
            Ok(Box::new(ToyBackend::new(delay, calls.clone())) as Box<dyn InferenceBackend>)
        })
    }

    #[test]
    fn serves_single_request() {
        let calls = Arc::new(AtomicUsize::new(0));
        let server = toy_server(Duration::ZERO, calls)
            .max_wait(Duration::from_millis(1))
            .start();
        assert_eq!(server.spec().unwrap().kind, "toy");
        let resp = server.classify(Tensor::full(&[1, 4, 4], 0.35)).unwrap();
        assert_eq!(resp.predicted, 3);
        assert!(resp.latency_us > 0);
        let m = server.shutdown();
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let calls = Arc::new(AtomicUsize::new(0));
        let server = toy_server(Duration::ZERO, calls)
            .max_wait(Duration::from_millis(20))
            .start();
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                server
                    .submit(Tensor::full(&[1, 4, 4], 0.1 * i as f32 % 1.0))
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let m = server.shutdown();
        assert_eq!(m.requests, 8);
        // 8 requests over buckets {1,4}: at most 8 batches, at least 2.
        assert!(m.batches >= 2 && m.batches <= 8, "batches {}", m.batches);
        assert!(m.mean_batch_size() >= 1.0);
    }

    #[test]
    fn drains_on_shutdown() {
        let calls = Arc::new(AtomicUsize::new(0));
        let server = toy_server(Duration::ZERO, calls)
            .max_wait(Duration::from_millis(50))
            .start();
        let rx = server.submit(Tensor::full(&[1, 4, 4], 0.2)).unwrap();
        let m = server.shutdown(); // must flush the pending request
        assert_eq!(m.requests, 1);
        assert!(rx.recv().is_ok());
    }

    #[test]
    fn failed_backend_reports_typed_error() {
        let server =
            Server::builder(|| Err(BackendError::Init("backend init failed".into()))).start();
        assert!(server.spec().is_none());
        assert!(matches!(
            server.init_error(),
            Some(BackendError::Init(_))
        ));
        match server.classify(Tensor::zeros(&[1, 4, 4])) {
            Err(BackendError::Unavailable(m)) => assert!(m.contains("init failed"), "{m}"),
            other => panic!("expected Unavailable, got {other:?}"),
        }
    }

    #[test]
    fn admission_rejection_fires_at_configured_depth() {
        let calls = Arc::new(AtomicUsize::new(0));
        let server = toy_server(Duration::from_millis(40), calls)
            .max_wait(Duration::from_micros(100))
            .max_queue_depth(2)
            .replicas(1)
            .start();
        // Burst faster than one slow replica can drain: queue holds at
        // most 2, so of 8 rapid submits at least 8 - (2 queued + a few
        // in flight) must be rejected with QueueFull{depth: 2}.
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..8 {
            match server.submit(Tensor::full(&[1, 4, 4], 0.1 * i as f32)) {
                Ok(rx) => accepted.push(rx),
                Err(BackendError::QueueFull { depth }) => {
                    assert_eq!(depth, 2);
                    rejected += 1;
                }
                Err(other) => panic!("unexpected error {other:?}"),
            }
        }
        assert!(rejected >= 1, "no admission rejection fired");
        for rx in accepted {
            rx.recv().unwrap(); // accepted work still completes
        }
        let m = server.shutdown();
        assert_eq!(m.rejected, rejected as u64);
        assert_eq!(m.requests + m.rejected, 8);
    }

    #[test]
    fn replica_pool_serves_all_requests() {
        let calls = Arc::new(AtomicUsize::new(0));
        let server = toy_server(Duration::from_millis(1), calls.clone())
            .max_wait(Duration::from_micros(200))
            .replicas(4)
            .start();
        assert!(server.live_replicas() >= 1);
        let rxs: Vec<_> = (0..32)
            .map(|_| server.submit(Tensor::full(&[1, 4, 4], 0.5)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let m = server.shutdown();
        assert_eq!(m.requests, 32);
        assert!(calls.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn max_batch_below_smallest_bucket_is_init_error() {
        struct BigBuckets(BackendSpec);
        impl InferenceBackend for BigBuckets {
            fn spec(&self) -> &BackendSpec {
                &self.0
            }
            fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
                Ok(InferOutput::untimed(vec![vec![0.5; 10]; req.batch()]))
            }
        }
        let server = Server::builder(|| {
            Ok(Box::new(BigBuckets(BackendSpec {
                kind: "big".into(),
                model: "big".into(),
                input_shape: (1, 4, 4),
                batch_buckets: vec![4, 8],
                reports_timing: false,
                max_replicas: None,
                compression: None,
                fingerprint: 0,
                routing: String::new(),
                workers: 1,
                coupling_fingerprint: None,
            })) as Box<dyn InferenceBackend>)
        })
        .max_batch(2)
        .start();
        match server.init_error() {
            Some(BackendError::Init(m)) => assert!(m.contains("max_batch"), "{m}"),
            other => panic!("expected Init error, got {other:?}"),
        }
    }

    #[test]
    fn panicking_backend_fails_fast_instead_of_hanging() {
        struct PanicBackend(BackendSpec);
        impl InferenceBackend for PanicBackend {
            fn spec(&self) -> &BackendSpec {
                &self.0
            }
            fn infer(&mut self, _req: &InferRequest) -> Result<InferOutput, BackendError> {
                panic!("backend bug");
            }
        }
        let server = Server::builder(|| {
            Ok(Box::new(PanicBackend(BackendSpec {
                kind: "panic".into(),
                model: "panic".into(),
                input_shape: (1, 4, 4),
                batch_buckets: vec![1],
                reports_timing: false,
                max_replicas: None,
                compression: None,
                fingerprint: 0,
                routing: String::new(),
                workers: 1,
                coupling_fingerprint: None,
            })) as Box<dyn InferenceBackend>)
        })
        .max_wait(Duration::from_millis(1))
        .start();
        // The in-flight request must error out (its sender unwinds with
        // the replica), not block forever.
        assert!(matches!(
            server.classify(Tensor::zeros(&[1, 4, 4])),
            Err(BackendError::Unavailable(_))
        ));
        // The dead pool closes the queue, so later submits fail fast too.
        let later = server.classify(Tensor::zeros(&[1, 4, 4]));
        assert!(matches!(later, Err(BackendError::Unavailable(_))));
        server.shutdown();
    }

    /// Panics on the `fail_on`-th infer call; serves normally before.
    struct DelayedPanicBackend {
        spec: BackendSpec,
        calls: usize,
        fail_on: usize,
    }

    impl DelayedPanicBackend {
        fn boxed(fail_on: usize) -> Box<dyn InferenceBackend> {
            Box::new(DelayedPanicBackend {
                spec: BackendSpec {
                    kind: "delayed-panic".into(),
                    model: "delayed-panic".into(),
                    input_shape: (1, 4, 4),
                    batch_buckets: vec![1],
                    reports_timing: false,
                    max_replicas: None,
                    compression: None,
                    fingerprint: 0,
                    routing: String::new(),
                    workers: 1,
                    coupling_fingerprint: None,
                },
                calls: 0,
                fail_on,
            })
        }
    }

    impl InferenceBackend for DelayedPanicBackend {
        fn spec(&self) -> &BackendSpec {
            &self.spec
        }
        fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
            self.calls += 1;
            if self.calls >= self.fail_on {
                panic!("backend bug on call {}", self.calls);
            }
            Ok(InferOutput::untimed(vec![vec![0.5; 10]; req.batch()]))
        }
    }

    #[test]
    fn dead_pool_drop_notifies_queued_waiters_and_rejects_new_work() {
        // One replica, bucket 1, first infer panics: requests that were
        // already queued must be drop-notified (recv fails fast), and
        // later admissions must get a typed error naming the dead pool —
        // nobody may hang on a queue no executor drains.
        let server = Server::builder(|| Ok(DelayedPanicBackend::boxed(1)))
            .max_wait(Duration::from_millis(1))
            .max_queue_depth(64)
            .start();
        let mut receivers = Vec::new();
        for _ in 0..6 {
            match server.submit(Tensor::zeros(&[1, 4, 4])) {
                // Accepted before the death was observed: the channel
                // must disconnect, never block forever.
                Ok(rx) => receivers.push(rx),
                // Submitted after the guard closed the queue.
                Err(BackendError::Unavailable(m)) => {
                    assert!(m.contains("died"), "wrong dead-pool message: {m}")
                }
                Err(other) => panic!("unexpected admission error {other:?}"),
            }
        }
        for rx in receivers {
            // Must be Disconnected (drop-notified), not Timeout — a
            // Timeout here is exactly the hang this test pins.
            assert!(
                matches!(
                    rx.recv_timeout(Duration::from_secs(5)),
                    Err(mpsc::RecvTimeoutError::Disconnected)
                ),
                "queued waiter was neither served nor drop-notified"
            );
        }
        // The death is now fully observable: flag, typed admission error,
        // and the abnormal-exit counter.
        assert!(server.pool_died());
        match server.classify(Tensor::zeros(&[1, 4, 4])) {
            Err(BackendError::Unavailable(m)) => {
                assert!(m.contains("died"), "wrong dead-pool message: {m}")
            }
            other => panic!("expected Unavailable, got {other:?}"),
        }
        let m = server.shutdown();
        assert_eq!(m.replicas_died, 1);
    }

    #[test]
    fn death_of_last_live_replica_spares_a_still_booting_one() {
        // Replica 0 (a panic backend) dies while replica 1 is still
        // inside its factory: the pool must NOT be declared dead — the
        // booting replica comes up and serves the queued work. Replica
        // 1's factory waits for the panic to have fired, so the
        // interleaving under test is deterministic, not timing-based.
        struct PanicAndFlag(BackendSpec, Arc<std::sync::atomic::AtomicBool>);
        impl InferenceBackend for PanicAndFlag {
            fn spec(&self) -> &BackendSpec {
                &self.0
            }
            fn infer(&mut self, _req: &InferRequest) -> Result<InferOutput, BackendError> {
                self.1.store(true, Ordering::SeqCst);
                panic!("backend bug");
            }
        }
        let built = Arc::new(AtomicUsize::new(0));
        let built2 = built.clone();
        let died = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let died2 = died.clone();
        let server = Server::builder(move || {
            if built2.fetch_add(1, Ordering::SeqCst) == 0 {
                let spec = BackendSpec {
                    kind: "panic-flag".into(),
                    model: "panic-flag".into(),
                    input_shape: (1, 4, 4),
                    batch_buckets: vec![1],
                    reports_timing: false,
                    max_replicas: None,
                    compression: None,
                    fingerprint: 0,
                    routing: String::new(),
                    workers: 1,
                    coupling_fingerprint: None,
                };
                Ok(Box::new(PanicAndFlag(spec, died2.clone())) as Box<dyn InferenceBackend>)
            } else {
                // Boot only after replica 0's panic began (bounded wait
                // so a regression fails instead of hanging the test).
                for _ in 0..500 {
                    if died2.load(Ordering::SeqCst) {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                std::thread::sleep(Duration::from_millis(50));
                Ok(Box::new(ToyBackend::new(
                    Duration::ZERO,
                    Arc::new(AtomicUsize::new(0)),
                )) as Box<dyn InferenceBackend>)
            }
        })
        .replicas(2)
        .max_wait(Duration::from_millis(1))
        .start();
        // First request rides replica 0 and dies with it.
        let first = server.submit(Tensor::zeros(&[1, 4, 4])).unwrap();
        assert!(
            matches!(
                first.recv_timeout(Duration::from_secs(5)),
                Err(mpsc::RecvTimeoutError::Disconnected)
            ),
            "in-flight request on the dying replica must disconnect"
        );
        // The pool is not dead: replica 1 is booting. This submit must
        // be accepted and eventually *served*, not cleared or rejected.
        let rx = server
            .submit(Tensor::full(&[1, 4, 4], 0.35))
            .expect("queue must stay open while a replica is booting");
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("booting replica never served the queued request");
        assert_eq!(resp.predicted, 3);
        assert!(!server.pool_died());
        let m = server.shutdown();
        assert_eq!(m.replicas_died, 1);
        assert_eq!(built.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn normal_shutdown_is_not_reported_as_pool_death() {
        let calls = Arc::new(AtomicUsize::new(0));
        let server = toy_server(Duration::ZERO, calls)
            .max_wait(Duration::from_millis(1))
            .replicas(2)
            .start();
        for _ in 0..4 {
            server.classify(Tensor::full(&[1, 4, 4], 0.5)).unwrap();
        }
        assert!(!server.pool_died());
        let m = server.shutdown();
        // The drain path must not be miscounted as replica death.
        assert_eq!(m.replicas_died, 0);
        assert_eq!(m.requests, 4);
    }

    #[test]
    fn metrics_snapshots_freeze_throughput() {
        // `Server::metrics`/`shutdown` return snapshots: the reported
        // RPS must not decay while the snapshot sits on the caller's
        // stack (the ISSUE 5 snapshot-decaying-RPS regression).
        let calls = Arc::new(AtomicUsize::new(0));
        let server = toy_server(Duration::ZERO, calls)
            .max_wait(Duration::from_millis(1))
            .start();
        for _ in 0..8 {
            server.classify(Tensor::full(&[1, 4, 4], 0.5)).unwrap();
        }
        let live = server.metrics();
        let r1 = live.throughput_rps();
        assert!(r1 > 0.0);
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(live.throughput_rps(), r1, "snapshot RPS decayed");
        let fin = server.shutdown();
        let r2 = fin.throughput_rps();
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(fin.throughput_rps(), r2, "final metrics RPS decayed");
    }

    #[test]
    fn replicas_clamped_by_backend_capability() {
        struct OneReplica(BackendSpec);
        impl InferenceBackend for OneReplica {
            fn spec(&self) -> &BackendSpec {
                &self.0
            }
            fn infer(&mut self, req: &InferRequest) -> Result<InferOutput, BackendError> {
                Ok(InferOutput::untimed(vec![vec![0.5; 10]; req.batch()]))
            }
        }
        let built = Arc::new(AtomicUsize::new(0));
        let built2 = built.clone();
        let server = Server::builder(move || {
            built2.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(OneReplica(BackendSpec {
                kind: "single".into(),
                model: "single".into(),
                input_shape: (1, 4, 4),
                batch_buckets: vec![1],
                reports_timing: false,
                max_replicas: Some(1),
                compression: None,
                fingerprint: 0,
                routing: String::new(),
                workers: 1,
                coupling_fingerprint: None,
            })) as Box<dyn InferenceBackend>)
        })
        .replicas(8)
        .start();
        // Give stragglers (if the clamp were broken) a moment to build.
        let _ = server.classify(Tensor::zeros(&[1, 4, 4])).unwrap();
        assert_eq!(built.load(Ordering::SeqCst), 1, "pool ignored max_replicas(1)");
        server.shutdown();
    }

    #[test]
    fn cache_hit_skips_the_backend_and_is_bit_identical() {
        let calls = Arc::new(AtomicUsize::new(0));
        let server = toy_server(Duration::ZERO, calls.clone())
            .max_wait(Duration::from_millis(1))
            .cache(CacheConfig::with_entries(64))
            .start();
        let img = Tensor::full(&[1, 4, 4], 0.35);
        let first = server.classify(img.clone()).unwrap();
        let backend_calls = calls.load(Ordering::Relaxed);
        let second = server.classify(img.clone()).unwrap();
        assert_eq!(
            calls.load(Ordering::Relaxed),
            backend_calls,
            "a cache hit must not reach the backend"
        );
        assert_eq!(
            first.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            second
                .lengths
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>(),
            "cached response must be bit-identical"
        );
        assert_eq!(second.predicted, first.predicted);
        assert_ne!(second.id, first.id, "hits keep their own request id");
        // A different input misses and runs the backend again.
        server.classify(Tensor::full(&[1, 4, 4], 0.65)).unwrap();
        assert!(calls.load(Ordering::Relaxed) > backend_calls);
        let m = server.shutdown();
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.cache_misses, 2);
        assert_eq!(m.cache_stale, 0);
        assert_eq!(m.requests, 3);
    }

    #[test]
    fn concurrent_identical_requests_coalesce_to_one_backend_call() {
        // A slow backend, one replica, bucket 1: the first request
        // opens a flight and holds the executor; the duplicates park on
        // the flight. Exactly one backend call serves all of them.
        let calls = Arc::new(AtomicUsize::new(0));
        let server = Arc::new(
            Server::builder({
                let calls = calls.clone();
                move || {
                    let mut b = ToyBackend::new(Duration::from_millis(100), calls.clone());
                    b.spec.batch_buckets = vec![1];
                    Ok(Box::new(b) as Box<dyn InferenceBackend>)
                }
            })
            .max_wait(Duration::from_millis(1))
            .cache(CacheConfig::with_entries(64))
            .start(),
        );
        let img = Tensor::full(&[1, 4, 4], 0.35);
        // Leader first, so the duplicates find its open flight.
        let lead_rx = server.submit(img.clone()).unwrap();
        let threads: Vec<_> = (0..7)
            .map(|_| {
                let server = server.clone();
                let img = img.clone();
                std::thread::spawn(move || server.classify(img).unwrap())
            })
            .collect();
        let lead_resp = lead_rx.recv().unwrap();
        for t in threads {
            let r = t.join().unwrap();
            assert_eq!(r.predicted, lead_resp.predicted);
            assert_eq!(
                r.lengths.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                lead_resp
                    .lengths
                    .iter()
                    .map(|x| x.to_bits())
                    .collect::<Vec<_>>(),
                "coalesced response must be bit-identical to the leader's"
            );
        }
        let server = Arc::into_inner(server).expect("all clones joined");
        let m = server.shutdown();
        assert_eq!(m.requests, 8);
        assert_eq!(m.cache_misses, 1, "exactly one flight leader");
        assert_eq!(
            m.cache_hits + m.cache_coalesced,
            7,
            "every duplicate was served without its own backend pass"
        );
        assert_eq!(
            calls.load(Ordering::Relaxed),
            1,
            "8 identical requests must cost one inference"
        );
    }

    #[test]
    fn failed_leader_fans_typed_error_to_coalesced_waiters() {
        // The backend fails every batch (typed error, replica survives).
        // The leader AND every waiter coalesced onto its flight must
        // observe the typed Unavailable — no waiter may hang on a flight
        // whose inference never produced a response.
        struct FailingBackend {
            spec: BackendSpec,
            gate: Arc<AtomicBool>,
        }
        impl InferenceBackend for FailingBackend {
            fn spec(&self) -> &BackendSpec {
                &self.spec
            }
            fn infer(&mut self, _req: &InferRequest) -> Result<InferOutput, BackendError> {
                while !self.gate.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(BackendError::Unavailable("accelerator fault".into()))
            }
        }
        let gate = Arc::new(AtomicBool::new(false));
        let server = Arc::new(
            Server::builder({
                let gate = gate.clone();
                move || {
                    let mut spec = ToyBackend::new(Duration::ZERO, Arc::default()).spec;
                    spec.batch_buckets = vec![1];
                    Ok(Box::new(FailingBackend {
                        spec,
                        gate: gate.clone(),
                    }) as Box<dyn InferenceBackend>)
                }
            })
            .max_wait(Duration::from_millis(1))
            .cache(CacheConfig::with_entries(64))
            .start(),
        );
        let img = Tensor::full(&[1, 4, 4], 0.5);
        let lead_rx = server.submit(img.clone()).unwrap();
        let waiters: Vec<_> = (0..3)
            .map(|_| {
                let server = server.clone();
                let img = img.clone();
                std::thread::spawn(move || server.classify(img))
            })
            .collect();
        // Let the duplicates coalesce before the leader's batch fails.
        std::thread::sleep(Duration::from_millis(50));
        gate.store(true, Ordering::SeqCst);
        assert!(
            matches!(
                lead_rx.recv_timeout(Duration::from_secs(5)),
                Err(mpsc::RecvTimeoutError::Disconnected)
            ),
            "leader must be drop-notified on batch failure"
        );
        for t in waiters {
            match t.join().unwrap() {
                Err(BackendError::Unavailable(_)) => {}
                other => panic!("waiter must see typed Unavailable, got {other:?}"),
            }
        }
        let server = Arc::into_inner(server).expect("all clones joined");
        let m = server.shutdown();
        assert!(m.backend_errors >= 1);
        assert_eq!(m.cache_stale, 0);
    }

    #[test]
    fn pool_death_drop_notifies_coalesced_waiters() {
        // Single replica panics on its first batch: the leader's flight
        // dies with the job queue, and every coalesced waiter must
        // disconnect — the cached flavor of
        // `dead_pool_drop_notifies_queued_waiters_and_rejects_new_work`.
        let server = Arc::new(
            Server::builder(|| Ok(DelayedPanicBackend::boxed(1)))
                .max_wait(Duration::from_millis(30))
                .cache(CacheConfig::with_entries(64))
                .start(),
        );
        let img = Tensor::full(&[1, 4, 4], 0.25);
        let mut rxs = Vec::new();
        for _ in 0..4 {
            match server.submit(img.clone()) {
                Ok(rx) => rxs.push(rx),
                Err(BackendError::Unavailable(_)) => {} // died already
                Err(other) => panic!("unexpected admission error {other:?}"),
            }
        }
        for rx in rxs {
            assert!(
                matches!(
                    rx.recv_timeout(Duration::from_secs(5)),
                    Err(mpsc::RecvTimeoutError::Disconnected)
                ),
                "coalesced waiter was neither served nor drop-notified"
            );
        }
        let server = Arc::into_inner(server).expect("sole owner");
        server.shutdown();
    }

    #[test]
    fn cache_accounting_stays_consistent_under_eviction_pressure() {
        // A 4-entry cache hammered with 32 distinct inputs from 4
        // threads: hits + misses + coalesced must equal requests, the
        // store stays bounded, and stale sightings stay impossible.
        let calls = Arc::new(AtomicUsize::new(0));
        let server = Arc::new(
            toy_server(Duration::ZERO, calls)
                .max_wait(Duration::from_millis(1))
                .cache(CacheConfig {
                    entries: 4,
                    shards: 2,
                })
                .start(),
        );
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let server = server.clone();
                std::thread::spawn(move || {
                    for i in 0..64u32 {
                        let v = ((t * 64 + i) % 32) as f32 / 40.0;
                        server.classify(Tensor::full(&[1, 4, 4], v)).unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let server = Arc::into_inner(server).expect("all clones joined");
        let store_len = server.cache_store().expect("cache enabled").len();
        assert!(store_len <= 4, "store exceeded capacity: {store_len}");
        let m = server.shutdown();
        assert_eq!(m.requests, 256);
        assert_eq!(
            m.cache_hits + m.cache_misses + m.cache_coalesced,
            m.requests,
            "every request must be exactly one of hit/miss/coalesced"
        );
        assert!(m.cache_evicted > 0, "32 keys through 4 entries must evict");
        assert_eq!(m.cache_stale, 0);
    }
}
