//! Server thread topology: clients → MPSC queue → service thread
//! (batcher + executor) → per-request response channels.
//!
//! The PJRT executable wraps raw PJRT pointers, so the service thread
//! *creates* its backend via a factory closure and owns it for its whole
//! life — nothing PJRT ever crosses a thread boundary.

use super::batcher::BatchPolicy;
use super::metrics::Metrics;
use super::{Request, Response};
use crate::tensor::Tensor;
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// What actually runs a batch: the PJRT engine set or the FPGA simulator.
pub trait Backend {
    /// Batch sizes this backend has engines for (ascending).
    fn buckets(&self) -> Vec<usize>;
    /// Run exactly `bucket` images (padded by the caller) and return
    /// lengths for each.
    fn run(&mut self, bucket: usize, images: &[Tensor]) -> Result<Vec<Vec<f32>>>;
    /// Input shape (C, H, W) for padding blanks.
    fn input_shape(&self) -> (usize, usize, usize);
}

type Job = (Request, mpsc::Sender<Response>);

/// Handle to a running server.
pub struct Server {
    tx: Option<mpsc::Sender<Job>>,
    handle: Option<JoinHandle<Result<()>>>,
    metrics: Arc<Mutex<Metrics>>,
    next_id: AtomicU64,
}

impl Server {
    /// Start the service thread. `make_backend` runs *on* that thread.
    pub fn start<F>(make_backend: F, max_wait: std::time::Duration) -> Server
    where
        F: FnOnce() -> Result<Box<dyn Backend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let m2 = metrics.clone();
        let handle = std::thread::Builder::new()
            .name("fastcaps-executor".into())
            .spawn(move || service_loop(rx, make_backend, m2, max_wait))
            .expect("spawning executor thread");
        Server {
            tx: Some(tx),
            handle: Some(handle),
            metrics,
            next_id: AtomicU64::new(1),
        }
    }

    /// Submit an image; returns the response channel.
    pub fn submit(&self, image: Tensor) -> mpsc::Receiver<Response> {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            enqueued: Instant::now(),
        };
        if let Some(tx) = &self.tx {
            // A send error means the service thread died; the receiver
            // will simply report disconnection to the caller.
            let _ = tx.send((req, rtx));
        }
        rrx
    }

    /// Submit and wait.
    pub fn classify(&self, image: Tensor) -> Result<Response> {
        self.submit(image)
            .recv()
            .map_err(|_| anyhow::anyhow!("server shut down before responding"))
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Drain and stop. Returns final metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.tx.take(); // close the queue
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn service_loop<F>(
    rx: mpsc::Receiver<Job>,
    make_backend: F,
    metrics: Arc<Mutex<Metrics>>,
    max_wait: std::time::Duration,
) -> Result<()>
where
    F: FnOnce() -> Result<Box<dyn Backend>>,
{
    let mut backend = make_backend()?;
    let policy = BatchPolicy::new(backend.buckets(), max_wait);
    let (c, h, w) = backend.input_shape();
    let blank = Tensor::zeros(&[c, h, w]);
    let mut queue: Vec<Job> = Vec::new();

    loop {
        // Fill the queue: blocking when empty, polling while collecting.
        if queue.is_empty() {
            match rx.recv() {
                Ok(job) => queue.push(job),
                Err(_) => return Ok(()), // all senders gone, drained
            }
        }
        // Drain everything already sitting in the channel — under backlog
        // the batcher must see the whole queue, or it degenerates to b=1.
        while let Ok(job) = rx.try_recv() {
            queue.push(job);
        }
        // Collect more until the policy ships or the deadline passes.
        loop {
            let deadline_hit = queue
                .first()
                .map(|(r, _)| r.enqueued.elapsed() >= max_wait)
                .unwrap_or(false);
            if let Some((bucket, take)) = policy.decide(queue.len(), deadline_hit) {
                let jobs: Vec<Job> = queue.drain(..take).collect();
                run_and_reply(&mut *backend, bucket, jobs, &blank, &metrics)?;
                break;
            }
            // Wait for one more request (bounded by the oldest deadline).
            let budget = max_wait
                .checked_sub(queue[0].0.enqueued.elapsed())
                .unwrap_or_default();
            match rx.recv_timeout(budget) {
                Ok(job) => queue.push(job),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Drain what's left, then exit.
                    while !queue.is_empty() {
                        let deadline = true;
                        if let Some((bucket, take)) =
                            policy.decide(queue.len(), deadline)
                        {
                            let jobs: Vec<Job> = queue.drain(..take).collect();
                            run_and_reply(&mut *backend, bucket, jobs, &blank, &metrics)?;
                        }
                    }
                    return Ok(());
                }
            }
        }
    }
}

fn run_and_reply(
    backend: &mut dyn Backend,
    bucket: usize,
    jobs: Vec<Job>,
    blank: &Tensor,
    metrics: &Arc<Mutex<Metrics>>,
) -> Result<()> {
    let take = jobs.len();
    let mut images: Vec<Tensor> = jobs.iter().map(|(r, _)| r.image.clone()).collect();
    while images.len() < bucket {
        images.push(blank.clone());
    }
    let lengths = backend.run(bucket, &images)?;
    let mut m = metrics.lock().unwrap();
    m.record_batch(bucket, take);
    for ((req, rtx), lens) in jobs.into_iter().zip(lengths) {
        let resp = Response::from_lengths(req.id, lens, req.enqueued, bucket);
        m.record(resp.latency_us);
        let _ = rtx.send(resp); // receiver may have gone away; fine
    }
    Ok(())
}

/// A backend that serves through the FPGA simulator's functional path —
/// used by tests and by `fastcaps serve --backend sim`.
pub struct SimBackend {
    pub model: crate::fpga::DeployedModel,
}

impl Backend for SimBackend {
    fn buckets(&self) -> Vec<usize> {
        vec![1, 8]
    }

    fn run(&mut self, _bucket: usize, images: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        images
            .iter()
            .map(|img| self.model.run_frame(img).map(|(_, l, _)| l))
            .collect()
    }

    fn input_shape(&self) -> (usize, usize, usize) {
        self.model.config.model.input
    }
}

/// A backend over loaded PJRT engines (one per bucket).
pub struct PjrtBackend {
    pub engines: Vec<crate::runtime::Engine>,
    pub shape: (usize, usize, usize),
}

impl PjrtBackend {
    pub fn new(engines: Vec<crate::runtime::Engine>) -> Result<PjrtBackend> {
        anyhow::ensure!(!engines.is_empty(), "need at least one engine");
        let s = &engines[0].entry.input_shape;
        anyhow::ensure!(s.len() == 4, "expected NCHW input shape");
        Ok(PjrtBackend {
            shape: (s[1], s[2], s[3]),
            engines,
        })
    }
}

impl Backend for PjrtBackend {
    fn buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.engines.iter().map(|e| e.batch_size()).collect();
        b.sort_unstable();
        b
    }

    fn run(&mut self, bucket: usize, images: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let engine = self
            .engines
            .iter()
            .find(|e| e.batch_size() == bucket)
            .ok_or_else(|| anyhow::anyhow!("no engine for bucket {bucket}"))?;
        engine.run_batch(images)
    }

    fn input_shape(&self) -> (usize, usize, usize) {
        self.shape
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Deterministic toy backend: "lengths" encode the image's mean.
    struct ToyBackend {
        calls: usize,
    }

    impl Backend for ToyBackend {
        fn buckets(&self) -> Vec<usize> {
            vec![1, 4]
        }

        fn run(&mut self, _bucket: usize, images: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            self.calls += 1;
            Ok(images
                .iter()
                .map(|img| {
                    let m = img.sum() / img.len() as f32;
                    let mut l = vec![0.1f32; 10];
                    l[(m * 10.0) as usize % 10] = 0.9;
                    l
                })
                .collect())
        }

        fn input_shape(&self) -> (usize, usize, usize) {
            (1, 4, 4)
        }
    }

    #[test]
    fn serves_single_request() {
        let server = Server::start(
            || Ok(Box::new(ToyBackend { calls: 0 }) as Box<dyn Backend>),
            Duration::from_millis(1),
        );
        let resp = server.classify(Tensor::full(&[1, 4, 4], 0.35)).unwrap();
        assert_eq!(resp.predicted, 3);
        assert!(resp.latency_us > 0);
        let m = server.shutdown();
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = Server::start(
            || Ok(Box::new(ToyBackend { calls: 0 }) as Box<dyn Backend>),
            Duration::from_millis(20),
        );
        let rxs: Vec<_> = (0..8)
            .map(|i| server.submit(Tensor::full(&[1, 4, 4], 0.1 * i as f32 % 1.0)))
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let m = server.shutdown();
        assert_eq!(m.requests, 8);
        // 8 requests over buckets {1,4}: at most 8 batches, at least 2.
        assert!(m.batches >= 2 && m.batches <= 8, "batches {}", m.batches);
        assert!(m.mean_batch_size() >= 1.0);
    }

    #[test]
    fn drains_on_shutdown() {
        let server = Server::start(
            || Ok(Box::new(ToyBackend { calls: 0 }) as Box<dyn Backend>),
            Duration::from_millis(50),
        );
        let rx = server.submit(Tensor::full(&[1, 4, 4], 0.2));
        let m = server.shutdown(); // must flush the pending request
        assert_eq!(m.requests, 1);
        assert!(rx.recv().is_ok());
    }

    #[test]
    fn failed_backend_reports() {
        let server = Server::start(
            || anyhow::bail!("backend init failed"),
            Duration::from_millis(1),
        );
        let resp = server.classify(Tensor::zeros(&[1, 4, 4]));
        assert!(resp.is_err());
    }
}
