//! Network serving front-end: listener, drain orchestration, and the
//! tag-aware client for the wire protocol.
//!
//! ```text
//!              ┌ acceptor thread (nonblocking accept + stop flag)
//!  TcpListener ┤        round-robin
//!              └──► IO shards (event_loop.rs): N threads, each owning
//!                   a set of nonblocking connections multiplexed with
//!                   poll(2), submitting into the executor pool and
//!                   writing completions back as they land
//! ```
//!
//! * **Protocol.** v1 clients ([`Connection::v1_compat`]) keep the
//!   strict in-order response stream; v2 clients ([`Connection::connect`])
//!   tag every request and may receive completions out of order. The
//!   version is negotiated per connection from the first frame — see
//!   [`super::event_loop`] for the server-side state machine and
//!   [`super::wire`] for the frame layout.
//! * **Validation.** Each classify payload is checked against the
//!   backend's [`BackendSpec::input_shape`](crate::backend::BackendSpec)
//!   *before* admission: a wrong-sized image gets a typed
//!   [`ErrorCode::InvalidRequest`] frame and the connection stays
//!   usable. Admission rejections (`QueueFull`) and a dead pool
//!   (`Unavailable`) surface the same way instead of hanging the client.
//! * **Backpressure.** Responses buffer per connection, bounded by
//!   [`NetConfig::max_write_buffer`]: a peer that stops reading loses
//!   read service at half the budget and is disconnected on overflow
//!   (`net_slow_client_drops`); replica threads never block on a socket.
//! * **Drain.** [`NetServer::shutdown`] stops accepting, lets every
//!   in-flight request finish and flush, closes connections, joins the
//!   shards, and only then drains the executor pool. A client can
//!   request the same drain over the wire with a
//!   [`FrameType::Shutdown`] frame ([`Connection::shutdown_server`]);
//!   `fastcaps serve --listen` blocks on
//!   [`NetServer::wait_shutdown_requested`] for exactly that.
//! * **Probes.** The same listener answers plaintext `HEALTH`/`READY`
//!   probes and a `METRICS` exposition dump (also as HTTP `GET
//!   /healthz`, `/readyz`, `/metrics`) for load balancers and scrapers.

use super::event_loop::{spawn_shard, ShardHandle};
use super::metrics::Metrics;
use super::server::Server;
use super::wire::{self, ErrorCode, FrameType, ServerFrame, WireError, WireResponse};
use crate::backend::BackendError;
use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Tuning knobs for the network front-end.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// IO shard threads; each owns a subset of the connections.
    pub io_shards: usize,
    /// Per-connection cap on buffered-but-unwritten response bytes.
    /// Read service stops at half this; overflow disconnects the
    /// connection and bumps `net_slow_client_drops`.
    pub max_write_buffer: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            io_shards: 2,
            max_write_buffer: 1 << 20,
        }
    }
}

/// State shared by the acceptor, the IO shards, and the front-end
/// handle.
pub(crate) struct NetShared {
    pub(crate) server: Server,
    pub(crate) input_shape: (usize, usize, usize),
    /// Exact classify-payload size (`BackendSpec::input_wire_bytes`):
    /// the spec-driven shape check at the wire boundary.
    pub(crate) expected_bytes: u32,
    /// Tells the acceptor to stop; set by [`NetServer::shutdown`]/Drop.
    pub(crate) stop: AtomicBool,
    /// Tells the shards to drain: finish in-flight work, flush, close.
    pub(crate) draining: AtomicBool,
    /// Set when a wire `Shutdown` frame (or local call) requests a
    /// graceful drain; `serve --listen` blocks on it.
    drain_requested: Mutex<bool>,
    drain_cv: Condvar,
    pub(crate) max_wbuf: usize,
    pub(crate) next_conn: AtomicU64,
}

impl NetShared {
    pub(crate) fn request_shutdown(&self) {
        *self.drain_requested.lock().unwrap() = true;
        self.drain_cv.notify_all();
    }

    /// Readiness for the `READY`/`/readyz` probe: serving, no drain
    /// requested or in progress, and at least one live executor
    /// replica. Flips to not-ready the moment a drain is *requested*
    /// (wire `Shutdown` frame or API), so load balancers stop routing
    /// new work while in-flight requests finish.
    pub(crate) fn ready(&self) -> bool {
        !self.draining.load(Ordering::SeqCst)
            && !*self.drain_requested.lock().unwrap()
            && self.server.live_replicas() > 0
    }
}

/// TCP front-end over a running [`Server`]. Owns the server: dropping
/// or [`shutdown`](NetServer::shutdown)ting the front-end drains the
/// pool too.
pub struct NetServer {
    inner: Option<Arc<NetShared>>,
    acceptor: Option<JoinHandle<()>>,
    shards: Vec<ShardHandle>,
    shard_joins: Vec<JoinHandle<()>>,
    local_addr: SocketAddr,
}

impl NetServer {
    /// Bind with default [`NetConfig`]. `addr` may use port 0 for an
    /// OS-assigned port ([`NetServer::local_addr`] reports it). A
    /// server whose backend never initialized is rejected here — there
    /// is nothing to serve.
    pub fn bind(addr: &str, server: Server) -> Result<NetServer, BackendError> {
        NetServer::bind_with(addr, server, NetConfig::default())
    }

    /// Bind with explicit shard count and write-buffer bound.
    pub fn bind_with(
        addr: &str,
        server: Server,
        cfg: NetConfig,
    ) -> Result<NetServer, BackendError> {
        if let Some(e) = server.init_error() {
            return Err(BackendError::Unavailable(format!(
                "refusing to listen for a backend that never started: {e}"
            )));
        }
        let spec = server
            .spec()
            .ok_or_else(|| BackendError::Unavailable("server has no backend spec".into()))?;
        let input_shape = spec.input_shape;
        let expected_bytes = spec.input_wire_bytes() as u32;
        let listener = TcpListener::bind(addr)
            .map_err(|e| BackendError::Init(format!("bind {addr}: {e}")))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| BackendError::Init(format!("local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| BackendError::Init(format!("set_nonblocking: {e}")))?;

        let shared = Arc::new(NetShared {
            server,
            input_shape,
            expected_bytes,
            stop: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            drain_requested: Mutex::new(false),
            drain_cv: Condvar::new(),
            max_wbuf: cfg.max_write_buffer.max(4096),
            next_conn: AtomicU64::new(0),
        });
        let mut shards = Vec::new();
        let mut shard_joins = Vec::new();
        for idx in 0..cfg.io_shards.max(1) {
            let (handle, join) = spawn_shard(idx, shared.clone())
                .map_err(|e| BackendError::Init(format!("spawning IO shard {idx}: {e}")))?;
            shards.push(handle);
            shard_joins.push(join);
        }
        let acceptor = {
            let shared = shared.clone();
            let shards = shards.clone();
            std::thread::Builder::new()
                .name("fastcaps-net-acceptor".into())
                .spawn(move || accept_loop(listener, &shared, &shards))
                .expect("spawning acceptor thread")
        };
        Ok(NetServer {
            inner: Some(shared),
            acceptor: Some(acceptor),
            shards,
            shard_joins,
            local_addr,
        })
    }

    /// Address the listener is bound to (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// IO shard threads serving this listener.
    pub fn io_shards(&self) -> usize {
        self.shards.len()
    }

    /// The wrapped server, e.g. for in-process submits alongside the
    /// socket path (benches compare the two).
    pub fn server(&self) -> &Server {
        &self.shared().server
    }

    /// Whether a graceful drain has been requested (wire `Shutdown`
    /// frame or [`NetServer::request_shutdown`]).
    pub fn shutdown_requested(&self) -> bool {
        *self.shared().drain_requested.lock().unwrap()
    }

    /// Ask for a graceful drain (same effect as a wire `Shutdown`
    /// frame): wakes [`NetServer::wait_shutdown_requested`] waiters.
    pub fn request_shutdown(&self) {
        self.shared().request_shutdown();
    }

    /// Block until a graceful drain is requested.
    pub fn wait_shutdown_requested(&self) {
        let shared = self.shared();
        let mut requested = shared.drain_requested.lock().unwrap();
        while !*requested {
            requested = shared.drain_cv.wait(requested).unwrap();
        }
    }

    fn shared(&self) -> &Arc<NetShared> {
        self.inner.as_ref().expect("NetServer already shut down")
    }

    /// Graceful drain: stop accepting, finish every request already
    /// read off a connection, flush and close connections, join the
    /// shards, then drain and stop the executor pool. Returns the final
    /// (frozen) metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.begin_drain();
        let inner = self.inner.take().expect("drained once");
        match Arc::try_unwrap(inner) {
            Ok(shared) => shared.server.shutdown(),
            // Unreachable once every thread is joined, but never panic
            // in a shutdown path: fall back to a snapshot.
            Err(arc) => arc.server.metrics(),
        }
    }

    fn begin_drain(&mut self) {
        let Some(shared) = self.inner.as_ref() else {
            return;
        };
        shared.stop.store(true, Ordering::SeqCst);
        shared.request_shutdown(); // unblock wait_shutdown_requested
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        shared.draining.store(true, Ordering::SeqCst);
        for s in &self.shards {
            s.wake();
        }
        for h in self.shard_joins.drain(..) {
            let _ = h.join();
        }
        self.shards.clear();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.begin_drain();
        // The pool itself drains via the Server's own Drop when the
        // last Arc<NetShared> reference goes away.
    }
}

fn accept_loop(listener: TcpListener, shared: &Arc<NetShared>, shards: &[ShardHandle]) {
    let mut next = 0usize;
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Round-robin handoff; the owning shard does the rest
                // (nonblocking mode, counters, protocol sniffing).
                shards[next % shards.len()].accept(stream);
                next = next.wrapping_add(1);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => {
                // Transient accept failure (EMFILE, aborted handshake):
                // keep serving the connections we have.
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

// ---------------------------------------------------------------------
// client

/// Tag-aware blocking client for the wire protocol.
///
/// Three usage shapes:
/// * lockstep — [`Connection::classify`] round-trips one image;
/// * pipelined in-order — [`Connection::submit`] N times, then
///   [`Connection::recv`] N times;
/// * out-of-order (v2 only) — [`Connection::submit`] freely and match
///   responses to requests by the returned tag via [`Connection::recv`]
///   or the non-blocking [`Connection::poll`].
///
/// [`Connection::connect`] speaks v2; [`Connection::v1_compat`] keeps
/// the untagged v1 dialect (strict in-order responses) for old servers
/// and for pinning the v1 path in tests. All failures — transport,
/// protocol, and typed server rejections — surface as one
/// [`WireError`], whose `code` round-trips the server's taxonomy
/// losslessly.
pub struct Connection {
    stream: TcpStream,
    rbuf: Vec<u8>,
    version: u8,
    next_tag: u64,
    /// v1 responses are untagged: tags are assigned client-side in send
    /// order and consumed FIFO as responses arrive.
    pending_v1: VecDeque<u64>,
}

impl Connection {
    /// Connect speaking wire protocol v2 (tagged, out-of-order).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Connection, WireError> {
        Connection::connect_with(addr, wire::V2)
    }

    /// Connect speaking wire protocol v1 (untagged, strict in-order) —
    /// the exact semantics of the pre-v2 client.
    pub fn v1_compat<A: ToSocketAddrs>(addr: A) -> Result<Connection, WireError> {
        Connection::connect_with(addr, wire::VERSION)
    }

    /// Connect with an explicit protocol version (1 or 2).
    pub fn connect_with<A: ToSocketAddrs>(addr: A, version: u8) -> Result<Connection, WireError> {
        if version != wire::VERSION && version != wire::V2 {
            return Err(WireError::protocol(format!(
                "unsupported wire protocol version {version} (want {} or {})",
                wire::VERSION,
                wire::V2
            )));
        }
        let stream = TcpStream::connect(addr).map_err(|e| WireError::io(&e))?;
        let _ = stream.set_nodelay(true);
        Ok(Connection {
            stream,
            rbuf: Vec::new(),
            version,
            next_tag: 0,
            pending_v1: VecDeque::new(),
        })
    }

    /// The wire protocol version this connection speaks (1 or 2).
    pub fn protocol_version(&self) -> u8 {
        self.version
    }

    /// Bound how long [`Connection::recv`] may block (None = forever).
    /// Tests use this so a server regression fails instead of hanging.
    pub fn set_read_timeout(&self, dur: Option<Duration>) -> Result<(), WireError> {
        self.stream
            .set_read_timeout(dur)
            .map_err(|e| WireError::io(&e))
    }

    /// Send one classify request without waiting for the response.
    /// Returns the request's tag; on v2 the server echoes it on the
    /// matching response, on v1 it is the client-side sequence number
    /// responses will be matched against in order.
    pub fn submit(&mut self, image: &Tensor) -> Result<u64, WireError> {
        let tag = self.next_tag;
        self.next_tag += 1;
        let frame = wire::encode_classify(self.version, tag, &image.data);
        self.stream
            .write_all(&frame)
            .map_err(|e| WireError::io(&e))?;
        if self.version == wire::VERSION {
            self.pending_v1.push_back(tag);
        }
        Ok(tag)
    }

    /// Receive the next response the server has ready, blocking (up to
    /// the read timeout). A typed error frame becomes `Err` with the
    /// offending request's tag; the connection stays usable for
    /// recoverable codes (`QueueFull`, `InvalidRequest`, `Unavailable`).
    pub fn recv(&mut self) -> Result<(u64, WireResponse), WireError> {
        let (tag, frame) = self.next_frame()?;
        self.finish_frame(tag, frame)
    }

    /// Non-blocking receive: `Ok(None)` when no complete response is
    /// buffered or readable right now.
    pub fn poll(&mut self) -> Result<Option<(u64, WireResponse)>, WireError> {
        if let Some((tag, frame)) = self.take_frame()? {
            return self.finish_frame(tag, frame).map(Some);
        }
        self.stream
            .set_nonblocking(true)
            .map_err(|e| WireError::io(&e))?;
        let mut io_err: Option<WireError> = None;
        let mut buf = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    io_err = Some(WireError::new(
                        ErrorCode::Io,
                        "connection closed by server",
                        None,
                    ));
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&buf[..n]);
                    if !matches!(wire::scan_frame(&self.rbuf), Ok(None)) {
                        break; // a full frame (or a fault the scan will report)
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    io_err = Some(WireError::io(&e));
                    break;
                }
            }
        }
        let restore = self.stream.set_nonblocking(false);
        if let Some(e) = io_err {
            return Err(e);
        }
        restore.map_err(|e| WireError::io(&e))?;
        match self.take_frame()? {
            Some((tag, frame)) => self.finish_frame(tag, frame).map(Some),
            None => Ok(None),
        }
    }

    /// Round-trip one image (lockstep convenience). Errors if the
    /// server answers a different outstanding tag — mixing `classify`
    /// with un-received `submit`s is not supported; drain with
    /// [`Connection::recv`] first.
    pub fn classify(&mut self, image: &Tensor) -> Result<WireResponse, WireError> {
        let tag = self.submit(image)?;
        let (got, resp) = self.recv()?;
        if got != tag {
            return Err(WireError::protocol(format!(
                "response tag {got} does not answer the classify request (tag {tag}); \
                 drain pipelined submits with recv() before using classify()"
            )));
        }
        Ok(resp)
    }

    /// Ask the server for a graceful drain and wait for the
    /// acknowledgement. Responses to outstanding requests are drained
    /// (and discarded) on the way; the server sends the ack only after
    /// answering everything this connection submitted.
    pub fn shutdown_server(mut self) -> Result<(), WireError> {
        let frame = wire::encode_empty(self.version, FrameType::Shutdown);
        self.stream
            .write_all(&frame)
            .map_err(|e| WireError::io(&e))?;
        loop {
            let (_, frame) = self.next_frame()?;
            if matches!(frame, ServerFrame::ShutdownAck) {
                return Ok(());
            }
        }
    }

    /// Scan one complete frame out of the receive buffer, if present.
    fn take_frame(&mut self) -> Result<Option<(Option<u64>, ServerFrame)>, WireError> {
        match wire::scan_frame(&self.rbuf) {
            Ok(None) => Ok(None),
            Ok(Some(f)) => {
                if f.version != self.version {
                    return Err(WireError::protocol(format!(
                        "server answered protocol v{} on a v{} connection",
                        f.version, self.version
                    )));
                }
                let payload = &self.rbuf[wire::HEADER_LEN..f.total_len];
                let (tag, frame) = wire::decode_server_payload(f.version, f.ty, payload)?;
                self.rbuf.drain(..f.total_len);
                Ok(Some((tag, frame)))
            }
            Err(fault) => Err(fault.into()),
        }
    }

    /// Blocking read until one complete frame is buffered.
    fn next_frame(&mut self) -> Result<(Option<u64>, ServerFrame), WireError> {
        loop {
            if let Some(f) = self.take_frame()? {
                return Ok(f);
            }
            let mut buf = [0u8; 16 * 1024];
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    return Err(WireError::new(
                        ErrorCode::Io,
                        "connection closed by server",
                        None,
                    ))
                }
                Ok(n) => self.rbuf.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Err(WireError::new(
                        ErrorCode::Io,
                        "read timed out waiting for a response",
                        None,
                    ))
                }
                Err(e) => return Err(WireError::io(&e)),
            }
        }
    }

    /// Resolve one decoded frame into the caller-facing result: attach
    /// the tag (v2 echoes it, v1 consumes the client-side FIFO), and
    /// turn error frames into typed [`WireError`]s.
    fn finish_frame(
        &mut self,
        tag: Option<u64>,
        frame: ServerFrame,
    ) -> Result<(u64, WireResponse), WireError> {
        let tag = match tag {
            // Connection-level v2 error: not tied to any request.
            Some(wire::CONN_TAG) => {
                return match frame {
                    ServerFrame::Error { code, message } => {
                        Err(WireError::new(code, message, None))
                    }
                    _ => Err(WireError::protocol(
                        "server sent a non-error frame on the connection tag",
                    )),
                };
            }
            Some(t) => t,
            None => match frame {
                ServerFrame::ShutdownAck => {
                    return Err(WireError::protocol(
                        "unexpected shutdown ack (no shutdown was requested)",
                    ))
                }
                _ => match self.pending_v1.pop_front() {
                    Some(t) => t,
                    // An untagged error with nothing outstanding is a
                    // connection-level fault (e.g. desync report).
                    None => {
                        return match frame {
                            ServerFrame::Error { code, message } => {
                                Err(WireError::new(code, message, None))
                            }
                            _ => Err(WireError::protocol(
                                "server sent a response with no request outstanding",
                            )),
                        }
                    }
                },
            },
        };
        match frame {
            ServerFrame::Response(resp) => Ok((tag, resp)),
            ServerFrame::Error { code, message } => Err(WireError::new(code, message, Some(tag))),
            ServerFrame::ShutdownAck => Err(WireError::protocol(
                "unexpected shutdown ack (no shutdown was requested)",
            )),
        }
    }
}
