//! Network serving front-end: a `TcpListener` over the executor pool,
//! making the coordinator reachable from processes that are not
//! `fastcaps` (the paper's serving story — edge FPGAs answering real
//! request traffic — rather than threads calling `Server::submit`
//! in-process).
//!
//! ```text
//!             ┌ acceptor thread (nonblocking accept + stop flag)
//!  TcpListener┤
//!             └ per connection: reader thread ──► writer thread
//!                  │ decode frame (wire.rs)        │ in request order:
//!                  │ validate vs BackendSpec       │ recv() response,
//!                  │ Server::submit ───────────────► write Response /
//!                  │   (bounded admission queue)     typed Error frame
//! ```
//!
//! * **Ordering.** The reader forwards one [`Reply`] per request into an
//!   in-order channel the writer drains, so responses stream back in
//!   request order even though the pool executes batches concurrently —
//!   clients may pipeline without tagging requests.
//! * **Validation.** The reader checks each classify payload against the
//!   backend's [`BackendSpec::input_shape`](crate::backend::BackendSpec)
//!   *before* admission: a wrong-sized image gets a typed
//!   [`ErrorCode::InvalidRequest`] frame and the connection stays
//!   usable. Admission rejections (`QueueFull`) and a dead pool
//!   (`Unavailable`) surface the same way instead of hanging the client.
//! * **Drain.** [`NetServer::shutdown`] stops accepting, shuts the read
//!   side of every connection (no new requests), lets writers finish
//!   every in-flight response, joins all threads, and only then drains
//!   and stops the executor pool. A client can request the same drain
//!   over the wire with a [`FrameType::Shutdown`] frame
//!   ([`NetClient::shutdown_server`]); `fastcaps serve --listen` blocks
//!   on [`NetServer::wait_shutdown_requested`] for exactly that.
//! * **Counters.** Per-connection request/error counts are folded into
//!   the shared [`Metrics`] when the connection closes
//!   (`connections_opened/closed`, `wire_requests`, `wire_errors`).

use super::metrics::Metrics;
use super::server::Server;
use super::wire::{self, ErrorCode, Fault, FrameType, ServerFrame, WireResponse};
use super::Response;
use crate::backend::BackendError;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Per-connection cap on decoded-but-unwritten replies. A client that
/// pipelines without reading responses fills this, then the writer's
/// TCP send buffer; the reader then blocks in `send` instead of growing
/// server memory — backpressure ends at the client's own socket.
const REPLY_WINDOW: usize = 256;

/// Upper bound on any single response write. A peer that stops reading
/// (but keeps the connection alive) would otherwise block the writer —
/// and therefore drain — forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// One in-order slot in a connection's response stream.
enum Reply {
    /// A response the executor pool will produce.
    Pending(mpsc::Receiver<Response>),
    /// A typed error produced at the wire/admission boundary.
    Reject(ErrorCode, String),
    /// Acknowledge a graceful-drain request.
    Ack,
}

struct NetShared {
    server: Server,
    input_shape: (usize, usize, usize),
    /// Exact classify-payload size (`BackendSpec::input_wire_bytes`):
    /// the spec-driven shape check at the wire boundary.
    expected_bytes: u32,
    /// Tells the acceptor to stop; set by [`NetServer::shutdown`]/Drop.
    stop: AtomicBool,
    /// Set when a wire `Shutdown` frame (or local call) requests a
    /// graceful drain; `serve --listen` blocks on it.
    drain_requested: Mutex<bool>,
    drain_cv: Condvar,
    /// Read-half handles of live connections, keyed by connection id,
    /// so drain can unblock readers mid-`read`.
    conns: Mutex<HashMap<u64, TcpStream>>,
    /// Join handles of spawned connection handler threads.
    handlers: Mutex<Vec<JoinHandle<()>>>,
    next_conn: AtomicU64,
}

impl NetShared {
    fn request_shutdown(&self) {
        *self.drain_requested.lock().unwrap() = true;
        self.drain_cv.notify_all();
    }
}

/// TCP front-end over a running [`Server`]. Owns the server: dropping
/// or [`shutdown`](NetServer::shutdown)ting the front-end drains the
/// pool too.
pub struct NetServer {
    inner: Option<Arc<NetShared>>,
    acceptor: Option<JoinHandle<()>>,
    local_addr: SocketAddr,
}

impl NetServer {
    /// Bind a listener and start accepting. `addr` may use port 0 for
    /// an OS-assigned port ([`NetServer::local_addr`] reports it). A
    /// server whose backend never initialized is rejected here — there
    /// is nothing to serve.
    pub fn bind(addr: &str, server: Server) -> Result<NetServer, BackendError> {
        if let Some(e) = server.init_error() {
            return Err(BackendError::Unavailable(format!(
                "refusing to listen for a backend that never started: {e}"
            )));
        }
        let spec = server
            .spec()
            .ok_or_else(|| BackendError::Unavailable("server has no backend spec".into()))?;
        let input_shape = spec.input_shape;
        let expected_bytes = spec.input_wire_bytes() as u32;
        let listener = TcpListener::bind(addr)
            .map_err(|e| BackendError::Init(format!("bind {addr}: {e}")))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| BackendError::Init(format!("local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| BackendError::Init(format!("set_nonblocking: {e}")))?;

        let shared = Arc::new(NetShared {
            server,
            input_shape,
            expected_bytes,
            stop: AtomicBool::new(false),
            drain_requested: Mutex::new(false),
            drain_cv: Condvar::new(),
            conns: Mutex::new(HashMap::new()),
            handlers: Mutex::new(Vec::new()),
            next_conn: AtomicU64::new(0),
        });
        let acceptor = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("fastcaps-net-acceptor".into())
                .spawn(move || accept_loop(listener, &shared))
                .expect("spawning acceptor thread")
        };
        Ok(NetServer {
            inner: Some(shared),
            acceptor: Some(acceptor),
            local_addr,
        })
    }

    /// Address the listener is bound to (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The wrapped server, e.g. for in-process submits alongside the
    /// socket path (benches compare the two).
    pub fn server(&self) -> &Server {
        &self.shared().server
    }

    /// Whether a graceful drain has been requested (wire `Shutdown`
    /// frame or [`NetServer::request_shutdown`]).
    pub fn shutdown_requested(&self) -> bool {
        *self.shared().drain_requested.lock().unwrap()
    }

    /// Ask for a graceful drain (same effect as a wire `Shutdown`
    /// frame): wakes [`NetServer::wait_shutdown_requested`] waiters.
    pub fn request_shutdown(&self) {
        self.shared().request_shutdown();
    }

    /// Block until a graceful drain is requested.
    pub fn wait_shutdown_requested(&self) {
        let shared = self.shared();
        let mut requested = shared.drain_requested.lock().unwrap();
        while !*requested {
            requested = shared.drain_cv.wait(requested).unwrap();
        }
    }

    fn shared(&self) -> &Arc<NetShared> {
        self.inner.as_ref().expect("NetServer already shut down")
    }

    /// Graceful drain: stop accepting, finish every request already
    /// read off a connection, close connections, then drain and stop
    /// the executor pool. Returns the final (frozen) metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.begin_drain();
        let inner = self.inner.take().expect("drained once");
        match Arc::try_unwrap(inner) {
            Ok(shared) => shared.server.shutdown(),
            // Unreachable once every thread is joined, but never panic
            // in a shutdown path: fall back to a snapshot.
            Err(arc) => arc.server.metrics(),
        }
    }

    fn begin_drain(&mut self) {
        let Some(shared) = self.inner.as_ref() else {
            return;
        };
        shared.stop.store(true, Ordering::SeqCst);
        shared.request_shutdown(); // unblock wait_shutdown_requested
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // Unblock readers stuck in `read`: no new requests, in-flight
        // replies still flow (only the read half closes).
        for stream in shared.conns.lock().unwrap().values() {
            let _ = stream.shutdown(Shutdown::Read);
        }
        let handlers: Vec<_> = shared.handlers.lock().unwrap().drain(..).collect();
        for h in handlers {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.begin_drain();
        // The pool itself drains via the Server's own Drop when the
        // last Arc<NetShared> reference goes away.
    }
}

fn accept_loop(listener: TcpListener, shared: &Arc<NetShared>) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The accepted socket may inherit the listener's
                // nonblocking mode on some platforms; handlers want
                // blocking reads.
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                let id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
                // The conns entry is how drain unblocks this reader; a
                // connection we cannot register we must not serve, or
                // shutdown could join a reader nobody can wake (fd
                // exhaustion is exactly when try_clone fails).
                let Ok(read_half) = stream.try_clone() else {
                    continue; // dropping the stream closes it
                };
                shared.conns.lock().unwrap().insert(id, read_half);
                shared.server.with_metrics(|m| m.record_connection_opened());
                let shared2 = shared.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("fastcaps-net-conn-{id}"))
                    .spawn(move || handle_connection(id, stream, &shared2))
                    .expect("spawning connection handler");
                let mut handlers = shared.handlers.lock().unwrap();
                // Reap finished connections so a long-running server's
                // handle list is bounded by *live* connections, not by
                // every connection ever accepted.
                handlers.retain(|h| !h.is_finished());
                handlers.push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => {
                // Transient accept failure (EMFILE, aborted handshake):
                // keep serving the connections we have.
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

/// Reader half of one connection; spawns its writer, decodes and
/// validates frames, forwards work to the pool, and folds counters into
/// the shared metrics on exit.
fn handle_connection(id: u64, stream: TcpStream, shared: &Arc<NetShared>) {
    // Bounded: past REPLY_WINDOW queued replies the reader blocks here
    // instead of buffering an unreading client's backlog in server
    // memory. A blocked send unblocks with an error when the writer
    // exits (client gone or write timeout), so drain cannot wedge on it.
    let (reply_tx, reply_rx) = mpsc::sync_channel::<Reply>(REPLY_WINDOW);
    let writer = stream
        .try_clone()
        .map(|w| {
            std::thread::Builder::new()
                .name(format!("fastcaps-net-write-{id}"))
                .spawn(move || write_loop(w, reply_rx))
                .expect("spawning connection writer")
        })
        .ok();

    let mut reader = BufReader::new(stream);
    let (c, h, w) = shared.input_shape;
    let expected_bytes = shared.expected_bytes;
    let mut wire_requests = 0u64;
    let mut wire_errors = 0u64;
    // Set when the connection dies on a desynchronized stream: unread
    // inbound bytes must be consumed before closing, or the close turns
    // into a TCP RST that can destroy the in-flight error frame.
    let mut linger_drain = false;

    // The reader owns the decision to keep or drop the connection: a
    // recoverable fault queues a typed error and continues; a
    // desynchronizing fault queues the error and breaks (the writer
    // still flushes everything queued before the connection closes).
    loop {
        match wire::read_header(&mut reader) {
            Err(Fault::Closed) | Err(Fault::Truncated) | Err(Fault::Io(_)) => break,
            Err(
                fault @ (Fault::BadMagic(_)
                | Fault::BadVersion(_)
                | Fault::UnknownType(_)
                | Fault::BadPayload(_)),
            ) => {
                // BadPayload cannot come from read_header today, but a
                // future header extension would route it here: a
                // desynchronized stream is fatal either way.
                wire_errors += 1;
                linger_drain = true;
                let _ = reply_tx.send(Reply::Reject(ErrorCode::Malformed, fault.to_string()));
                break;
            }
            Err(fault @ Fault::Oversized(_)) => {
                wire_errors += 1;
                linger_drain = true;
                let _ = reply_tx.send(Reply::Reject(ErrorCode::Oversized, fault.to_string()));
                break;
            }
            Ok((FrameType::Classify, len)) => {
                wire_requests += 1;
                let Ok(payload) = wire::read_payload(&mut reader, len) else {
                    break; // stream died mid-payload
                };
                if len != expected_bytes {
                    // Spec-driven shape validation at the wire boundary:
                    // typed error, connection survives.
                    wire_errors += 1;
                    let _ = reply_tx.send(Reply::Reject(
                        ErrorCode::InvalidRequest,
                        format!(
                            "image payload is {len} bytes; backend input shape \
                             ({c}, {h}, {w}) needs exactly {expected_bytes} \
                             bytes of f32-le data"
                        ),
                    ));
                    continue;
                }
                let image = match wire::decode_classify(&payload)
                    .map_err(|f| f.to_string())
                    .and_then(|data| {
                        Tensor::from_vec(&[c, h, w], data).map_err(|e| e.to_string())
                    }) {
                    Ok(img) => img,
                    Err(msg) => {
                        wire_errors += 1;
                        let _ = reply_tx.send(Reply::Reject(ErrorCode::InvalidRequest, msg));
                        continue;
                    }
                };
                let reply = match shared.server.submit(image) {
                    Ok(rx) => Reply::Pending(rx),
                    Err(e @ BackendError::QueueFull { .. }) => {
                        wire_errors += 1;
                        Reply::Reject(ErrorCode::QueueFull, e.to_string())
                    }
                    Err(e @ BackendError::Unavailable(_)) => {
                        wire_errors += 1;
                        Reply::Reject(ErrorCode::Unavailable, e.to_string())
                    }
                    Err(e) => {
                        wire_errors += 1;
                        Reply::Reject(ErrorCode::Execution, e.to_string())
                    }
                };
                if reply_tx.send(reply).is_err() {
                    break; // writer died (client gone)
                }
            }
            Ok((FrameType::Shutdown, len)) => {
                if wire::read_payload(&mut reader, len).is_err() {
                    break;
                }
                let _ = reply_tx.send(Reply::Ack);
                shared.request_shutdown();
                break;
            }
            Ok((ty, _len)) => {
                // A server→client frame type arriving here means the
                // peer is not a FastCaps client; drop the connection.
                wire_errors += 1;
                linger_drain = true;
                let _ = reply_tx.send(Reply::Reject(
                    ErrorCode::Malformed,
                    format!("client sent server-side frame type {ty:?}"),
                ));
                break;
            }
        }
    }

    // Let the writer flush every queued reply (in-flight requests get
    // their responses during drain), then account the connection.
    drop(reply_tx);
    let writer_errors = writer.and_then(|h| h.join().ok()).unwrap_or(0);
    if linger_drain {
        // Lingering close: swallow whatever the peer already sent
        // (bounded in bytes and time) so our FIN isn't turned into a
        // RST while the error frame is still in flight.
        let mut stream = reader.into_inner();
        let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
        let mut scratch = [0u8; 4096];
        let mut budget = 64 * 1024usize;
        loop {
            match std::io::Read::read(&mut stream, &mut scratch) {
                Ok(0) | Err(_) => break,
                Ok(n) => {
                    budget = budget.saturating_sub(n);
                    if budget == 0 {
                        break;
                    }
                }
            }
        }
    }
    shared.conns.lock().unwrap().remove(&id);
    shared
        .server
        .with_metrics(|m| m.record_connection_closed(wire_requests, wire_errors + writer_errors));
}

/// Writer half: drains the in-order reply stream, waiting on the pool's
/// response channel per pending request. Returns the number of error
/// frames it produced itself (dropped requests → `Unavailable`).
fn write_loop(stream: TcpStream, replies: mpsc::Receiver<Reply>) -> u64 {
    let mut w = BufWriter::new(stream);
    let mut own_errors = 0u64;
    for reply in replies {
        let ok = match reply {
            Reply::Pending(rx) => match rx.recv() {
                Ok(resp) => wire::write_response(&mut w, &resp).is_ok(),
                Err(_) => {
                    // The executor dropped the request (backend failure
                    // or shutdown race): the client gets a typed error
                    // instead of a silent hole in the response stream.
                    own_errors += 1;
                    wire::write_error(
                        &mut w,
                        ErrorCode::Unavailable,
                        "executor dropped the request (backend failure or shutdown)",
                    )
                    .is_ok()
                }
            },
            Reply::Reject(code, msg) => wire::write_error(&mut w, code, &msg).is_ok(),
            Reply::Ack => wire::write_empty(&mut w, FrameType::ShutdownAck).is_ok(),
        };
        if !ok || w.flush().is_err() {
            break; // client gone; reader will notice on its next read
        }
    }
    own_errors
}

// ---------------------------------------------------------------------
// client

/// Client-side error for the socket path.
#[derive(Debug)]
pub enum NetError {
    /// Transport failed (connect, read, write, truncated stream).
    Io(String),
    /// The byte stream was not valid protocol.
    Protocol(String),
    /// The server answered with a typed error frame.
    Rejected { code: ErrorCode, message: String },
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Io(m) => write!(f, "net io: {m}"),
            NetError::Protocol(m) => write!(f, "net protocol: {m}"),
            NetError::Rejected { code, message } => {
                write!(f, "server rejected request ({code:?}): {message}")
            }
        }
    }
}

impl std::error::Error for NetError {}

impl From<Fault> for NetError {
    fn from(f: Fault) -> NetError {
        match f {
            Fault::Closed | Fault::Truncated | Fault::Io(_) => NetError::Io(f.to_string()),
            other => NetError::Protocol(other.to_string()),
        }
    }
}

/// Blocking client for the wire protocol. Supports both the simple
/// round-trip ([`NetClient::classify`]) and pipelining
/// ([`NetClient::send`] N times, then [`NetClient::recv`] N times —
/// responses come back in request order).
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl NetClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<NetClient, NetError> {
        let stream = TcpStream::connect(addr).map_err(|e| NetError::Io(e.to_string()))?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone().map_err(|e| NetError::Io(e.to_string()))?;
        Ok(NetClient {
            reader: BufReader::new(stream),
            writer,
        })
    }

    /// Bound how long [`NetClient::recv`] may block (None = forever).
    /// Tests use this so a server regression fails instead of hanging.
    pub fn set_read_timeout(&self, dur: Option<Duration>) -> Result<(), NetError> {
        self.reader
            .get_ref()
            .set_read_timeout(dur)
            .map_err(|e| NetError::Io(e.to_string()))
    }

    /// Send one classify request without waiting for the response.
    pub fn send(&mut self, image: &Tensor) -> Result<(), NetError> {
        wire::write_classify(&mut self.writer, &image.data)
            .map_err(|e| NetError::Io(e.to_string()))
    }

    /// Receive the next response in request order. A typed error frame
    /// becomes [`NetError::Rejected`]; the connection stays usable for
    /// recoverable codes (`QueueFull`, `InvalidRequest`, `Unavailable`).
    pub fn recv(&mut self) -> Result<WireResponse, NetError> {
        match wire::read_server_frame(&mut self.reader)? {
            ServerFrame::Response(resp) => Ok(resp),
            ServerFrame::Error { code, message } => Err(NetError::Rejected { code, message }),
            ServerFrame::ShutdownAck => Err(NetError::Protocol(
                "unexpected shutdown ack (no shutdown was requested)".into(),
            )),
        }
    }

    /// Round-trip one image.
    pub fn classify(&mut self, image: &Tensor) -> Result<WireResponse, NetError> {
        self.send(image)?;
        self.recv()
    }

    /// Ask the server for a graceful drain and wait for the
    /// acknowledgement. Pending pipelined responses are drained first
    /// (they arrive before the ack, in order).
    pub fn shutdown_server(mut self) -> Result<(), NetError> {
        wire::write_empty(&mut self.writer, FrameType::Shutdown)
            .map_err(|e| NetError::Io(e.to_string()))?;
        loop {
            match wire::read_server_frame(&mut self.reader)? {
                ServerFrame::ShutdownAck => return Ok(()),
                ServerFrame::Response(_) | ServerFrame::Error { .. } => continue,
            }
        }
    }
}
