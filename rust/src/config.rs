//! Model and hardware configuration presets.
//!
//! All experiment drivers, benches and the CLI build their workloads from
//! these presets so the paper's three accelerator configurations —
//! *original*, *pruned* (LAKP) and *pruned + optimized* (LAKP + §III-B) —
//! are constructed identically everywhere.

/// CapsNet architecture (Fig. 3): Conv → PrimaryCaps → DigitCaps.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CapsNetConfig {
    pub name: String,
    /// Input image: channels, height, width.
    pub input: (usize, usize, usize),
    /// Conv1: output channels, kernel size, stride.
    pub conv1_ch: usize,
    pub conv1_k: usize,
    pub conv1_stride: usize,
    /// PrimaryCaps conv: capsule types × capsule dim output channels.
    pub pc_types: usize,
    pub pc_dim: usize,
    pub pc_k: usize,
    pub pc_stride: usize,
    /// DigitCaps: number of classes and output capsule dimension.
    pub num_classes: usize,
    pub dc_dim: usize,
    /// Dynamic routing iterations.
    pub routing_iters: usize,
}

impl CapsNetConfig {
    /// Original CapsNet (Sabour et al.) on 28×28 grayscale — the paper's
    /// MNIST / F-MNIST deployment target.
    pub fn paper_full(name: &str) -> CapsNetConfig {
        CapsNetConfig {
            name: name.to_string(),
            input: (1, 28, 28),
            conv1_ch: 256,
            conv1_k: 9,
            conv1_stride: 1,
            pc_types: 32,
            pc_dim: 8,
            pc_k: 9,
            pc_stride: 2,
            num_classes: 10,
            dc_dim: 16,
            routing_iters: 3,
        }
    }

    /// LAKP-pruned MNIST variant: PrimaryCaps reduced to 7 capsule types →
    /// 252 capsules (paper §III-A: "1152 to 252"); Conv1 pruned
    /// proportionally (256 → 64 kernels at the 99.26% compression point).
    pub fn paper_pruned_mnist() -> CapsNetConfig {
        CapsNetConfig {
            name: "capsnet-mnist-pruned".into(),
            conv1_ch: 64,
            pc_types: 7,
            ..CapsNetConfig::paper_full("capsnet-mnist-pruned")
        }
    }

    /// LAKP-pruned F-MNIST variant: 12 capsule types → 432 capsules
    /// (paper §III-A: "1152 to ... 432"); Conv1 256 → 96 kernels (98.84%).
    pub fn paper_pruned_fmnist() -> CapsNetConfig {
        CapsNetConfig {
            name: "capsnet-fmnist-pruned".into(),
            conv1_ch: 96,
            pc_types: 12,
            ..CapsNetConfig::paper_full("capsnet-fmnist-pruned")
        }
    }

    /// Scaled-down variant for fp32/simulator cross-checks and fast tests.
    pub fn tiny() -> CapsNetConfig {
        CapsNetConfig {
            name: "capsnet-tiny".into(),
            input: (1, 20, 20),
            conv1_ch: 16,
            conv1_k: 5,
            conv1_stride: 1,
            pc_types: 4,
            pc_dim: 8,
            pc_k: 5,
            pc_stride: 2,
            num_classes: 10,
            dc_dim: 16,
            routing_iters: 3,
        }
    }

    /// Conv1 output spatial size.
    pub fn conv1_out(&self) -> (usize, usize) {
        let (_, h, w) = self.input;
        (
            (h - self.conv1_k) / self.conv1_stride + 1,
            (w - self.conv1_k) / self.conv1_stride + 1,
        )
    }

    /// PrimaryCaps conv output spatial size.
    pub fn pc_out(&self) -> (usize, usize) {
        let (h, w) = self.conv1_out();
        (
            (h - self.pc_k) / self.pc_stride + 1,
            (w - self.pc_k) / self.pc_stride + 1,
        )
    }

    /// PrimaryCaps conv output channels (= types × dim).
    pub fn pc_channels(&self) -> usize {
        self.pc_types * self.pc_dim
    }

    /// Number of primary capsules feeding dynamic routing.
    pub fn num_primary_caps(&self) -> usize {
        let (h, w) = self.pc_out();
        self.pc_types * h * w
    }

    /// Weight-parameter counts per stage (conv1, primarycaps, digitcaps).
    pub fn param_counts(&self) -> (u64, u64, u64) {
        let (c_in, _, _) = self.input;
        let conv1 = (self.conv1_ch * c_in * self.conv1_k * self.conv1_k) as u64;
        let pc =
            (self.pc_channels() * self.conv1_ch * self.pc_k * self.pc_k) as u64;
        // DigitCaps transform is shared across spatial positions within a
        // capsule type (see `capsnet::weights::Weights::w_ij`).
        let dc =
            (self.pc_types * self.num_classes * self.pc_dim * self.dc_dim) as u64;
        (conv1, pc, dc)
    }

    pub fn total_params(&self) -> u64 {
        let (a, b, c) = self.param_counts();
        a + b + c
    }

    /// Total MACs of one inference (conv stages + routing u·W projections).
    pub fn total_macs(&self) -> u64 {
        let (c_in, _, _) = self.input;
        let (c1h, c1w) = self.conv1_out();
        let (pch, pcw) = self.pc_out();
        let conv1 = crate::tensor::conv2d_macs(
            c_in,
            self.conv1_ch,
            c1h,
            c1w,
            self.conv1_k,
            self.conv1_k,
        );
        let pc = crate::tensor::conv2d_macs(
            self.conv1_ch,
            self.pc_channels(),
            pch,
            pcw,
            self.pc_k,
            self.pc_k,
        );
        let proj = (self.num_primary_caps()
            * self.num_classes
            * self.pc_dim
            * self.dc_dim) as u64;
        let agreement = (self.num_primary_caps()
            * self.num_classes
            * self.dc_dim) as u64
            * self.routing_iters as u64;
        conv1 + pc + proj + agreement
    }
}

/// Kernel-level sparsity of a deployed (LAKP-pruned) model.
///
/// LAKP prunes individual `k×k` kernels from the `c_out × c_in` kernel grid
/// (§III-A). A PrimaryCaps *capsule type* survives only if any of its
/// `pc_dim` output channels keeps at least one kernel; the paper's pruned
/// MNIST model keeps 7 of 32 types (252 of 1152 capsules) while retaining
/// only 0.74% of conv parameters — i.e. the surviving channels are
/// themselves kernel-sparse, which the Index Control Module (§III-C)
/// exploits by skipping pruned kernels entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparsityPlan {
    /// Surviving Conv1 kernels (of `conv1_ch × c_in`).
    pub conv1_kernels: usize,
    /// Surviving PrimaryCaps kernels (of `pc_channels × conv1_ch_survived`).
    pub pc_kernels: usize,
    /// Surviving Conv1 output channels (channels with ≥1 kernel).
    pub conv1_channels: usize,
    /// Surviving PrimaryCaps capsule types.
    pub pc_types: usize,
}

impl SparsityPlan {
    /// Dense (unpruned) plan for a config.
    pub fn dense(cfg: &CapsNetConfig) -> SparsityPlan {
        let (c_in, _, _) = cfg.input;
        SparsityPlan {
            conv1_kernels: cfg.conv1_ch * c_in,
            pc_kernels: cfg.pc_channels() * cfg.conv1_ch,
            conv1_channels: cfg.conv1_ch,
            pc_types: cfg.pc_types,
        }
    }

    /// Paper's MNIST deployment: 64 conv1 kernels + 423 PrimaryCaps kernels
    /// inside 7 surviving capsule types → 99.26% of conv parameters pruned.
    pub fn paper_mnist() -> SparsityPlan {
        SparsityPlan {
            conv1_kernels: 64,
            pc_kernels: 423,
            conv1_channels: 64,
            pc_types: 7,
        }
    }

    /// Paper's F-MNIST deployment: 96 + 667 kernels, 12 types → 98.84%.
    pub fn paper_fmnist() -> SparsityPlan {
        SparsityPlan {
            conv1_kernels: 96,
            pc_kernels: 667,
            conv1_channels: 96,
            pc_types: 12,
        }
    }

    /// Surviving conv-stage parameters under a config's kernel sizes.
    pub fn survived_conv_params(&self, cfg: &CapsNetConfig) -> u64 {
        (self.conv1_kernels * cfg.conv1_k * cfg.conv1_k) as u64
            + (self.pc_kernels * cfg.pc_k * cfg.pc_k) as u64
    }

    /// Effective compression rate (%) over the prunable (conv) parameters
    /// of the *unpruned* reference architecture — the quantity the paper
    /// reports as 99.26% / 98.84%.
    pub fn compression_rate(&self, pruned_cfg: &CapsNetConfig, full_cfg: &CapsNetConfig) -> f64 {
        let dense = SparsityPlan::dense(full_cfg).survived_conv_params(full_cfg) as f64;
        100.0 * (1.0 - self.survived_conv_params(pruned_cfg) as f64 / dense)
    }

    /// Number of primary capsules after pruning.
    pub fn num_primary_caps(&self, cfg: &CapsNetConfig) -> usize {
        let (h, w) = cfg.pc_out();
        self.pc_types * h * w
    }

    /// Index-memory overhead (§III-C): one index per surviving kernel,
    /// as a fraction of surviving weights. Paper: "only 0.1% of the total
    /// number of weights that remain".
    pub fn index_overhead(&self, cfg: &CapsNetConfig) -> f64 {
        let indices = (self.conv1_kernels + self.pc_kernels) as f64;
        let survived = self.survived_conv_params(cfg) as f64
            + (self.num_primary_caps(cfg) * cfg.num_classes * cfg.pc_dim * cfg.dc_dim)
                as f64;
        indices / survived
    }
}

/// FPGA device budget — Xilinx PYNQ-Z1 (Zynq XC7Z020).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FpgaBudget {
    pub luts: u32,
    pub lutram: u32,
    pub bram36: f32,
    pub dsp48e: u32,
    pub clock_mhz: f64,
}

impl FpgaBudget {
    pub fn pynq_z1() -> FpgaBudget {
        FpgaBudget {
            luts: 53_200,
            lutram: 17_400,
            bram36: 140.0,
            dsp48e: 220,
            clock_mhz: 100.0,
        }
    }
}

/// Which of the paper's two optimizations are applied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AcceleratorOptions {
    /// §III-B: Taylor exp, exp/log divider, loop reorder, PE pipelining.
    pub optimized_routing: bool,
    /// Number of processing elements (paper: array of 10).
    pub num_pes: usize,
    /// MACs per PE (element-wise 16-bit multiplies + adder tree; paper: 9).
    pub macs_per_pe: usize,
}

impl AcceleratorOptions {
    pub fn baseline() -> Self {
        AcceleratorOptions {
            optimized_routing: false,
            num_pes: 10,
            macs_per_pe: 9,
        }
    }

    pub fn optimized() -> Self {
        AcceleratorOptions {
            optimized_routing: true,
            num_pes: 10,
            macs_per_pe: 9,
        }
    }

    pub fn peak_macs_per_cycle(&self) -> u64 {
        (self.num_pes * self.macs_per_pe) as u64
    }
}

/// A full experiment configuration: model + kernel sparsity + device +
/// options. The `model` holds the *compacted* architecture (dead channels
/// removed); `sparsity` holds the intra-channel kernel sparsity that the
/// Index Control Module exploits.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub model: CapsNetConfig,
    pub sparsity: SparsityPlan,
    pub budget: FpgaBudget,
    pub options: AcceleratorOptions,
}

impl SystemConfig {
    /// Paper configuration "Original CapsNet [4]" (Table II col. 1).
    pub fn original(dataset: &str) -> SystemConfig {
        let model = CapsNetConfig::paper_full(&format!("capsnet-{dataset}"));
        SystemConfig {
            sparsity: SparsityPlan::dense(&model),
            model,
            budget: FpgaBudget::pynq_z1(),
            options: AcceleratorOptions::baseline(),
        }
    }

    /// LAKP-pruned, non-optimized routing (Fig. 1 middle bars).
    pub fn pruned(dataset: &str) -> SystemConfig {
        let (model, sparsity) = match dataset {
            "fmnist" => (
                CapsNetConfig::paper_pruned_fmnist(),
                SparsityPlan::paper_fmnist(),
            ),
            _ => (
                CapsNetConfig::paper_pruned_mnist(),
                SparsityPlan::paper_mnist(),
            ),
        };
        SystemConfig {
            model,
            sparsity,
            budget: FpgaBudget::pynq_z1(),
            options: AcceleratorOptions::baseline(),
        }
    }

    /// Proposed: LAKP-pruned + optimized routing (Table II col. 2).
    pub fn proposed(dataset: &str) -> SystemConfig {
        SystemConfig {
            options: AcceleratorOptions::optimized(),
            ..SystemConfig::pruned(dataset)
        }
    }

    /// The *full* paper architecture with LAKP masks applied in place —
    /// no channel/type compaction: all 1152 primary capsules remain (a
    /// dead conv output channel still emits its bias, exactly like the
    /// masked-dense reference), but only the plan's surviving kernels
    /// are stored, executed, and cycle-priced, and the ~80 KB of packed
    /// survivor weights live on-chip instead of replaying over DDR (the
    /// uncompacted 1152-capsule û working set still spills — see
    /// `DeployedModel::ddr_bytes`). This is what the `sim-sparse`
    /// backend deploys; `pruned`/`proposed` model the further-compacted
    /// architecture the paper ships (252/432 capsules, û on-chip),
    /// which is *not* value-equivalent to masking alone.
    pub fn masked(dataset: &str) -> SystemConfig {
        let model = CapsNetConfig::paper_full(&format!("capsnet-{dataset}"));
        let paper = match dataset {
            "fmnist" => SparsityPlan::paper_fmnist(),
            _ => SparsityPlan::paper_mnist(),
        };
        SystemConfig::masked_with_counts(model, paper.conv1_kernels, paper.pc_kernels)
    }

    /// A masked (uncompacted) deployment of `model` at explicit survivor
    /// counts — the single owner of the `sim-sparse` deployment
    /// invariants, shared by [`SystemConfig::masked`] and the
    /// `fastcaps prune --serve --backend sim-sparse` path: masking
    /// removes kernels, not channels or capsule types (compaction is a
    /// separate deployment step), on the PYNQ-Z1 budget with the
    /// optimized schedule.
    pub fn masked_with_counts(
        model: CapsNetConfig,
        conv1_kernels: usize,
        pc_kernels: usize,
    ) -> SystemConfig {
        SystemConfig {
            sparsity: SparsityPlan {
                conv1_kernels,
                pc_kernels,
                conv1_channels: model.conv1_ch,
                pc_types: model.pc_types,
            },
            model,
            budget: FpgaBudget::pynq_z1(),
            options: AcceleratorOptions::optimized(),
        }
    }

    pub fn is_pruned(&self) -> bool {
        self.sparsity != SparsityPlan::dense(&self.model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_capsule_counts() {
        let full = CapsNetConfig::paper_full("capsnet-mnist");
        assert_eq!(full.conv1_out(), (20, 20));
        assert_eq!(full.pc_out(), (6, 6));
        assert_eq!(full.num_primary_caps(), 1152); // 32 × 6 × 6
        let pruned = CapsNetConfig::paper_pruned_mnist();
        assert_eq!(pruned.num_primary_caps(), 252); // 7 × 6 × 6
        let pruned_f = CapsNetConfig::paper_pruned_fmnist();
        assert_eq!(pruned_f.num_primary_caps(), 432); // 12 × 6 × 6
    }

    #[test]
    fn digitcaps_param_reduction_matches_paper() {
        // §III-A: "each capsule operates with 10·16·8 weight parameters" —
        // the per-capsule transform block is 1280 weights; pruning removes
        // that block of *work* for each of the 900 eliminated capsules
        // (under the shared-transform layout the stored weights shrink
        // 32→7 types, and the routing workload shrinks with the capsules).
        let full = CapsNetConfig::paper_full("capsnet-mnist");
        let pruned = CapsNetConfig::paper_pruned_mnist();
        let per_capsule = (full.num_classes * full.dc_dim * full.pc_dim) as u64;
        assert_eq!(per_capsule, 1280);
        assert_eq!(full.num_primary_caps() - pruned.num_primary_caps(), 900);
        let (_, _, dc_full) = full.param_counts();
        let (_, _, dc_pruned) = pruned.param_counts();
        assert_eq!(dc_full, 32 * 1280);
        assert_eq!(dc_pruned, 7 * 1280);
    }

    #[test]
    fn compression_rates_match_paper() {
        // Effective compression ≈ 99.26% (MNIST) and 98.84% (F-MNIST) over
        // the prunable conv parameters.
        let full = CapsNetConfig::paper_full("x");
        let rate_m = SparsityPlan::paper_mnist()
            .compression_rate(&CapsNetConfig::paper_pruned_mnist(), &full);
        let rate_f = SparsityPlan::paper_fmnist()
            .compression_rate(&CapsNetConfig::paper_pruned_fmnist(), &full);
        assert!((rate_m - 99.26).abs() < 0.05, "mnist rate {rate_m}");
        assert!((rate_f - 98.84).abs() < 0.05, "fmnist rate {rate_f}");
        assert!(rate_m > rate_f, "MNIST prunes harder than F-MNIST");
    }

    #[test]
    fn index_overhead_is_tiny() {
        // §III-C: kernel indices cost ~0.1% of surviving weights.
        let cfg = CapsNetConfig::paper_pruned_mnist();
        let oh = SparsityPlan::paper_mnist().index_overhead(&cfg);
        assert!(oh < 0.005, "index overhead {oh}");
    }

    #[test]
    fn macs_dominated_by_primarycaps() {
        let full = CapsNetConfig::paper_full("x");
        let (c1h, c1w) = full.conv1_out();
        let conv1 = crate::tensor::conv2d_macs(1, 256, c1h, c1w, 9, 9);
        assert!(full.total_macs() > 20 * conv1); // PrimaryCaps >> Conv1
    }

    #[test]
    fn pynq_budget() {
        let b = FpgaBudget::pynq_z1();
        assert_eq!(b.dsp48e, 220);
        assert_eq!(b.bram36, 140.0);
    }

    #[test]
    fn presets_constructible() {
        for d in ["mnist", "fmnist"] {
            let o = SystemConfig::original(d);
            let p = SystemConfig::pruned(d);
            let x = SystemConfig::proposed(d);
            assert!(!o.is_pruned() && p.is_pruned() && x.is_pruned());
            assert!(!o.options.optimized_routing);
            assert!(x.options.optimized_routing);
            assert!(o.model.total_params() > p.model.total_params());
        }
    }

    #[test]
    fn masked_config_keeps_full_capsule_set() {
        for (d, kernels) in [("mnist", 64 + 423), ("fmnist", 96 + 667)] {
            let m = SystemConfig::masked(d);
            assert!(m.is_pruned(), "kernel-sparse ⇒ pruned regime");
            assert_eq!(m.model.num_primary_caps(), 1152);
            assert_eq!(m.sparsity.num_primary_caps(&m.model), 1152);
            assert_eq!(m.sparsity.conv1_kernels + m.sparsity.pc_kernels, kernels);
            assert_eq!(m.sparsity.pc_types, 32, "no type compaction");
            // Survivor weights fit on-chip (the point of pruning):
            // 78,894 B (MNIST) / 123,606 B (F-MNIST) — a fraction of
            // the 560 KB device the dense 10.7 MB model overflows 19×.
            assert!(m.sparsity.survived_conv_params(&m.model) * 2 < 150_000);
        }
    }

    #[test]
    fn tiny_config_valid() {
        let t = CapsNetConfig::tiny();
        assert!(t.num_primary_caps() > 0);
        assert!(t.total_macs() < CapsNetConfig::paper_full("x").total_macs() / 100);
    }
}
