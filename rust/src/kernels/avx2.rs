//! AVX2 (x86_64 `std::arch`) kernel implementations.
//!
//! The public kernels are `unsafe fn` only because of
//! `#[target_feature(enable = "avx2")]` — the slices are bounds-handled
//! explicitly and the single safety precondition is that the CPU
//! supports AVX2 (the dispatch wrappers in the parent module guarantee
//! it via `is_x86_feature_detected!`). Under the crate-wide
//! `deny(unsafe_op_in_unsafe_fn)`, only the pointer-based load/store
//! intrinsics need `unsafe` blocks; the lane arithmetic is safe inside
//! a `target_feature` function.
//!
//! Bit-exactness strategy (see the module docs in `kernels`):
//!
//! * Integer kernels widen i16 lanes to i32, multiply exactly
//!   (`_mm256_mullo_epi32` — products of two i16s fit i32), then widen
//!   to i64 before accumulating, so no lane can ever overflow mid-sum
//!   and any accumulation order yields the scalar path's bits.
//! * f32 kernels use separate `_mm256_mul_ps` + `_mm256_add_ps`
//!   (never FMA) so each lane performs exactly the scalar
//!   one-rounded-multiply + one-rounded-add sequence, and
//!   `_mm256_div_ps` which is IEEE correctly rounded per lane like the
//!   scalar `/`.

use core::arch::x86_64::*;

/// Widen the low 4 i32 lanes and the high 4 i32 lanes of `v` to i64 and
/// add both into `acc`.
#[inline]
#[target_feature(enable = "avx2")]
fn add_i32x8_into_i64x4(acc: __m256i, v: __m256i) -> __m256i {
    let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(v));
    let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(v));
    _mm256_add_epi64(_mm256_add_epi64(acc, lo), hi)
}

/// Horizontal sum of the 4 i64 lanes.
#[inline]
#[target_feature(enable = "avx2")]
fn hsum_i64x4(v: __m256i) -> i64 {
    let mut lanes = [0i64; 4];
    // SAFETY: `lanes` is exactly 32 bytes and the store is unaligned.
    unsafe { _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v) };
    lanes[0] + lanes[1] + lanes[2] + lanes[3]
}

/// `acc[i] += x · w[i]` with i64 accumulators.
///
/// # Safety
/// The CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_i16(acc: &mut [i64], x: i16, w: &[i16]) {
    let n = acc.len().min(w.len());
    let xv = _mm256_set1_epi32(x as i32);
    let mut i = 0;
    while i + 8 <= n {
        // SAFETY: `i + 8 <= n <= acc.len().min(w.len())` keeps every
        // unaligned lane load and store in bounds.
        unsafe {
            let wv = _mm_loadu_si128(w.as_ptr().add(i) as *const __m128i);
            let prod = _mm256_mullo_epi32(_mm256_cvtepi16_epi32(wv), xv);
            let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(prod));
            let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(prod));
            let a0 = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            let a1 = _mm256_loadu_si256(acc.as_ptr().add(i + 4) as *const __m256i);
            let lo_ptr = acc.as_mut_ptr().add(i) as *mut __m256i;
            let hi_ptr = acc.as_mut_ptr().add(i + 4) as *mut __m256i;
            _mm256_storeu_si256(lo_ptr, _mm256_add_epi64(a0, lo));
            _mm256_storeu_si256(hi_ptr, _mm256_add_epi64(a1, hi));
        }
        i += 8;
    }
    while i < n {
        acc[i] += x as i64 * w[i] as i64;
        i += 1;
    }
}

/// `Σ a[i]·b[i]` in i64.
///
/// # Safety
/// The CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_i16(a: &[i16], b: &[i16]) -> i64 {
    let n = a.len().min(b.len());
    let mut vacc = _mm256_setzero_si256();
    let mut i = 0;
    while i + 8 <= n {
        // SAFETY: `i + 8 <= n <= a.len().min(b.len())` keeps both
        // unaligned 8-lane loads in bounds.
        unsafe {
            let av = _mm256_cvtepi16_epi32(_mm_loadu_si128(a.as_ptr().add(i) as *const __m128i));
            let bv = _mm256_cvtepi16_epi32(_mm_loadu_si128(b.as_ptr().add(i) as *const __m128i));
            vacc = add_i32x8_into_i64x4(vacc, _mm256_mullo_epi32(av, bv));
        }
        i += 8;
    }
    let mut acc = hsum_i64x4(vacc);
    while i < n {
        acc += a[i] as i64 * b[i] as i64;
        i += 1;
    }
    acc
}

/// `Σ x[i]²` in i64.
///
/// # Safety
/// The CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn sumsq_i16(x: &[i16]) -> i64 {
    let mut vacc = _mm256_setzero_si256();
    let mut i = 0;
    while i + 8 <= x.len() {
        // SAFETY: `i + 8 <= x.len()` keeps the 8-lane load in bounds.
        unsafe {
            let v = _mm256_cvtepi16_epi32(_mm_loadu_si128(x.as_ptr().add(i) as *const __m128i));
            vacc = add_i32x8_into_i64x4(vacc, _mm256_mullo_epi32(v, v));
        }
        i += 8;
    }
    let mut acc = hsum_i64x4(vacc);
    while i < x.len() {
        acc += x[i] as i64 * x[i] as i64;
        i += 1;
    }
    acc
}

/// `Σ x[i]` in i64.
///
/// # Safety
/// The CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn sum_i16(x: &[i16]) -> i64 {
    let mut vacc = _mm256_setzero_si256();
    let mut i = 0;
    while i + 8 <= x.len() {
        // SAFETY: `i + 8 <= x.len()` keeps the 8-lane load in bounds.
        unsafe {
            let v = _mm256_cvtepi16_epi32(_mm_loadu_si128(x.as_ptr().add(i) as *const __m128i));
            vacc = add_i32x8_into_i64x4(vacc, v);
        }
        i += 8;
    }
    let mut acc = hsum_i64x4(vacc);
    while i < x.len() {
        acc += x[i] as i64;
        i += 1;
    }
    acc
}

/// Max-fold (i16::MIN on empty input).
///
/// # Safety
/// The CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn max_i16(x: &[i16]) -> i16 {
    let mut vmax = _mm256_set1_epi16(i16::MIN);
    let mut i = 0;
    while i + 16 <= x.len() {
        // SAFETY: `i + 16 <= x.len()` keeps the 16-lane load in bounds.
        unsafe {
            let v = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
            vmax = _mm256_max_epi16(vmax, v);
        }
        i += 16;
    }
    let mut lanes = [i16::MIN; 16];
    // SAFETY: `lanes` is exactly 32 bytes and the store is unaligned.
    unsafe { _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, vmax) };
    let mut m = i16::MIN;
    for &v in &lanes {
        if v > m {
            m = v;
        }
    }
    while i < x.len() {
        if x[i] > m {
            m = x[i];
        }
        i += 1;
    }
    m
}

/// `out[i] = sat16((x[i]·scale + 1<<(SHIFT-1)) >> SHIFT)` — the i32
/// lane computation mirrors `scalar::scale_i16_q` exactly, and
/// `_mm_packs_epi32` performs the identical signed saturation to i16.
///
/// # Safety
/// The CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn scale_i16_q<const SHIFT: i32>(x: &[i16], scale: i32, out: &mut [i16]) {
    let n = x.len().min(out.len());
    let sv = _mm256_set1_epi32(scale);
    let round = _mm256_set1_epi32(1 << (SHIFT - 1));
    let mut i = 0;
    while i + 8 <= n {
        // SAFETY: `i + 8 <= n <= x.len().min(out.len())` keeps the
        // 8-lane load and the packed 8×i16 store in bounds.
        unsafe {
            let v = _mm256_cvtepi16_epi32(_mm_loadu_si128(x.as_ptr().add(i) as *const __m128i));
            let p = _mm256_srai_epi32::<SHIFT>(_mm256_add_epi32(_mm256_mullo_epi32(v, sv), round));
            let (plo, phi) = (_mm256_castsi256_si128(p), _mm256_extracti128_si256::<1>(p));
            _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, _mm_packs_epi32(plo, phi));
        }
        i += 8;
    }
    while i < n {
        let p = (x[i] as i32 * scale + (1 << (SHIFT - 1))) >> SHIFT;
        out[i] = p.clamp(i16::MIN as i32, i16::MAX as i32) as i16;
        i += 1;
    }
}

/// `acc[i] += x · w[i]` in f32 (mul + add, never fused).
///
/// # Safety
/// The CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_f32(acc: &mut [f32], x: f32, w: &[f32]) {
    let n = acc.len().min(w.len());
    let xv = _mm256_set1_ps(x);
    let mut i = 0;
    while i + 8 <= n {
        // SAFETY: `i + 8 <= n <= acc.len().min(w.len())` keeps the
        // unaligned loads and the store in bounds.
        unsafe {
            let wv = _mm256_loadu_ps(w.as_ptr().add(i));
            let av = _mm256_loadu_ps(acc.as_ptr().add(i));
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(av, _mm256_mul_ps(xv, wv)));
        }
        i += 8;
    }
    while i < n {
        acc[i] += x * w[i];
        i += 1;
    }
}

/// `out[i] = x[i] · s`.
///
/// # Safety
/// The CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn mul_f32(x: &[f32], s: f32, out: &mut [f32]) {
    let n = x.len().min(out.len());
    let sv = _mm256_set1_ps(s);
    let mut i = 0;
    while i + 8 <= n {
        // SAFETY: `i + 8 <= n <= x.len().min(out.len())` keeps the load
        // and the store in bounds.
        unsafe {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(v, sv));
        }
        i += 8;
    }
    while i < n {
        out[i] = x[i] * s;
        i += 1;
    }
}

/// `x[i] /= d` in place.
///
/// # Safety
/// The CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn div_in_place_f32(x: &mut [f32], d: f32) {
    let dv = _mm256_set1_ps(d);
    let mut i = 0;
    while i + 8 <= x.len() {
        // SAFETY: `i + 8 <= x.len()` keeps the in-place load and store
        // in bounds.
        unsafe {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), _mm256_div_ps(v, dv));
        }
        i += 8;
    }
    while i < x.len() {
        x[i] /= d;
        i += 1;
    }
}
