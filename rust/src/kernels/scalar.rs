//! Portable scalar kernel implementations — the reference the AVX2 path
//! must match bit-for-bit (see the module docs for the per-type
//! contract). These run on every architecture and are what
//! `FASTCAPS_SIMD=off` selects.

/// `acc[i] += x · w[i]` with i64 accumulation.
pub fn axpy_i16(acc: &mut [i64], x: i16, w: &[i16]) {
    let x = x as i64;
    for (a, &wv) in acc.iter_mut().zip(w) {
        *a += x * wv as i64;
    }
}

/// `acc[i] += x · w[i·stride]` with i64 accumulation.
pub fn axpy_strided_i16(acc: &mut [i64], x: i16, w: &[i16], stride: usize) {
    let x = x as i64;
    for (i, a) in acc.iter_mut().enumerate() {
        *a += x * w[i * stride] as i64;
    }
}

/// `Σ a[i]·b[i]` in i64.
pub fn dot_i16(a: &[i16], b: &[i16]) -> i64 {
    let mut acc = 0i64;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as i64 * y as i64;
    }
    acc
}

/// `Σ x[i]²` in i64.
pub fn sumsq_i16(x: &[i16]) -> i64 {
    let mut acc = 0i64;
    for &v in x {
        acc += v as i64 * v as i64;
    }
    acc
}

/// `Σ x[i]` in i64.
pub fn sum_i16(x: &[i16]) -> i64 {
    let mut acc = 0i64;
    for &v in x {
        acc += v as i64;
    }
    acc
}

/// Max-fold (i16::MIN on empty input).
pub fn max_i16(x: &[i16]) -> i16 {
    let mut m = i16::MIN;
    for &v in x {
        if v > m {
            m = v;
        }
    }
    m
}

/// `out[i] = sat16((x[i]·scale + 1<<(SHIFT-1)) >> SHIFT)`. The product
/// fits i32 exactly (|x| ≤ 2¹⁵, 0 ≤ scale ≤ 2¹⁵−1), so the whole
/// computation is done in i32 — the contract the AVX2 lanes mirror.
pub fn scale_i16_q<const SHIFT: i32>(x: &[i16], scale: i32, out: &mut [i16]) {
    for (o, &v) in out.iter_mut().zip(x) {
        let p = (v as i32 * scale + (1 << (SHIFT - 1))) >> SHIFT;
        *o = p.clamp(i16::MIN as i32, i16::MAX as i32) as i16;
    }
}

/// `acc[i] += x · w[i]` in f32: one rounded multiply + one rounded add
/// per element (never fused — the bit contract with AVX2).
pub fn axpy_f32(acc: &mut [f32], x: f32, w: &[f32]) {
    for (a, &wv) in acc.iter_mut().zip(w) {
        *a += x * wv;
    }
}

/// `acc[i] += x · w[i·stride]` in f32.
pub fn axpy_strided_f32(acc: &mut [f32], x: f32, w: &[f32], stride: usize) {
    for (i, a) in acc.iter_mut().enumerate() {
        *a += x * w[i * stride];
    }
}

/// `out[i] = x[i] · s`.
pub fn mul_f32(x: &[f32], s: f32, out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = v * s;
    }
}

/// `x[i] /= d` in place.
pub fn div_in_place_f32(x: &mut [f32], d: f32) {
    for v in x {
        *v /= d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_known_values() {
        let mut acc = vec![1i64, 2, 3];
        axpy_i16(&mut acc, 2, &[10, -20, 30]);
        assert_eq!(acc, vec![21, -38, 63]);
    }

    #[test]
    fn reductions_known_values() {
        assert_eq!(dot_i16(&[1, 2, 3], &[4, 5, 6]), 32);
        assert_eq!(sumsq_i16(&[-3, 4]), 25);
        assert_eq!(sum_i16(&[-3, 4, 10]), 11);
        assert_eq!(max_i16(&[-3, 7, 2]), 7);
    }

    #[test]
    fn scale_rounds_and_saturates() {
        let mut out = vec![0i16; 3];
        // 100·256 = 25600; (25600+128)>>8 = 100 — identity at scale 256.
        scale_i16_q::<8>(&[100, -100, i16::MAX], 256, &mut out);
        assert_eq!(out[0], 100);
        assert_eq!(out[1], -100);
        assert_eq!(out[2], i16::MAX);
        // A big scale saturates instead of wrapping.
        scale_i16_q::<8>(&[i16::MAX], i16::MAX as i32, &mut out[..1]);
        assert_eq!(out[0], i16::MAX);
    }

    #[test]
    fn f32_kernels_known_values() {
        let mut acc = vec![1.0f32, 2.0];
        axpy_f32(&mut acc, 0.5, &[4.0, -2.0]);
        assert_eq!(acc, vec![3.0, 1.0]);
        let mut out = vec![0.0f32; 2];
        mul_f32(&[3.0, -1.5], 2.0, &mut out);
        assert_eq!(out, vec![6.0, -3.0]);
        let mut xs = vec![6.0f32, -3.0];
        div_in_place_f32(&mut xs, 3.0);
        assert_eq!(xs, vec![2.0, -1.0]);
    }
}
