//! Runtime-dispatched SIMD kernel layer for the hot inner loops of both
//! numeric datapaths.
//!
//! FastCaps gets its FPGA speedup from wide parallel MACs in the conv
//! and routing PEs; this module is the software image of that width: the
//! inner loops of the Q8.8/Q4.12 fixed-point simulator path
//! ([`crate::fpga`], [`crate::routing::fixed`]) and the fp32 oracle
//! paths ([`crate::capsnet`], [`crate::tensor`]) call these kernels
//! instead of open-coding element-at-a-time arithmetic.
//!
//! Two implementations exist per kernel:
//!
//! * [`scalar`] — portable Rust, the reference on every architecture.
//! * [`avx2`] — x86_64 `std::arch` intrinsics behind
//!   `#[target_feature(enable = "avx2")]`.
//!
//! One is selected **once at startup** via
//! `is_x86_feature_detected!("avx2")`, overridable with the
//! `FASTCAPS_SIMD` environment variable (`off` forces the scalar
//! fallback, `avx2` forces the vector path where supported). The active
//! dispatch is display metadata only — it appears in serve/prune
//! banners and the `BackendSpec` summary but is deliberately **not**
//! part of any deployment fingerprint (same policy as `workers`): the
//! kernels below are bit-identical across dispatch levels, so a cache
//! entry produced under one level is valid under the other.
//!
//! # Bit-exactness contract
//!
//! * **Integer kernels** (`axpy_i16`, `dot_i16`, `sumsq_i16`,
//!   `sum_i16`, `max_i16`, `scale_i16_q`): every multiply is exact in
//!   i32 (i16·i16 ≤ 2³⁰) and every sum accumulates in a wide i64
//!   register that cannot overflow mid-sum, so integer addition is
//!   associative *and* commutative here — any accumulation order gives
//!   the same bits. AVX2 is therefore bit-identical to scalar by
//!   construction, which the property tests below and the existing
//!   fpga/compiled golden tests pin.
//! * **f32 kernels** (`axpy_f32`, `axpy_strided_f32`, `mul_f32`,
//!   `div_in_place_f32`): only *elementwise* loops are vectorized —
//!   each output lane performs exactly the scalar `a + x*w` (one
//!   rounded multiply, one rounded add; no FMA contraction) or the
//!   scalar `x / d`. No floating-point reduction is ever reassociated:
//!   dot products, norms and softmax sums keep the scalar
//!   left-to-right order in both implementations. fp32 outputs are
//!   therefore bit-identical across dispatch levels *and* to the
//!   pre-SIMD code — the goldens in `tests/compiled_golden.rs` stand
//!   unchanged and the ISSUE's ≤1e-5 drift budget is met with zero
//!   drift.

use std::sync::atomic::{AtomicU8, Ordering};

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

/// The dispatch level the kernel wrappers route through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar loops (the reference implementation).
    Scalar,
    /// x86_64 AVX2 intrinsics.
    Avx2,
}

impl SimdLevel {
    /// Short name used in banners and the backend summary (`simd=…`).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// 0 = not yet selected, 1 = scalar, 2 = avx2.
static LEVEL: AtomicU8 = AtomicU8::new(0);

/// Whether the host CPU supports the AVX2 kernel set.
pub fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

#[cold]
fn init_level() -> SimdLevel {
    let choice = match std::env::var("FASTCAPS_SIMD") {
        Ok(v) => match v.to_ascii_lowercase().as_str() {
            "off" | "scalar" | "0" => SimdLevel::Scalar,
            "avx2" => {
                if avx2_supported() {
                    SimdLevel::Avx2
                } else {
                    eprintln!(
                        "fastcaps: FASTCAPS_SIMD=avx2 requested but the host \
                         CPU does not support AVX2; falling back to scalar"
                    );
                    SimdLevel::Scalar
                }
            }
            "" | "auto" => {
                if avx2_supported() {
                    SimdLevel::Avx2
                } else {
                    SimdLevel::Scalar
                }
            }
            other => {
                eprintln!(
                    "fastcaps: unknown FASTCAPS_SIMD value {other:?} \
                     (want off|avx2|auto); using auto detection"
                );
                if avx2_supported() {
                    SimdLevel::Avx2
                } else {
                    SimdLevel::Scalar
                }
            }
        },
        Err(_) => {
            if avx2_supported() {
                SimdLevel::Avx2
            } else {
                SimdLevel::Scalar
            }
        }
    };
    // First selection wins on a race — both racers compute the same
    // value, since env + cpuid are stable for the process lifetime.
    LEVEL.store(
        match choice {
            SimdLevel::Scalar => 1,
            SimdLevel::Avx2 => 2,
        },
        Ordering::Relaxed,
    );
    choice
}

/// The active dispatch level (selected once, on first use).
#[inline]
pub fn active() -> SimdLevel {
    match LEVEL.load(Ordering::Relaxed) {
        1 => SimdLevel::Scalar,
        2 => SimdLevel::Avx2,
        _ => init_level(),
    }
}

/// Short name of the active dispatch (`"scalar"` / `"avx2"`), as
/// printed in the serve/prune banners and `BackendSpec::summary`.
pub fn active_name() -> &'static str {
    active().name()
}

/// Force the dispatch level, bypassing env/detection. For tests and
/// benches that need to compare both paths in one process; forcing
/// `Avx2` on a host without AVX2 support falls back to scalar rather
/// than executing illegal instructions.
pub fn force_level(level: SimdLevel) {
    let effective = match level {
        SimdLevel::Avx2 if !avx2_supported() => SimdLevel::Scalar,
        other => other,
    };
    LEVEL.store(
        match effective {
            SimdLevel::Scalar => 1,
            SimdLevel::Avx2 => 2,
        },
        Ordering::Relaxed,
    );
}

// ---------------------------------------------------------------------
// dispatch wrappers — the API the datapaths call
//
// Each wrapper is a branch on a relaxed atomic (predicted perfectly
// after the first call) into either implementation; both arms are
// inlinable, so the scalar path pays no function-pointer indirection.

/// `acc[i] += x · w[i]` with exact i32 products widened into i64
/// accumulators. The Q12 û-projection / routing-FC inner loop.
#[inline]
pub fn axpy_i16(acc: &mut [i64], x: i16, w: &[i16]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only returns `Avx2` after runtime detection.
        SimdLevel::Avx2 => unsafe { avx2::axpy_i16(acc, x, w) },
        _ => scalar::axpy_i16(acc, x, w),
    }
}

/// `acc[i] += x · w[i·stride]` — the packed-CSR conv row MAC
/// (stride > 1 rows fall back to the scalar loop in both paths, so the
/// dispatch stays bit-uniform).
#[inline]
pub fn axpy_strided_i16(acc: &mut [i64], x: i16, w: &[i16], stride: usize) {
    if stride == 1 {
        axpy_i16(acc, x, &w[..acc.len()]);
    } else {
        scalar::axpy_strided_i16(acc, x, w, stride);
    }
}

/// Wide dot product `Σ a[i]·b[i]` (agreement step).
#[inline]
pub fn dot_i16(a: &[i16], b: &[i16]) -> i64 {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only returns `Avx2` after runtime detection.
        SimdLevel::Avx2 => unsafe { avx2::dot_i16(a, b) },
        _ => scalar::dot_i16(a, b),
    }
}

/// Wide sum of squares `Σ x[i]²` (squash norm²).
#[inline]
pub fn sumsq_i16(x: &[i16]) -> i64 {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only returns `Avx2` after runtime detection.
        SimdLevel::Avx2 => unsafe { avx2::sumsq_i16(x) },
        _ => scalar::sumsq_i16(x),
    }
}

/// Wide sum `Σ x[i]` (softmax denominator staging).
#[inline]
pub fn sum_i16(x: &[i16]) -> i64 {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only returns `Avx2` after runtime detection.
        SimdLevel::Avx2 => unsafe { avx2::sum_i16(x) },
        _ => scalar::sum_i16(x),
    }
}

/// Max-fold over raw i16 values (softmax max staging). Returns
/// `i16::MIN` on an empty slice.
#[inline]
pub fn max_i16(x: &[i16]) -> i16 {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only returns `Avx2` after runtime detection.
        SimdLevel::Avx2 => unsafe { avx2::max_i16(x) },
        _ => scalar::max_i16(x),
    }
}

/// `out[i] = sat16((x[i]·scale + 1<<(SHIFT-1)) >> SHIFT)` — the squash
/// scale-and-requantize writeback. `scale` must be a non-negative value
/// ≤ i16::MAX so the product fits i32 exactly.
#[inline]
pub fn scale_i16_q<const SHIFT: i32>(x: &[i16], scale: i32, out: &mut [i16]) {
    debug_assert!((0..=i16::MAX as i32).contains(&scale));
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only returns `Avx2` after runtime detection.
        SimdLevel::Avx2 => unsafe { avx2::scale_i16_q::<SHIFT>(x, scale, out) },
        _ => scalar::scale_i16_q::<SHIFT>(x, scale, out),
    }
}

/// `acc[i] += x · w[i]` in f32 — one rounded multiply + one rounded add
/// per lane, bit-identical to the scalar loop (no FMA contraction).
#[inline]
pub fn axpy_f32(acc: &mut [f32], x: f32, w: &[f32]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only returns `Avx2` after runtime detection.
        SimdLevel::Avx2 => unsafe { avx2::axpy_f32(acc, x, w) },
        _ => scalar::axpy_f32(acc, x, w),
    }
}

/// `acc[i] += x · w[i·stride]` in f32 (stride > 1 stays scalar in both
/// paths).
#[inline]
pub fn axpy_strided_f32(acc: &mut [f32], x: f32, w: &[f32], stride: usize) {
    if stride == 1 {
        axpy_f32(acc, x, &w[..acc.len()]);
    } else {
        scalar::axpy_strided_f32(acc, x, w, stride);
    }
}

/// `out[i] = x[i] · s` (squash writeback).
#[inline]
pub fn mul_f32(x: &[f32], s: f32, out: &mut [f32]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only returns `Avx2` after runtime detection.
        SimdLevel::Avx2 => unsafe { avx2::mul_f32(x, s, out) },
        _ => scalar::mul_f32(x, s, out),
    }
}

/// `x[i] /= d` in place (softmax normalize). IEEE division is correctly
/// rounded per element, so the vector path is bit-identical to scalar.
#[inline]
pub fn div_in_place_f32(x: &mut [f32], d: f32) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only returns `Avx2` after runtime detection.
        SimdLevel::Avx2 => unsafe { avx2::div_in_place_f32(x, d) },
        _ => scalar::div_in_place_f32(x, d),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_i16(r: &mut Rng) -> i16 {
        // Full raw range including the i16::MIN corner the saturating
        // quantizer can produce.
        (r.below(65536) as i32 - 32768) as i16
    }

    #[test]
    fn active_name_is_valid() {
        assert!(matches!(active_name(), "scalar" | "avx2"));
        assert_eq!(active().name(), active_name());
    }

    #[test]
    fn force_level_round_trips() {
        let prev = active();
        force_level(SimdLevel::Scalar);
        assert_eq!(active(), SimdLevel::Scalar);
        force_level(SimdLevel::Avx2);
        if avx2_supported() {
            assert_eq!(active(), SimdLevel::Avx2);
        } else {
            // Forcing AVX2 on an unsupported host must degrade, not UB.
            assert_eq!(active(), SimdLevel::Scalar);
        }
        force_level(prev);
    }

    // -----------------------------------------------------------------
    // scalar-vs-AVX2 bit-identity properties (skip where undetected)

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn property_axpy_i16_avx2_bit_identical() {
        if !avx2_supported() {
            return;
        }
        crate::testing::check(
            "axpy_i16 avx2 == scalar",
            200,
            0x51_0001,
            |r| {
                let n = 1 + r.below(40);
                let x = rand_i16(r);
                let w: Vec<i16> = (0..n).map(|_| rand_i16(r)).collect();
                let acc: Vec<i64> = (0..n).map(|_| r.below(1 << 20) as i64 - (1 << 19)).collect();
                (x, w, acc)
            },
            |(x, w, acc)| {
                let mut a = acc.clone();
                let mut b = acc.clone();
                scalar::axpy_i16(&mut a, *x, w);
                // SAFETY: guarded by `avx2_supported()` above.
                unsafe { avx2::axpy_i16(&mut b, *x, w) };
                a == b
            },
        );
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn property_reductions_avx2_bit_identical() {
        if !avx2_supported() {
            return;
        }
        crate::testing::check(
            "dot/sumsq/sum/max avx2 == scalar",
            200,
            0x51_0002,
            |r| {
                let n = 1 + r.below(67);
                let a: Vec<i16> = (0..n).map(|_| rand_i16(r)).collect();
                let b: Vec<i16> = (0..n).map(|_| rand_i16(r)).collect();
                (a, b)
            },
            // SAFETY: guarded by `avx2_supported()` above.
            |(a, b)| unsafe {
                scalar::dot_i16(a, b) == avx2::dot_i16(a, b)
                    && scalar::sumsq_i16(a) == avx2::sumsq_i16(a)
                    && scalar::sum_i16(a) == avx2::sum_i16(a)
                    && scalar::max_i16(a) == avx2::max_i16(a)
            },
        );
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn property_scale_i16_q_avx2_bit_identical() {
        if !avx2_supported() {
            return;
        }
        crate::testing::check(
            "scale_i16_q avx2 == scalar",
            200,
            0x51_0003,
            |r| {
                let n = 1 + r.below(50);
                let x: Vec<i16> = (0..n).map(|_| rand_i16(r)).collect();
                let scale = r.below(i16::MAX as usize + 1) as i32;
                (x, scale)
            },
            |(x, scale)| {
                let mut a = vec![0i16; x.len()];
                let mut b = vec![0i16; x.len()];
                scalar::scale_i16_q::<8>(x, *scale, &mut a);
                // SAFETY: guarded by `avx2_supported()` above.
                unsafe { avx2::scale_i16_q::<8>(x, *scale, &mut b) };
                a == b
            },
        );
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn property_f32_kernels_avx2_bit_identical() {
        if !avx2_supported() {
            return;
        }
        crate::testing::check(
            "f32 elementwise kernels avx2 == scalar (bitwise)",
            200,
            0x51_0004,
            |r| {
                let n = 1 + r.below(45);
                let x = r.normal_f32(0.0, 2.0);
                let w: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 1.0)).collect();
                let acc: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 1.0)).collect();
                (x, w, acc)
            },
            |(x, w, acc)| {
                let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                let mut a = acc.clone();
                let mut b = acc.clone();
                scalar::axpy_f32(&mut a, *x, w);
                // SAFETY: guarded by `avx2_supported()` above.
                unsafe { avx2::axpy_f32(&mut b, *x, w) };
                let mut ma = vec![0.0f32; w.len()];
                let mut mb = vec![0.0f32; w.len()];
                scalar::mul_f32(w, *x, &mut ma);
                // SAFETY: guarded by `avx2_supported()` above.
                unsafe { avx2::mul_f32(w, *x, &mut mb) };
                let mut da = w.clone();
                let mut db = w.clone();
                let d = 1.0 + x.abs();
                scalar::div_in_place_f32(&mut da, d);
                // SAFETY: guarded by `avx2_supported()` above.
                unsafe { avx2::div_in_place_f32(&mut db, d) };
                bits(&a) == bits(&b) && bits(&ma) == bits(&mb) && bits(&da) == bits(&db)
            },
        );
    }

    #[test]
    fn property_strided_matches_dense_on_stride_one() {
        crate::testing::check(
            "strided kernels at stride 1 == dense kernels",
            100,
            0x51_0005,
            |r| {
                let n = 1 + r.below(30);
                let x = rand_i16(r);
                let w: Vec<i16> = (0..n + 4).map(|_| rand_i16(r)).collect();
                (n, x, w)
            },
            |(n, x, w)| {
                let mut a = vec![0i64; *n];
                let mut b = vec![0i64; *n];
                axpy_strided_i16(&mut a, *x, w, 1);
                axpy_i16(&mut b, *x, &w[..*n]);
                a == b
            },
        );
    }

    #[test]
    fn scalar_strided_walks_stride() {
        let w: Vec<i16> = (0i16..10).collect();
        let mut acc = vec![0i64; 4];
        scalar::axpy_strided_i16(&mut acc, 3, &w, 2);
        // picks w[0], w[2], w[4], w[6]
        assert_eq!(acc, vec![0, 6, 12, 18]);
        let wf: Vec<f32> = (0..10).map(|v| v as f32).collect();
        let mut af = vec![0.0f32; 3];
        scalar::axpy_strided_f32(&mut af, 2.0, &wf, 3);
        assert_eq!(af, vec![0.0, 6.0, 12.0]);
    }

    #[test]
    fn max_of_empty_is_min() {
        assert_eq!(max_i16(&[]), i16::MIN);
        assert_eq!(scalar::max_i16(&[-5, -9]), -5);
    }
}
