//! # FastCaps
//!
//! Reproduction of *"FastCaps: A Design Methodology for Accelerating Capsule
//! Network on FPGAs"* (Rahoof, Chaturvedi, Shafique) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the deployment side: the Look-Ahead Kernel
//!   Pruning (LAKP) engine and its baselines, a cycle-level simulator of the
//!   paper's PYNQ-Z1 accelerator (PE array, BRAM banks, index control,
//!   conv + dynamic-routing modules, Taylor-approximated non-linear units),
//!   a PJRT runtime that executes the AOT-lowered JAX model, a
//!   sparse-compiled executor ([`capsnet::compiled`]) that shares the
//!   Index Control Module's alive-kernel packing, a unified [`backend`]
//!   execution API over all the model implementations, and
//!   a serving coordinator (admission → shared queue → executor pool of
//!   backend replicas) that keeps Python off the request path, and a TCP
//!   network front-end ([`coordinator::net`] / [`coordinator::wire`])
//!   that makes the whole stack servable to other processes.
//! * **L2 (python/compile/model.py)** — the CapsNet forward graph in JAX,
//!   lowered once to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the routing
//!   hot-spots, validated against a pure-jnp oracle.
//!
//! The public API is organised by subsystem; see `DESIGN.md` (repo root)
//! for the paper-to-module map and the backend-subsystem diagram, and
//! the paper-anchored assertions in `rust/tests/` and `rust/benches/`
//! for the reproduced numbers.

// The numeric code is written as explicit index loop nests that mirror
// the accelerator's hardware loops (out-channel / in-channel / tap order
// is the bit-exactness contract between the dense, sparse-compiled and
// fixed-point datapaths); iterator-chain rewrites would obscure that
// correspondence, so the range-loop style lint is opted out crate-wide.
#![allow(clippy::needless_range_loop)]
// Every `unsafe` operation must sit in an explicit `unsafe {}` block
// with its own `// SAFETY:` note, even inside `unsafe fn` — enforced
// here at compile time and by `fclint` (see [`analysis`]) in CI.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod backend;
pub mod cache;
pub mod capsnet;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fixed;
pub mod fpga;
pub mod kernels;
pub mod pruning;
pub mod report;
pub mod routing;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod util;

/// Crate-wide result type (anyhow-based; the only external error dep).
pub type Result<T> = anyhow::Result<T>;
